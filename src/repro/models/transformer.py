"""Layer/superlayer assembly for all architecture families.

A *superlayer* is one scan step: ``cfg.layer_pattern`` consecutive layers
(dense/moe archs: 1 attn layer; jamba: the 8-layer mamba/attn block; rwkv:
1 rwkv layer). Stacking superlayers under ``lax.scan`` keeps the HLO size
O(1) in depth — required for 512-way SPMD compiles of 96..126-layer models.

Each layer is pre-norm residual:
  attn : x += Attn(RMS(x));  x += FFN_or_MoE(RMS(x))
  mamba: x += Mamba(RMS(x)); x += FFN_or_MoE(RMS(x))   (jamba style)
  rwkv : x += TimeMix(RMS(x)); x += ChannelMix(RMS(x))

MoE placement follows cfg.is_moe_layer(global_idx); because
``moe_every`` divides the pattern length, the pattern position alone
determines it and every superlayer has identical pytree structure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba as MB
from repro.models import moe as MOE
from repro.models import rwkv6 as RW


def _ffn_is_moe(cfg, p_idx: int) -> bool:
    return cfg.is_moe_layer(p_idx)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_layer(key, cfg, kind: str, p_idx: int, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    p = {"norm1": jnp.ones((d,), jnp.float32)}
    if kind == "attn":
        p["mixer"] = L.init_attention(ks[0], cfg, dtype)
    elif kind == "mamba":
        p["mixer"] = MB.init_mamba(ks[0], cfg, dtype)
    elif kind == "rwkv":
        p["mixer"] = RW.init_rwkv(ks[0], cfg, dtype)
        p["norm2"] = jnp.ones((d,), jnp.float32)
        return p  # rwkv channel-mix params live inside the mixer dict
    else:
        raise ValueError(kind)
    p["norm2"] = jnp.ones((d,), jnp.float32)
    p["ffn"] = (MOE.init_moe(ks[1], cfg, dtype) if _ffn_is_moe(cfg, p_idx)
                else L.init_mlp(ks[1], cfg, dtype))
    return p


def init_superlayer(key, cfg, dtype):
    keys = jax.random.split(key, cfg.superlayer)
    return {
        f"l{p}": init_layer(keys[p], cfg, cfg.layer_pattern[p], p, dtype)
        for p in range(cfg.superlayer)
    }


def init_stack(key, cfg, dtype):
    """All superlayers, stacked on a leading n_superlayers axis for scan."""
    keys = jax.random.split(key, cfg.n_superlayers)
    per = [init_superlayer(k, cfg, dtype) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per)


# ---------------------------------------------------------------------------
# Full-sequence forward (training / prefill)
# ---------------------------------------------------------------------------


def layer_fwd(p, x, cfg, kind: str, p_idx: int, *, positions, prefix: int,
              attn_impl: str, block: int, collect_state: bool,
              packed=None, full_capacity: bool = False):
    """Returns (x, aux, state). state is None unless collect_state.

    packed: PackedTriSched for the ragged batched-prefill path (attention
    goes block-diagonal per request). full_capacity: drop-free MoE buffers
    (serving semantics — a prefill that drops tokens diverges from the
    incremental decode it seeds)."""
    aux = jnp.zeros((), jnp.float32)
    state = None
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind == "attn":
        out, k, v = L.attention(p["mixer"], h, cfg, positions=positions,
                                prefix=prefix, attn_impl=attn_impl,
                                block=block, packed=packed)
        if collect_state:
            state = {"k": k, "v": v}
        x = x + out
    elif kind == "mamba":
        out, st = MB.mamba_mix(p["mixer"], h, cfg, state=None)
        if collect_state:
            state = st
        x = x + out
    elif kind == "rwkv":
        out, st_t = RW.rwkv_time_mix(p["mixer"], h, cfg, state=None)
        x = x + out
        h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        out2, shift_c = RW.rwkv_channel_mix(p["mixer"], h2, cfg, state=None)
        if collect_state:
            state = dict(st_t, shift_c=shift_c)
        return x + out2, aux, state

    h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    if _ffn_is_moe(cfg, p_idx):
        out2, aux = MOE.moe_mlp(p["ffn"], h2, cfg,
                                full_capacity=full_capacity)
    else:
        out2 = L.mlp(p["ffn"], h2, cfg)
    return x + out2, aux, state


def superlayer_fwd(p, x, cfg, *, positions, prefix, attn_impl, block,
                   collect_state, packed=None, full_capacity: bool = False):
    aux = jnp.zeros((), jnp.float32)
    states = {}
    for i, kind in enumerate(cfg.layer_pattern):
        x, a, st = layer_fwd(p[f"l{i}"], x, cfg, kind, i, positions=positions,
                             prefix=prefix, attn_impl=attn_impl, block=block,
                             collect_state=collect_state, packed=packed,
                             full_capacity=full_capacity)
        aux = aux + a
        if collect_state:
            states[f"l{i}"] = st
    return x, aux, (states if collect_state else None)


# ---------------------------------------------------------------------------
# Single-token decode
# ---------------------------------------------------------------------------


def layer_decode(p, x, cfg, kind: str, p_idx: int, cache, pos,
                 decode_tbl=None, decode_spec=None):
    """x: (B, 1, d); cache: per-layer state dict. Returns (x, new_cache).

    decode_tbl/decode_spec select the packed mixed-position decode path
    for attention mixers (one launch over each slot's own valid KV prefix
    — see layers.packed_decode_attention); recurrent mixers are untouched
    (their single-token update is per-slot independent already)."""
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind == "attn":
        if decode_spec is not None:
            out, ck, cv = L.packed_decode_attention(
                p["mixer"], h, cfg, cache_k=cache["k"], cache_v=cache["v"],
                pos=pos, decode_tbl=decode_tbl, decode_spec=decode_spec)
        else:
            out, ck, cv = L.decode_attention(p["mixer"], h, cfg,
                                             cache_k=cache["k"],
                                             cache_v=cache["v"], pos=pos)
        new_cache = {"k": ck, "v": cv}
        x = x + out
    elif kind == "mamba":
        out, new_cache = MB.mamba_mix(p["mixer"], h, cfg, state=cache)
        x = x + out
    elif kind == "rwkv":
        st_t = {"shift": cache["shift"], "s": cache["s"]}
        out, st_t = RW.rwkv_time_mix(p["mixer"], h, cfg, state=st_t)
        x = x + out
        h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        out2, shift_c = RW.rwkv_channel_mix(p["mixer"], h2, cfg,
                                            state=cache["shift_c"])
        return x + out2, dict(st_t, shift_c=shift_c)

    h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    if _ffn_is_moe(cfg, p_idx):
        out2 = MOE.moe_mlp(p["ffn"], h2, cfg, return_aux=False,
                           full_capacity=True)  # serving never drops
    else:
        out2 = L.mlp(p["ffn"], h2, cfg)
    return x + out2, new_cache


def superlayer_decode(p, x, cfg, cache, pos, decode_tbl=None,
                      decode_spec=None):
    new_cache = {}
    for i, kind in enumerate(cfg.layer_pattern):
        x, new_cache[f"l{i}"] = layer_decode(
            p[f"l{i}"], x, cfg, kind, i, cache[f"l{i}"], pos,
            decode_tbl=decode_tbl, decode_spec=decode_spec)
    return x, new_cache


# ---------------------------------------------------------------------------
# Fused continuous-batching step (prefill members + decode rows, one launch)
# ---------------------------------------------------------------------------


def layer_fused(p, x_pack, x_dec, cfg, kind: str, p_idx: int, cache, pos, *,
                pack_positions, packed, fused_tbl, fused_spec):
    """One layer of the fused step: BOTH streams share the layer's weights
    and the attention mixer issues ONE fused launch (layers.fused_attention).
    Attention-only architectures — recurrent mixers have no packed-member
    notion, so the engine gates fused mode to attn-only archs.
    Returns (x_pack, x_dec, new_cache, {"k","v"} pack states)."""
    if kind != "attn":
        raise ValueError(
            f"fused step supports attention mixers only, got {kind!r}")
    h_p = L.rms_norm(x_pack, p["norm1"], cfg.norm_eps)
    h_d = L.rms_norm(x_dec, p["norm1"], cfg.norm_eps)
    out_p, out_d, k, v, ck, cv = L.fused_attention(
        p["mixer"], h_p, h_d, cfg, pack_positions=pack_positions,
        packed=packed, cache_k=cache["k"], cache_v=cache["v"], pos=pos,
        fused_tbl=fused_tbl, fused_spec=fused_spec)
    x_pack = x_pack + out_p
    x_dec = x_dec + out_d

    h2_p = L.rms_norm(x_pack, p["norm2"], cfg.norm_eps)
    h2_d = L.rms_norm(x_dec, p["norm2"], cfg.norm_eps)
    if _ffn_is_moe(cfg, p_idx):
        # serving semantics on both halves: drop-free buffers, no aux
        x_pack = x_pack + MOE.moe_mlp(p["ffn"], h2_p, cfg, return_aux=False,
                                      full_capacity=True)
        x_dec = x_dec + MOE.moe_mlp(p["ffn"], h2_d, cfg, return_aux=False,
                                    full_capacity=True)
    else:
        x_pack = x_pack + L.mlp(p["ffn"], h2_p, cfg)
        x_dec = x_dec + L.mlp(p["ffn"], h2_d, cfg)
    return x_pack, x_dec, {"k": ck, "v": cv}, {"k": k, "v": v}


def superlayer_fused(p, x_pack, x_dec, cfg, cache, pos, *, pack_positions,
                     packed, fused_tbl, fused_spec):
    new_cache, states = {}, {}
    for i, kind in enumerate(cfg.layer_pattern):
        x_pack, x_dec, new_cache[f"l{i}"], states[f"l{i}"] = layer_fused(
            p[f"l{i}"], x_pack, x_dec, cfg, kind, i, cache[f"l{i}"], pos,
            pack_positions=pack_positions, packed=packed,
            fused_tbl=fused_tbl, fused_spec=fused_spec)
    return x_pack, x_dec, new_cache, states


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def init_layer_cache(cfg, kind: str, batch: int, max_len: int, dtype):
    if kind == "attn":
        s = max_len if cfg.sliding_window is None \
            else min(cfg.sliding_window, max_len)
        return {
            "k": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim), dtype),
        }
    if kind == "mamba":
        return MB.init_mamba_state(cfg, batch, dtype)
    if kind == "rwkv":
        return RW.init_rwkv_state(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Stacked (n_superlayers, ...) decode cache pytree."""
    per = {
        f"l{p}": init_layer_cache(cfg, cfg.layer_pattern[p], batch, max_len,
                                  dtype)
        for p in range(cfg.superlayer)
    }
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_superlayers,) + x.shape), per)
