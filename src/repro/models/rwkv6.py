"""RWKV-6 (Finch) block: data-dependent-decay linear attention (WKV6) +
token shift + squared-ReLU channel mix.

Recurrence per head (state S: (hd_k, hd_v)):
    S_t  = diag(w_t) S_{t-1} + k_t^T v_t
    out_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with w_t = exp(-exp(w0 + tanh(x_w A) B)) data-dependent per channel (the
Finch hallmark).

Implemented CHUNKED: intra-chunk interactions use an exact per-channel decay
tensor with all exp arguments <= 0 (numerically safe — see comments), i.e. a
strictly-lower-TRIANGULAR intra-chunk domain; the chunk pairing reuses the
framework's triangular schedule accounting. Inter-chunk state is a lax.scan.
Simplification vs the full paper: token-shift mixing coefficients are static
learned vectors (the ddlerp LoRA is applied to the decay only) — noted in
DESIGN.md.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, layer_norm

CHUNK = 32


def init_rwkv(key, cfg, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    h, hd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    lora = cfg.rwkv_lora_dim
    ks = jax.random.split(key, 12)
    zeros = lambda *s: jnp.zeros(s, jnp.float32)
    return {
        "mu": {n: jnp.full((d,), 0.5, jnp.float32)
               for n in ("r", "k", "v", "g", "w", "ck", "cr")},
        "wr": dense_init(ks[0], (d, d), dtype=dtype),
        "wk": dense_init(ks[1], (d, d), dtype=dtype),
        "wv": dense_init(ks[2], (d, d), dtype=dtype),
        "wg": dense_init(ks[3], (d, d), dtype=dtype),
        "wo": dense_init(ks[4], (d, d), dtype=dtype),
        "w0": jnp.full((d,), -0.7, jnp.float32),
        "w_lora_a": dense_init(ks[5], (d, lora), dtype=jnp.float32),
        "w_lora_b": zeros(lora, d),
        "u": jnp.full((h, hd), 0.5, jnp.float32),
        "ln_x_scale": jnp.ones((d,), jnp.float32),
        "ln_x_bias": zeros(d),
        "cm_wk": dense_init(ks[6], (d, ff), dtype=dtype),
        "cm_wv": dense_init(ks[7], (ff, d), dtype=dtype),
        "cm_wr": dense_init(ks[8], (d, d), dtype=dtype),
    }


def _shift(x, prev):
    """Token shift: x_{t-1} with `prev` (B, d) seeding position 0."""
    return jnp.concatenate([prev[:, None].astype(x.dtype), x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _wkv_chunk(s0, r, k, v, lw, u):
    """Exact intra-chunk WKV6. All per (B, H).

    r,k,v: (B, L, H, hd); lw: (B, L, H, hd) per-token log-decay (<= 0);
    s0: (B, H, hd, hd). Returns (out (B, L, H, hd), s_end).

    Stability: every exp() argument below is a sum of log-decays over a
    non-empty-or-empty range, hence <= 0; entries above the strict lower
    triangle are set to -inf BEFORE the exp.
    """
    b, l, h, hd = r.shape
    lw_inc = jnp.cumsum(lw, axis=1)           # sum_{p<=t}
    lw_exc = lw_inc - lw                      # sum_{p<t}
    lw_last = lw_inc[:, -1:]                  # sum over whole chunk

    # intra-chunk: score_ts = sum_c r_tc k_sc exp(lw_exc_t - lw_inc_s), s<t
    arg = lw_exc[:, :, None] - lw_inc[:, None, :, :]  # (B, t, s, H, hd)
    tril = jnp.tril(jnp.ones((l, l), bool), k=-1)
    arg = jnp.where(tril[None, :, :, None, None], arg, -jnp.inf)
    scores = jnp.einsum("bthc,bshc,btshc->bths", r, k, jnp.exp(arg))
    out = jnp.einsum("bths,bshc->bthc", scores, v)

    # diagonal u-bonus: out_t += (r_t . (u*k_t)) v_t
    diag = jnp.einsum("bthc,hc,bthc->bth", r, u, k)
    out += diag[..., None] * v

    # state contribution: out_t += (r_t * exp(lw_exc_t)) @ S0
    r_dec = r * jnp.exp(lw_exc)
    out += jnp.einsum("bthk,bhkv->bthv", r_dec, s0)

    # state update: S_end = diag(exp(lw_last)) S0 + sum_s (k_s*exp(lw_last-lw_inc_s))^T v_s
    k_dec = k * jnp.exp(lw_last - lw_inc)
    s_end = jnp.exp(lw_last)[:, 0][..., None] * s0 \
        + jnp.einsum("bshk,bshv->bhkv", k_dec, v)
    return out, s_end


def rwkv_time_mix(params, x, cfg, *, state=None):
    """x: (B, S, d) -> (out, new_state). state: dict(shift (B,d), s (B,H,hd,hd))."""
    b, s, d = x.shape
    h, hd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    prev = state["shift"] if state is not None else jnp.zeros((b, d), x.dtype)
    xs = _shift(x, prev)
    mu = params["mu"]
    xr, xk, xv = _mix(x, xs, mu["r"]), _mix(x, xs, mu["k"]), _mix(x, xs, mu["v"])
    xg, xw = _mix(x, xs, mu["g"]), _mix(x, xs, mu["w"])

    r = (xr @ params["wr"]).reshape(b, s, h, hd).astype(jnp.float32)
    k = (xk @ params["wk"]).reshape(b, s, h, hd).astype(jnp.float32)
    v = (xv @ params["wv"]).reshape(b, s, h, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ params["wg"])

    # Finch data-dependent decay via LoRA; log w in [-e^4, ~-0.0017]
    lw_raw = params["w0"] + jnp.tanh(
        xw.astype(jnp.float32) @ params["w_lora_a"]) @ params["w_lora_b"]
    lw = -jnp.exp(jnp.clip(lw_raw, -8.0, 4.0))  # log-decay, < 0
    lw = lw.reshape(b, s, h, hd)

    s0 = (state["s"].astype(jnp.float32) if state is not None
          else jnp.zeros((b, h, hd, hd), jnp.float32))

    if s == 1:  # decode fast path
        # out = r (S + diag(u) k^T v); S' = diag(w) S + k^T v
        kv = jnp.einsum("bhk,bhv->bhkv", k[:, 0], v[:, 0])
        out = jnp.einsum("bhk,bhkv->bhv", r[:, 0], s0) \
            + jnp.einsum("bhc,hc,bhc->bh", r[:, 0], params["u"], k[:, 0])[..., None] * v[:, 0]
        out = out[:, None].reshape(b, 1, d)
        s_end = jnp.exp(lw[:, 0])[..., None] * s0 + kv
    else:
        # Region with a fused Pallas twin (kernels/wkv_scan): the chunked
        # XLA path materializes the (B, t, s, H, hd) intra-chunk decay
        # tensor; the kernel keeps the (hd, hd) state in VMEM. The roofline
        # wkv-kernel adjustment keys off this scope name.
        with jax.named_scope("wkv_scan_kernel"):
            chunk = min(CHUNK, s)
            while s % chunk:
                chunk //= 2
            nch = s // chunk
            resh = lambda t: (
                t.reshape((b, nch, chunk) + t.shape[2:]).swapaxes(0, 1))

            def step(carry, args):
                rc, kc, vc, lwc = args
                out_c, s_end = _wkv_chunk(carry, rc, kc, vc, lwc,
                                          params["u"])
                return s_end, out_c

            s_end, outs = jax.lax.scan(
                step, s0, (resh(r), resh(k), resh(v), resh(lw)))
            out = outs.swapaxes(0, 1).reshape(b, s, d)

    out = layer_norm(out.astype(x.dtype), params["ln_x_scale"],
                     params["ln_x_bias"], cfg.norm_eps)
    out = (out * g) @ params["wo"]
    new_state = {"shift": x[:, -1].astype(x.dtype), "s": s_end}
    return out, new_state


def rwkv_channel_mix(params, x, cfg, *, state=None):
    b, s, d = x.shape
    prev = state if state is not None else jnp.zeros((b, d), x.dtype)
    xs = _shift(x, prev)
    xk = _mix(x, xs, params["mu"]["ck"])
    xr = _mix(x, xs, params["mu"]["cr"])
    kk = jax.nn.relu(xk @ params["cm_wk"])
    out = jax.nn.sigmoid(xr @ params["cm_wr"]) * ((kk * kk) @ params["cm_wv"])
    return out, x[:, -1].astype(x.dtype)


def init_rwkv_state(cfg, batch, dtype=jnp.float32):
    h, hd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    return {
        "shift": jnp.zeros((batch, cfg.d_model), dtype),
        "s": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "shift_c": jnp.zeros((batch, cfg.d_model), dtype),
    }
