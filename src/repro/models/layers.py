"""Shared model layers: norms, RoPE, GQA attention, dense MLPs.

Parameters are plain pytrees (nested dicts of jnp arrays); layer stacks add
a leading superlayer dimension handled by the scan in transformer.py.
"""

from __future__ import annotations

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.kernels.tri_attn import ops as attn_ops
from repro.kernels.tri_attn import ref as attn_ref
from repro.parallel import hints


def dense_init(key, shape, in_axis=0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape, jnp.float32)
            / jnp.sqrt(fan_in)).astype(dtype)


def rms_norm(x, scale, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
            ).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, n_heads, head_dim); positions: (S,) or (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA) — schedule-aware triangular kernels for train/prefill,
# plain einsum against the KV cache for decode.
# ---------------------------------------------------------------------------


def init_attention(key, cfg, dtype):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, hkv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, hkv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (h * hd, d), dtype=dtype),
    }


def attention(params, x, cfg, *, positions, prefix: int = 0,
              attn_impl: str = "scan", block: int = 512, packed=None):
    """Full-sequence attention (training / prefill).

    x: (B, S, d). Returns (out (B, S, d), k, v) — k/v (B, S, Hkv, hd) already
    RoPE-rotated, ready to seed a decode cache.

    packed: optional PackedTriSched — S is then the concatenation of a
    ragged request batch and attention is block-diagonal per request (the
    batched ragged-prefill path AND the ragged document-batch training
    path: the packed attention carries a custom VJP, so jax.grad issues
    one packed launch per direction; ``positions`` must restart per
    request/document).
    """
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (x @ params["wk"]).reshape(b, s, hkv, hd)
    v = (x @ params["wv"]).reshape(b, s, hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    qt = q.transpose(0, 2, 1, 3)  # (B, H, S, hd)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    qkv_sh = hints.get("attn_qkv")
    if qkv_sh is not None:
        # §Perf attention layouts. Head-TP (spec shards the head axis):
        # expand KV to the full head count and pin q/k/v to (dp, model-on-
        # heads, None, None) — per-device working set equals replicated-KV
        # GQA but the score/out einsums contract UNSHARDED dims (no
        # per-tile all-reduce). Replicated (attn_rep, spec=None on heads):
        # just pins q/k/v unsharded on model — redundant compute, zero
        # attention collectives (for archs whose heads don't divide TP).
        heads_sharded = getattr(qkv_sh, "spec", (None, None))[1] is not None
        g = h // hkv
        if heads_sharded and g > 1:
            kt = jnp.repeat(kt, g, axis=1)
            vt = jnp.repeat(vt, g, axis=1)
        qt = hints.constrain(qt, "attn_qkv")
        kt = hints.constrain(kt, "attn_qkv")
        vt = hints.constrain(vt, "attn_qkv")
    if packed is not None:
        # Ragged multi-request prefill: one launch over the packed grid;
        # member schedules carry each request's window/prefix.
        ot = attn_ops.packed_prefill_attention(
            qt, kt, vt, packed,
            impl="pallas" if attn_impl == "pallas" else "scan")
        # same checkpoint name as the per-domain path so the training-mode
        # remat policy can save the context across the packed VJP too
        ctx = jax.ad_checkpoint.checkpoint_name(
            ot.transpose(0, 2, 1, 3).reshape(b, s, h * hd), "attn_out")
        return ctx @ params["wo"], k, v
    blk = block
    while s % blk:
        blk //= 2
    if attn_impl == "ref" or s <= blk:  # single tile: oracle is cheapest
        ot = attn_ref.mha_reference(qt, kt, vt, window=cfg.sliding_window,
                                    prefix=prefix)
    else:
        ot = attn_ops.triangular_attention(
            qt, kt, vt, window=cfg.sliding_window, prefix=prefix,
            impl=attn_impl, block_q=blk, block_k=blk)
    ctx = jax.ad_checkpoint.checkpoint_name(
        ot.transpose(0, 2, 1, 3).reshape(b, s, h * hd), "attn_out")
    out = ctx @ params["wo"]
    return out, k, v


def _decode_qkv(params, x, cfg, cache_k, cache_v, pos):
    """Shared decode front half: project + rotate the new token and write
    its k/v into each slot's cache (rolling slot pos % S_cache for SWA).
    Returns (q (B, 1, H, hd) rotated, new cache_k, new cache_v, pos (B,))."""
    b = x.shape[0]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s_cache = cache_k.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q = (x @ params["wq"]).reshape(b, 1, h, hd)
    k = (x @ params["wk"]).reshape(b, 1, hkv, hd)
    v = (x @ params["wv"]).reshape(b, 1, hkv, hd)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)

    slot = pos % s_cache  # rolling for SWA; identity while pos < s_cache
    bidx = jnp.arange(b)
    cache_k = cache_k.at[bidx, slot].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, slot].set(v[:, 0].astype(cache_v.dtype))
    return q, cache_k, cache_v, pos


def decode_attention(params, x, cfg, *, cache_k, cache_v, pos):
    """Single-token decode. x: (B, 1, d); cache_k/v: (B, S_cache, Hkv, hd)
    (rotated keys); pos: scalar or (B,) int32 — absolute position of each
    sequence's new token (per-slot positions enable continuous batching).

    For sliding-window configs the cache is a rolling buffer of W slots and
    slot s holds absolute position p_s = pos - ((pos - s) mod W).
    Returns (out (B, 1, d), new_cache_k, new_cache_v).
    """
    b, _, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s_cache = cache_k.shape[1]
    w = cfg.sliding_window
    q, cache_k, cache_v, pos = _decode_qkv(params, x, cfg, cache_k, cache_v,
                                           pos)

    slots = jnp.arange(s_cache)
    if w is not None:  # rolling buffer: recover absolute positions
        slot_pos = pos[:, None] - jnp.mod(pos[:, None] - slots, s_cache)
        valid = slot_pos >= 0  # (B, S_cache)
    else:
        valid = slots[None, :] <= pos[:, None]

    g = h // hkv
    qg = q.reshape(b, 1, hkv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        cache_k.astype(jnp.float32)) / jnp.sqrt(float(hd))
    scores = jnp.where(valid[:, None, None, None, :], scores,
                       attn_ref.NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, cache_v.astype(jnp.float32))
    out = o.reshape(b, 1, h * hd).astype(x.dtype) @ params["wo"]
    return out, cache_k, cache_v


def packed_decode_attention(params, x, cfg, *, cache_k, cache_v, pos,
                            decode_tbl, decode_spec):
    """Packed mixed-position decode: same projections/cache write as
    decode_attention, but attention runs over the packed decode grid —
    each live slot attends ONLY its own valid KV prefix
    (sum_r ceil(kv_len_r / blk) tiles in one launch instead of the
    lockstep einsum's B * S_cache pad-to-max work). decode_tbl is the
    round's traced (5, R) member table, decode_spec its static half
    (ops.DecodeRoundSpec). Slots without a live member get zero attention
    output (their k/v cache write still happens, matching lockstep)."""
    b, _, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q, cache_k, cache_v, _ = _decode_qkv(params, x, cfg, cache_k, cache_v,
                                         pos)
    ot = attn_ops.packed_decode_attention(q[:, 0], cache_k, cache_v,
                                          decode_tbl, decode_spec)
    out = ot.reshape(b, 1, h * hd).astype(x.dtype) @ params["wo"]
    return out, cache_k, cache_v


def fused_attention(params, x_pack, x_dec, cfg, *, pack_positions, packed,
                    cache_k, cache_v, pos, fused_tbl, fused_spec):
    """Fused continuous-batching attention: ONE launch covers the round's
    newly admitted prompts (x_pack (1, S_pack, d), packed block-diagonal
    self-attention like ``attention(packed=...)``) AND every live decode
    slot (x_dec (B, 1, d), each attending its own valid KV prefix like
    ``packed_decode_attention``). The decode half's projections and cache
    write are byte-identical to the split path (_decode_qkv); fused_tbl /
    fused_spec route both kinds through ops.fused_step_attention.

    Returns (out_pack (1, S_pack, d), out_dec (B, 1, d),
    k_pack, v_pack (1, S_pack, Hkv, hd) rotated — the admit-splice seed,
    new cache_k, new cache_v)."""
    b = x_dec.shape[0]
    _, s, _ = x_pack.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x_pack @ params["wq"]).reshape(1, s, h, hd)
    k = (x_pack @ params["wk"]).reshape(1, s, hkv, hd)
    v = (x_pack @ params["wv"]).reshape(1, s, hkv, hd)
    q = apply_rope(q, pack_positions, cfg.rope_theta)
    k = apply_rope(k, pack_positions, cfg.rope_theta)

    q_dec, cache_k, cache_v, _ = _decode_qkv(params, x_dec, cfg, cache_k,
                                             cache_v, pos)
    op, od = attn_ops.fused_step_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), q_dec[:, 0], cache_k, cache_v,
        fused_tbl, packed, fused_spec)
    out_pack = (op.transpose(0, 2, 1, 3).reshape(1, s, h * hd)
                @ params["wo"])
    out_dec = (od.reshape(b, 1, h * hd).astype(x_dec.dtype)
               @ params["wo"])
    return out_pack, out_dec, k, v, cache_k, cache_v


# ---------------------------------------------------------------------------
# Dense MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_activation == "swiglu":
        return {
            "wi": dense_init(ks[0], (d, f), dtype=dtype),
            "wg": dense_init(ks[1], (d, f), dtype=dtype),
            "wo": dense_init(ks[2], (f, d), dtype=dtype),
        }
    return {
        "wi": dense_init(ks[0], (d, f), dtype=dtype),
        "wo": dense_init(ks[2], (f, d), dtype=dtype),
    }


def mlp(params, x, cfg):
    if cfg.mlp_activation == "swiglu":
        return (jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])) @ params["wo"]
    if cfg.mlp_activation == "relu2":  # nemotron squared-ReLU
        h = jax.nn.relu(x @ params["wi"])
        return (h * h) @ params["wo"]
    return jax.nn.gelu(x @ params["wi"]) @ params["wo"]
