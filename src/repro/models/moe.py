"""Token-choice top-k Mixture-of-Experts with capacity-bounded scatter
dispatch (Mixtral / Granite-MoE / Jamba style).

Dispatch strategy: rank tokens within each expert by cumulative count and
scatter into a dense (E, C, d) buffer; tokens ranked past the capacity C are
dropped (standard capacity-factor semantics). This avoids the O(T*E*C)
one-hot dispatch tensor of the mesh-TF formulation while staying fully
dense/XLA-friendly and differentiable. Expert weights carry a leading E dim
that the sharding rules map to the expert-parallel axis when divisible.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.parallel import hints


def init_moe(key, cfg, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),
        "wi": dense_init(ks[1], (e, d, f), in_axis=1, dtype=dtype),
        "wo": dense_init(ks[3], (e, f, d), in_axis=1, dtype=dtype),
    }
    if cfg.mlp_activation == "swiglu":
        p["wg"] = dense_init(ks[2], (e, d, f), in_axis=1, dtype=dtype)
    return p


def moe_mlp(params, x, cfg, *, return_aux: bool = True,
            full_capacity: bool = False):
    """x: (B, S, d) -> (B, S, d) [, aux load-balance loss].

    top-k routing with softmax over the selected logits (Mixtral style).
    full_capacity=True sizes the expert buffers so NO token is ever dropped
    (serving semantics — decode paths must be drop-free or incremental
    decoding diverges from the batched forward).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    t = b * s
    xf = x.reshape(t, d)

    # dispatch groups (§Perf): ranking tokens with a GLOBAL cumsum chains
    # every DP shard; grouping the ranking (groups aligned with the batch
    # sharding) keeps dispatch local per shard — the standard
    # local-dispatch formulation. groups=1 == the original global dispatch.
    groups = int(hints.get("moe_groups", 1))
    if t % groups:
        groups = 1
    tg = t // groups

    logits = (xf.astype(jnp.float32) @ params["router"])  # (T, E)
    top_logits, top_e = jax.lax.top_k(logits, k)  # (T, k)
    weights = jax.nn.softmax(top_logits, axis=-1)  # (T, k)

    # load-balance aux (Switch-style): E * sum_e f_e * p_e
    probs = jax.nn.softmax(logits, axis=-1)
    assign = jax.nn.one_hot(top_e, e, dtype=jnp.float32).sum(1)  # (T, E)
    aux = e * jnp.mean(jnp.mean(assign, 0) * jnp.mean(probs, 0))

    # capacity: per-expert slots per group
    cap = tg if full_capacity else max(1, int(k * tg / e *
                                              cfg.capacity_factor))

    # rank of each (token, slot) within its (group, expert)
    ge = top_e.reshape(groups, tg * k)  # (G, Tg*k)
    onehot = jax.nn.one_hot(ge, e, dtype=jnp.int32)  # (G, Tg*k, E)
    rank = (jnp.cumsum(onehot, axis=1) - onehot)  # exclusive count
    rank = jnp.take_along_axis(rank, ge[..., None], axis=2)[..., 0]
    keep = rank < cap  # (G, Tg*k)

    # scatter tokens into the (G, E, C, d) expert buffers
    xg = hints.constrain(xf.reshape(groups, tg, d), "moe_buf3")
    gidx = jnp.broadcast_to(jnp.arange(groups)[:, None], ge.shape)
    tok_idx = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg), k)[None], ge.shape)
    scat_e = jnp.where(keep, ge, e - 1)  # clamp; masked below anyway
    scat_c = jnp.where(keep, rank, cap - 1)
    vals = jnp.where(keep[..., None], xg[gidx, tok_idx], 0)
    buf = jnp.zeros((groups, e, cap, d), xf.dtype)
    buf = buf.at[gidx, scat_e, scat_c].add(vals)  # unique (g,e,c) if kept
    buf = hints.constrain(buf, "moe_buf")

    # expert FFN on (G, E, C, d). 'moe_wi'/'moe_wo' hints (§Perf): gather
    # the FSDP-sharded expert weights before the einsum — contracting a
    # data-sharded d otherwise all-reduces the (G,E,C,f) ACTIVATIONS per
    # layer (GBs) instead of gathering the (small) weights (MBs).
    wi = hints.constrain(params["wi"], "moe_wi")
    wo = hints.constrain(params["wo"], "moe_wo")
    if cfg.mlp_activation == "swiglu":
        wg = hints.constrain(params["wg"], "moe_wi")
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, wg)) * \
            jnp.einsum("gecd,edf->gecf", buf, wi)
    else:
        h = jax.nn.relu(jnp.einsum("gecd,edf->gecf", buf, wi))
        h = h * h
    out_buf = jnp.einsum("gecf,efd->gecd", h, wo)

    # gather back and combine with routing weights
    w_g = weights.reshape(groups, tg * k)
    gathered = out_buf[gidx, scat_e, scat_c]  # (G, Tg*k, d)
    gathered = jnp.where(keep[..., None], gathered, 0) * w_g[..., None]
    out = jnp.zeros((groups, tg, d), gathered.dtype)
    out = out.at[gidx, tok_idx].add(gathered)
    out = out.reshape(b, s, d).astype(x.dtype)
    return (out, aux) if return_aux else out
