"""Mamba (selective SSM) layer — the 'mamba' token mixer in Jamba.

Diagonal selective state space:
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t      (per channel c,
    y_t = C_t . h_t + D * x_t                              state n)

Implemented chunked: jax.lax.associative_scan inside fixed-size chunks and a
lax.scan carrying the (d_inner, d_state) state across chunks — matmul-heavy
within chunks (MXU-friendly), O(1) state for decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

CHUNK = 128


def init_mamba(key, cfg, dtype):
    d, din, ds, dc = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * din), dtype=dtype),
        "conv_w": dense_init(ks[1], (dc, din), dtype=dtype),
        "x_proj": dense_init(ks[2], (din, 2 * ds + 1), dtype=dtype),
        "dt_bias": jnp.zeros((din,), jnp.float32),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32),
                                  (din, 1))),
        "d_skip": jnp.ones((din,), jnp.float32),
        "out_proj": dense_init(ks[5], (din, d), dtype=dtype),
    }


def _causal_conv(x, w, prev):
    """Depthwise causal conv. x: (B, S, din); w: (dc, din);
    prev: (B, dc-1, din) carry from the previous segment (zeros at start).
    Returns (y (B, S, din), new_prev)."""
    dc = w.shape[0]
    xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)  # (B, S+dc-1, d)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(dc))
    new_prev = xp[:, -(dc - 1):] if dc > 1 else prev
    return y, new_prev


def _ssm_chunk(h0, a, bx, c):
    """One chunk. h0: (B, din, ds); a: (B, L, din, ds) decay exp(dt*A);
    bx: (B, L, din, ds) input injections; c: (B, L, ds).
    Returns (y (B, L, din), h_end)."""

    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_sc, b_sc = jax.lax.associative_scan(op, (a, bx), axis=1)
    h = a_sc * h0[:, None] + b_sc  # (B, L, din, ds)
    y = jnp.einsum("blds,bls->bld", h, c)
    return y, h[:, -1]


def mamba_mix(params, x, cfg, *, state=None):
    """x: (B, S, d). state: None (training) or dict(h, conv) for streaming.
    Returns (out (B, S, d), new_state)."""
    b, s, d = x.shape
    din, ds, dc = cfg.d_inner, cfg.d_state, cfg.d_conv
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)  # (B, S, din) each

    conv_prev = (state["conv"] if state is not None
                 else jnp.zeros((b, dc - 1, din), x.dtype))
    xin, conv_new = _causal_conv(xin, params["conv_w"], conv_prev)
    xin = jax.nn.silu(xin).astype(jnp.float32)

    proj = jnp.einsum("bsd,dk->bsk", xin, params["x_proj"].astype(jnp.float32))
    b_t, c_t, dt_in = (proj[..., :ds], proj[..., ds:2 * ds], proj[..., -1])
    dt = jax.nn.softplus(dt_in[..., None] + params["dt_bias"])  # (B, S, din)
    a = -jnp.exp(params["a_log"])  # (din, ds)

    h0 = (state["h"].astype(jnp.float32) if state is not None
          else jnp.zeros((b, din, ds), jnp.float32))
    if s == 1:  # decode fast path
        decay = jnp.exp(dt[..., None] * a)
        inject = (dt * xin)[..., None] * b_t[:, :, None, :]
        h = decay[:, 0] * h0 + inject[:, 0]
        y = jnp.einsum("bds,bs->bd", h, c_t[:, 0])[:, None]
        h_end = h
    else:
        # Everything inside this scope is what kernels/ssm_scan's fused
        # Pallas kernel keeps in VMEM on real TPU (the (B,*,din,ds)
        # decay/injection temporaries + the chunk recurrence); the roofline
        # ssm-kernel adjustment keys off the scope name.
        with jax.named_scope("ssm_scan_kernel"):
            decay = jnp.exp(dt[..., None] * a)  # (B, S, din, ds)
            inject = (dt * xin)[..., None] * b_t[:, :, None, :]
            chunk = min(CHUNK, s)
            while s % chunk:
                chunk //= 2
            nch = s // chunk

            def step(h, args):
                de, inj, ct = args
                y, h_end = _ssm_chunk(h, de, inj, ct)
                return h_end, y

            resh = lambda t: (
                t.reshape((b, nch, chunk) + t.shape[2:]).swapaxes(0, 1))
            h_end, ys = jax.lax.scan(
                step, h0, (resh(decay), resh(inject), resh(c_t)))
            y = ys.swapaxes(0, 1).reshape(b, s, din)

    y = y + params["d_skip"] * xin
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["out_proj"]
    new_state = {"h": h_end, "conv": conv_new}
    return out, new_state


def init_mamba_state(cfg, batch, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
    }
