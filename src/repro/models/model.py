"""Top-level model: embedding -> scanned superlayers -> norm -> LM head.

Public API (all pure functions over a params pytree):
  init_params(key, cfg)                       -> params
  forward(params, cfg, batch, ...)            -> (hidden, aux[, states])
  logits_from_hidden(params, cfg, hidden)     -> (B, S, padded_vocab) f32
  loss_fn(params, cfg, batch, ...)            -> (scalar loss, metrics)
  decode_step(params, cfg, cache, tok, pos)   -> (logits, new_cache)
  init_cache / prefill_cache

Batch dict fields:
  tokens : (B, S_tok) int32                   (absent for pure-embeds input)
  embeds : (B, P, d) model-dtype              (stub frontend: audio frames /
                                               vision patches, prepended)
  labels : (B, S) int32                       (next-token targets)
  mask   : (B, S) f32 optional                (loss weights; e.g. 0 on prefix)

The VLM prefix (cfg.n_patches > 0) switches attention to the prefix-causal
domain (PrefixSchedule — rectangle ∪ triangle, beyond-paper mapping).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.parallel import hints

_DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


def param_dtype(cfg):
    return _DTYPES[cfg.dtype]


def init_params(key, cfg):
    dtype = param_dtype(cfg)
    k_emb, k_stack, k_head = jax.random.split(key, 3)
    d, vp = cfg.d_model, cfg.padded_vocab
    params = {
        "embed": (jax.random.normal(k_emb, (vp, d), jnp.float32)
                  * 0.02).astype(dtype),
        "layers": T.init_stack(k_stack, cfg, dtype),
        "final_norm": jnp.ones((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, (d, vp), dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def embed_inputs(params, cfg, batch):
    """Token embeddings, with optional stub-frontend prefix embeds."""
    parts = []
    if "embeds" in batch and batch["embeds"] is not None:
        parts.append(batch["embeds"].astype(param_dtype(cfg)))
    if "tokens" in batch and batch["tokens"] is not None:
        parts.append(jnp.take(params["embed"], batch["tokens"], axis=0))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return x


def forward(params, cfg, batch, *, attn_impl: str = "scan",
            remat: bool = True, collect_state: bool = False,
            block: int = 512, act_sharding=None, positions=None,
            packed=None, full_capacity: bool = False):
    """Returns (hidden (B, S, d), aux, states_or_None).

    act_sharding: optional NamedSharding pinned onto the (B, S, d) scan
    carry — Megatron-style activation partitioning (batch over DP, d over
    TP) that bounds the per-chip saved-carry memory of the layer scan.

    positions/packed/full_capacity serve the batched ragged prefill: S is
    then the concatenation of R prompts, positions restart per request,
    packed is the PackedTriSched making attention block-diagonal, and MoE
    buffers are sized drop-free (decode-path semantics)."""
    x = embed_inputs(params, cfg, batch)
    s = x.shape[1]
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    prefix = cfg.n_patches if cfg.frontend == "vision_patches" else 0

    def step(x, layer_params):
        if act_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, act_sharding)
        x = hints.constrain(x, "act_seq")
        x, aux, st = T.superlayer_fwd(
            layer_params, x, cfg, positions=positions, prefix=prefix,
            attn_impl=attn_impl, block=block, collect_state=collect_state,
            packed=packed, full_capacity=full_capacity)
        return x, (aux, st)

    if remat:
        # 'remat_policy' hint (§Perf): save named intermediates (e.g. the
        # attention context) so backward skips re-running the triangular
        # tile scan; default full remat.
        pol_names = hints.get("remat_policy")
        policy = (jax.checkpoint_policies.save_only_these_names(*pol_names)
                  if pol_names else jax.checkpoint_policies.nothing_saveable)
        step = jax.checkpoint(step, policy=policy)
    x, (auxs, states) = jax.lax.scan(step, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.sum(auxs), (states if collect_state else None)


def logits_from_hidden(params, cfg, hidden):
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return (hidden @ head).astype(jnp.float32)


def cross_entropy(logits, labels, mask, vocab_size: int):
    """Mean CE over masked positions. logits f32 (B, S, Vp); labels (B, S).

    Positions past the true vocab are never targets; padded-vocab logits are
    masked to -inf so they cannot absorb probability mass.
    """
    vp = logits.shape[-1]
    if vp > vocab_size:
        neg = jnp.finfo(jnp.float32).min
        pad = jnp.arange(vp) >= vocab_size
        logits = jnp.where(pad, neg, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(params, cfg, batch, *, attn_impl: str = "scan",
            remat: bool = True, aux_weight: float = 0.01, block: int = 512,
            act_sharding=None, packed=None):
    """packed: optional PackedTriSched — the ragged document-batch training
    fast path. ``batch["tokens"]`` is then (B, S_total), the concatenation
    of bin-packed documents (train/data.pack_documents); attention is
    block-diagonal per document (per-doc causal isolation) and the backward
    runs the packed dq / dk/dv launches instead of R pad-to-max ones.
    ``batch["positions"]`` ((B, S_total), restarting per document) and
    ``batch["mask"]`` (1 on every real token — each has a next-token
    target drawn with the document — and 0 on the pad tail rows) carry
    the per-document bookkeeping."""
    hidden, aux, _ = forward(params, cfg, batch, attn_impl=attn_impl,
                             remat=remat, block=block,
                             act_sharding=act_sharding,
                             positions=batch.get("positions"),
                             packed=packed)
    logits = logits_from_hidden(params, cfg, hidden)
    labels = batch["labels"]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    ce = cross_entropy(logits, labels, mask, cfg.vocab_size)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return T.init_cache(cfg, batch, max_len, dtype)


def decode_step(params, cfg, cache, tokens, pos, decode_tbl=None,
                decode_spec=None):
    """One decode step. tokens: (B, 1) int32; pos: scalar or (B,) int32
    (absolute position of each new token). Returns (logits (B, 1, Vp) f32,
    new_cache).

    decode_tbl + decode_spec switch attention layers to the packed
    mixed-position decode (serve/decode.decode_step_packed): one launch
    per round over each live slot's own valid KV prefix instead of the
    lockstep full-cache einsum. Every layer shares the round's table (all
    caches advance by the same token)."""
    x = jnp.take(params["embed"], tokens, axis=0)

    def step(x, scanned):
        layer_params, layer_cache = scanned
        x, new_cache = T.superlayer_decode(layer_params, x, cfg, layer_cache,
                                           pos, decode_tbl=decode_tbl,
                                           decode_spec=decode_spec)
        return x, new_cache

    x, new_cache = jax.lax.scan(step, x, (params["layers"], cache))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return logits_from_hidden(params, cfg, x), new_cache


def fused_step(params, cfg, cache, pack_tokens, pack_positions, dec_tokens,
               pos, psched, fused_tbl, fused_spec, admit_rows):
    """One fused continuous-batching step: admitted prompts AND live decode
    slots flow through the layer stack together, with ONE attention launch
    per superlayer scan step (i.e. one pallas_call in the whole jaxpr —
    the jaxpr lint pins this).

    pack_tokens: (1, S_pack) int32 packed admitted prompts;
    pack_positions: (S_pack,) restarting per request; dec_tokens: (B, 1);
    pos: (B,) decode positions; admit_rows: (A,) int32 pack rows of each
    admitted prompt's last real token (its first sampled token comes from
    there). Returns (logits_admit (1, A, Vp) f32, logits_dec (B, 1, Vp)
    f32, new_cache, pack k/v states for the admit KV splice)."""
    x_pack = jnp.take(params["embed"], pack_tokens, axis=0)
    x_dec = jnp.take(params["embed"], dec_tokens, axis=0)

    def step(xs, scanned):
        layer_params, layer_cache = scanned
        x_p, x_d = xs
        x_p, x_d, new_cache, st = T.superlayer_fused(
            layer_params, x_p, x_d, cfg, layer_cache, pos,
            pack_positions=pack_positions, packed=psched,
            fused_tbl=fused_tbl, fused_spec=fused_spec)
        return (x_p, x_d), (new_cache, st)

    (x_pack, x_dec), (new_cache, states) = jax.lax.scan(
        step, (x_pack, x_dec), (params["layers"], cache))
    x_pack = L.rms_norm(x_pack, params["final_norm"], cfg.norm_eps)
    x_dec = L.rms_norm(x_dec, params["final_norm"], cfg.norm_eps)
    admit_hidden = jnp.take(x_pack, admit_rows, axis=1)  # (1, A, d)
    return (logits_from_hidden(params, cfg, admit_hidden),
            logits_from_hidden(params, cfg, x_dec), new_cache, states)


def prefill_cache(params, cfg, batch, max_len: int, *,
                  attn_impl: str = "scan", block: int = 512,
                  cache_dtype=jnp.bfloat16):
    """Run the full-sequence forward, collect per-layer states, and assemble
    a decode cache covering positions [0, S). Returns (hidden, cache)."""
    hidden, _, states = forward(params, cfg, batch, attn_impl=attn_impl,
                                remat=False, collect_state=True, block=block)
    b, s = hidden.shape[0], hidden.shape[1]
    cache = init_cache(cfg, b, max_len, cache_dtype)

    def fill(c, st):
        # KV layers: states carry (n_sl, B, S, Hkv, hd); write into slots.
        if c.ndim == 5 and st.ndim == 5:  # (n_sl, B, S_slots, Hkv, hd)
            s_slots = c.shape[2]
            if cfg.sliding_window is not None and s > s_slots:
                # rolling buffer: keep the last window, slot p % W
                take = st[:, :, s - s_slots:]
                roll = (s - s_slots) % s_slots
                take = jnp.roll(take, shift=roll, axis=2)
                return take.astype(c.dtype)
            return jax.lax.dynamic_update_slice(
                c, st[:, :, :s_slots].astype(c.dtype), (0, 0, 0, 0, 0))
        return st.astype(c.dtype)  # recurrent states replace wholesale

    cache = jax.tree.map(fill, cache, states)
    return hidden, cache


# ---------------------------------------------------------------------------
# Convenience jitted entry points (CPU/example scale)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "attn_impl", "block"))
def jit_loss(params, cfg, batch, attn_impl="scan", block=512):
    return loss_fn(params, cfg, batch, attn_impl=attn_impl, block=block)


@functools.partial(jax.jit, static_argnames=("cfg",))
def jit_decode_step(params, cfg, cache, tokens, pos):
    return decode_step(params, cfg, cache, tokens, pos)
