"""Mesh construction. FUNCTIONS only — importing this module must never
touch jax device state (dryrun.py sets XLA_FLAGS before first jax init)."""

from __future__ import annotations

import jax

from repro.launch.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod meshes: 16x16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: "data" = DP/FSDP, "model" = TP; "pod" composes with "data" for the
    batch dimension (pure DP across the DCI, FSDP inside the pod), and is
    the documented GPipe insertion point past 4k chips (DESIGN.md §5).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh():
    """All local devices on a 1-D "data" axis (CPU tests / examples)."""
    n = len(jax.devices())
    return make_mesh((n,), ("data",))
