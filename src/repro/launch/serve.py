"""Serving driver: slot-based continuous-batching engine on a reduced
config (real decode steps on CPU; the full-scale decode path is what
dryrun.py lowers for the decode_32k / long_500k cells).

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --requests 6
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import registry as REG
from repro.models import model as MD
from repro.serve.engine import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", choices=REG.ARCH_IDS)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefill-mode", default="packed",
                    choices=["packed", "sequential"],
                    help="packed = one ragged launch per admit round "
                         "(attention archs); sequential = per-token loop")
    ap.add_argument("--prefill-block", type=int, default=16)
    ap.add_argument("--decode-mode", default="auto",
                    choices=["auto", "packed", "lockstep"],
                    help="auto = packed mixed-position decode on "
                         "position-skewed rounds, lockstep otherwise")
    ap.add_argument("--decode-block", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = REG.smoke_config(args.arch)
    params = MD.init_params(jax.random.key(args.seed), cfg)
    engine = Engine(params, cfg, slots=args.slots, max_len=args.max_len,
                    temperature=args.temperature, seed=args.seed,
                    prefill_mode=args.prefill_mode,
                    prefill_block=args.prefill_block,
                    decode_mode=args.decode_mode,
                    decode_block=args.decode_block)

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for uid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(4, args.prompt_len + 1))
        engine.submit(prompt, max_new=args.max_new, uid=uid)
    results = engine.run()
    dt = time.time() - t0
    n_tok = sum(len(v) for v in results.values())
    for uid in sorted(results):
        print(f"req {uid}: {len(results[uid])} tokens -> "
              f"{results[uid][:8]}...")
    st = engine.stats
    print(f"{len(results)}/{args.requests} requests, {n_tok} tokens "
          f"in {dt:.1f}s ({n_tok/dt:.1f} tok/s, {args.slots} slots)")
    print(f"prefill[{engine.prefill_mode}]: {st['prefill_launches']} "
          f"launches for {st['prefill_requests']} requests / "
          f"{st['prefill_tokens']} tokens over {st['admit_rounds']} "
          f"admit rounds")
    print(f"decode[{engine.decode_mode}]: {st['decode_rounds']} rounds "
          f"({st['decode_packed_launches']} packed / "
          f"{st['decode_lockstep_launches']} lockstep), tiles "
          f"{st['decode_tiles_packed']} packed vs "
          f"{st['decode_tiles_padded']} pad-to-max")
    return results


if __name__ == "__main__":
    main()
