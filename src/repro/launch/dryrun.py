import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any other import (jax locks the device
# count at first init). Only this process sees 512 placeholder devices;
# tests and benches see the single real CPU device.

import argparse      # noqa: E402
import functools     # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import registry as REG           # noqa: E402
from repro.launch import mesh as MESH               # noqa: E402
from repro.parallel import sharding as SH           # noqa: E402
from repro.roofline import hlo_parse as HLO         # noqa: E402
from repro.roofline import model as RF              # noqa: E402
from repro.train import optimizer as OPT            # noqa: E402
from repro.train import train_step as TS            # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding rules produce a partitionable program (no mismatch),
  * it fits (memory_analysis bytes/device),
  * and it yields the roofline terms (trip-count-corrected HLO FLOPs /
    HBM-traffic bytes / collective bytes -> §Roofline).

Results are cached one JSON per cell under artifacts/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""

# Per-arch training knobs (chosen by activation-memory napkin math; the
# global batch is 256 so microbatch counts must keep B/mb divisible by the
# 32-way multi-pod DP axis). Adafactor for the >=300B archs: AdamW moments
# (8 bytes/param) alone would exceed v5e HBM at 256 chips.
TRAIN_KNOBS = {
    # arch: (optimizer, microbatches)
    "mixtral-8x7b": ("adamw", 4),
    "granite-moe-3b-a800m": ("adamw", 8),  # mb=1 peaks 101 GiB/dev (§Perf G1)
    "rwkv6-1.6b": ("adamw", 1),
    "yi-9b": ("adamw", 4),
    "nemotron-4-340b": ("adafactor", 8),
    "llama3-405b": ("adafactor", 8),
    "granite-34b": ("adamw", 4),
    "musicgen-large": ("adamw", 1),
    "internvl2-1b": ("adamw", 1),
    "jamba-1.5-large-398b": ("adafactor", 8),
}

ATTN_BLOCK = 512

# §Perf optimization passes (see parallel/hints.py + EXPERIMENTS.md §Perf).
# "opts" is a comma-set: attn_tp,moe_local,act_seq,mb=<n>
OPT_CHOICES = ("attn_tp", "moe_local", "act_seq")


def _act_sharding(mesh):
    dp = SH.dp_axes(mesh)
    return NamedSharding(mesh, P(dp, None, "model"))


def auto_opts(cfg, mesh, shape) -> tuple:
    """Per-(arch, shape) defaults found by the §Perf hill-climb:

    * moe_local — grouped per-DP-shard dispatch, only when the expert count
      does NOT divide the DP axis (otherwise plain EP sharding is already
      active and grouping fights it: jamba regression, EXPERIMENTS §Perf)
      and only for token-heavy shapes (train/prefill; decode dispatch is
      tiny and the constraints just force reshards).
    * attn_rep — replicated attention when heads don't divide the TP axis;
      training only (the backward per-tile score all-reduces are what it
      removes; at prefill the baseline propagation is already fine).
    """
    model_size = mesh.shape["model"]
    dp_size = SH._axis_size(mesh, SH.dp_axes(mesh))
    opts = []
    if cfg.n_experts and cfg.n_experts % dp_size != 0 \
            and not shape.is_decode:
        opts.append("moe_local")
    if cfg.n_heads % model_size and "attn" in cfg.layer_kinds \
            and shape.kind == "train":
        opts.append("attn_rep")
    return tuple(opts)


def _opt_hints(mesh, cfg, opts) -> dict:
    """Translate --opt flags into sharding hints valid for this cell."""
    from repro.parallel import hints as HN  # noqa: F401 (context applied by caller)
    dp = SH.dp_axes(mesh)
    model_size = mesh.shape["model"]
    hint = {}
    if "attn_tp" in opts and cfg.n_heads % model_size == 0:
        hint["attn_qkv"] = NamedSharding(mesh, P(dp, "model", None, None))
    if "moe_local" in opts and cfg.n_experts:
        dp_size = SH._axis_size(mesh, dp)
        hint["moe_groups"] = dp_size
        hint["moe_buf"] = NamedSharding(mesh, P(dp, None, None, None))
        hint["moe_buf3"] = NamedSharding(mesh, P(dp, None, None))
    if "moe_gather" in opts and cfg.n_experts:
        hint["moe_wi"] = NamedSharding(mesh, P(None, None, "model"))
        hint["moe_wo"] = NamedSharding(mesh, P(None, "model", None))
    if "attn_rep" in opts:
        hint["attn_qkv"] = NamedSharding(mesh, P(dp, None, None, None))
    if "act_seq" in opts:
        hint["act_seq"] = NamedSharding(mesh, P(dp, "model", None))
    if "remat_attn" in opts:
        hint["remat_policy"] = ("attn_out",)
    return hint


def build_cell(arch: str, shape_name: str, mesh, opts=()):
    """Returns (jitted_fn, arg_specs tuple) for one cell."""
    cfg = REG.get_config(arch)
    shape = REG.get_shape(shape_name)
    params = REG.params_specs(cfg)
    overrides = None
    if "embed_dp" in opts:
        # vocab replicated, d sharded over EVERY axis: token gather and its
        # scatter-add gradient become collective-free (§Perf G6/L6)
        overrides = {"embed": P(None, tuple(mesh.axis_names))}
    p_sh = SH.param_shardings(mesh, params, overrides=overrides)

    if shape.is_decode:
        serve = TS.make_serve_step(cfg)
        cache = REG.cache_specs(cfg, shape)
        c_sh = SH.cache_shardings(mesh, cache)
        dspec = REG.decode_specs(cfg, shape)
        t_sh = SH.token_shardings(mesh, dspec)
        fn = jax.jit(
            serve,
            in_shardings=(p_sh, c_sh, t_sh["tokens"], t_sh["pos"]),
            out_shardings=(None, c_sh),
            donate_argnums=(1,),
        )
        return fn, (params, cache, dspec["tokens"], dspec["pos"])

    batch = REG.batch_specs(cfg, shape)
    b_sh = SH.batch_shardings(mesh, batch)

    if shape.kind == "prefill":
        prefill = TS.make_prefill_step(cfg, attn_impl="scan",
                                       block=ATTN_BLOCK)
        cache_out = REG.cache_specs(cfg, shape)
        c_sh = SH.cache_shardings(mesh, cache_out)
        fn = jax.jit(prefill, in_shardings=(p_sh, b_sh),
                     out_shardings=(None, c_sh))
        return fn, (params, batch)

    # train
    opt_kind, microbatches = TRAIN_KNOBS[arch]
    for o in opts:
        if o.startswith("mb="):
            microbatches = int(o[3:])
    opt = OPT.OptConfig(kind=opt_kind)
    opt_state = jax.eval_shape(lambda p: OPT.init_opt_state(opt, p), params)
    o_sh = SH.param_shardings(mesh, opt_state, overrides=overrides)
    state = TS.TrainState(params=params, opt_state=opt_state,
                          step=jax.ShapeDtypeStruct((), jnp.int32),
                          err_state=None)
    s_sh = TS.TrainState(params=p_sh, opt_state=o_sh,
                         step=NamedSharding(mesh, P()), err_state=None)
    act = None if "act_seq" in opts else _act_sharding(mesh)
    step_fn = TS.make_train_step(
        cfg, opt, microbatches=microbatches, attn_impl="scan",
        remat=True, block=ATTN_BLOCK, act_sharding=act)
    fn = jax.jit(step_fn, in_shardings=(s_sh, b_sh),
                 out_shardings=(s_sh, None), donate_argnums=(0,))
    return fn, (state, batch)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             force: bool = False, save_hlo: bool = False,
             opts=(), tag: str = "") -> dict:
    suffix = f"__{tag}" if tag else ""
    out_path = os.path.join(
        out_dir, f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = REG.get_config(arch)
    shape = REG.get_shape(shape_name)
    ok, why = REG.supported(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "supported": ok, "skip_reason": why,
        "opts": sorted(opts), "tag": tag,
    }
    if not ok:
        _dump(out_path, rec)
        return rec

    mesh = MESH.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    if "auto" in opts:
        opts = tuple(o for o in opts if o != "auto") \
            + auto_opts(cfg, mesh, shape)
        rec["opts"] = sorted(set(opts))
    n_chips = mesh.size
    rec["n_chips"] = n_chips
    rec["mesh_shape"] = dict(zip(mesh.axis_names,
                                 [int(s) for s in mesh.devices.shape]))
    try:
        from repro.parallel import hints as HN
        t0 = time.time()
        with mesh, HN.hints(**_opt_hints(mesh, cfg, opts)):
            fn, arg_specs = build_cell(arch, shape_name, mesh, opts=opts)
            lowered = fn.lower(*arg_specs)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)
        if save_hlo:
            import gzip
            hlo_path = out_path.replace(".json", ".hlo.gz")
            with gzip.open(hlo_path, "wt") as f:
                f.write(compiled.as_text())
        analysis = HLO.analyze_compiled(compiled)
        rec["analysis"] = {k: v for k, v in analysis.items()}
        mf = RF.model_flops(cfg, shape)
        terms = RF.terms_from_analysis(analysis, n_chips=n_chips,
                                       model_flops=mf)
        rec["roofline"] = terms.as_dict()
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    _dump(out_path, rec)
    return rec


def _dump(path, rec):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def run_tri_3body_cell(out_dir: str, *, n_rows: int = 256, block: int = 8,
                       d: int = 8, strict: bool = False,
                       force: bool = False) -> dict:
    """Roofline cell for the tri_3body kernel family (ROADMAP open item):
    lower + compile the tet-grid scan AND the BB-3D baseline scan, and
    record their trip-count-corrected FLOPs / HBM bytes so the 6x launch
    reduction shows up in artifacts alongside the model cells."""
    from repro.core import mapping as M
    from repro.kernels.tri_3body import ops as OPS3

    tag = f"n{n_rows}_b{block}_d{d}" + ("_strict" if strict else "")
    out_path = os.path.join(out_dir, f"kernel__tri_3body__{tag}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    n = n_rows // block
    rec = {"kernel": "tri_3body", "n_rows": n_rows, "block": block,
           "d": d, "strict": strict,
           "tiles_tet": M.tet(n), "tiles_bb3": n ** 3,
           "launch_reduction": (n ** 3) / M.tet(n)}
    x = jax.ShapeDtypeStruct((n_rows, d), jnp.float32)
    try:
        for name, impl in (("tet", "scan"), ("bb3", "bb3_scan")):
            fn = jax.jit(functools.partial(
                OPS3.three_body, block=block, impl=impl, strict=strict))
            t0 = time.time()
            compiled = fn.lower(x).compile()
            an = HLO.analyze_compiled(compiled)
            rec[name] = {
                "compile_s": round(time.time() - t0, 2),
                "flops": an["flops"],
                "hbm_bytes": an["hbm_bytes"],
                "intensity_flops_per_byte":
                    an["flops"] / max(an["hbm_bytes"], 1.0),
            }
        rec["flops_ratio_bb3_over_tet"] = (
            rec["bb3"]["flops"] / max(rec["tet"]["flops"], 1.0))
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    _dump(out_path, rec)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--opt", default="",
                    help="comma list: attn_tp,moe_local,act_seq,mb=<n>")
    ap.add_argument("--tag", default="",
                    help="suffix for the output JSON (A/B experiments)")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--kernel", default=None, choices=["tri_3body"],
                    help="dry-run a standalone kernel cell instead of the "
                         "(arch x shape x mesh) grid")
    ap.add_argument("--kernel-n-rows", type=int, default=256)
    ap.add_argument("--kernel-block", type=int, default=8)
    ap.add_argument("--kernel-d", type=int, default=8)
    ap.add_argument("--strict", action="store_true",
                    help="tri_3body: a > b > c in-kernel masking")
    args = ap.parse_args()
    opts = tuple(o for o in args.opt.split(",") if o)

    if args.kernel == "tri_3body":
        rec = run_tri_3body_cell(
            args.out, n_rows=args.kernel_n_rows, block=args.kernel_block,
            d=args.kernel_d, strict=args.strict, force=args.force)
        status = "ok" if rec.get("ok") else "FAIL " + rec.get("error", "")
        print(f"kernel tri_3body {status} tiles "
              f"{rec['tiles_tet']}/{rec['tiles_bb3']} "
              f"flops bb3/tet={rec.get('flops_ratio_bb3_over_tet', 0):.2f}")
        return

    archs = REG.ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = (list(REG.SHAPES) if (args.all or args.shape is None)
              else [args.shape])
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                t0 = time.time()
                rec = run_cell(arch, shape_name, mesh_kind, args.out,
                               force=args.force, save_hlo=args.save_hlo,
                               opts=opts, tag=args.tag)
                status = ("SKIP " + rec.get("skip_reason", "")[:40]
                          if not rec.get("supported", True)
                          else "ok" if rec.get("ok") else
                          "FAIL " + rec.get("error", "")[:80])
                dom = rec.get("roofline", {}).get("dominant", "-")
                print(f"[{time.time()-t0:7.1f}s] {mesh_kind:6s} {arch:24s} "
                      f"{shape_name:12s} {status} dom={dom}", flush=True)


if __name__ == "__main__":
    main()
