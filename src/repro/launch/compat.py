"""JAX cross-version compatibility helpers.

`jax.sharding.AxisType` (and `jax.make_mesh`'s `axis_types=` kwarg) only
exist in newer JAX; on 0.4.x every mesh axis is implicitly what newer
versions call `Auto`. All mesh construction in this repo goes through
``make_mesh`` below so both eras behave identically: on new JAX the axes
are explicitly marked Auto, on old JAX the kwarg is simply omitted.

FUNCTIONS only — importing this module must never touch jax device state
(same contract as launch/mesh.py; dryrun.py sets XLA_FLAGS before the
first jax init).
"""

from __future__ import annotations

import jax


def auto_axis_types(num_axes: int):
    """(AxisType.Auto,) * num_axes on JAX that has AxisType, else None."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * num_axes


def cost_analysis_dict(compiled) -> dict:
    """`compiled.cost_analysis()` as one flat dict across JAX versions.

    JAX 0.4.x returns a per-device list of dicts; newer JAX returns the
    dict directly. Either way the first (only, on single-controller
    programs) entry is what callers want.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """Version-proof `jax.make_mesh` with every axis in Auto mode."""
    kwargs = {} if devices is None else {"devices": devices}
    axis_types = auto_axis_types(len(tuple(axis_names)))
    if axis_types is not None:
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


# ---------------------------------------------------------------------------
# HLO op_name spellings of kernel-fusable regions
# ---------------------------------------------------------------------------

# The roofline HLO walk identifies "kernel interiors" — regions with a
# Pallas twin in kernels/ — by op_name metadata. Two sources:
#  * the scan-attention cell, the only model code shaped as
#    vmap(vmap(<cell with lax.scan>)); its op_name spelling differs across
#    JAX versions: "vmap(vmap())/.../while" on newer JAX,
#    "vmap(vmap(while))" on 0.4.x — BOTH spellings must stay matched, and
#  * explicit jax.named_scope markers placed around scan fallbacks
#    (ssm_scan for the mamba recurrence, wkv for rwkv, tri_attn).
# One tested table; every consumer builds its regex from here so a JAX
# upgrade that reshuffles one spelling fails a single pinned test instead
# of silently zeroing the interior-bytes column.
KERNEL_REGION_OP_NAME_SPELLINGS = (
    r"vmap\(vmap\(\)\)[^\"]*while",   # newer JAX: vmap(vmap())/.../while
    r"vmap\(vmap\(while\)\)",         # JAX 0.4.x: collapsed spelling
    r"ssm_scan_kernel",
    r"wkv_scan_kernel",
    r"tri_attn_kernel",
)


def kernel_region_regex():
    """Compiled alternation over KERNEL_REGION_OP_NAME_SPELLINGS."""
    import re

    return re.compile("|".join(KERNEL_REGION_OP_NAME_SPELLINGS))
