"""Training driver.

Runs REAL steps, so on this CPU container it targets the reduced (smoke)
configs — the same code path the production mesh lowers in dryrun.py, with
checkpointing, preemption guard and deterministic restart.

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --steps 50 \
      --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

XLA latency-hiding / async-collective flags for real TPU runs are set here
(they are harmless no-ops on CPU).
"""

from __future__ import annotations

import os

os.environ.setdefault(
    "LIBTPU_INIT_ARGS",
    "--xla_enable_async_all_gather=true "
    "--xla_enable_async_collective_permute=true "
    "--xla_tpu_enable_data_parallel_all_reduce_opt=true "
    "--xla_tpu_data_parallel_opt_different_sized_ops=true "
    "--xla_tpu_overlap_compute_collective_tc=true",
)

import argparse     # noqa: E402
import dataclasses  # noqa: E402
import time         # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import registry as REG        # noqa: E402
from repro.configs.base import ShapeConfig       # noqa: E402
from repro.train import checkpoint as CKPT       # noqa: E402
from repro.train import data as DATA             # noqa: E402
from repro.train import fault_tolerance as FT    # noqa: E402
from repro.train import optimizer as OPT         # noqa: E402
from repro.train import train_step as TS         # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", choices=REG.ARCH_IDS)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full-scale config (TPU pod only)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 error-feedback gradient all-reduce over the "
                         "local data mesh (parallel/compression.py)")
    args = ap.parse_args(argv)

    cfg = (REG.get_config(args.arch) if args.full_config
           else REG.smoke_config(args.arch))
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    opt = OPT.OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                        total_steps=args.steps)

    state = TS.init_state(jax.random.key(args.seed), cfg, opt,
                          compression=args.compress_grads)
    n_params = sum(p.size for p in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} (reduced={not args.full_config}) "
          f"params={n_params/1e6:.2f}M steps={args.steps}"
          + (" [int8-EF grad AR]" if args.compress_grads else ""))

    compressed_ar = None
    if args.compress_grads:
        from repro.launch.mesh import make_local_mesh
        from repro.parallel.compression import make_compressed_allreduce
        compressed_ar = make_compressed_allreduce(make_local_mesh(), "data")

    ds = DATA.SyntheticLM(cfg, shape, seed=args.seed,
                          act_dtype=jnp.float32)
    step_fn = jax.jit(TS.make_train_step(
        cfg, opt, microbatches=args.microbatches, attn_impl="scan",
        remat=True, compressed_allreduce=compressed_ar),
        donate_argnums=(0,))

    manager = (CKPT.CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
               if args.ckpt_dir else None)
    if manager is not None and CKPT.latest_step(args.ckpt_dir) is not None:
        target = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        state, manifest = CKPT.restore(args.ckpt_dir, target)
        print(f"restored checkpoint at step {int(state.step)}")

    t0 = time.time()
    last = [t0]

    def batch_fn(step):
        return ds.batch(step)

    def logging_step(state, batch):
        state, metrics = step_fn(state, batch)
        s = int(state.step)
        if s % args.log_every == 0 or s == args.steps:
            dt = time.time() - last[0]
            last[0] = time.time()
            print(f"step {s:5d} loss={float(metrics['loss']):.4f} "
                  f"ce={float(metrics['ce']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({dt:.2f}s)", flush=True)
        return state, metrics

    with FT.PreemptionGuard() as guard:
        state, log = FT.run_training(
            state, logging_step, batch_fn, args.steps,
            manager=manager, guard=guard)
    if manager is not None:
        manager.save_sync(state, int(state.step))
        manager.wait()
    print(f"done: {int(state.step)} steps in {time.time()-t0:.1f}s; "
          f"final loss {log[-1]['loss']:.4f}" if log else "no steps run")
    return state, log


if __name__ == "__main__":
    main()
