"""Fault tolerance: heartbeats, straggler detection, elastic re-planning,
preemption-safe training loops.

Everything here is deliberately host-side and deterministic so it can be
unit-tested on CPU and drops onto jax.distributed unchanged: the monitor
consumes (worker, step, timestamp) events from any transport (here: direct
calls; in deployment: the coordination service), and the re-planner is a
pure function from the live-worker set to a new mesh shape + data shards.

Recovery invariant (tested): crash at any step -> restore latest checkpoint
-> replay remaining batches == bitwise-identical final state, because the
data pipeline is a pure function of (seed, step) and the train step is
deterministic.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple


# ---------------------------------------------------------------------------
# Heartbeats & stragglers — moved to repro.resilience.health (shared with
# the serving engine's RoundWatch); re-exported here so existing imports
# keep working.
# ---------------------------------------------------------------------------

from repro.resilience.health import HeartbeatMonitor, WorkerHealth  # noqa: E402,F401


# ---------------------------------------------------------------------------
# Elastic re-planning
# ---------------------------------------------------------------------------


def replan_mesh(n_chips: int, *, model: int = 16, pod_size: int = 256
                ) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest usable mesh from n_chips surviving chips.

    Keeps the model (TP) axis intact — parameter shardings stay valid, so
    elastic restore only re-slices the data axis. Whole lost pods shrink the
    pod axis; partial losses shrink data. Deterministic in n_chips.
    """
    if n_chips < model:
        # degrade TP to the largest power-of-two divisor that fits
        while model > 1 and n_chips < model:
            model //= 2
    pods = max(1, n_chips // pod_size)
    per_pod = n_chips // pods
    data = max(1, per_pod // model)
    if pods > 1:
        return (pods, data, model), ("pod", "data", "model")
    return (data, model), ("data", "model")


def shard_assignment(n_shards: int, workers: Sequence[int]) -> Dict[int, List[int]]:
    """Deterministic round-robin data-shard ownership for the live set."""
    workers = sorted(workers)
    out: Dict[int, List[int]] = {w: [] for w in workers}
    for s in range(n_shards):
        out[workers[s % len(workers)]].append(s)
    return out


# ---------------------------------------------------------------------------
# Preemption guard
# ---------------------------------------------------------------------------


class PreemptionGuard:
    """Converts SIGTERM (or a chosen signal) into a checked flag so the
    training loop can checkpoint-and-exit at a step boundary instead of
    dying mid-allreduce."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = False
        self._signals = signals
        self._prev = {}

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, prev in self._prev.items():
            signal.signal(s, prev)
        return False

    def _handler(self, signum, frame):
        self._flag = True

    @property
    def preempted(self) -> bool:
        return self._flag


# ---------------------------------------------------------------------------
# Resumable training loop
# ---------------------------------------------------------------------------


def run_training(state, train_step: Callable, batch_fn: Callable,
                 n_steps: int, *, manager=None, guard=None,
                 monitor=None, worker: int = 0,
                 fail_at: Optional[int] = None) -> Tuple[object, list]:
    """Drive `train_step` from state.step to n_steps.

    batch_fn(step) -> batch (pure function: restart-safe).
    manager: CheckpointManager for cadenced saves.
    fail_at: raise SimulatedFailure before executing that step (tests).
    Returns (final_state, metrics_log).
    """
    log = []
    step = int(state.step)
    while step < n_steps:
        if guard is not None and guard.preempted:
            if manager is not None:
                manager.save_sync(state, step)
            break
        if fail_at is not None and step == fail_at:
            raise SimulatedFailure(step)
        batch = batch_fn(step)
        state, metrics = train_step(state, batch)
        step += 1
        if monitor is not None:
            monitor.beat(worker, step)
        log.append({k: float(v) for k, v in metrics.items()})
        if manager is not None and manager.should_save(step):
            manager.save_sync(state, step)
    return state, log


class SimulatedFailure(RuntimeError):
    def __init__(self, step):
        super().__init__(f"simulated node failure at step {step}")
        self.step = step
