"""Synthetic sharded data pipeline.

Deterministic per (seed, step): resuming from a checkpoint at step k
re-produces batch k+1 bit-exactly with no stored iterator state — the
fault-tolerance property the restart tests rely on. Tokens follow a Zipfian
unigram draw with short Markov repeats so the loss curve is non-trivial
(pure uniform tokens give a flat CE at ln(V)).

``place`` shards the host batch onto the mesh with
jax.make_array_from_callback (per-device slices; no full-array transfer on
real multi-host deployments).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _zipf_tokens(rng: np.random.Generator, vocab: int, b: int,
                 n: int) -> np.ndarray:
    """(b, n) Zipf-ish unigram draw with short Markov repeats (shared by
    the rectangular and packed-document factories). Both slices have
    (n - 9)//8 + 1 elements for every n, so the copy is length-safe."""
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(vocab, size=(b, n), p=probs).astype(np.int32)
    if n >= 13:  # short deterministic repeats: every 8th position copies -4
        toks[:, 8::8] = toks[:, 4:-4:8]
    return toks


class SyntheticLM:
    """Batch factory for one (cfg, shape) cell."""

    def __init__(self, cfg, shape, *, seed: int = 0,
                 act_dtype=jnp.bfloat16):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.act_dtype = act_dtype

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(
            np.random.Philox(key=[self.seed, (0xB10C << 32) | step]))

    def _tokens(self, rng, b: int, s: int) -> np.ndarray:
        return _zipf_tokens(rng, self.cfg.vocab_size, b, s + 1)

    def batch(self, step: int) -> dict:
        cfg, shape = self.cfg, self.shape
        b, s = shape.global_batch, shape.seq_len
        rng = self._rng(step)
        if cfg.frontend == "audio_frames":
            toks = self._tokens(rng, b, s)
            emb = rng.standard_normal((b, s, cfg.d_model),
                                      dtype=np.float32) * 0.02
            return {
                "embeds": jnp.asarray(emb, self.act_dtype),
                "labels": jnp.asarray(toks[:, 1:]),
            }
        if cfg.frontend == "vision_patches":
            p = cfg.n_patches
            toks = self._tokens(rng, b, s - p)
            emb = rng.standard_normal((b, p, cfg.d_model),
                                      dtype=np.float32) * 0.02
            labels = np.concatenate(
                [np.zeros((b, p), np.int32), toks[:, 1:]], axis=1)
            mask = np.concatenate(
                [np.zeros((b, p), np.float32), np.ones((b, s - p),
                                                       np.float32)], axis=1)
            return {
                "embeds": jnp.asarray(emb, self.act_dtype),
                "tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(labels),
                "mask": jnp.asarray(mask),
            }
        toks = self._tokens(rng, b, s)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}


# ---------------------------------------------------------------------------
# Ragged document-batch training: bin packing onto the packed schedule
# ---------------------------------------------------------------------------


def pack_documents(doc_lens, capacity: int, *, block: int):
    """First-fit-decreasing bin packing of documents into packed-row bins.

    doc_lens[i] is document i's raw token count; each occupies
    ceil(len / block) * block packed rows (its member triangle's padded
    edge). Bins hold at most ``capacity`` padded tokens. Returns a list of
    bins, each a list of doc indices in placement order (descending padded
    length — FFD keeps the per-bin tile totals within 22% of optimal,
    plenty for equalizing packed launches).
    """
    assert capacity >= block > 0
    padded = [-(-int(s) // block) * block for s in doc_lens]
    assert all(0 < p <= capacity for p in padded), (
        f"documents must be 1..{capacity} padded tokens, got {padded}")
    order = sorted(range(len(padded)), key=lambda i: -padded[i])
    bins, fill = [], []
    for i in order:
        for b, used in enumerate(fill):
            if used + padded[i] <= capacity:
                bins[b].append(i)
                fill[b] += padded[i]
                break
        else:
            bins.append([i])
            fill.append(padded[i])
    return bins


class PackedDocsLM:
    """Ragged-document batch factory for packed triangular training.

    ``doc_lens`` fixes the batch GEOMETRY (one compile for every step of
    the run): each step re-draws token VALUES deterministically per
    (seed, step), exactly like SyntheticLM. Emits one packed row per
    batch — tokens (1, S_total) with the documents concatenated (each
    zero-padded to a ``block`` multiple), labels shifted WITHIN each
    document, mask zero on pad rows, positions restarting per document —
    plus ``member_lens`` for ops.make_packed_sched. ``padded_batch``
    builds the pad-to-max baseline over the SAME documents (the
    bounding-box training batch the packed path replaces), so the two
    losses are directly comparable: both average over the identical real
    token set.
    """

    def __init__(self, cfg, doc_lens, *, block: int, seed: int = 0):
        self.cfg, self.seed, self.block = cfg, seed, block
        self.doc_lens = tuple(int(s) for s in doc_lens)
        assert all(s >= 2 for s in self.doc_lens), (
            "documents need >= 2 tokens for a next-token target")
        self.pads = tuple(-(-s // block) * block for s in self.doc_lens)
        self.starts = tuple(np.cumsum((0,) + self.pads[:-1]).tolist())
        self.s_total = sum(self.pads)

    @property
    def member_lens(self):
        """Padded per-document lengths — feed to ops.make_packed_sched."""
        return self.pads

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(
            np.random.Philox(key=[self.seed, (0xD0C5 << 32) | step]))

    def _docs(self, step: int):
        """Per-document (len + 1)-token draws for one step."""
        rng = self._rng(step)
        return [_zipf_tokens(rng, self.cfg.vocab_size, 1, s + 1)[0]
                for s in self.doc_lens]

    def batch(self, step: int) -> dict:
        toks = np.zeros((1, self.s_total), np.int32)
        labels = np.zeros((1, self.s_total), np.int32)
        mask = np.zeros((1, self.s_total), np.float32)
        positions = np.zeros((1, self.s_total), np.int32)
        for st, pad, s, doc in zip(self.starts, self.pads, self.doc_lens,
                                   self._docs(step)):
            toks[0, st:st + s] = doc[:-1]
            labels[0, st:st + s] = doc[1:]
            mask[0, st:st + s] = 1.0
            positions[0, st:st + pad] = np.arange(pad)
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels),
                "mask": jnp.asarray(mask),
                "positions": jnp.asarray(positions)}

    def padded_batch(self, step: int) -> dict:
        """Pad-to-max baseline: (R, S_max) rows over the same documents."""
        r, s_max = len(self.doc_lens), max(self.pads)
        toks = np.zeros((r, s_max), np.int32)
        labels = np.zeros((r, s_max), np.int32)
        mask = np.zeros((r, s_max), np.float32)
        for row, (s, doc) in enumerate(zip(self.doc_lens, self._docs(step))):
            toks[row, :s] = doc[:-1]
            labels[row, :s] = doc[1:]
            mask[row, :s] = 1.0
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels),
                "mask": jnp.asarray(mask)}


def place(batch: dict, shardings: Optional[dict] = None) -> dict:
    """Device-put a host batch with the given sharding tree (or default)."""
    if shardings is None:
        return jax.tree.map(jnp.asarray, batch)

    def put(x, sh):
        arr = np.asarray(x)
        return jax.make_array_from_callback(
            arr.shape, sh, lambda idx: arr[idx])

    return jax.tree.map(put, batch, shardings)
