"""Synthetic sharded data pipeline.

Deterministic per (seed, step): resuming from a checkpoint at step k
re-produces batch k+1 bit-exactly with no stored iterator state — the
fault-tolerance property the restart tests rely on. Tokens follow a Zipfian
unigram draw with short Markov repeats so the loss curve is non-trivial
(pure uniform tokens give a flat CE at ln(V)).

``place`` shards the host batch onto the mesh with
jax.make_array_from_callback (per-device slices; no full-array transfer on
real multi-host deployments).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticLM:
    """Batch factory for one (cfg, shape) cell."""

    def __init__(self, cfg, shape, *, seed: int = 0,
                 act_dtype=jnp.bfloat16):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.act_dtype = act_dtype

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(
            np.random.Philox(key=[self.seed, (0xB10C << 32) | step]))

    def _tokens(self, rng, b: int, s: int) -> np.ndarray:
        v = self.cfg.vocab_size
        # Zipf-ish unigram over the true vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(v, size=(b, s + 1), p=probs).astype(np.int32)
        # short deterministic repeats: every 8th position copies pos-4
        toks[:, 8::8] = toks[:, 4:-4:8] if s >= 12 else toks[:, 8::8]
        return toks

    def batch(self, step: int) -> dict:
        cfg, shape = self.cfg, self.shape
        b, s = shape.global_batch, shape.seq_len
        rng = self._rng(step)
        if cfg.frontend == "audio_frames":
            toks = self._tokens(rng, b, s)
            emb = rng.standard_normal((b, s, cfg.d_model),
                                      dtype=np.float32) * 0.02
            return {
                "embeds": jnp.asarray(emb, self.act_dtype),
                "labels": jnp.asarray(toks[:, 1:]),
            }
        if cfg.frontend == "vision_patches":
            p = cfg.n_patches
            toks = self._tokens(rng, b, s - p)
            emb = rng.standard_normal((b, p, cfg.d_model),
                                      dtype=np.float32) * 0.02
            labels = np.concatenate(
                [np.zeros((b, p), np.int32), toks[:, 1:]], axis=1)
            mask = np.concatenate(
                [np.zeros((b, p), np.float32), np.ones((b, s - p),
                                                       np.float32)], axis=1)
            return {
                "embeds": jnp.asarray(emb, self.act_dtype),
                "tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(labels),
                "mask": jnp.asarray(mask),
            }
        toks = self._tokens(rng, b, s)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}


def place(batch: dict, shardings: Optional[dict] = None) -> dict:
    """Device-put a host batch with the given sharding tree (or default)."""
    if shardings is None:
        return jax.tree.map(jnp.asarray, batch)

    def put(x, sh):
        arr = np.asarray(x)
        return jax.make_array_from_callback(
            arr.shape, sh, lambda idx: arr[idx])

    return jax.tree.map(put, batch, shardings)
