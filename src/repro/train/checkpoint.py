"""Chunked, atomic, elastic checkpointing.

Layout (one directory per step):

    <dir>/step_00000420.tmp/        # written first
        manifest.json               # {key: {file, shape, dtype}} + meta
        <flat.key.path>.npy         # one file per pytree leaf
    <dir>/step_00000420/            # atomic os.replace of the .tmp dir
    <dir>/LATEST                    # atomic pointer file, written LAST

Crash-safety argument: a checkpoint is visible iff the directory rename AND
the LATEST pointer write (os.replace of a tmp file) both completed; each is
atomic on POSIX. A crash mid-save leaves a .tmp directory that restore
ignores and the next save overwrites.

Elastic restore: leaves are loaded host-side (np.load, mmap) and re-placed
with jax.device_put against the *current* mesh's shardings — restoring onto
a different device count / mesh shape than the one that saved is the normal
path, tested in tests/test_fault_tolerance.py.

Async: save() can run in a background thread (save_async); the manager
serializes saves and wait() joins before exit. Device->host transfer happens
on the caller thread (cheap, avoids cross-thread device access), file IO in
the worker.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = ".".join(
            str(e.key) if isinstance(e, jax.tree_util.DictKey)
            else str(getattr(e, "idx", getattr(e, "name", e)))
            for e in path)
        out[key or "_root"] = leaf
    return out


def save(ckpt_dir: str, state, step: int, *, extra: Optional[dict] = None):
    """Blocking atomic save of a pytree at `step`."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(state)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "_") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    # atomic LATEST pointer
    ptr_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(str(step))
    os.replace(ptr_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        step = int(f.read().strip())
    if os.path.isdir(os.path.join(ckpt_dir, f"step_{step:08d}")):
        return step
    # pointer ahead of a wiped dir: fall back to scanning
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return steps[-1] if steps else None


def restore(ckpt_dir: str, target, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of `target` (a pytree of arrays or
    ShapeDtypeStructs). shardings: optional matching pytree of NamedSharding
    for elastic re-placement onto the current mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    flat_target = _flatten(target)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    loaded = {}
    for key, spec in flat_target.items():
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint at step {step} missing leaf {key}")
        arr = np.load(os.path.join(d, meta["file"]), mmap_mode="r")
        if tuple(arr.shape) != tuple(spec.shape):
            raise ValueError(
                f"leaf {key}: checkpoint shape {arr.shape} != target "
                f"{spec.shape}")
        sh = flat_shard.get(key)
        loaded[key] = (jax.device_put(np.asarray(arr), sh) if sh is not None
                       else jax.device_put(np.asarray(arr)))

    # rebuild the tree in target order
    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    keys = list(_flatten(target).keys())
    leaves = [loaded[k] for k in keys]
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


class CheckpointManager:
    """Cadenced async saves with retention. Thread-safe, one writer."""

    def __init__(self, ckpt_dir: str, *, every: int = 100, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.every = every
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.every == 0

    def save_async(self, state, step: int, *, extra=None):
        # snapshot to host on the caller thread
        host_state = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), state)
        self.wait()

        def work():
            with self._lock:
                save(self.ckpt_dir, host_state, step, extra=extra)
                self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save_sync(self, state, step: int, *, extra=None):
        self.wait()
        with self._lock:
            path = save(self.ckpt_dir, state, step, extra=extra)
            self._gc()
        return path

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        if not os.path.isdir(self.ckpt_dir):
            return
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)
