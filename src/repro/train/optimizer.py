"""Optimizers: AdamW and Adafactor (factored second moment), pytree-native.

Adafactor matters at scale: for the >=300B assigned archs the AdamW moments
(2 x 4 bytes/param) dominate per-chip memory; the factored second moment is
O(rows + cols) and the dry-run memory analysis selects it per-arch (see
launch/dryrun.py OPT_BY_ARCH).

State layout mirrors the params pytree so parallel/sharding.py rules apply
to optimizer state unchanged (moments inherit the param's sharding; factored
row/col stats inherit the reduced-rank prefix).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    # adafactor
    decay_exp: float = 0.8  # beta2_t = 1 - t^-0.8
    clip_threshold: float = 1.0


def schedule(opt: OptConfig, step):
    """Linear warmup -> cosine decay to min_lr_frac. Uses step+1 so the
    very first update has a non-zero learning rate."""
    stepf = step.astype(jnp.float32) if hasattr(step, "astype") \
        else jnp.float32(step)
    stepf = stepf + 1.0
    warm = stepf / jnp.maximum(opt.warmup_steps, 1)
    t = (stepf - opt.warmup_steps) / jnp.maximum(
        opt.total_steps - opt.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = opt.min_lr_frac + (1 - opt.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return opt.lr * jnp.where(stepf < opt.warmup_steps, warm, cos)


def _decay_mask(path) -> bool:
    """Weight decay only on >=2-D matmul weights (not norms/biases)."""
    name = ""
    for e in reversed(path):
        if isinstance(e, jax.tree_util.DictKey):
            name = str(e.key)
            break
    return not (name.startswith("norm") or name in
                ("final_norm", "dt_bias", "d_skip", "w0", "u",
                 "ln_x_scale", "ln_x_bias"))


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_opt_state(opt: OptConfig, params):
    if opt.kind == "adamw":
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }
    if opt.kind == "adafactor":
        def vrow(p):
            return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p.shape)
                    else jnp.zeros(p.shape, jnp.float32))

        def vcol(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p.shape) else jnp.zeros((1,), jnp.float32))

        return {
            "vr": jax.tree.map(vrow, params),
            "vc": jax.tree.map(vcol, params),
        }
    raise ValueError(opt.kind)


# ---------------------------------------------------------------------------
# Updates
# ---------------------------------------------------------------------------


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), grads), g


def apply_updates(opt: OptConfig, params, grads, opt_state, step):
    """Returns (new_params, new_opt_state, metrics). grads any float dtype."""
    grads, gnorm = clip_by_global_norm(grads, opt.grad_clip)
    lr = schedule(opt, step)
    stepf = step.astype(jnp.float32) + 1.0

    if opt.kind == "adamw":
        b1, b2 = opt.b1, opt.b2
        bc1 = 1 - b1 ** stepf
        bc2 = 1 - b2 ** stepf

        def upd(path, p, g, m, v):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + opt.eps)
            if _decay_mask(path):
                u = u + opt.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        flat = jax.tree_util.tree_map_with_path(
            upd, params, grads, opt_state["m"], opt_state["v"],
            is_leaf=lambda x: isinstance(x, jax.Array))
        new_p = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"m": new_m, "v": new_v}, {"lr": lr, "grad_norm": gnorm}

    if opt.kind == "adafactor":
        b2t = 1.0 - stepf ** (-opt.decay_exp)

        def upd(path, p, g, vr, vc):
            g2 = g * g + 1e-30
            if _factored(p.shape):
                vr = b2t * vr + (1 - b2t) * jnp.mean(g2, axis=-1)
                vc = b2t * vc + (1 - b2t) * jnp.mean(g2, axis=-2)
                rfac = vr / jnp.mean(vr, axis=-1, keepdims=True)
                u = g / (jnp.sqrt(rfac)[..., None] * jnp.sqrt(vc)[..., None, :]
                         + 1e-30)
            else:
                vr = b2t * vr + (1 - b2t) * g2
                vc = vc
                u = g / (jnp.sqrt(vr) + 1e-30)
            # RMS update clipping (Adafactor d=1)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / opt.clip_threshold)
            if _decay_mask(path):
                u = u + opt.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), vr, vc

        flat = jax.tree_util.tree_map_with_path(
            upd, params, grads, opt_state["vr"], opt_state["vc"],
            is_leaf=lambda x: isinstance(x, jax.Array))
        new_p = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_vr = jax.tree.map(lambda t: t[1], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_vc = jax.tree.map(lambda t: t[2], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
        return (new_p, {"vr": new_vr, "vc": new_vc},
                {"lr": lr, "grad_norm": gnorm})

    raise ValueError(opt.kind)
