"""The sharded training step: loss -> grads -> optimizer, with gradient
accumulation (lax.scan microbatching) and optional int8 grad compression.

The LM-head logits ((B, S, padded_vocab) f32 — up to 4 TB global for the
256k-vocab archs at train_4k) are never materialized across the whole batch:
cross-entropy is computed inside each microbatch shard of the scan.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import model as MD
from repro.obs import metrics as MET
from repro.obs import trace as TR
from repro.train import optimizer as OPT


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array  # () int32
    err_state: Any = None  # int8-compression error feedback (optional)


def init_state(key, cfg, opt: OPT.OptConfig, *, compression: bool = False):
    params = MD.init_params(key, cfg)
    state = TrainState(
        params=params,
        opt_state=OPT.init_opt_state(opt, params),
        step=jnp.zeros((), jnp.int32),
        err_state=None,
    )
    if compression:
        from repro.parallel import compression as C
        state.err_state = C.init_error_state(params)
    return state


def make_train_step(cfg, opt: OPT.OptConfig, *, microbatches: int = 1,
                    attn_impl: str = "scan", remat: bool = True,
                    aux_weight: float = 0.01, block: int = 512,
                    compressed_allreduce=None, act_sharding=None,
                    packed=None):
    """Returns train_step(state, batch) -> (state, metrics).

    microbatches M > 1 splits the global batch's leading dim into M
    sequential grad-accumulation steps (activation memory / M).
    compressed_allreduce: optional (grads, err) -> (grads, err) hook from
    parallel/compression.make_compressed_allreduce.
    act_sharding: NamedSharding for the layer-scan activation carry.
    packed: optional PackedTriSched — ragged document-batch training over
    the packed layout (train/data.pack_documents builds the batches; the
    schedule is static, so one program serves every step of that packing).
    Batches must then carry "positions" and "mask" alongside tokens/labels.
    """

    def loss_fn(params, mb):
        return MD.loss_fn(params, cfg, mb, attn_impl=attn_impl, remat=remat,
                          aux_weight=aux_weight, block=block,
                          act_sharding=act_sharding, packed=packed)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    step_labels = {"impl": attn_impl,
                   "packed": "1" if packed is not None else "0"}

    def train_step(state: TrainState, batch):
        # Host-side telemetry: fires per eager call, or once per trace when
        # the caller jits the step (the same trace-time convention as the
        # kernel launch counters — see obs/launch.py).
        MET.counter_inc("train_step_calls", 1, step_labels)
        MET.counter_inc("train_microbatches", microbatches, step_labels)
        params = state.params

        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def mb_step(acc, mb):
                (l, met), g = grad_fn(params, mb)
                acc_g, acc_l = acc
                acc_g = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), acc_g, g)
                return (acc_g, acc_l + l), met

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), mets = jax.lax.scan(
                mb_step, (zero_g, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = jax.tree.map(jnp.mean, mets)

        err = state.err_state
        if compressed_allreduce is not None and err is not None:
            grads, err = compressed_allreduce(grads, err)

        new_params, new_opt, opt_metrics = OPT.apply_updates(
            opt, params, grads, state.opt_state, state.step)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        new_state = TrainState(params=new_params, opt_state=new_opt,
                               step=state.step + 1, err_state=err)
        return new_state, metrics

    def instrumented_step(state: TrainState, batch):
        # Wall-clock covers device work for eager callers (attach ->
        # block_until_ready); under jit the span covers the trace only.
        with TR.span("train.step", **step_labels) as sp:
            new_state, metrics = train_step(state, batch)
            sp.attach(metrics)
        return new_state, metrics

    return instrumented_step


# ---------------------------------------------------------------------------
# Serving steps (lowered by the dry-run for decode shapes)
# ---------------------------------------------------------------------------


def make_serve_step(cfg):
    """serve_step(params, cache, tokens, pos) -> (next_token_logits, cache).

    One new token per sequence against a KV cache / recurrent state of
    seq_len (the decode_* / long_* shape cells)."""

    def serve_step(params, cache, tokens, pos):
        logits, cache = MD.decode_step(params, cfg, cache, tokens, pos)
        return logits[:, -1], cache

    return serve_step


def make_prefill_step(cfg, *, attn_impl: str = "scan", block: int = 512,
                      cache_dtype=jnp.bfloat16):
    """prefill_step(params, batch) -> (last-position logits, decode cache)."""

    def prefill_step(params, batch):
        MET.counter_inc("prefill_step_calls", 1, {"impl": attn_impl})
        s_total = (batch["tokens"].shape[1] if "tokens" in batch else 0)
        if "embeds" in batch:
            s_total += batch["embeds"].shape[1]
        hidden, cache = MD.prefill_cache(params, cfg, batch, s_total,
                                         attn_impl=attn_impl, block=block,
                                         cache_dtype=cache_dtype)
        logits = MD.logits_from_hidden(params, cfg, hidden[:, -1:])
        return logits[:, 0], cache

    return prefill_step
