"""Pure-jnp/numpy oracle for the 3-body triplet reduction (tet domain).

The workload is the 3D analogue of tri_edm: for every *unique* tile triple
(i, j, k) with k <= j <= i over an n-tile axis, reduce the fully-symmetric
triplet interaction

    s(I, J, K) = sum_{a in I, b in J, c in K} G[a,b] * G[b,c] * G[a,c],

with G = X X^T the Gram matrix of the points. Because the summand is
symmetric under any permutation of (a, b, c), the total over ALL ordered
triples of points is recovered from the packed unique-tile values with the
multiset permutation count as weight:

    total = sum_lam mult(i,j,k) * s[lam],   mult = 6 / (#equal-pair syms)

(6 for i > j > k, 3 for exactly two equal, 1 for i == j == k). That makes
the packed tet launch — tet(n) tiles instead of BB-3D's n^3 — exactly
sufficient, the 3D version of the paper's "compute each unique pair once".
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import mapping as M


def gram(x: jnp.ndarray) -> jnp.ndarray:
    """x: (N, d) -> (N, N) Gram matrix in f32."""
    x = x.astype(jnp.float32)
    return x @ x.T


def tile_mult(i: int, j: int, k: int) -> int:
    """Permutation multiplicity of the multiset {i, j, k}."""
    if i == j == k:
        return 1
    if i == j or j == k or i == k:
        return 3
    return 6


def three_body_packed_ref(x: jnp.ndarray, block: int,
                          strict: bool = False) -> jnp.ndarray:
    """Oracle: (N, d) -> (T3, 1) per-unique-tile-triple reductions.

    strict=True keeps only globally strictly-ordered point triples
    a > b > c (masking A to a > b and B to b > c; a > c follows), matching
    the kernels' in-diagonal-tile masking."""
    n_rows = x.shape[0]
    n = n_rows // block
    g = np.asarray(gram(x))
    idx = np.arange(n_rows)
    out = np.empty((M.tet(n), 1), np.float32)
    for lam in range(M.tet(n)):
        i, j, k = M.tet_map(lam)
        si, sj, sk = (slice(t * block, (t + 1) * block) for t in (i, j, k))
        a, b, c = g[si, sj], g[sj, sk], g[si, sk]
        if strict:
            a = np.where(idx[si][:, None] > idx[sj][None, :], a, 0.0)
            b = np.where(idx[sj][:, None] > idx[sk][None, :], b, 0.0)
        out[lam, 0] = float(np.sum((a @ b) * c))
    return jnp.asarray(out)


def three_body_total_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Dense oracle for the total over all ordered point triples."""
    g = gram(x)
    return jnp.einsum("ab,bc,ac->", g, g, g)


def three_body_total_strict_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Dense oracle counting each unordered DISTINCT-point triple once:
    sum over a > b > c of G[a,b] G[b,c] G[a,c]."""
    g = np.asarray(gram(x))
    n_rows = g.shape[0]
    idx = np.arange(n_rows)
    lower = idx[:, None] > idx[None, :]
    a = np.where(lower, g, 0.0)  # a > b
    # sum_{a>b>c} = sum_{a,c} (A_strict @ A_strict)[a,c] * G[a,c]
    return jnp.asarray(np.sum((a @ a) * g))


def tet_coords(n: int) -> np.ndarray:
    """(T3, 3) table of tet_map(lam) for lam in [0, T3(n)) — built once and
    shared by gathers and multiplicity weights."""
    return np.array([M.tet_map(lam) for lam in range(M.tet(n))],
                    np.int64).reshape(M.tet(n), 3)


def combine_packed(packed: jnp.ndarray, n: int,
                   coords: np.ndarray | None = None) -> jnp.ndarray:
    """(T3, 1) packed unique-tile values -> multiplicity-weighted total."""
    if coords is None:
        coords = tet_coords(n)
    mult = np.array([tile_mult(i, j, k) for i, j, k in coords], np.float32)
    return jnp.sum(jnp.asarray(mult) * packed[:, 0])
