"""Pallas kernels for the 3-body triplet reduction over the tetrahedron.

Three kernels, mirroring tri_edm's LTM/BB/dummy trio one dimension up:
  three_body_tet — 1-D grid of T3 = tet(n) steps, tet_map index_map,
                   packed (T3, 1) output: one reduction per unique tile
                   triple k <= j <= i. The exact-map strategy.
  three_body_bb3 — n x n x n bounding-box grid with the block-coordinate
                   simplex guard; (n, n, n) output, ~5/6 of tiles dead.
  dummy_tet      — computes only the mapping and writes i+j+k, isolating
                   the cube-root map cost from the problem (the paper's
                   'dummy kernel' methodology in 3D).

Per tile triple the body is three (b, d) x (d, b) MXU contractions plus a
(b, b) x (b, b) product-and-reduce:
  A = Xi Xj^T, B = Xj Xk^T, C = Xi Xk^T,  s = sum((A @ B) * C).

TPU notes: d is padded to the lane width by Mosaic; block should be a
multiple of 8 (sublane) and ideally 128, as for tri_edm.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import mapping as M


def _triplet_tile(xi, xj, xk):
    xi = xi.astype(jnp.float32)
    xj = xj.astype(jnp.float32)
    xk = xk.astype(jnp.float32)
    dot = lambda u, v: jax.lax.dot_general(
        u, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    a = dot(xi, xj)  # (b, b) = G[I, J]
    b = dot(xj, xk)  # (b, b) = G[J, K]
    c = dot(xi, xk)  # (b, b) = G[I, K]
    ab = jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    return jnp.sum(ab * c)


def _tet_kernel(x_i_ref, x_j_ref, x_k_ref, out_ref):
    out_ref[0, 0] = _triplet_tile(x_i_ref[...], x_j_ref[...], x_k_ref[...])


def three_body_tet(x, block: int, *, interpret: bool = True):
    """x: (N, d) -> packed (T3, 1) unique-tile-triple reductions."""
    n_rows, d = x.shape
    assert n_rows % block == 0
    n = n_rows // block
    t3 = M.tet(n)
    return pl.pallas_call(
        _tet_kernel,
        grid=(t3,),
        in_specs=[
            pl.BlockSpec((block, d), lambda lam: (M.tet_map(lam)[0], 0)),
            pl.BlockSpec((block, d), lambda lam: (M.tet_map(lam)[1], 0)),
            pl.BlockSpec((block, d), lambda lam: (M.tet_map(lam)[2], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda lam: (lam, 0)),
        out_shape=jax.ShapeDtypeStruct((t3, 1), jnp.float32),
        interpret=interpret,
    )(x, x, x)


def _bb3_kernel(x_i_ref, x_j_ref, x_k_ref, out_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    inside = M.bb3_active(i, j, k)  # block-coordinate simplex guard

    @pl.when(inside)
    def _():
        out_ref[0, 0, 0] = _triplet_tile(
            x_i_ref[...], x_j_ref[...], x_k_ref[...])

    @pl.when(jnp.logical_not(inside))
    def _():
        out_ref[0, 0, 0] = 0.0


def three_body_bb3(x, block: int, *, interpret: bool = True):
    """BB-3D baseline: (n, n, n) output; tiles outside the simplex are
    launched and immediately guarded out — the O(n^3) waste the tet map
    eliminates."""
    n_rows, d = x.shape
    assert n_rows % block == 0
    n = n_rows // block
    return pl.pallas_call(
        _bb3_kernel,
        grid=(n, n, n),
        in_specs=[
            pl.BlockSpec((block, d), lambda i, j, k: (i, 0)),
            pl.BlockSpec((block, d), lambda i, j, k: (j, 0)),
            pl.BlockSpec((block, d), lambda i, j, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1), lambda i, j, k: (i, j, k)),
        out_shape=jax.ShapeDtypeStruct((n, n, n), jnp.float32),
        interpret=interpret,
    )(x, x, x)


def _dummy_kernel(out_ref):
    lam = pl.program_id(0)
    i, j, k = M.tet_map(lam)
    out_ref[...] = jnp.full_like(out_ref, (i + j + k).astype(jnp.float32))


def dummy_tet(n: int, *, interpret: bool = True):
    """3D dummy kernel: map lambda -> (i, j, k), write i+j+k. Pure mapping
    cost; one f32 per block."""
    t3 = M.tet(n)
    return pl.pallas_call(
        _dummy_kernel,
        grid=(t3,),
        out_specs=pl.BlockSpec((1, 1), lambda lam: (lam, 0)),
        out_shape=jax.ShapeDtypeStruct((t3, 1), jnp.float32),
        interpret=interpret,
    )()
