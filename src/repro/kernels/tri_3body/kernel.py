"""Pallas kernels for the 3-body triplet reduction over the tetrahedron.

Three kernels, mirroring tri_edm's LTM/BB/dummy trio one dimension up:
  three_body_tet — 1-D grid of T3 = tet(n) steps, tet_map index_map,
                   packed (T3, 1) output: one reduction per unique tile
                   triple k <= j <= i. The exact-map strategy.
  three_body_bb3 — n x n x n bounding-box grid with the block-coordinate
                   simplex guard; (n, n, n) output, ~5/6 of tiles dead.
  dummy_tet      — computes only the mapping and writes i+j+k, isolating
                   the cube-root map cost from the problem (the paper's
                   'dummy kernel' methodology in 3D).

Per tile triple the body is three (b, d) x (d, b) MXU contractions plus a
(b, b) x (b, b) product-and-reduce:
  A = Xi Xj^T, B = Xj Xk^T, C = Xi Xk^T,  s = sum((A @ B) * C).

strict=True enforces a > b > c over GLOBAL point indices in-kernel (not
post-hoc): A is masked to a > b and B to b > c before the product-reduce,
so each unordered triple of DISTINCT points is counted exactly once and
the total is the plain sum of the packed values (no multiset weights).
Off-diagonal tile triples (i > j > k) are unaffected — their masks are
all-ones by construction — so strictness only changes the O(n^2) diagonal
tiles, exactly the paper's intra-diagonal-masking observation one
dimension up.

TPU notes: d is padded to the lane width by Mosaic; block should be a
multiple of 8 (sublane) and ideally 128, as for tri_edm.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import mapping as M
from repro.obs import launch as OBS


def _strict_masks(i, j, k, blk: int):
    """(a > b, b > c) masks over global point indices for tile (i, j, k).

    All-ones whenever the tiles are distinct (i > j implies a > b for every
    a in tile i, b in tile j), so applying them unconditionally is exact
    and branch-free — only diagonal tiles are actually masked."""
    row = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
    m_ab = (i * blk + row) > (j * blk + col)
    m_bc = (j * blk + row) > (k * blk + col)
    return m_ab, m_bc


def _triplet_tile(xi, xj, xk, masks=None):
    xi = xi.astype(jnp.float32)
    xj = xj.astype(jnp.float32)
    xk = xk.astype(jnp.float32)
    dot = lambda u, v: jax.lax.dot_general(
        u, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    a = dot(xi, xj)  # (b, b) = G[I, J]
    b = dot(xj, xk)  # (b, b) = G[J, K]
    c = dot(xi, xk)  # (b, b) = G[I, K]
    if masks is not None:  # strict a > b > c (a > c follows)
        m_ab, m_bc = masks
        a = jnp.where(m_ab, a, 0.0)
        b = jnp.where(m_bc, b, 0.0)
    ab = jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    return jnp.sum(ab * c)


def _tet_kernel(x_i_ref, x_j_ref, x_k_ref, out_ref, *, block: int,
                strict: bool):
    lam = pl.program_id(0)
    i, j, k = M.tet_map(lam)
    masks = _strict_masks(i, j, k, block) if strict else None
    out_ref[0, 0] = _triplet_tile(x_i_ref[...], x_j_ref[...], x_k_ref[...],
                                  masks)


def three_body_tet(x, block: int, *, strict: bool = False,
                   interpret: bool = True):
    """x: (N, d) -> packed (T3, 1) unique-tile-triple reductions."""
    n_rows, d = x.shape
    assert n_rows % block == 0
    n = n_rows // block
    t3 = M.tet(n)
    # certified traced-cbrt envelope (repro.analysis.envelope derives it
    # from float error bounds; lint fails if the constant drifts)
    assert t3 - 1 <= M.TET_TRACED_MAX_LAM, (
        f"grid {t3} exceeds the certified tet_map int32 envelope "
        f"(max lam {M.TET_TRACED_MAX_LAM}); use a larger block")
    return OBS.instrumented_pallas_call(
        functools.partial(_tet_kernel, block=block, strict=strict),
        meta=OBS.meta_exact("tri_3body.tet", "tri_3body", impl="pallas",
                            kind="tet", steps=t3,
                            block_shape=(block, block, block),
                            bb_bound=n * n * n),
        grid=(t3,),
        in_specs=[
            pl.BlockSpec((block, d), lambda lam: (M.tet_map(lam)[0], 0)),
            pl.BlockSpec((block, d), lambda lam: (M.tet_map(lam)[1], 0)),
            pl.BlockSpec((block, d), lambda lam: (M.tet_map(lam)[2], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda lam: (lam, 0)),
        out_shape=jax.ShapeDtypeStruct((t3, 1), jnp.float32),
        interpret=interpret,
    )(x, x, x)


def _bb3_kernel(x_i_ref, x_j_ref, x_k_ref, out_ref, *, block: int,
                strict: bool):
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    inside = M.bb3_active(i, j, k)  # block-coordinate simplex guard

    @pl.when(inside)
    def _():
        masks = _strict_masks(i, j, k, block) if strict else None
        out_ref[0, 0, 0] = _triplet_tile(
            x_i_ref[...], x_j_ref[...], x_k_ref[...], masks)

    @pl.when(jnp.logical_not(inside))
    def _():
        out_ref[0, 0, 0] = 0.0


def three_body_bb3(x, block: int, *, strict: bool = False,
                   interpret: bool = True):
    """BB-3D baseline: (n, n, n) output; tiles outside the simplex are
    launched and immediately guarded out — the O(n^3) waste the tet map
    eliminates."""
    n_rows, d = x.shape
    assert n_rows % block == 0
    n = n_rows // block
    return OBS.instrumented_pallas_call(
        functools.partial(_bb3_kernel, block=block, strict=strict),
        meta=OBS.meta_dense("tri_3body.bb3", "tri_3body", impl="pallas",
                            grid=(n, n, n),
                            block_shape=(block, block, block),
                            tiles_domain=M.tet(n), kind="bb3"),
        grid=(n, n, n),
        in_specs=[
            pl.BlockSpec((block, d), lambda i, j, k: (i, 0)),
            pl.BlockSpec((block, d), lambda i, j, k: (j, 0)),
            pl.BlockSpec((block, d), lambda i, j, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1), lambda i, j, k: (i, j, k)),
        out_shape=jax.ShapeDtypeStruct((n, n, n), jnp.float32),
        interpret=interpret,
    )(x, x, x)


def _dummy_kernel(out_ref):
    lam = pl.program_id(0)
    i, j, k = M.tet_map(lam)
    out_ref[...] = jnp.full_like(out_ref, (i + j + k).astype(jnp.float32))


def dummy_tet(n: int, *, interpret: bool = True):
    """3D dummy kernel: map lambda -> (i, j, k), write i+j+k. Pure mapping
    cost; one f32 per block."""
    t3 = M.tet(n)
    return OBS.instrumented_pallas_call(
        _dummy_kernel,
        meta=OBS.meta_exact("tri_3body.dummy_tet", "tri_3body",
                            impl="pallas", kind="tet", steps=t3,
                            block_shape=(1, 1), bb_bound=n * n * n),
        grid=(t3,),
        out_specs=pl.BlockSpec((1, 1), lambda lam: (lam, 0)),
        out_shape=jax.ShapeDtypeStruct((t3, 1), jnp.float32),
        interpret=interpret,
    )()
