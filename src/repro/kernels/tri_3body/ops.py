"""Public 3-body op: packed tetrahedral triplet-interaction reduction.

impl='pallas'   — tet-grid Pallas kernel (interpret on CPU).
impl='scan'     — pure-XLA scan over the tet enumeration (fast CPU path).
impl='bb3_scan' — bounding-box baseline as a scan: n^3 steps, simplex
                  guard; wasted steps emit zeros (for benchmarks).
impl='bb3'      — bounding-box Pallas baseline ((n, n, n) output).
impl='ref'      — numpy oracle.

``three_body_total`` reduces the packed values to the total over all
ordered point triples using the multiset permutation weights — the
correctness anchor against the dense einsum oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mapping as M
from repro.kernels.tri_3body import kernel as K
from repro.kernels.tri_3body import ref as R


def _three_body_scan(x, block: int):
    """lax.scan over lambda with tet_map dynamic slicing (packed out)."""
    n_rows, d = x.shape
    n = n_rows // block
    t3 = M.tet(n)
    xf = x.astype(jnp.float32)

    def step(_, lam):
        i, j, k = M.tet_map(lam)
        sl = lambda t: jax.lax.dynamic_slice(xf, (t * block, 0), (block, d))
        xi, xj, xk = sl(i), sl(j), sl(k)
        a, b, c = xi @ xj.T, xj @ xk.T, xi @ xk.T
        return None, jnp.sum((a @ b) * c)

    _, vals = jax.lax.scan(step, None, jnp.arange(t3, dtype=jnp.int32))
    return vals[:, None]


def _three_body_scan_bb3(x, block: int):
    """BB-3D baseline as a scan: n^3 lambda steps, simplex steps guarded by
    the block-coordinate predicate; same packing semantics as tri_edm's
    bb_scan (dead steps emit zeros)."""
    n_rows, d = x.shape
    n = n_rows // block
    xf = x.astype(jnp.float32)

    def step(_, lam):
        i, j, k = M.bb3_map(lam, n)

        def active():
            sl = lambda t: jax.lax.dynamic_slice(
                xf, (t * block, 0), (block, d))
            xi, xj, xk = sl(i), sl(j), sl(k)
            a, b, c = xi @ xj.T, xj @ xk.T, xi @ xk.T
            return jnp.sum((a @ b) * c)

        return None, jax.lax.cond(M.bb3_active(i, j, k), active,
                                  lambda: 0.0)

    _, vals = jax.lax.scan(step, None,
                           jnp.arange(n * n * n, dtype=jnp.int32))
    return vals[:, None]


def three_body(x, block: int = 128, *, impl: str = "pallas",
               interpret: bool = True):
    """x: (N, d) points -> per-tile-triple reductions.

    Packed impls return (T3, 1); 'bb3' returns (n, n, n) with the simplex
    guard applied ('bb3_scan' returns (n^3, 1) with zeroed dead steps).
    """
    assert x.shape[0] % block == 0, (
        f"n_rows={x.shape[0]} must be a multiple of block={block}")
    if impl == "pallas":
        return K.three_body_tet(x, block, interpret=interpret)
    if impl == "scan":
        return _three_body_scan(x, block)
    if impl == "bb3_scan":
        return _three_body_scan_bb3(x, block)
    if impl == "bb3":
        return K.three_body_bb3(x, block, interpret=interpret)
    if impl == "ref":
        return R.three_body_packed_ref(x, block)
    raise ValueError(f"unknown impl {impl!r}")


def three_body_total(x, block: int = 128, *, impl: str = "pallas",
                     interpret: bool = True):
    """Total interaction over all ordered point triples, from the packed
    unique-tile launch (mult-weighted) — equals ref.three_body_total_ref.

    Works for every impl: the BB-3D layouts ((n,n,n) cube / (n^3, 1) flat)
    are gathered down to the packed (T3, 1) order first, so the baseline
    totals are comparable to the tet launch. The host-side coords table is
    enumerated once and shared with the multiplicity weights."""
    n = x.shape[0] // block
    out = three_body(x, block, impl=impl, interpret=interpret)
    coords = R.tet_coords(n)
    if impl == "bb3":
        packed = out[coords[:, 0], coords[:, 1], coords[:, 2]][:, None]
    elif impl == "bb3_scan":
        lin = (coords[:, 0] * n + coords[:, 1]) * n + coords[:, 2]
        packed = out[lin]
    else:
        packed = out
    return R.combine_packed(packed, n, coords)
