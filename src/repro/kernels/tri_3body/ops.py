"""Public 3-body op: packed tetrahedral triplet-interaction reduction.

impl='pallas'   — tet-grid Pallas kernel (interpret on CPU).
impl='scan'     — pure-XLA scan over the tet enumeration (fast CPU path).
impl='bb3_scan' — bounding-box baseline as a scan: n^3 steps, simplex
                  guard; wasted steps emit zeros (for benchmarks).
impl='bb3'      — bounding-box Pallas baseline ((n, n, n) output).
impl='ref'      — numpy oracle.

``three_body_total`` reduces the packed values to the total over all
ordered point triples using the multiset permutation weights — the
correctness anchor against the dense einsum oracle.

strict=True (all impls) masks non-strictly-ordered point triples INSIDE
the kernel (a > b > c over global indices; only diagonal tiles i==j or
j==k are affected) so each unordered triple of distinct points is counted
exactly once — the physics-kernel semantics (e.g. Axilrod–Teller). The
strict total is then the plain sum of the packed values, checked against
ref.three_body_total_strict_ref.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mapping as M
from repro.kernels.tri_3body import kernel as K
from repro.kernels.tri_3body import ref as R
from repro.obs import launch as OBS


def _tile_body(xi, xj, xk, i, j, k, block: int, strict: bool):
    a, b, c = xi @ xj.T, xj @ xk.T, xi @ xk.T
    if strict:
        m_ab, m_bc = K._strict_masks(i, j, k, block)
        a = jnp.where(m_ab, a, 0.0)
        b = jnp.where(m_bc, b, 0.0)
    return jnp.sum((a @ b) * c)


def _three_body_scan(x, block: int, strict: bool = False):
    """lax.scan over lambda with tet_map dynamic slicing (packed out)."""
    n_rows, d = x.shape
    n = n_rows // block
    t3 = M.tet(n)
    OBS.record_launch(
        OBS.meta_exact("tri_3body.tet", "tri_3body", impl="scan",
                       kind="tet", steps=t3,
                       block_shape=(block, block, block),
                       bb_bound=n * n * n), (x,))
    xf = x.astype(jnp.float32)

    def step(_, lam):
        i, j, k = M.tet_map(lam)
        sl = lambda t: jax.lax.dynamic_slice(xf, (t * block, 0), (block, d))
        return None, _tile_body(sl(i), sl(j), sl(k), i, j, k, block, strict)

    _, vals = jax.lax.scan(step, None, jnp.arange(t3, dtype=jnp.int32))
    return vals[:, None]


def _three_body_scan_bb3(x, block: int, strict: bool = False):
    """BB-3D baseline as a scan: n^3 lambda steps, simplex steps guarded by
    the block-coordinate predicate; same packing semantics as tri_edm's
    bb_scan (dead steps emit zeros)."""
    n_rows, d = x.shape
    n = n_rows // block
    OBS.record_launch(
        OBS.meta_dense("tri_3body.bb3", "tri_3body", impl="scan",
                       grid=(n, n, n), block_shape=(block, block, block),
                       tiles_domain=M.tet(n), kind="bb3"), (x,))
    xf = x.astype(jnp.float32)

    def step(_, lam):
        i, j, k = M.bb3_map(lam, n)

        def active():
            sl = lambda t: jax.lax.dynamic_slice(
                xf, (t * block, 0), (block, d))
            return _tile_body(sl(i), sl(j), sl(k), i, j, k, block, strict)

        return None, jax.lax.cond(M.bb3_active(i, j, k), active,
                                  lambda: 0.0)

    _, vals = jax.lax.scan(step, None,
                           jnp.arange(n * n * n, dtype=jnp.int32))
    return vals[:, None]


def three_body(x, block: int = 128, *, impl: str = "pallas",
               strict: bool = False, interpret: bool = True):
    """x: (N, d) points -> per-tile-triple reductions.

    Packed impls return (T3, 1); 'bb3' returns (n, n, n) with the simplex
    guard applied ('bb3_scan' returns (n^3, 1) with zeroed dead steps).
    strict=True masks to a > b > c in-kernel (distinct-point semantics).
    """
    assert x.shape[0] % block == 0, (
        f"n_rows={x.shape[0]} must be a multiple of block={block}")
    if impl == "pallas":
        return K.three_body_tet(x, block, strict=strict, interpret=interpret)
    if impl == "scan":
        return _three_body_scan(x, block, strict)
    if impl == "bb3_scan":
        return _three_body_scan_bb3(x, block, strict)
    if impl == "bb3":
        return K.three_body_bb3(x, block, strict=strict, interpret=interpret)
    if impl == "ref":
        return R.three_body_packed_ref(x, block, strict=strict)
    raise ValueError(f"unknown impl {impl!r}")


def three_body_total(x, block: int = 128, *, impl: str = "pallas",
                     strict: bool = False, interpret: bool = True):
    """Total triplet interaction, from the packed unique-tile launch.

    strict=False: multiset-permutation-weighted total over ALL ordered
    point triples — equals ref.three_body_total_ref. strict=True: each
    unordered triple of distinct points once (in-kernel a > b > c masking),
    so the total is the plain SUM of the packed values — equals
    ref.three_body_total_strict_ref. No post-hoc diagonal correction in
    either case.

    Works for every impl: the BB-3D layouts ((n,n,n) cube / (n^3, 1) flat)
    are gathered down to the packed (T3, 1) order first, so the baseline
    totals are comparable to the tet launch. The host-side coords table is
    enumerated once and shared with the multiplicity weights."""
    n = x.shape[0] // block
    out = three_body(x, block, impl=impl, strict=strict, interpret=interpret)
    coords = R.tet_coords(n)
    if impl == "bb3":
        packed = out[coords[:, 0], coords[:, 1], coords[:, 2]][:, None]
    elif impl == "bb3_scan":
        lin = (coords[:, 0] * n + coords[:, 1]) * n + coords[:, 2]
        packed = out[lin]
    else:
        packed = out
    if strict:
        return jnp.sum(packed[:, 0])
    return R.combine_packed(packed, n, coords)
