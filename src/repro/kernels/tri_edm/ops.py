"""Public EDM op: packed triangular Euclidean distance matrix.

impl='pallas' — LTM Pallas kernel (interpret on CPU).
impl='scan'   — pure-XLA scan over the LTM enumeration (fast CPU path used
                by the paper-reproduction benchmarks at large N).
impl='bb'     — bounding-box Pallas baseline (full output).
impl='ref'    — oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mapping as M
from repro.kernels.tri_edm import kernel as K
from repro.kernels.tri_edm import ref as R
from repro.obs import launch as OBS


def _edm_scan(x, block: int, *, squared: bool = False):
    """lax.scan over lambda with g(lambda) dynamic slicing (packed out)."""
    n_rows, d = x.shape
    n = n_rows // block
    t = M.tri(n)
    OBS.record_launch(
        OBS.meta_exact("tri_edm.ltm", "tri_edm", impl="scan", kind="ltm",
                       steps=t, block_shape=(block, block),
                       bb_bound=n * n), (x,))
    xf = x.astype(jnp.float32)
    sq = jnp.sum(xf * xf, axis=-1)

    def step(_, lam):
        i, j = M.ltm_map(lam)
        xi = jax.lax.dynamic_slice(xf, (i * block, 0), (block, d))
        xj = jax.lax.dynamic_slice(xf, (j * block, 0), (block, d))
        si = jax.lax.dynamic_slice(sq, (i * block,), (block,))
        sj = jax.lax.dynamic_slice(sq, (j * block,), (block,))
        d2 = jnp.maximum(si[:, None] + sj[None, :] - 2.0 * (xi @ xj.T), 0.0)
        r = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
        c = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
        d2 = jnp.where((i == j) & (r == c), 0.0, d2)  # exact self-distance
        return None, (d2 if squared else jnp.sqrt(d2))

    _, blocks = jax.lax.scan(step, None, jnp.arange(t, dtype=jnp.int32))
    return blocks


def _edm_scan_bb(x, block: int, *, squared: bool = False):
    """Bounding-box baseline as a scan: n*n lambda steps, upper-triangle
    steps guarded out by a block-coordinate predicate (the paper's optimized
    BB). Same output packing as LTM for a fair comparison: wasted steps
    emit zeros."""
    n_rows, d = x.shape
    n = n_rows // block
    OBS.record_launch(
        OBS.meta_dense("tri_edm.bb", "tri_edm", impl="scan", grid=(n, n),
                       block_shape=(block, block), tiles_domain=M.tri(n)),
        (x,))
    xf = x.astype(jnp.float32)
    sq = jnp.sum(xf * xf, axis=-1)

    def step(_, lam):
        i, j = lam // n, lam % n

        def active():
            xi = jax.lax.dynamic_slice(xf, (i * block, 0), (block, d))
            xj = jax.lax.dynamic_slice(xf, (j * block, 0), (block, d))
            si = jax.lax.dynamic_slice(sq, (i * block,), (block,))
            sj = jax.lax.dynamic_slice(sq, (j * block,), (block,))
            d2 = jnp.maximum(si[:, None] + sj[None, :] - 2.0 * (xi @ xj.T),
                             0.0)
            r = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
            c = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
            d2_ = jnp.where((i == j) & (r == c), 0.0, d2)
            return d2_ if squared else jnp.sqrt(d2_)

        # paper's optimized BB: discard by block coords before thread work
        return None, jax.lax.cond(
            j <= i, active, lambda: jnp.zeros((block, block), jnp.float32))

    _, blocks = jax.lax.scan(step, None,
                             jnp.arange(n * n, dtype=jnp.int32))
    return blocks


def edm(x, block: int = 128, *, squared: bool = False, impl: str = "pallas",
        interpret: bool = True):
    """x: (N, d) features -> EDM.

    Packed impls return (T, block, block); 'bb'/'ref' return full/guarded
    grids ('bb_scan' returns (n*n, block, block) with zeroed dead tiles).
    """
    if impl == "pallas":
        return K.edm_ltm(x, block, squared=squared, interpret=interpret)
    if impl == "scan":
        return _edm_scan(x, block, squared=squared)
    if impl == "bb_scan":
        return _edm_scan_bb(x, block, squared=squared)
    if impl == "bb":
        return K.edm_bb(x, block, squared=squared, interpret=interpret)
    if impl == "ref":
        return R.edm_full(x, squared=squared)
    raise ValueError(f"unknown impl {impl!r}")


pack_tri = R.pack_tri
unpack_tri = R.unpack_tri
