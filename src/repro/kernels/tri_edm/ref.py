"""Pure-jnp oracle for the Euclidean distance matrix (paper §IV eq. 17)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import mapping as M


def edm_full(x: jnp.ndarray, *, squared: bool = False) -> jnp.ndarray:
    """x: (N, d) -> (N, N) pairwise Euclidean distances (f32)."""
    x = x.astype(jnp.float32)
    sq = jnp.sum(x * x, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    d2 = jnp.maximum(d2, 0.0)
    n = x.shape[0]
    d2 = jnp.where(jnp.eye(n, dtype=bool), 0.0, d2)  # exact self-distance
    return d2 if squared else jnp.sqrt(d2)


def pack_tri(full: jnp.ndarray, block: int) -> jnp.ndarray:
    """(N, N) -> block-packed lower-tri storage (T, block, block).

    Block lambda holds full[i*b:(i+1)*b, j*b:(j+1)*b] with (i,j)=g(lambda).
    This is the Gustavson/Jung packed layout the paper cites — ~half the
    memory of the full matrix.
    """
    n = full.shape[0] // block
    t = M.tri(n)
    ii = np.empty(t, np.int32)
    jj = np.empty(t, np.int32)
    for lam in range(t):
        ii[lam], jj[lam] = M.ltm_map(lam)
    blocks = full.reshape(n, block, n, block).transpose(0, 2, 1, 3)
    return blocks[ii, jj]


def unpack_tri(packed: jnp.ndarray, n_rows: int, *,
               symmetric: bool = True) -> jnp.ndarray:
    """(T, b, b) -> (N, N); upper triangle mirrored if symmetric else 0."""
    t, b, _ = packed.shape
    n = n_rows // b
    assert M.tri(n) == t
    full = np.zeros((n, n, b, b), np.float32)
    for lam in range(t):
        i, j = M.ltm_map(lam)
        full[i, j] = packed[lam]
        if symmetric and i != j:
            full[j, i] = packed[lam].T
    out = jnp.asarray(full.transpose(0, 2, 1, 3).reshape(n_rows, n_rows))
    if symmetric:
        return out
    return out


def edm_packed_ref(x: jnp.ndarray, block: int, *, squared: bool = False):
    """Oracle for the packed kernels: pack_tri(edm_full(x))."""
    return pack_tri(edm_full(x, squared=squared), block)
