"""Pallas kernels for the paper's EDM benchmark (§IV).

Three kernels:
  edm_ltm    — 1-D triangular grid of T = tri(n) steps, g(lambda) index_map,
               block-packed output (T, b, b). The paper's LTM strategy.
  edm_bb     — n x n bounding-box grid with the paper's optimized block-level
               guard; full (N, N) output, upper tiles dead. The BB baseline.
  dummy_ltm  — the paper's 'dummy kernel': computes only the mapping and
               writes i+j, isolating the mapping cost from the problem.

TPU notes: feature dim d is padded to the lane width by Mosaic (the paper
uses d in 1..4); block should be a multiple of 8 (sublane) and ideally 128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import mapping as M
from repro.obs import launch as OBS


def _edm_tile(xi, xj, i, j, *, squared: bool):
    xi = xi.astype(jnp.float32)
    xj = xj.astype(jnp.float32)
    sqi = jnp.sum(xi * xi, axis=-1, keepdims=True)  # (b, 1)
    sqj = jnp.sum(xj * xj, axis=-1, keepdims=True)  # (b, 1)
    cross = jax.lax.dot_general(xi, xj, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    d2 = jnp.maximum(sqi + sqj.T - 2.0 * cross, 0.0)
    # exact zero self-distance on diagonal tiles (a+b-2ab roundoff otherwise
    # survives the sqrt as ~sqrt(eps)*|x|)
    b = d2.shape[0]
    r = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    d2 = jnp.where((i == j) & (r == c), 0.0, d2)
    return d2 if squared else jnp.sqrt(d2)


def _ltm_kernel(x_i_ref, x_j_ref, out_ref, *, squared: bool):
    lam = pl.program_id(0)
    i, j = M.ltm_map(lam)
    out_ref[0] = _edm_tile(x_i_ref[...], x_j_ref[...], i, j, squared=squared)


def edm_ltm(x, block: int, *, squared: bool = False, interpret: bool = True):
    """x: (N, d) -> packed (T, block, block) lower-tri EDM blocks."""
    n_rows, d = x.shape
    assert n_rows % block == 0
    n = n_rows // block
    t = M.tri(n)
    # certified traced-isqrt envelope (see repro.analysis.envelope)
    assert t - 1 <= M.LTM_TRACED_MAX_LAM, (
        f"grid {t} exceeds the certified ltm_map int32 envelope "
        f"(max lam {M.LTM_TRACED_MAX_LAM}); use a larger block")
    return OBS.instrumented_pallas_call(
        functools.partial(_ltm_kernel, squared=squared),
        meta=OBS.meta_exact("tri_edm.ltm", "tri_edm", impl="pallas",
                            kind="ltm", steps=t, block_shape=(block, block),
                            bb_bound=n * n),
        grid=(t,),
        in_specs=[
            pl.BlockSpec((block, d), lambda lam: (M.ltm_map(lam)[0], 0)),
            pl.BlockSpec((block, d), lambda lam: (M.ltm_map(lam)[1], 0)),
        ],
        out_specs=pl.BlockSpec((1, block, block), lambda lam: (lam, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, block, block), jnp.float32),
        interpret=interpret,
    )(x, x)


def _bb_kernel(x_i_ref, x_j_ref, out_ref, *, squared: bool):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j <= i)  # paper's optimized BB: block-coordinate guard
    def _():
        out_ref[...] = _edm_tile(x_i_ref[...], x_j_ref[...], i, j,
                                 squared=squared)

    @pl.when(j > i)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)


def edm_bb(x, block: int, *, squared: bool = False, interpret: bool = True):
    """BB baseline: full (N, N) output; tiles with j > i are wasted work."""
    n_rows, d = x.shape
    assert n_rows % block == 0
    n = n_rows // block
    return OBS.instrumented_pallas_call(
        functools.partial(_bb_kernel, squared=squared),
        meta=OBS.meta_dense("tri_edm.bb", "tri_edm", impl="pallas",
                            grid=(n, n), block_shape=(block, block),
                            tiles_domain=M.tri(n)),
        grid=(n, n),
        in_specs=[
            pl.BlockSpec((block, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_rows, n_rows), jnp.float32),
        interpret=interpret,
    )(x, x)


def _dummy_kernel(out_ref):
    lam = pl.program_id(0)
    i, j = M.ltm_map(lam)
    out_ref[...] = jnp.full_like(out_ref, (i + j).astype(jnp.float32))


def dummy_ltm(n: int, *, interpret: bool = True):
    """Paper's dummy kernel: map lambda -> (i, j), write i+j. Pure mapping
    cost; one f32 per block."""
    t = M.tri(n)
    return OBS.instrumented_pallas_call(
        _dummy_kernel,
        meta=OBS.meta_exact("tri_edm.dummy_ltm", "tri_edm", impl="pallas",
                            kind="ltm", steps=t, block_shape=(1, 1),
                            bb_bound=n * n),
        grid=(t,),
        out_specs=pl.BlockSpec((1, 1), lambda lam: (lam, 0)),
        out_shape=jax.ShapeDtypeStruct((t, 1), jnp.float32),
        interpret=interpret,
    )()
