"""Pure-jnp oracle for triangular-domain attention (causal / band / prefix).

This is the correctness reference for both the Pallas kernel (kernel.py) and
the scan implementation (scan_impl.py). It materializes the full S x S score
matrix — O(S^2) memory — so it is only usable at test scale.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = float(jnp.finfo(jnp.float32).min)


def attention_mask(s_q: int, s_k: int, *, window=None, prefix: int = 0,
                   q_offset: int = 0):
    """Boolean (s_q, s_k) mask. True = attend.

    causal:  k_pos <= q_pos
    window:  additionally q_pos - k_pos < window   (sliding window, SWA)
    prefix:  OR k_pos < prefix                     (bidirectional prefix, VLM)
    q_offset shifts query positions (decode / chunked prefill).
    """
    qp = jnp.arange(s_q)[:, None] + q_offset
    kp = jnp.arange(s_k)[None, :]
    m = kp <= qp
    if window is not None:
        m &= (qp - kp) < window
    if prefix:
        m |= kp < prefix
    return m


def repeat_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B, Hkv, S, D) -> (B, H, S, D) by repeating each kv head G times."""
    b, hkv, s, d = k.shape
    g = n_heads // hkv
    return jnp.repeat(k, g, axis=1) if g > 1 else k


def mha_reference(q, k, v, *, sm_scale=None, window=None, prefix: int = 0,
                  q_offset: int = 0, return_lse: bool = False):
    """Masked multi-head attention oracle.

    q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D) with H % Hkv == 0.
    Returns out (B, H, Sq, D) [and lse (B, H, Sq) if return_lse].
    """
    b, h, sq, d = q.shape
    sk = k.shape[2]
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    k = repeat_kv(k, h)
    v = repeat_kv(v, h)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = attention_mask(sq, sk, window=window, prefix=prefix,
                          q_offset=q_offset)
    s = jnp.where(mask[None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p / l, v.astype(jnp.float32))
    out = out.astype(q.dtype)
    if return_lse:
        lse = (m[..., 0] + jnp.log(l[..., 0]))
        return out, lse
    return out
