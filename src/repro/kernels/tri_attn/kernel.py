"""Pallas TPU kernels: flash attention over triangular-domain 1-D grids.

The paper's g(lambda) becomes the BlockSpec index_map: the forward (and dq
backward) iterate a 1-D grid of T = tri(n) steps enumerated ROW-major (the
LTM order), the dk/dv backward iterates COLUMN-major (cm_map) so per-column
accumulators stay resident in VMEM scratch. Wasted tiles: zero off-diagonal
(vs. the BB baseline's n(n-1)/2), only intra-tile masking on boundary tiles
remains — exactly the paper's O(n^2) -> O(n) claim at tile granularity.

Schedules: 'ltm' (causal), 'band' (sliding window, beyond-paper), 'prefix'
(VLM prefix-causal, beyond-paper). 'bb' is the paper's bounding-box baseline
(2-D grid + block-level guard). PackedTriSched/packed_fwd extend the same
machinery to the CONCATENATION of R ragged requests: one 1-D grid of
sum_r blocks_r steps whose (7, R) member table rides in scalar-prefetch
SMEM (core/packing.py supplies the O(log R) request search).
packed_fwd's training counterpart packed_bwd walks the SAME member table
twice (dq row-major, dk/dv column-major) so jax.grad through a ragged
document batch is one launch per direction. packed_decode_fwd is the
single-token variant — one mixed-position decode round per launch, the
(5, R) RUNTIME member table (incl. band-limited kv_first) in
scalar-prefetch SMEM over a bucketed static capacity.

All kernels accumulate in f32 VMEM scratch and are validated in interpret
mode against ref.py (tests/test_kernels_tri_attn.py). TPU notes: block_q and
block_k should be multiples of 128 (MXU); head_dim 64/128/192 all lower (192
pads lanes).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import mapping as M
from repro.obs import launch as OBS

MASK_VALUE = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Schedule parameterization shared by fwd / dq / dkv kernels
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TriSched:
    """Static schedule metadata for one attention call (bq == bk required
    for triangular/band kinds so the tile domain is square)."""

    kind: str  # 'ltm' | 'band' | 'prefix'
    n: int  # tiles per side
    bq: int
    bk: int
    window: Optional[int] = None  # tokens (band)
    prefix: int = 0  # tokens (prefix)

    def __post_init__(self):
        assert self.kind in ("ltm", "band", "prefix")
        if self.kind == "band":
            assert self.window is not None and self.window >= 1
            assert self.bq == self.bk

    @property
    def w_b(self) -> int:
        """Band width in tiles: tile j needed iff exists q,k in tiles with
        0 <= q-k < window  =>  j >= i - ((window-2)//bk + 1)."""
        if self.window is None:
            return self.n
        return min((self.window - 2) // self.bk + 2, self.n)

    @property
    def p_b(self) -> int:
        return -(-self.prefix // self.bk) if self.prefix else 0

    # ---- row-major enumeration (forward, dq) -----------------------------
    @property
    def rm_steps(self) -> int:
        if self.kind == "ltm":
            return M.tri(self.n)
        if self.kind == "band":
            return M.band_blocks(self.n, self.w_b)
        return M.prefix_full_blocks(self.n, self.p_b)

    def rm_map(self, lam):
        if self.kind == "ltm":
            return M.ltm_map(lam)
        if self.kind == "band":
            return M.band_map(lam, self.w_b)
        return M.prefix_full_map(lam, self.n, self.p_b)

    def rm_first_col(self, i):
        if self.kind == "band":
            return jnp.maximum(0, i - self.w_b + 1)
        return i * 0

    def rm_last_col(self, i):
        if self.kind == "prefix":
            return jnp.maximum(i, self.p_b - 1)
        return i

    # ---- column-major enumeration (dk/dv) --------------------------------
    @property
    def cm_steps(self) -> int:
        return self.rm_steps  # same domain, different order

    def cm_map(self, lam):
        if self.kind == "ltm":
            return M.cm_map(lam, self.n)
        if self.kind == "band":
            return M.band_cm_map(lam, self.n, self.w_b)
        return M.prefix_cm_map(lam, self.n, self.p_b)

    def cm_first_row(self, j):
        if self.kind == "prefix":
            return jnp.where(j < self.p_b, 0, j)
        return j

    def cm_last_row(self, j):
        if self.kind == "band":
            return jnp.minimum(j + self.w_b - 1, self.n - 1)
        return jnp.full_like(j, self.n - 1) if not isinstance(j, int) else self.n - 1


def _token_mask(sched: TriSched, i, j, bq, bk):
    """(bq, bk) boolean mask for tile (i, j): True = attend."""
    qp = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kp = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = kp <= qp
    if sched.window is not None:
        m &= (qp - kp) < sched.window
    if sched.prefix:
        m |= kp < sched.prefix
    return m


# ---------------------------------------------------------------------------
# Packed multi-request schedule (ragged prefill) — core/packing.py lifted to
# token-mask level. All members share one square block edge; the packed
# operand is the concatenation of the members' sequences along S.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PackedTriSched:
    """Static metadata for ONE packed ragged-attention launch.

    members[r] describes request r's own domain (kind/n/window/prefix, all
    in that request's local coordinates). Request r's tokens occupy packed
    rows [tok_offsets[r], tok_offsets[r+1]); its tiles occupy packed grid
    steps [offsets[r], offsets[r+1]) of the single 1-D lambda grid.
    """

    members: tuple  # Tuple[TriSched, ...]

    def __post_init__(self):
        assert self.members, "packed schedule needs at least one member"
        blk = self.members[0].bq
        for m in self.members:
            assert m.bq == m.bk == blk, (
                "packed members must share one square block edge")

    @property
    def blk(self) -> int:
        return self.members[0].bq

    @property
    def steps(self) -> int:
        return sum(m.rm_steps for m in self.members)

    @property
    def total_tiles(self) -> int:
        return sum(m.n for m in self.members)

    @property
    def s_total(self) -> int:
        return self.total_tiles * self.blk

    @property
    def windows(self) -> tuple:
        """Per-request window in TOKENS; 0 = unwindowed."""
        return tuple(m.window or 0 for m in self.members)

    @property
    def prefixes(self) -> tuple:
        """Per-request bidirectional prefix in TOKENS; 0 = none."""
        return tuple(m.prefix for m in self.members)

    def table(self):
        """(7, R) int32 member table — the ONLY dynamic state the packed
        kernel needs, shipped to SMEM via scalar prefetch (index_maps must
        not capture constants). Rows 0/1 are the kernel-layer mirror of
        core PackedSchedule.offsets/row_offsets (same cumulative layout,
        see core/packing.py); rows 5/6 add the token-level mask params the
        block-coordinate core has no business knowing. Rows:
          0 starts   cumulative block offsets (offsets[:-1])
          1 rows     cumulative tile-row offsets into the packed operand
          2 n        member tiles per side
          3 w_b      band-family width in tiles (== n for unbanded)
          4 p_b      prefix width in tiles (0 = band family)
          5 win      window in tokens (0 = unwindowed)
          6 pre      prefix in tokens (0 = none)
        """
        import numpy as np

        starts, rows = [0], [0]
        for m in self.members:
            starts.append(starts[-1] + m.rm_steps)
            rows.append(rows[-1] + m.n)
        cols = [(s, t, m.n, m.w_b, m.p_b, w, p)
                for s, t, m, w, p in zip(starts[:-1], rows[:-1], self.members,
                                         self.windows, self.prefixes)]
        return np.asarray(cols, np.int32).T.copy()


class _TableRow:
    """Scalar-indexable view of one row of the member table; adapts both a
    (7, R) array and a Pallas SMEM Ref to packing's ``starts[mid]`` API."""

    def __init__(self, tbl, row: int):
        self._tbl, self._row = tbl, row

    def __getitem__(self, idx):
        return self._tbl[self._row, idx]


def _packed_decode(lam, tbl, n_requests: int):
    """lambda + member table -> (r, i, j, q_row, k_row); tbl is the (7, R)
    table as array or SMEM ref. O(log R) search + O(1) map (core/packing)."""
    from repro.core import packing as PK

    r = PK.request_from_starts(lam, _TableRow(tbl, 0), n_requests)
    local = lam - tbl[0, r]
    i, j = PK.member_map_params(local, tbl[2, r], tbl[3, r], tbl[4, r])
    return r, i, j, tbl[1, r] + i, tbl[1, r] + j


def _packed_token_mask(i, j, blk, win, pre):
    """(blk, blk) mask for one member tile (i, j): causal + the member's
    window/prefix (request-LOCAL token positions; win/pre traced scalars)."""
    qp = i * blk + jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
    kp = j * blk + jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
    m = kp <= qp
    m &= (qp - kp) < jnp.where(win > 0, win, jnp.int32(2 ** 30))
    m |= kp < pre
    return m


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_s, l_s, acc_s, *,
                sched: TriSched, scale: float):
    lam = pl.program_id(2)
    i, j = sched.rm_map(lam)

    @pl.when(j == sched.rm_first_col(i))
    def _init():
        m_s[...] = jnp.full_like(m_s, MASK_VALUE)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(_token_mask(sched, i, j, sched.bq, sched.bk), s, MASK_VALUE)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(j == sched.rm_last_col(i))
    def _emit():
        l = l_s[...]
        o_ref[0, 0] = (acc_s[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_s[...] + jnp.log(l))[:, 0].astype(lse_ref.dtype)


def fwd(q, k, v, sched: TriSched, *, sm_scale=None, interpret=True):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D). Returns (out, lse)."""
    b, h, s_len, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    bq, bk, n = sched.bq, sched.bk, sched.n
    assert n * bq == s_len and n * bk == s_len

    grid = (b, h, sched.rm_steps)
    rm_i = lambda lam: sched.rm_map(lam)[0]
    rm_j = lambda lam: sched.rm_map(lam)[1]
    kernel = functools.partial(_fwd_kernel, sched=sched, scale=scale)
    out, lse = OBS.instrumented_pallas_call(
        kernel,
        meta=OBS.meta_from_trisched("tri_attn.fwd", sched, impl="pallas",
                                    cells=b * h, grid=grid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, lam: (b_, h_, rm_i(lam), 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, lam: (b_, h_ // g, rm_j(lam), 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, lam: (b_, h_ // g, rm_j(lam), 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, lam: (b_, h_, rm_i(lam), 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h_, lam: (b_, h_, rm_i(lam))),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((b, h, s_len), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# Packed forward: ONE 1-D grid over the concatenation of R ragged requests.
# The per-request binary search + both closed-form member maps run on the
# scalar core each grid step (O(log R) + O(1)); on real TPU the offset
# tables could move to scalar-prefetch SMEM (PrefetchScalarGridSpec), but
# for R <= slot counts the baked-constant gathers are equivalent.
# ---------------------------------------------------------------------------


def _packed_fwd_kernel(tbl_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                       m_s, l_s, acc_s, *, psched: PackedTriSched,
                       scale: float):
    from repro.core import packing as PK

    lam = pl.program_id(2)
    r, i, j, _, _ = _packed_decode(lam, tbl_ref, len(psched.members))
    first_col = PK.first_col_params(i, tbl_ref[3, r])
    last_col = PK.last_col_params(i, tbl_ref[4, r])

    @pl.when(j == first_col)
    def _init():
        m_s[...] = jnp.full_like(m_s, MASK_VALUE)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(
        _packed_token_mask(i, j, psched.blk, tbl_ref[5, r], tbl_ref[6, r]),
        s, MASK_VALUE)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(j == last_col)
    def _emit():
        l = l_s[...]
        o_ref[0, 0] = (acc_s[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_s[...] + jnp.log(l))[:, 0].astype(lse_ref.dtype)


def packed_fwd(q, k, v, psched: PackedTriSched, *, sm_scale=None,
               interpret=True):
    """Ragged batched prefill in ONE launch.

    q: (B, H, S_total, D); k, v: (B, Hkv, S_total, D) — all requests'
    sequences concatenated along S (each padded to a multiple of blk).
    Grid is (B, H, sum_r member_blocks): zero interior waste, no
    cross-request tiles. The (7, R) member table rides in via scalar
    prefetch (SMEM), so index_maps and body share one O(log R) decode.
    Returns (out, lse) in the packed layout.
    """
    import numpy as np

    b, h, s_len, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    assert s_len == psched.s_total, (s_len, psched.s_total)
    blk = psched.blk
    n_req = len(psched.members)
    tbl = np.ascontiguousarray(psched.table())

    def q_spec(b_, h_, lam, tbl_):
        _, _, _, q_row, _ = _packed_decode(lam, tbl_, n_req)
        return (b_, h_, q_row, 0)

    def kv_spec(b_, h_, lam, tbl_):
        _, _, _, _, k_row = _packed_decode(lam, tbl_, n_req)
        return (b_, h_ // g, k_row, 0)

    def lse_spec(b_, h_, lam, tbl_):
        _, _, _, q_row, _ = _packed_decode(lam, tbl_, n_req)
        return (b_, h_, q_row)

    kernel = functools.partial(_packed_fwd_kernel, psched=psched, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h, psched.steps),
        in_specs=[
            pl.BlockSpec((1, 1, blk, d), q_spec),
            pl.BlockSpec((1, 1, blk, d), kv_spec),
            pl.BlockSpec((1, 1, blk, d), kv_spec),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, blk, d), q_spec),
            pl.BlockSpec((1, 1, blk), lse_spec),
        ],
        scratch_shapes=[
            pltpu.VMEM((blk, 1), jnp.float32),
            pltpu.VMEM((blk, 1), jnp.float32),
            pltpu.VMEM((blk, d), jnp.float32),
        ],
    )
    out, lse = OBS.instrumented_pallas_call(
        kernel,
        meta=OBS.meta_from_packed("tri_attn.packed_fwd", psched,
                                  impl="pallas", cells=b * h,
                                  grid=(b, h, psched.steps)),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((b, h, s_len), jnp.float32),
        ],
        interpret=interpret,
    )(tbl, q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# Packed backward: the training-side counterpart of packed_fwd. dq re-walks
# the ROW-major packed grid (same enumeration as the forward, per-row dq
# accumulator); dk/dv walk the COLUMN-major enumeration of every member
# (core/packing.member_cm_map_params) so per-column accumulators stay
# resident in VMEM scratch across the member's rows. Both directions share
# the forward's (7, R) member table — rm_steps == cm_steps per member, so
# the cumulative ``starts`` row delegates identically.
# ---------------------------------------------------------------------------


def _packed_decode_cm(lam, tbl, n_requests: int):
    """Column-major packed decode: lambda + (7, R) table ->
    (r, i, j, q_row, k_row). Same O(log R) search as _packed_decode; the
    member map is the column-major two-family closed form."""
    from repro.core import packing as PK

    r = PK.request_from_starts(lam, _TableRow(tbl, 0), n_requests)
    local = lam - tbl[0, r]
    i, j = PK.member_cm_map_params(local, tbl[2, r], tbl[3, r], tbl[4, r])
    return r, i, j, tbl[1, r] + i, tbl[1, r] + j


def _packed_dq_kernel(tbl_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
                      delta_ref, dq_ref, dq_s, *, n_requests: int, blk: int,
                      scale: float):
    from repro.core import packing as PK

    lam = pl.program_id(2)
    r, i, j, _, _ = _packed_decode(lam, tbl_ref, n_requests)

    @pl.when(j == PK.first_col_params(i, tbl_ref[3, r]))
    def _init():
        dq_s[...] = jnp.zeros_like(dq_s)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)[:, None]
    delta = delta_ref[0, 0].astype(jnp.float32)[:, None]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(_packed_token_mask(i, j, blk, tbl_ref[5, r], tbl_ref[6, r]),
                  s, MASK_VALUE)
    p = jnp.exp(s - lse)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta) * scale
    dq_s[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)

    @pl.when(j == PK.last_col_params(i, tbl_ref[4, r]))
    def _emit():
        dq_ref[0, 0] = dq_s[...].astype(dq_ref.dtype)


def _packed_dkv_kernel(tbl_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
                       delta_ref, dk_ref, dv_ref, dk_s, dv_s, *,
                       n_requests: int, blk: int, scale: float):
    from repro.core import packing as PK

    lam = pl.program_id(2)
    r, i, j, _, _ = _packed_decode_cm(lam, tbl_ref, n_requests)

    @pl.when(i == PK.cm_first_row_params(j, tbl_ref[4, r]))
    def _init():
        dk_s[...] = jnp.zeros_like(dk_s)
        dv_s[...] = jnp.zeros_like(dv_s)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)[:, None]
    delta = delta_ref[0, 0].astype(jnp.float32)[:, None]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(_packed_token_mask(i, j, blk, tbl_ref[5, r], tbl_ref[6, r]),
                  s, MASK_VALUE)
    p = jnp.exp(s - lse)  # (blk, blk)
    dv_s[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta) * scale
    dk_s[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)

    @pl.when(i == PK.cm_last_row_params(j, tbl_ref[2, r], tbl_ref[3, r]))
    def _emit():
        dk_ref[0, 0] = dk_s[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_s[...].astype(dv_ref.dtype)


def packed_bwd(q, k, v, out, lse, do, psched: PackedTriSched, *,
               sm_scale=None, interpret=True):
    """Packed ragged backward: (dq, dk, dv) for a whole mixed-length batch
    in ONE launch per direction (dq row-major, dk/dv column-major — the
    same two 1-D grids the per-domain ``bwd`` uses, lifted to the packed
    member table). dk/dv are group-summed to k/v's kv-head count. Replaces
    R per-document pad-to-max backward launches: sum_r blocks_r grid steps
    per direction, zero cross-request tiles."""
    import numpy as np

    b, h, s_len, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = float(sm_scale if sm_scale is not None else 1.0 / (d ** 0.5))
    assert s_len == psched.s_total, (s_len, psched.s_total)
    blk = psched.blk
    n_req = len(psched.members)
    tbl = np.ascontiguousarray(psched.table())
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)

    def rm_q(b_, h_, lam, tbl_):
        _, _, _, q_row, _ = _packed_decode(lam, tbl_, n_req)
        return (b_, h_, q_row, 0)

    def rm_kv(b_, h_, lam, tbl_):
        _, _, _, _, k_row = _packed_decode(lam, tbl_, n_req)
        return (b_, h_ // g, k_row, 0)

    def rm_row(b_, h_, lam, tbl_):
        _, _, _, q_row, _ = _packed_decode(lam, tbl_, n_req)
        return (b_, h_, q_row)

    dq = OBS.instrumented_pallas_call(
        functools.partial(_packed_dq_kernel, n_requests=n_req, blk=blk,
                          scale=scale),
        meta=OBS.meta_from_packed("tri_attn.packed_bwd_dq", psched,
                                  impl="pallas", cells=b * h,
                                  grid=(b, h, psched.steps)),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, h, psched.steps),
            in_specs=[
                pl.BlockSpec((1, 1, blk, d), rm_q),
                pl.BlockSpec((1, 1, blk, d), rm_kv),
                pl.BlockSpec((1, 1, blk, d), rm_kv),
                pl.BlockSpec((1, 1, blk, d), rm_q),
                pl.BlockSpec((1, 1, blk), rm_row),
                pl.BlockSpec((1, 1, blk), rm_row),
            ],
            out_specs=pl.BlockSpec((1, 1, blk, d), rm_q),
            scratch_shapes=[pltpu.VMEM((blk, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(tbl, q, k, v, do, lse, delta)

    def cm_q(b_, h_, lam, tbl_):
        _, _, _, q_row, _ = _packed_decode_cm(lam, tbl_, n_req)
        return (b_, h_, q_row, 0)

    def cm_kv(b_, h_, lam, tbl_):
        _, _, _, _, k_row = _packed_decode_cm(lam, tbl_, n_req)
        return (b_, h_ // g, k_row, 0)

    def cm_row(b_, h_, lam, tbl_):
        _, _, _, q_row, _ = _packed_decode_cm(lam, tbl_, n_req)
        return (b_, h_, q_row)

    def cm_out(b_, h_, lam, tbl_):
        _, _, _, _, k_row = _packed_decode_cm(lam, tbl_, n_req)
        return (b_, h_, k_row, 0)

    dk_ph, dv_ph = OBS.instrumented_pallas_call(
        functools.partial(_packed_dkv_kernel, n_requests=n_req, blk=blk,
                          scale=scale),
        meta=OBS.meta_from_packed("tri_attn.packed_bwd_dkv", psched,
                                  impl="pallas", cells=b * h,
                                  grid=(b, h, psched.steps)),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, h, psched.steps),
            in_specs=[
                pl.BlockSpec((1, 1, blk, d), cm_q),
                pl.BlockSpec((1, 1, blk, d), cm_kv),
                pl.BlockSpec((1, 1, blk, d), cm_kv),
                pl.BlockSpec((1, 1, blk, d), cm_q),
                pl.BlockSpec((1, 1, blk), cm_row),
                pl.BlockSpec((1, 1, blk), cm_row),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, blk, d), cm_out),
                pl.BlockSpec((1, 1, blk, d), cm_out),
            ],
            scratch_shapes=[
                pltpu.VMEM((blk, d), jnp.float32),
                pltpu.VMEM((blk, d), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s_len, d), k.dtype),
            jax.ShapeDtypeStruct((b, h, s_len, d), v.dtype),
        ],
        interpret=interpret,
    )(tbl, q, k, v, do, lse, delta)

    if g > 1:  # sum per-q-head partials into kv heads
        dk = dk_ph.reshape(b, hkv, g, s_len, d).sum(axis=2).astype(k.dtype)
        dv = dv_ph.reshape(b, hkv, g, s_len, d).sum(axis=2).astype(v.dtype)
    else:
        dk, dv = dk_ph, dv_ph
    return dq, dk, dv


# ---------------------------------------------------------------------------
# Packed mixed-position DECODE: one 1-D grid per decode round over the
# concatenation of every active slot's valid KV region (core/packing's
# decode_round lifted to the kernel). Unlike the prefill table (baked
# constants — the packing is static per compile), the decode table is
# RUNTIME data: positions advance every round, so the (5, R) member table
# rides in as a scalar-prefetch SMEM operand and the grid is padded to a
# static bucketed capacity. Rows:
#   0 starts    cumulative tile offsets per member (ascending, starts[0]=0)
#   1 slot      batch row of the member's KV cache / query / output
#   2 kv_tiles  member tiles (emit at j == kv_tiles - 1); empty members
#               (retired slots) carry 0, the pad member DECODE_NO_EMIT
#   3 kv_len    valid KV END in tokens (token mask kpos < kv_len); 0 = pad
#   4 kv_first  valid KV START in tokens (0 = attend the whole prefix; a
#               BAND-limited member attends cache tiles
#               [kv_first // blk, ceil(kv_len / blk)) and tokens
#               [kv_first, kv_len) — the decode-round member of a sliding
#               window over a non-rolling cache, so per-slot kv_tiles is
#               capped near ceil(window / blk) however deep the position)
# Convention: the LAST member is always the pad member owning the grid
# steps [needed, capacity); its slot is n_slots (the virtual garbage row
# of the (B+1)-row output) and it never inits state destructively for a
# live slot nor emits (kv_tiles sentinel).
# ---------------------------------------------------------------------------


DECODE_NO_EMIT = 2 ** 30  # pad-member kv_tiles sentinel: emit never fires


def _decode_member(lam, tbl, n_members: int):
    """lambda + (5, R) decode table ->
    (r, slot, j, kv_tiles, kv_len, kv_first).

    j is the member-local KV tile (RowSchedule members are single rows, so
    the local lambda IS the column — no closed-form map needed); the cache
    tile it reads is kv_first // blk + j. tbl may be a jnp array or a
    Pallas SMEM ref."""
    from repro.core import packing as PK

    r = PK.request_from_starts(lam, _TableRow(tbl, 0), n_members)
    return (r, tbl[1, r], lam - tbl[0, r], tbl[2, r], tbl[3, r],
            tbl[4, r])


def _packed_decode_kernel(tbl_ref, q_ref, k_ref, v_ref, o_ref,
                          m_s, l_s, acc_s, *, n_members: int, blk: int,
                          scale: float):
    lam = pl.program_id(1)
    _, _, j, kv_tiles, kv_len, kv_first = _decode_member(lam, tbl_ref,
                                                         n_members)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, MASK_VALUE)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0].astype(jnp.float32)           # (1, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (blk, d)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = (kv_first // blk + j) * blk + jax.lax.broadcasted_iota(
        jnp.int32, (1, blk), 1)
    s = jnp.where((kpos >= kv_first) & (kpos < kv_len), s, MASK_VALUE)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(j == kv_tiles - 1)
    def _emit():
        o_ref[0] = (acc_s[...] / l_s[...]).astype(o_ref.dtype)


def packed_decode_fwd(q, k, v, tbl, *, capacity: int, blk: int,
                      sm_scale=None, interpret=True):
    """One packed launch for a whole mixed-position decode round.

    q: (B, H, D) — each slot's single rotated query; k, v: (B, S_cache,
    Hkv, D) — the NATIVE decode-cache layout (no transposes on the hot
    path), new token already written. tbl: (5, R) runtime member table
    (ops.make_decode_table). Grid is (H, capacity): sum_r kv_tiles_r live
    steps + masked pad steps, vs the lockstep einsum's B * S_cache work.
    Band-limited members (kv_first > 0) read only cache tiles
    [kv_first // blk, ceil(kv_len / blk)). Returns (B + 1, H, D): row B is
    the pad member's garbage row — callers slice [:B] and mask by the
    member table's coverage.
    """
    b, h, d = q.shape
    s_cache, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    assert s_cache % blk == 0, (s_cache, blk)
    cache_tiles = s_cache // blk
    scale = float(sm_scale if sm_scale is not None else 1.0 / (d ** 0.5))
    n_members = tbl.shape[1]

    def q_spec(h_, lam, tbl_):
        _, slot, _, _, _, _ = _decode_member(lam, tbl_, n_members)
        return (jnp.minimum(slot, b - 1), h_, 0)

    def kv_spec(h_, lam, tbl_):
        _, slot, j, _, _, kv_first = _decode_member(lam, tbl_, n_members)
        return (jnp.minimum(slot, b - 1),
                jnp.minimum(kv_first // blk + j, cache_tiles - 1),
                h_ // g, 0)

    def o_spec(h_, lam, tbl_):
        # pad member's slot == b: the extra garbage row, so pad steps can
        # never flush stale VMEM over a live slot's emitted block.
        _, slot, _, _, _, _ = _decode_member(lam, tbl_, n_members)
        return (slot, h_, 0)

    kernel = functools.partial(_packed_decode_kernel, n_members=n_members,
                               blk=blk, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(h, capacity),
        in_specs=[
            pl.BlockSpec((1, 1, d), q_spec),
            pl.BlockSpec((1, blk, 1, d), kv_spec),
            pl.BlockSpec((1, blk, 1, d), kv_spec),
        ],
        out_specs=pl.BlockSpec((1, 1, d), o_spec),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    out = OBS.instrumented_pallas_call(
        kernel,
        meta=OBS.meta_exact("tri_attn.packed_decode_fwd", "tri_attn",
                            impl="pallas", kind="decode_round",
                            steps=capacity, block_shape=(1, blk),
                            bb_bound=b * cache_tiles, cells=h,
                            extra=(("capacity", capacity),)),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b + 1, h, d), q.dtype),
        interpret=interpret,
    )(tbl, q, k, v)
    return out


# ---------------------------------------------------------------------------
# FUSED continuous-batching step: ONE 1-D grid carrying newly admitted
# prompts (prefill members over the packed operand) AND live decode slots
# (row members over the KV cache) — the admit round and the decode round
# collapse into a single launch (core/packing's mixed_step lifted to the
# kernel). The unified (8, R) member table is RUNTIME data (decode
# positions advance every round; the prefill columns are constants of the
# compile but ride along so the whole grid shares one delegation):
#   0 starts    cumulative grid-step offsets per member (ascending)
#   1 kind      0 = prefill member, 1 = decode row member (incl. the pad)
#   2 n         prefill: member tiles per side | decode: kv_tiles
#               (DECODE_NO_EMIT for the pad member)
#   3 w_b       prefill: band width in tiles  | decode: kv_len in tokens
#   4 p_b       prefill: prefix width in tiles| decode: kv_first in tokens
#   5 q_off     prefill: packed tile-row offset | decode: cache/query slot
#   6 win       prefill window in tokens (0 = none) | decode: 0
#   7 pre       prefill prefix in tokens (0 = none) | decode: 0
# Output routing is per member KIND: prefill members emit their packed
# hidden tiles into o_pack (whose last tile row is the garbage target of
# every decode step), decode members emit their slot's row into o_dec
# (whose row B is the garbage target of every prefill step and the pad).
# ---------------------------------------------------------------------------


def _fused_member(lam, tbl, n_members: int):
    """lambda + (8, R) fused table -> (r, is_p, local, i_p, j_p).

    One O(log R) search shared by body and index maps; the prefill
    closed-form map runs on CLAMPED params (n=1, w=1, p=0, local=0) when
    the member is a decode row, so rows 2-4 holding kv_{tiles,len,first}
    can never overflow or divide inside the band/prefix delegation."""
    from repro.core import packing as PK

    r = PK.request_from_starts(lam, _TableRow(tbl, 0), n_members)
    is_p = tbl[1, r] == 0
    local = lam - tbl[0, r]
    i_p, j_p = PK.member_map_params(
        jnp.where(is_p, local, 0), jnp.where(is_p, tbl[2, r], 1),
        jnp.where(is_p, tbl[3, r], 1), jnp.where(is_p, tbl[4, r], 0))
    return r, is_p, local, i_p, j_p


def _fused_step_kernel(tbl_ref, qp_ref, kp_ref, vp_ref, qd_ref, kc_ref,
                       vc_ref, op_ref, od_ref, m_s, l_s, acc_s, *,
                       n_members: int, blk: int, scale: float):
    from repro.core import packing as PK

    lam = pl.program_id(1)
    r, is_p, local, i_p, j_p = _fused_member(lam, tbl_ref, n_members)
    kv_tiles = tbl_ref[2, r]
    kv_len = tbl_ref[3, r]
    kv_first = jnp.where(is_p, 0, tbl_ref[4, r])
    j_eff = jnp.where(is_p, j_p, local)
    first = jnp.where(is_p, PK.first_col_params(i_p, tbl_ref[3, r]), 0)
    last = jnp.where(is_p, PK.last_col_params(i_p, tbl_ref[4, r]),
                     kv_tiles - 1)

    @pl.when(j_eff == first)
    def _init():
        m_s[...] = jnp.full_like(m_s, MASK_VALUE)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    # Decode rows broadcast their single query over the block: every row
    # computes the same online softmax, and the emit takes row 0.
    qp = qp_ref[0, 0].astype(jnp.float32)           # (blk, d)
    qd = qd_ref[0].astype(jnp.float32)              # (1, d)
    q = jnp.where(is_p, qp, jnp.broadcast_to(qd, qp.shape))
    k = jnp.where(is_p, kp_ref[0, 0].astype(jnp.float32),
                  kc_ref[0, :, 0, :].astype(jnp.float32))
    v = jnp.where(is_p, vp_ref[0, 0].astype(jnp.float32),
                  vc_ref[0, :, 0, :].astype(jnp.float32))
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pmask = _packed_token_mask(i_p, j_p, blk, tbl_ref[6, r], tbl_ref[7, r])
    kpos = (kv_first // blk + jnp.where(is_p, 0, local)) * blk \
        + jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
    dmask = (kpos >= kv_first) & (kpos < kv_len)
    s = jnp.where(jnp.where(is_p, pmask, dmask), s, MASK_VALUE)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(is_p & (j_eff == last))
    def _emit_pack():
        op_ref[0, 0] = (acc_s[...] / l_s[...]).astype(op_ref.dtype)

    @pl.when(jnp.logical_not(is_p) & (j_eff == last))
    def _emit_dec():
        od_ref[0] = (acc_s[0:1, :] / l_s[0:1, :]).astype(od_ref.dtype)


def fused_step_fwd(q_pack, k_pack, v_pack, q_dec, k_cache, v_cache, tbl, *,
                   capacity: int, blk: int, n_pack_tiles: int,
                   sm_scale=None, interpret=True):
    """One fused launch for a whole continuous-batching engine step.

    q_pack: (1, H, S_pack, D) with k_pack/v_pack (1, Hkv, S_pack, D) — the
    newly admitted prompts concatenated along S (the packed-prefill
    layout); q_dec: (B, H, D) with k_cache/v_cache (B, S_cache, Hkv, D) —
    the live slots' rotated queries against the native decode cache, new
    token already written. tbl: the (8, R) fused member table
    (ops.make_fused_table). Grid is (H, capacity): prefill blocks + live
    decode tiles + masked pad steps — ONE pallas_call where the split
    engine paid an admit launch and a decode launch. Returns

      o_pack (1, H, S_pack + blk, D) — packed hidden tiles; the final blk
             rows are the decode/pad steps' garbage tile, sliced off by
             the caller;
      o_dec  (B + 1, H, D) — per-slot decode rows; row B is the prefill/
             pad steps' garbage row, masked by the caller via coverage.
    """
    _, h, s_pack, d = q_pack.shape
    b = q_dec.shape[0]
    s_cache, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    assert s_cache % blk == 0, (s_cache, blk)
    assert s_pack == n_pack_tiles * blk, (s_pack, n_pack_tiles, blk)
    cache_tiles = s_cache // blk
    scale = float(sm_scale if sm_scale is not None else 1.0 / (d ** 0.5))
    n_members = tbl.shape[1]

    def qp_spec(h_, lam, tbl_):
        r, is_p, _, i_p, _ = _fused_member(lam, tbl_, n_members)
        return (0, h_, jnp.where(is_p, tbl_[5, r] + i_p, 0), 0)

    def kp_spec(h_, lam, tbl_):
        r, is_p, _, _, j_p = _fused_member(lam, tbl_, n_members)
        return (0, h_ // g, jnp.where(is_p, tbl_[5, r] + j_p, 0), 0)

    def qd_spec(h_, lam, tbl_):
        r, is_p, _, _, _ = _fused_member(lam, tbl_, n_members)
        slot = jnp.where(is_p, 0, tbl_[5, r])
        return (jnp.minimum(slot, b - 1), h_, 0)

    def kc_spec(h_, lam, tbl_):
        r, is_p, local, _, _ = _fused_member(lam, tbl_, n_members)
        slot = jnp.where(is_p, 0, tbl_[5, r])
        kv_first = jnp.where(is_p, 0, tbl_[4, r])
        j_d = jnp.where(is_p, 0, local)
        return (jnp.minimum(slot, b - 1),
                jnp.minimum(kv_first // blk + j_d, cache_tiles - 1),
                h_ // g, 0)

    def op_spec(h_, lam, tbl_):
        # decode/pad steps park on the extra garbage tile row n_pack_tiles
        r, is_p, _, i_p, _ = _fused_member(lam, tbl_, n_members)
        return (0, h_, jnp.where(is_p, tbl_[5, r] + i_p, n_pack_tiles), 0)

    def od_spec(h_, lam, tbl_):
        # prefill steps (and the pad member, whose slot is n_slots) park on
        # the garbage row b of the (B + 1)-row decode output
        r, is_p, _, _, _ = _fused_member(lam, tbl_, n_members)
        return (jnp.where(is_p, b, jnp.minimum(tbl_[5, r], b)), h_, 0)

    kernel = functools.partial(_fused_step_kernel, n_members=n_members,
                               blk=blk, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(h, capacity),
        in_specs=[
            pl.BlockSpec((1, 1, blk, d), qp_spec),
            pl.BlockSpec((1, 1, blk, d), kp_spec),
            pl.BlockSpec((1, 1, blk, d), kp_spec),
            pl.BlockSpec((1, 1, d), qd_spec),
            pl.BlockSpec((1, blk, 1, d), kc_spec),
            pl.BlockSpec((1, blk, 1, d), kc_spec),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, blk, d), op_spec),
            pl.BlockSpec((1, 1, d), od_spec),
        ],
        scratch_shapes=[
            pltpu.VMEM((blk, 1), jnp.float32),
            pltpu.VMEM((blk, 1), jnp.float32),
            pltpu.VMEM((blk, d), jnp.float32),
        ],
    )
    o_pack, o_dec = OBS.instrumented_pallas_call(
        kernel,
        meta=OBS.meta_exact(
            "tri_attn.fused_step_fwd", "tri_attn", impl="pallas",
            kind="fused_step", steps=capacity, block_shape=(blk, blk),
            bb_bound=n_pack_tiles * n_pack_tiles + b * cache_tiles,
            cells=h, extra=(("capacity", capacity),
                            ("members", n_members))),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((1, h, s_pack + blk, d), q_pack.dtype),
            jax.ShapeDtypeStruct((b + 1, h, d), q_dec.dtype),
        ],
        interpret=interpret,
    )(tbl, q_pack, k_pack, v_pack, q_dec, k_cache, v_cache)
    return o_pack, o_dec


# ---------------------------------------------------------------------------
# Backward: dq (row-major grid, same enumeration as forward)
# ---------------------------------------------------------------------------


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               dq_s, *, sched: TriSched, scale: float):
    lam = pl.program_id(2)
    i, j = sched.rm_map(lam)

    @pl.when(j == sched.rm_first_col(i))
    def _init():
        dq_s[...] = jnp.zeros_like(dq_s)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)[:, None]
    delta = delta_ref[0, 0].astype(jnp.float32)[:, None]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(_token_mask(sched, i, j, sched.bq, sched.bk), s, MASK_VALUE)
    p = jnp.exp(s - lse)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta) * scale
    dq_s[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)

    @pl.when(j == sched.rm_last_col(i))
    def _emit():
        dq_ref[0, 0] = dq_s[...].astype(dq_ref.dtype)


# ---------------------------------------------------------------------------
# Backward: dk/dv (column-major grid; per-q-head partials, group-summed in
# ops.py — output revisiting cannot accumulate across kv-head groups)
# ---------------------------------------------------------------------------


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_s, dv_s, *, sched: TriSched, scale: float):
    lam = pl.program_id(2)
    i, j = sched.cm_map(lam)

    @pl.when(i == sched.cm_first_row(j))
    def _init():
        dk_s[...] = jnp.zeros_like(dk_s)
        dv_s[...] = jnp.zeros_like(dv_s)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)[:, None]
    delta = delta_ref[0, 0].astype(jnp.float32)[:, None]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(_token_mask(sched, i, j, sched.bq, sched.bk), s, MASK_VALUE)
    p = jnp.exp(s - lse)  # (bq, bk)
    dv_s[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta) * scale
    dk_s[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)

    @pl.when(i == sched.cm_last_row(j))
    def _emit():
        dk_ref[0, 0] = dk_s[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_s[...].astype(dv_ref.dtype)


def bwd(q, k, v, out, lse, do, sched: TriSched, *, sm_scale=None,
        interpret=True):
    """Returns (dq, dk, dv) with dk/dv shaped like k/v (group-summed)."""
    b, h, s_len, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    bq, bk = sched.bq, sched.bk
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)

    rm_i = lambda lam: sched.rm_map(lam)[0]
    rm_j = lambda lam: sched.rm_map(lam)[1]
    grid = (b, h, sched.rm_steps)
    dq = OBS.instrumented_pallas_call(
        functools.partial(_dq_kernel, sched=sched, scale=scale),
        meta=OBS.meta_from_trisched("tri_attn.bwd_dq", sched, impl="pallas",
                                    cells=b * h, grid=grid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, lam: (b_, h_, rm_i(lam), 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, lam: (b_, h_ // g, rm_j(lam), 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, lam: (b_, h_ // g, rm_j(lam), 0)),
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, lam: (b_, h_, rm_i(lam), 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h_, lam: (b_, h_, rm_i(lam))),
            pl.BlockSpec((1, 1, bq), lambda b_, h_, lam: (b_, h_, rm_i(lam))),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h_, lam: (b_, h_, rm_i(lam), 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    cm_i = lambda lam: sched.cm_map(lam)[0]
    cm_j = lambda lam: sched.cm_map(lam)[1]
    grid_cm = (b, h, sched.cm_steps)
    dk_ph, dv_ph = OBS.instrumented_pallas_call(
        functools.partial(_dkv_kernel, sched=sched, scale=scale),
        meta=OBS.meta_from_trisched("tri_attn.bwd_dkv", sched,
                                    impl="pallas", cells=b * h,
                                    grid=grid_cm),
        grid=grid_cm,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, lam: (b_, h_, cm_i(lam), 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, lam: (b_, h_ // g, cm_j(lam), 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, lam: (b_, h_ // g, cm_j(lam), 0)),
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, lam: (b_, h_, cm_i(lam), 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h_, lam: (b_, h_, cm_i(lam))),
            pl.BlockSpec((1, 1, bq), lambda b_, h_, lam: (b_, h_, cm_i(lam))),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, lam: (b_, h_, cm_j(lam), 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, lam: (b_, h_, cm_j(lam), 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s_len, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, s_len, d), q.dtype),
        ],
        interpret=interpret,
        scratch_shapes=[
            pltpu.VMEM((bk, d), jnp.float32),
            pltpu.VMEM((bk, d), jnp.float32),
        ],
    )(q, k, v, do, lse, delta)

    if g > 1:  # sum per-q-head partials into kv heads
        dk = dk_ph.reshape(b, hkv, g, s_len, d).sum(axis=2).astype(k.dtype)
        dv = dv_ph.reshape(b, hkv, g, s_len, d).sum(axis=2).astype(v.dtype)
    else:
        dk, dv = dk_ph, dv_ph
    return dq, dk, dv


# ---------------------------------------------------------------------------
# BB baseline (paper's bounding-box strategy): 2-D grid + block-level guard
# ---------------------------------------------------------------------------


def _bb_fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_s, l_s, acc_s, *,
                   sched: TriSched, scale: float):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, MASK_VALUE)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    # Paper's optimized BB: whole tile discarded by *block* coordinates.
    @pl.when(j <= i)
    def _active():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(_token_mask(sched, i, j, sched.bq, sched.bk), s,
                      MASK_VALUE)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(j == sched.n - 1)
    def _emit():
        l = l_s[...]
        o_ref[0, 0] = (acc_s[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_s[...] + jnp.log(l))[:, 0].astype(lse_ref.dtype)


def fwd_bb(q, k, v, sched: TriSched, *, sm_scale=None, interpret=True):
    """Bounding-box baseline: n x n grid, upper tiles guarded (dead DMA +
    dead grid steps — the cost the paper eliminates)."""
    b, h, s_len, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    bq, bk, n = sched.bq, sched.bk, sched.n
    kernel = functools.partial(_bb_fwd_kernel, sched=sched, scale=scale)
    out, lse = OBS.instrumented_pallas_call(
        kernel,
        meta=OBS.meta_dense("tri_attn.fwd_bb", "tri_attn", impl="pallas",
                            grid=(n, n), block_shape=(bq, bk),
                            tiles_domain=M.tri(n), cells=b * h),
        grid=(b, h, n, n),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, i, j: (b_, h_ // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, i, j: (b_, h_ // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h_, i, j: (b_, h_, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((b, h, s_len), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out, lse
