"""Pallas TPU kernels: flash attention over triangular-domain 1-D grids.

The paper's g(lambda) becomes the BlockSpec index_map: the forward (and dq
backward) iterate a 1-D grid of T = tri(n) steps enumerated ROW-major (the
LTM order), the dk/dv backward iterates COLUMN-major (cm_map) so per-column
accumulators stay resident in VMEM scratch. Wasted tiles: zero off-diagonal
(vs. the BB baseline's n(n-1)/2), only intra-tile masking on boundary tiles
remains — exactly the paper's O(n^2) -> O(n) claim at tile granularity.

Schedules: 'ltm' (causal), 'band' (sliding window, beyond-paper), 'prefix'
(VLM prefix-causal, beyond-paper). 'bb' is the paper's bounding-box baseline
(2-D grid + block-level guard).

All kernels accumulate in f32 VMEM scratch and are validated in interpret
mode against ref.py (tests/test_kernels_tri_attn.py). TPU notes: block_q and
block_k should be multiples of 128 (MXU); head_dim 64/128/192 all lower (192
pads lanes).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import mapping as M

MASK_VALUE = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Schedule parameterization shared by fwd / dq / dkv kernels
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TriSched:
    """Static schedule metadata for one attention call (bq == bk required
    for triangular/band kinds so the tile domain is square)."""

    kind: str  # 'ltm' | 'band' | 'prefix'
    n: int  # tiles per side
    bq: int
    bk: int
    window: Optional[int] = None  # tokens (band)
    prefix: int = 0  # tokens (prefix)

    def __post_init__(self):
        assert self.kind in ("ltm", "band", "prefix")
        if self.kind == "band":
            assert self.window is not None and self.window >= 1
            assert self.bq == self.bk

    @property
    def w_b(self) -> int:
        """Band width in tiles: tile j needed iff exists q,k in tiles with
        0 <= q-k < window  =>  j >= i - ((window-2)//bk + 1)."""
        if self.window is None:
            return self.n
        return min((self.window - 2) // self.bk + 2, self.n)

    @property
    def p_b(self) -> int:
        return -(-self.prefix // self.bk) if self.prefix else 0

    # ---- row-major enumeration (forward, dq) -----------------------------
    @property
    def rm_steps(self) -> int:
        if self.kind == "ltm":
            return M.tri(self.n)
        if self.kind == "band":
            return M.band_blocks(self.n, self.w_b)
        return M.prefix_full_blocks(self.n, self.p_b)

    def rm_map(self, lam):
        if self.kind == "ltm":
            return M.ltm_map(lam)
        if self.kind == "band":
            return M.band_map(lam, self.w_b)
        return M.prefix_full_map(lam, self.n, self.p_b)

    def rm_first_col(self, i):
        if self.kind == "band":
            return jnp.maximum(0, i - self.w_b + 1)
        return i * 0

    def rm_last_col(self, i):
        if self.kind == "prefix":
            return jnp.maximum(i, self.p_b - 1)
        return i

    # ---- column-major enumeration (dk/dv) --------------------------------
    @property
    def cm_steps(self) -> int:
        return self.rm_steps  # same domain, different order

    def cm_map(self, lam):
        if self.kind == "ltm":
            return M.cm_map(lam, self.n)
        if self.kind == "band":
            return M.band_cm_map(lam, self.n, self.w_b)
        return M.prefix_cm_map(lam, self.n, self.p_b)

    def cm_first_row(self, j):
        if self.kind == "prefix":
            return jnp.where(j < self.p_b, 0, j)
        return j

    def cm_last_row(self, j):
        if self.kind == "band":
            return jnp.minimum(j + self.w_b - 1, self.n - 1)
        return jnp.full_like(j, self.n - 1) if not isinstance(j, int) else self.n - 1


def _token_mask(sched: TriSched, i, j, bq, bk):
    """(bq, bk) boolean mask for tile (i, j): True = attend."""
    qp = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kp = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = kp <= qp
    if sched.window is not None:
        m &= (qp - kp) < sched.window
    if sched.prefix:
        m |= kp < sched.prefix
    return m


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_s, l_s, acc_s, *,
                sched: TriSched, scale: float):
    lam = pl.program_id(2)
    i, j = sched.rm_map(lam)

    @pl.when(j == sched.rm_first_col(i))
    def _init():
        m_s[...] = jnp.full_like(m_s, MASK_VALUE)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(_token_mask(sched, i, j, sched.bq, sched.bk), s, MASK_VALUE)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(j == sched.rm_last_col(i))
    def _emit():
        l = l_s[...]
        o_ref[0, 0] = (acc_s[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_s[...] + jnp.log(l))[:, 0].astype(lse_ref.dtype)


def fwd(q, k, v, sched: TriSched, *, sm_scale=None, interpret=True):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D). Returns (out, lse)."""
    b, h, s_len, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    bq, bk, n = sched.bq, sched.bk, sched.n
    assert n * bq == s_len and n * bk == s_len

    grid = (b, h, sched.rm_steps)
    rm_i = lambda lam: sched.rm_map(lam)[0]
    rm_j = lambda lam: sched.rm_map(lam)[1]
    kernel = functools.partial(_fwd_kernel, sched=sched, scale=scale)
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, lam: (b_, h_, rm_i(lam), 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, lam: (b_, h_ // g, rm_j(lam), 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, lam: (b_, h_ // g, rm_j(lam), 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, lam: (b_, h_, rm_i(lam), 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h_, lam: (b_, h_, rm_i(lam))),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((b, h, s_len), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# Backward: dq (row-major grid, same enumeration as forward)
# ---------------------------------------------------------------------------


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               dq_s, *, sched: TriSched, scale: float):
    lam = pl.program_id(2)
    i, j = sched.rm_map(lam)

    @pl.when(j == sched.rm_first_col(i))
    def _init():
        dq_s[...] = jnp.zeros_like(dq_s)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)[:, None]
    delta = delta_ref[0, 0].astype(jnp.float32)[:, None]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(_token_mask(sched, i, j, sched.bq, sched.bk), s, MASK_VALUE)
    p = jnp.exp(s - lse)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta) * scale
    dq_s[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)

    @pl.when(j == sched.rm_last_col(i))
    def _emit():
        dq_ref[0, 0] = dq_s[...].astype(dq_ref.dtype)


# ---------------------------------------------------------------------------
# Backward: dk/dv (column-major grid; per-q-head partials, group-summed in
# ops.py — output revisiting cannot accumulate across kv-head groups)
# ---------------------------------------------------------------------------


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_s, dv_s, *, sched: TriSched, scale: float):
    lam = pl.program_id(2)
    i, j = sched.cm_map(lam)

    @pl.when(i == sched.cm_first_row(j))
    def _init():
        dk_s[...] = jnp.zeros_like(dk_s)
        dv_s[...] = jnp.zeros_like(dv_s)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)[:, None]
    delta = delta_ref[0, 0].astype(jnp.float32)[:, None]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(_token_mask(sched, i, j, sched.bq, sched.bk), s, MASK_VALUE)
    p = jnp.exp(s - lse)  # (bq, bk)
    dv_s[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta) * scale
    dk_s[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)

    @pl.when(i == sched.cm_last_row(j))
    def _emit():
        dk_ref[0, 0] = dk_s[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_s[...].astype(dv_ref.dtype)


def bwd(q, k, v, out, lse, do, sched: TriSched, *, sm_scale=None,
        interpret=True):
    """Returns (dq, dk, dv) with dk/dv shaped like k/v (group-summed)."""
    b, h, s_len, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    bq, bk = sched.bq, sched.bk
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)

    rm_i = lambda lam: sched.rm_map(lam)[0]
    rm_j = lambda lam: sched.rm_map(lam)[1]
    grid = (b, h, sched.rm_steps)
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, sched=sched, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, lam: (b_, h_, rm_i(lam), 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, lam: (b_, h_ // g, rm_j(lam), 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, lam: (b_, h_ // g, rm_j(lam), 0)),
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, lam: (b_, h_, rm_i(lam), 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h_, lam: (b_, h_, rm_i(lam))),
            pl.BlockSpec((1, 1, bq), lambda b_, h_, lam: (b_, h_, rm_i(lam))),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h_, lam: (b_, h_, rm_i(lam), 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    cm_i = lambda lam: sched.cm_map(lam)[0]
    cm_j = lambda lam: sched.cm_map(lam)[1]
    grid_cm = (b, h, sched.cm_steps)
    dk_ph, dv_ph = pl.pallas_call(
        functools.partial(_dkv_kernel, sched=sched, scale=scale),
        grid=grid_cm,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, lam: (b_, h_, cm_i(lam), 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, lam: (b_, h_ // g, cm_j(lam), 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, lam: (b_, h_ // g, cm_j(lam), 0)),
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, lam: (b_, h_, cm_i(lam), 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h_, lam: (b_, h_, cm_i(lam))),
            pl.BlockSpec((1, 1, bq), lambda b_, h_, lam: (b_, h_, cm_i(lam))),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, lam: (b_, h_, cm_j(lam), 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, lam: (b_, h_, cm_j(lam), 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s_len, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, s_len, d), q.dtype),
        ],
        interpret=interpret,
        scratch_shapes=[
            pltpu.VMEM((bk, d), jnp.float32),
            pltpu.VMEM((bk, d), jnp.float32),
        ],
    )(q, k, v, do, lse, delta)

    if g > 1:  # sum per-q-head partials into kv heads
        dk = dk_ph.reshape(b, hkv, g, s_len, d).sum(axis=2).astype(k.dtype)
        dv = dv_ph.reshape(b, hkv, g, s_len, d).sum(axis=2).astype(v.dtype)
    else:
        dk, dv = dk_ph, dv_ph
    return dq, dk, dv


# ---------------------------------------------------------------------------
# BB baseline (paper's bounding-box strategy): 2-D grid + block-level guard
# ---------------------------------------------------------------------------


def _bb_fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_s, l_s, acc_s, *,
                   sched: TriSched, scale: float):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, MASK_VALUE)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    # Paper's optimized BB: whole tile discarded by *block* coordinates.
    @pl.when(j <= i)
    def _active():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(_token_mask(sched, i, j, sched.bq, sched.bk), s,
                      MASK_VALUE)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(j == sched.n - 1)
    def _emit():
        l = l_s[...]
        o_ref[0, 0] = (acc_s[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_s[...] + jnp.log(l))[:, 0].astype(lse_ref.dtype)


def fwd_bb(q, k, v, sched: TriSched, *, sm_scale=None, interpret=True):
    """Bounding-box baseline: n x n grid, upper tiles guarded (dead DMA +
    dead grid steps — the cost the paper eliminates)."""
    b, h, s_len, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    bq, bk, n = sched.bq, sched.bk, sched.n
    kernel = functools.partial(_bb_fwd_kernel, sched=sched, scale=scale)
    out, lse = pl.pallas_call(
        kernel,
        grid=(b, h, n, n),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, i, j: (b_, h_ // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, i, j: (b_, h_ // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h_, i, j: (b_, h_, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((b, h, s_len), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out, lse
