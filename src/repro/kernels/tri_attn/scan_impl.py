"""Schedule-driven flash attention as a pure-XLA scan (no Pallas).

Why this exists: the paper's LTM enumeration gives the triangular tile
domain a FIXED trip count T = tri(n), which is what makes a lax.scan
formulation of causal flash attention possible at all (a 2-D loop would need
a data-dependent inner trip count). Each scan step dynamic-slices tile
(i, j) = g(lambda) and carries the online-softmax state; compiled HLO
therefore contains exactly T tile-matmuls — the triangular FLOP/byte savings
show up directly in ``compiled.cost_analysis()`` for the dry-run/roofline,
and this path trains the models on CPU.

It mirrors kernel.py 1:1 (same schedules, same math, custom VJP with
row-major dq scan and column-major dk/dv scan) and is validated against
ref.py and against the Pallas kernel in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.tri_attn.kernel import (
    MASK_VALUE,
    PackedTriSched,
    TriSched,
    _decode_member,
    _fused_member,
    _packed_decode,
    _packed_token_mask,
    _token_mask,
)
from repro.obs import launch as OBS


def _slice_rows(x, blk_idx, blk):
    """dynamic-slice rows [blk_idx*blk, +blk) of x (..., S, D)."""
    start = (0,) * (x.ndim - 2) + (blk_idx * blk, 0)
    sizes = x.shape[:-2] + (blk, x.shape[-1])
    return jax.lax.dynamic_slice(x, start, sizes)


def _update_rows(buf, upd, blk_idx, blk):
    start = (0,) * (buf.ndim - 2) + (blk_idx * blk, 0)
    return jax.lax.dynamic_update_slice(buf, upd, start)


def _fwd_cell(q, k, v, sched: TriSched, scale):
    """One (batch, kv-head) cell. q: (G, S, D); k, v: (S, D).

    Returns out (G, S, D) in q.dtype and lse (G, S) f32."""
    g, s_len, d = q.shape
    bq, bk = sched.bq, sched.bk

    def step(carry, lam):
        m, l, acc, out, lse = carry
        i, j = sched.rm_map(lam)
        reset = j == sched.rm_first_col(i)
        m = jnp.where(reset, MASK_VALUE, m)
        l = jnp.where(reset, 0.0, l)
        acc = jnp.where(reset, 0.0, acc)

        qi = _slice_rows(q, i, bq).astype(jnp.float32)  # (G, bq, D)
        kj = _slice_rows(k, j, bk).astype(jnp.float32)  # (bk, D)
        vj = _slice_rows(v, j, bk).astype(jnp.float32)
        s = jnp.einsum("gqd,kd->gqk", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(_token_mask(sched, i, j, bq, bk)[None], s, MASK_VALUE)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "gqk,kd->gqd", p, vj, preferred_element_type=jnp.float32)
        # Unconditional write: the last lambda of row i leaves the final value.
        out = _update_rows(out, (acc / l[..., None]).astype(out.dtype), i, bq)
        lse = jax.lax.dynamic_update_slice(
            lse, m_new + jnp.log(l), (0, i * bq))
        return (m_new, l, acc, out, lse), None

    init = (
        jnp.full((g, bq), MASK_VALUE, jnp.float32),
        jnp.zeros((g, bq), jnp.float32),
        jnp.zeros((g, bq, d), jnp.float32),
        jnp.zeros((g, s_len, d), q.dtype),
        jnp.zeros((g, s_len), jnp.float32),
    )
    (_, _, _, out, lse), _ = jax.lax.scan(
        step, init, jnp.arange(sched.rm_steps, dtype=jnp.int32))
    return out, lse


def _packed_fwd_cell(q, k, v, psched: PackedTriSched, scale):
    """Packed ragged forward, one (batch, kv-head) cell. q: (G, S_total, D);
    k, v: (S_total, D) — requests concatenated along S.

    Mirrors the packed Pallas kernel 1:1: a single lax.scan of
    sum_r member_blocks steps whose slices follow core/packing's
    (request, i, j) map. Per-request rows are lambda-contiguous, so the
    unconditional row write leaves each row's final value in place exactly
    as in _fwd_cell. Returns (out (G, S_total, D), lse (G, S_total))."""
    g, s_len, d = q.shape
    blk = psched.blk
    n_req = len(psched.members)
    tbl = jnp.asarray(psched.table())  # constants are fine in a lax.scan

    def step(carry, lam):
        from repro.core import packing as PK

        m, l, acc, out, lse = carry
        r, i, j, row_q, row_k = _packed_decode(lam, tbl, n_req)
        reset = j == PK.first_col_params(i, tbl[3, r])
        m = jnp.where(reset, MASK_VALUE, m)
        l = jnp.where(reset, 0.0, l)
        acc = jnp.where(reset, 0.0, acc)

        qi = _slice_rows(q, row_q, blk).astype(jnp.float32)  # (G, blk, D)
        kj = _slice_rows(k, row_k, blk).astype(jnp.float32)
        vj = _slice_rows(v, row_k, blk).astype(jnp.float32)
        s = jnp.einsum("gqd,kd->gqk", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(
            _packed_token_mask(i, j, blk, tbl[5, r], tbl[6, r])[None], s,
            MASK_VALUE)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "gqk,kd->gqd", p, vj, preferred_element_type=jnp.float32)
        out = _update_rows(out, (acc / l[..., None]).astype(out.dtype),
                           row_q, blk)
        lse = jax.lax.dynamic_update_slice(
            lse, m_new + jnp.log(l), (0, row_q * blk))
        return (m_new, l, acc, out, lse), None

    init = (
        jnp.full((g, blk), MASK_VALUE, jnp.float32),
        jnp.zeros((g, blk), jnp.float32),
        jnp.zeros((g, blk, d), jnp.float32),
        jnp.zeros((g, s_len, d), q.dtype),
        jnp.zeros((g, s_len), jnp.float32),
    )
    (_, _, _, out, lse), _ = jax.lax.scan(
        step, init, jnp.arange(psched.steps, dtype=jnp.int32))
    return out, lse


def _packed_dq_cell(q, k, v, do, lse, delta, psched: PackedTriSched, scale):
    """Packed dq, one (batch, kv-head) cell — the row-major backward scan
    over the SAME packed lambda grid as _packed_fwd_cell (per-row dq
    accumulator, unconditional row write: each member's rows are
    lambda-contiguous, so the row's last column leaves the final value)."""
    from repro.core import packing as PK

    g, s_len, d = q.shape
    blk = psched.blk
    n_req = len(psched.members)
    tbl = jnp.asarray(psched.table())

    def step(carry, lam):
        dq_acc, dq = carry
        r, i, j, row_q, row_k = _packed_decode(lam, tbl, n_req)
        reset = j == PK.first_col_params(i, tbl[3, r])
        dq_acc = jnp.where(reset, 0.0, dq_acc)
        qi = _slice_rows(q, row_q, blk).astype(jnp.float32)
        kj = _slice_rows(k, row_k, blk).astype(jnp.float32)
        vj = _slice_rows(v, row_k, blk).astype(jnp.float32)
        doi = _slice_rows(do, row_q, blk).astype(jnp.float32)
        lse_i = jax.lax.dynamic_slice(lse, (0, row_q * blk), (g, blk))
        dlt_i = jax.lax.dynamic_slice(delta, (0, row_q * blk), (g, blk))
        s = jnp.einsum("gqd,kd->gqk", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(
            _packed_token_mask(i, j, blk, tbl[5, r], tbl[6, r])[None], s,
            MASK_VALUE)
        p = jnp.exp(s - lse_i[..., None])
        dp = jnp.einsum("gqd,kd->gqk", doi, vj,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - dlt_i[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("gqk,kd->gqd", ds, kj,
                                     preferred_element_type=jnp.float32)
        dq = _update_rows(dq, dq_acc.astype(dq.dtype), row_q, blk)
        return (dq_acc, dq), None

    init = (jnp.zeros((g, blk, d), jnp.float32),
            jnp.zeros((g, s_len, d), q.dtype))
    (_, dq), _ = jax.lax.scan(
        step, init, jnp.arange(psched.steps, dtype=jnp.int32))
    return dq


def _packed_dkv_cell(q, k, v, do, lse, delta, psched: PackedTriSched, scale):
    """Packed dk/dv, one (batch, kv-head) cell — COLUMN-major packed scan
    (core/packing.member_cm_map_params): each member's column's rows are
    lambda-contiguous, so per-column accumulators carry exactly like the
    per-domain _dkv_cell."""
    from repro.core import packing as PK
    from repro.kernels.tri_attn.kernel import _packed_decode_cm

    g, s_len, d = q.shape
    blk = psched.blk
    n_req = len(psched.members)
    tbl = jnp.asarray(psched.table())

    def step(carry, lam):
        dk_acc, dv_acc, dk, dv = carry
        r, i, j, row_q, row_k = _packed_decode_cm(lam, tbl, n_req)
        reset = i == PK.cm_first_row_params(j, tbl[4, r])
        dk_acc = jnp.where(reset, 0.0, dk_acc)
        dv_acc = jnp.where(reset, 0.0, dv_acc)
        qi = _slice_rows(q, row_q, blk).astype(jnp.float32)
        kj = _slice_rows(k, row_k, blk).astype(jnp.float32)
        vj = _slice_rows(v, row_k, blk).astype(jnp.float32)
        doi = _slice_rows(do, row_q, blk).astype(jnp.float32)
        lse_i = jax.lax.dynamic_slice(lse, (0, row_q * blk), (g, blk))
        dlt_i = jax.lax.dynamic_slice(delta, (0, row_q * blk), (g, blk))
        s = jnp.einsum("gqd,kd->gqk", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(
            _packed_token_mask(i, j, blk, tbl[5, r], tbl[6, r])[None], s,
            MASK_VALUE)
        p = jnp.exp(s - lse_i[..., None])  # (G, blk, blk)
        dv_acc = dv_acc + jnp.einsum("gqk,gqd->kd", p, doi,
                                     preferred_element_type=jnp.float32)
        dp = jnp.einsum("gqd,kd->gqk", doi, vj,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - dlt_i[..., None]) * scale
        dk_acc = dk_acc + jnp.einsum("gqk,gqd->kd", ds, qi,
                                     preferred_element_type=jnp.float32)
        dk = _update_rows(dk, dk_acc.astype(dk.dtype), row_k, blk)
        dv = _update_rows(dv, dv_acc.astype(dv.dtype), row_k, blk)
        return (dk_acc, dv_acc, dk, dv), None

    init = (jnp.zeros((blk, d), jnp.float32), jnp.zeros((blk, d), jnp.float32),
            jnp.zeros((s_len, d), k.dtype), jnp.zeros((s_len, d), v.dtype))
    (_, _, dk, dv), _ = jax.lax.scan(
        step, init, jnp.arange(psched.steps, dtype=jnp.int32))
    return dk, dv


@functools.lru_cache(maxsize=None)
def make_packed_scan_attention(psched: PackedTriSched, scale: float):
    """Packed ragged attention for static (psched, scale) — custom VJP.

    q (B, H, S_total, D); k, v (B, Hkv, S_total, D) -> (B, H, S_total, D).
    The backward is the packed dq (row-major) + dk/dv (column-major) scans
    over the same member table: jax.grad through a ragged document batch
    costs 3 x sum_r blocks_r tile-matmuls total, never the pad-to-max
    bounding box (the training-path analogue of the prefill claim)."""

    cell_fwd = jax.vmap(jax.vmap(  # over B, then Hkv
        lambda q, k, v: _packed_fwd_cell(q, k, v, psched, scale),
        in_axes=(0, 0, 0)), in_axes=(0, 0, 0))
    cell_dq = jax.vmap(jax.vmap(
        lambda q, k, v, do, lse, dlt: _packed_dq_cell(
            q, k, v, do, lse, dlt, psched, scale),
        in_axes=(0, 0, 0, 0, 0, 0)), in_axes=(0, 0, 0, 0, 0, 0))
    cell_dkv = jax.vmap(jax.vmap(
        lambda q, k, v, do, lse, dlt: _packed_dkv_cell(
            q, k, v, do, lse, dlt, psched, scale),
        in_axes=(0, 0, 0, 0, 0, 0)), in_axes=(0, 0, 0, 0, 0, 0))

    def _group(q, hkv):  # (B, H, S, D) -> (B, Hkv, G, S, D)
        b, h, s, d = q.shape
        return q.reshape(b, hkv, h // hkv, s, d)

    def _ungroup(q):  # inverse
        b, hkv, g, s, d = q.shape
        return q.reshape(b, hkv * g, s, d)

    @jax.custom_vjp
    def attn(q, k, v):
        out, _ = attn_fwd(q, k, v)
        return out

    def attn_fwd(q, k, v):
        hkv = k.shape[1]
        OBS.record_launch(
            OBS.meta_from_packed("tri_attn.packed_fwd", psched, impl="scan",
                                 cells=q.shape[0] * q.shape[1]), (q, k, v))
        out_g, lse_g = cell_fwd(_group(q, hkv), k, v)
        return _ungroup(out_g), (q, k, v, _ungroup(out_g), lse_g)

    def attn_bwd(res, do):
        q, k, v, out, lse_g = res
        hkv = k.shape[1]
        cells = q.shape[0] * q.shape[1]
        OBS.record_launch(
            OBS.meta_from_packed("tri_attn.packed_bwd_dq", psched,
                                 impl="scan", cells=cells), (q, k, v, do))
        OBS.record_launch(
            OBS.meta_from_packed("tri_attn.packed_bwd_dkv", psched,
                                 impl="scan", cells=cells), (q, k, v, do))
        delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                        axis=-1)  # (B, H, S)
        qg, dog = _group(q, hkv), _group(do, hkv)
        dg = _group(delta[..., None], hkv)[..., 0]  # (B, Hkv, G, S)
        dq = cell_dq(qg, k, v, dog, lse_g, dg)
        dk, dv = cell_dkv(qg, k, v, dog, lse_g, dg)
        return _ungroup(dq), dk, dv

    attn.defvjp(attn_fwd, attn_bwd)
    return attn


def packed_decode_scan(q, k, v, tbl, *, capacity: int, blk: int,
                       n_members: int, scale: float):
    """Packed mixed-position decode round as one lax.scan (the CPU path).

    Mirrors the packed decode Pallas kernel 1:1 — same member table, same
    tile enumeration, same online-softmax order — but vectorizes the H axis
    in one pass instead of a grid dimension. q: (B, H, D); k, v:
    (B, S_cache, Hkv, D) native cache layout; tbl: (5, R) TRACED member
    table (runtime data, incl. the band-limit kv_first row; the whole
    round recompiles only when the static ``capacity`` bucket changes).
    Returns (B, H, D) with slots not covered by any member left zero."""
    b, h, d = q.shape
    s_cache, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    cache_tiles = s_cache // blk
    OBS.record_launch(
        OBS.meta_exact("tri_attn.packed_decode_fwd", "tri_attn",
                       impl="scan", kind="decode_round", steps=capacity,
                       block_shape=(1, blk), bb_bound=b * cache_tiles,
                       extra=(("capacity", capacity),)), (q, k, v))

    def step(carry, lam):
        m, l, acc, out = carry
        _, slot, j, kv_tiles, kv_len, kv_first = _decode_member(
            lam, tbl, n_members)
        slot_c = jnp.minimum(slot, b - 1)
        j_c = jnp.minimum(kv_first // blk + j, cache_tiles - 1)
        reset = j == 0
        m = jnp.where(reset, MASK_VALUE, m)
        l = jnp.where(reset, 0.0, l)
        acc = jnp.where(reset, 0.0, acc)

        qs = jax.lax.dynamic_slice(
            q, (slot_c, 0, 0), (1, h, d))[0].astype(jnp.float32)
        kb = jax.lax.dynamic_slice(
            k, (slot_c, j_c * blk, 0, 0),
            (1, blk, hkv, d))[0].astype(jnp.float32)  # (blk, Hkv, D)
        vb = jax.lax.dynamic_slice(
            v, (slot_c, j_c * blk, 0, 0), (1, blk, hkv, d))[0].astype(
            jnp.float32)
        qg = qs.reshape(hkv, g, d)
        s = jnp.einsum("kgd,tkd->kgt", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        kpos = (kv_first // blk + j) * blk + jnp.arange(blk, dtype=jnp.int32)
        s = jnp.where((kpos[None, None, :] >= kv_first)
                      & (kpos[None, None, :] < kv_len), s, MASK_VALUE)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "kgt,tkd->kgd", p, vb, preferred_element_type=jnp.float32)

        # Emit-gated (unlike _fwd_cell's unconditional write): the pad
        # member shares slot clamps with live slots, so only a member's
        # last tile may touch the output.
        norm = (acc / l).reshape(1, h, d).astype(out.dtype)
        upd = jax.lax.dynamic_update_slice(out, norm, (slot_c, 0, 0))
        out = jnp.where(j == kv_tiles - 1, upd, out)
        return (m_new, l, acc, out), None

    init = (
        jnp.full((hkv, g, 1), MASK_VALUE, jnp.float32),
        jnp.zeros((hkv, g, 1), jnp.float32),
        jnp.zeros((hkv, g, d), jnp.float32),
        jnp.zeros((b, h, d), q.dtype),
    )
    (_, _, _, out), _ = jax.lax.scan(
        step, init, jnp.arange(capacity, dtype=jnp.int32))
    return out


def fused_step_scan(q_pack, k_pack, v_pack, q_dec, k_cache, v_cache, tbl, *,
                    capacity: int, blk: int, n_members: int, scale: float):
    """Fused continuous-batching step as one lax.scan (the CPU path).

    Mirrors the fused Pallas kernel 1:1 — same (8, R) member table, same
    per-kind output routing, same online-softmax order — vectorizing the H
    axis in one pass. q_pack: (1, H, S_pack, D); k_pack/v_pack:
    (1, Hkv, S_pack, D); q_dec: (B, H, D); k_cache/v_cache:
    (B, S_cache, Hkv, D). Returns (out_pack (1, H, S_pack, D),
    out_dec (B, H, D)) with uncovered pack rows / decode slots left zero.
    """
    _, h, s_pack, d = q_pack.shape
    b = q_dec.shape[0]
    s_cache, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    cache_tiles = s_cache // blk
    n_pack_tiles = s_pack // blk
    OBS.record_launch(
        OBS.meta_exact("tri_attn.fused_step_fwd", "tri_attn",
                       impl="scan", kind="fused_step", steps=capacity,
                       block_shape=(blk, blk),
                       bb_bound=n_pack_tiles * n_pack_tiles
                       + b * cache_tiles, cells=h,
                       extra=(("capacity", capacity),
                              ("members", n_members))),
        (q_pack, k_pack, v_pack, q_dec, k_cache, v_cache))

    qpg = q_pack[0].reshape(hkv, g, s_pack, d)
    kpg = k_pack[0]  # (hkv, s_pack, d)
    vpg = v_pack[0]
    qdg = q_dec.reshape(b, hkv, g, d)

    def step(carry, lam):
        m, l, acc, out_p, out_d = carry
        r, is_p, local, i_p, j_p = _fused_member(lam, tbl, n_members)
        kv_tiles = tbl[2, r]
        kv_len = tbl[3, r]
        kv_first = jnp.where(is_p, 0, tbl[4, r])
        j_eff = jnp.where(is_p, j_p, local)
        from repro.core import packing as PK

        first = jnp.where(is_p, PK.first_col_params(i_p, tbl[3, r]), 0)
        last = jnp.where(is_p, PK.last_col_params(i_p, tbl[4, r]),
                         kv_tiles - 1)
        reset = j_eff == first
        m = jnp.where(reset, MASK_VALUE, m)
        l = jnp.where(reset, 0.0, l)
        acc = jnp.where(reset, 0.0, acc)

        row_q = jnp.where(is_p, tbl[5, r] + i_p, 0)
        row_k = jnp.where(is_p, tbl[5, r] + j_p, 0)
        slot_c = jnp.minimum(jnp.where(is_p, 0, tbl[5, r]), b - 1)
        j_d = jnp.where(is_p, 0, local)
        j_c = jnp.minimum(kv_first // blk + j_d, cache_tiles - 1)

        qp_t = jax.lax.dynamic_slice(
            qpg, (0, 0, row_q * blk, 0),
            (hkv, g, blk, d)).astype(jnp.float32)
        qd_t = jax.lax.dynamic_slice(
            qdg, (slot_c, 0, 0, 0), (1, hkv, g, d))[0].astype(
            jnp.float32)[:, :, None, :]                    # (hkv, g, 1, d)
        q = jnp.where(is_p, qp_t, jnp.broadcast_to(qd_t, qp_t.shape))
        kp_t = jax.lax.dynamic_slice(
            kpg, (0, row_k * blk, 0), (hkv, blk, d)).astype(jnp.float32)
        vp_t = jax.lax.dynamic_slice(
            vpg, (0, row_k * blk, 0), (hkv, blk, d)).astype(jnp.float32)
        kc_t = jax.lax.dynamic_slice(
            k_cache, (slot_c, j_c * blk, 0, 0),
            (1, blk, hkv, d))[0].transpose(1, 0, 2).astype(jnp.float32)
        vc_t = jax.lax.dynamic_slice(
            v_cache, (slot_c, j_c * blk, 0, 0),
            (1, blk, hkv, d))[0].transpose(1, 0, 2).astype(jnp.float32)
        k = jnp.where(is_p, kp_t, kc_t)
        v = jnp.where(is_p, vp_t, vc_t)

        s = jnp.einsum("kgqd,ktd->kgqt", q, k,
                       preferred_element_type=jnp.float32) * scale
        pmask = _packed_token_mask(i_p, j_p, blk, tbl[6, r], tbl[7, r])
        kpos = (kv_first // blk + j_d) * blk + jnp.arange(
            blk, dtype=jnp.int32)
        dmask = jnp.broadcast_to(
            ((kpos >= kv_first) & (kpos < kv_len))[None, :], (blk, blk))
        s = jnp.where(jnp.where(is_p, pmask, dmask)[None, None], s,
                      MASK_VALUE)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "kgqt,ktd->kgqd", p, v, preferred_element_type=jnp.float32)

        # Per-kind emit-gated routing (cf. packed_decode_scan): only a
        # member's LAST column may touch an output, and only its own one.
        norm = acc / l                                    # (hkv, g, blk, d)
        upd_p = jax.lax.dynamic_update_slice(
            out_p, norm.astype(out_p.dtype), (0, 0, row_q * blk, 0))
        out_p = jnp.where(is_p & (j_eff == last), upd_p, out_p)
        row0 = norm[:, :, 0, :].reshape(1, h, d)
        upd_d = jax.lax.dynamic_update_slice(
            out_d, row0.astype(out_d.dtype), (slot_c, 0, 0))
        out_d = jnp.where(jnp.logical_not(is_p) & (j_eff == last), upd_d,
                          out_d)
        return (m_new, l, acc, out_p, out_d), None

    init = (
        jnp.full((hkv, g, blk, 1), MASK_VALUE, jnp.float32),
        jnp.zeros((hkv, g, blk, 1), jnp.float32),
        jnp.zeros((hkv, g, blk, d), jnp.float32),
        jnp.zeros((hkv, g, s_pack, d), q_pack.dtype),
        jnp.zeros((b, h, d), q_dec.dtype),
    )
    (_, _, _, out_p, out_d), _ = jax.lax.scan(
        step, init, jnp.arange(capacity, dtype=jnp.int32))
    return out_p.reshape(1, h, s_pack, d), out_d


def _dq_cell(q, k, v, do, lse, delta, sched: TriSched, scale):
    g, s_len, d = q.shape
    bq, bk = sched.bq, sched.bk

    def step(carry, lam):
        dq_acc, dq = carry
        i, j = sched.rm_map(lam)
        reset = j == sched.rm_first_col(i)
        dq_acc = jnp.where(reset, 0.0, dq_acc)
        qi = _slice_rows(q, i, bq).astype(jnp.float32)
        kj = _slice_rows(k, j, bk).astype(jnp.float32)
        vj = _slice_rows(v, j, bk).astype(jnp.float32)
        doi = _slice_rows(do, i, bq).astype(jnp.float32)
        lse_i = jax.lax.dynamic_slice(lse, (0, i * bq), (g, bq))
        dlt_i = jax.lax.dynamic_slice(delta, (0, i * bq), (g, bq))
        s = jnp.einsum("gqd,kd->gqk", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(_token_mask(sched, i, j, bq, bk)[None], s, MASK_VALUE)
        p = jnp.exp(s - lse_i[..., None])
        dp = jnp.einsum("gqd,kd->gqk", doi, vj,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - dlt_i[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("gqk,kd->gqd", ds, kj,
                                     preferred_element_type=jnp.float32)
        dq = _update_rows(dq, dq_acc.astype(dq.dtype), i, bq)
        return (dq_acc, dq), None

    init = (jnp.zeros((g, bq, d), jnp.float32),
            jnp.zeros((g, s_len, d), q.dtype))
    (_, dq), _ = jax.lax.scan(
        step, init, jnp.arange(sched.rm_steps, dtype=jnp.int32))
    return dq


def _dkv_cell(q, k, v, do, lse, delta, sched: TriSched, scale):
    g, s_len, d = q.shape
    bq, bk = sched.bq, sched.bk

    def step(carry, lam):
        dk_acc, dv_acc, dk, dv = carry
        i, j = sched.cm_map(lam)
        reset = i == sched.cm_first_row(j)
        dk_acc = jnp.where(reset, 0.0, dk_acc)
        dv_acc = jnp.where(reset, 0.0, dv_acc)
        qi = _slice_rows(q, i, bq).astype(jnp.float32)
        kj = _slice_rows(k, j, bk).astype(jnp.float32)
        vj = _slice_rows(v, j, bk).astype(jnp.float32)
        doi = _slice_rows(do, i, bq).astype(jnp.float32)
        lse_i = jax.lax.dynamic_slice(lse, (0, i * bq), (g, bq))
        dlt_i = jax.lax.dynamic_slice(delta, (0, i * bq), (g, bq))
        s = jnp.einsum("gqd,kd->gqk", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(_token_mask(sched, i, j, bq, bk)[None], s, MASK_VALUE)
        p = jnp.exp(s - lse_i[..., None])  # (G, bq, bk)
        dv_acc = dv_acc + jnp.einsum("gqk,gqd->kd", p, doi,
                                     preferred_element_type=jnp.float32)
        dp = jnp.einsum("gqd,kd->gqk", doi, vj,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - dlt_i[..., None]) * scale
        dk_acc = dk_acc + jnp.einsum("gqk,gqd->kd", ds, qi,
                                     preferred_element_type=jnp.float32)
        dk = _update_rows(dk, dk_acc.astype(dk.dtype), j, bk)
        dv = _update_rows(dv, dv_acc.astype(dv.dtype), j, bk)
        return (dk_acc, dv_acc, dk, dv), None

    init = (jnp.zeros((bk, d), jnp.float32), jnp.zeros((bk, d), jnp.float32),
            jnp.zeros((s_len, d), k.dtype), jnp.zeros((s_len, d), v.dtype))
    (_, _, dk, dv), _ = jax.lax.scan(
        step, init, jnp.arange(sched.cm_steps, dtype=jnp.int32))
    return dk, dv


@functools.lru_cache(maxsize=None)
def make_scan_attention(sched: TriSched, scale: float):
    """Build the custom-VJP scan attention for static (sched, scale).

    Input/output layout: q (B, H, S, D); k, v (B, Hkv, S, D) -> (B, H, S, D).
    """

    cell_fwd = jax.vmap(jax.vmap(  # over B, then Hkv
        lambda q, k, v: _fwd_cell(q, k, v, sched, scale),
        in_axes=(0, 0, 0)), in_axes=(0, 0, 0))
    cell_dq = jax.vmap(jax.vmap(
        lambda q, k, v, do, lse, dlt: _dq_cell(q, k, v, do, lse, dlt, sched, scale),
        in_axes=(0, 0, 0, 0, 0, 0)), in_axes=(0, 0, 0, 0, 0, 0))
    cell_dkv = jax.vmap(jax.vmap(
        lambda q, k, v, do, lse, dlt: _dkv_cell(q, k, v, do, lse, dlt, sched, scale),
        in_axes=(0, 0, 0, 0, 0, 0)), in_axes=(0, 0, 0, 0, 0, 0))

    def _group(q, hkv):  # (B, H, S, D) -> (B, Hkv, G, S, D)
        b, h, s, d = q.shape
        return q.reshape(b, hkv, h // hkv, s, d)

    def _ungroup(q):  # inverse
        b, hkv, g, s, d = q.shape
        return q.reshape(b, hkv * g, s, d)

    @jax.custom_vjp
    def attn(q, k, v):
        out, _ = attn_fwd(q, k, v)
        return out

    def attn_fwd(q, k, v):
        hkv = k.shape[1]
        OBS.record_launch(
            OBS.meta_from_trisched("tri_attn.fwd", sched, impl="scan",
                                   cells=q.shape[0] * q.shape[1]),
            (q, k, v))
        out_g, lse_g = cell_fwd(_group(q, hkv), k, v)
        return _ungroup(out_g), (q, k, v, _ungroup(out_g), lse_g)

    def attn_bwd(res, do):
        q, k, v, out, lse_g = res
        hkv = k.shape[1]
        cells = q.shape[0] * q.shape[1]
        OBS.record_launch(
            OBS.meta_from_trisched("tri_attn.bwd_dq", sched, impl="scan",
                                   cells=cells), (q, k, v, do))
        OBS.record_launch(
            OBS.meta_from_trisched("tri_attn.bwd_dkv", sched, impl="scan",
                                   cells=cells), (q, k, v, do))
        delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                        axis=-1)  # (B, H, S)
        qg, dog = _group(q, hkv), _group(do, hkv)
        dg = _group(delta[..., None], hkv)[..., 0]  # (B, Hkv, G, S)
        dq = cell_dq(qg, k, v, dog, lse_g, dg)
        dk, dv = cell_dkv(qg, k, v, dog, lse_g, dg)
        return _ungroup(dq), dk, dv

    attn.defvjp(attn_fwd, attn_bwd)
    return attn
