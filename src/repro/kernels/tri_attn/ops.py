"""Public attention op: schedule-aware triangular-domain flash attention.

``triangular_attention`` is the single entry point the models use. It picks
the schedule kind from the mask parameters, and dispatches between:

  impl='pallas' — the TPU Pallas kernels (kernel.py); interpret=True on CPU.
  impl='scan'   — the pure-XLA LTM scan (scan_impl.py); the dry-run / CPU
                  training path. Differentiable via custom VJP.
  impl='ref'    — the O(S^2)-memory oracle (ref.py); tests only.
  impl='bb'     — the paper's bounding-box baseline Pallas kernel (fwd only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.tri_attn import kernel as K
from repro.kernels.tri_attn import ref as R
from repro.kernels.tri_attn import scan_impl as SC
from repro.kernels.tri_attn.kernel import TriSched


def make_sched(s_len: int, *, block_q: int, block_k: int, window=None,
               prefix: int = 0) -> TriSched:
    bq = min(block_q, s_len)
    bk = min(block_k, s_len)
    if window is not None or prefix:
        bk = bq = min(bq, bk)  # square tiles for band/prefix domains
    assert s_len % bq == 0 and s_len % bk == 0, (
        f"seq {s_len} not divisible by blocks ({bq}, {bk})")
    if window is not None:
        kind = "band"
    elif prefix:
        kind = "prefix"
    else:
        kind = "ltm"
        bk = bq = min(bq, bk)  # triangular domain also needs square tiles
    return TriSched(kind=kind, n=s_len // bq, bq=bq, bk=bk,
                    window=window, prefix=prefix)


@functools.lru_cache(maxsize=None)
def _pallas_attention(sched: TriSched, scale: float, interpret: bool):
    @jax.custom_vjp
    def attn(q, k, v):
        out, _ = K.fwd(q, k, v, sched, sm_scale=scale, interpret=interpret)
        return out

    def attn_fwd(q, k, v):
        out, lse = K.fwd(q, k, v, sched, sm_scale=scale, interpret=interpret)
        return out, (q, k, v, out, lse)

    def attn_bwd(res, do):
        q, k, v, out, lse = res
        return K.bwd(q, k, v, out, lse, do, sched, sm_scale=scale,
                     interpret=interpret)

    attn.defvjp(attn_fwd, attn_bwd)
    return attn


def triangular_attention(q, k, v, *, window=None, prefix: int = 0,
                         sm_scale=None, impl: str = "scan",
                         block_q: int = 256, block_k: int = 256,
                         interpret: bool = True):
    """Causal (optionally windowed / prefix-causal) attention.

    q: (B, H, S, D); k, v: (B, Hkv, S, D), H % Hkv == 0. Returns (B, H, S, D).
    """
    b, h, s_len, d = q.shape
    scale = float(sm_scale if sm_scale is not None else 1.0 / (d ** 0.5))
    if impl == "ref":
        return R.mha_reference(q, k, v, sm_scale=scale, window=window,
                               prefix=prefix)
    sched = make_sched(s_len, block_q=block_q, block_k=block_k,
                       window=window, prefix=prefix)
    if impl == "pallas":
        return _pallas_attention(sched, scale, interpret)(q, k, v)
    if impl == "scan":
        return SC.make_scan_attention(sched, scale)(q, k, v)
    if impl == "bb":
        out, _ = K.fwd_bb(q, k, v, sched, sm_scale=scale, interpret=interpret)
        return out
    raise ValueError(f"unknown impl {impl!r}")
