"""Public attention op: schedule-aware triangular-domain flash attention.

``triangular_attention`` is the single entry point the models use. It picks
the schedule kind from the mask parameters, and dispatches between:

  impl='pallas' — the TPU Pallas kernels (kernel.py); interpret=True on CPU.
  impl='scan'   — the pure-XLA LTM scan (scan_impl.py); the dry-run / CPU
                  training path. Differentiable via custom VJP.
  impl='ref'    — the O(S^2)-memory oracle (ref.py); tests only.
  impl='bb'     — the paper's bounding-box baseline Pallas kernel (fwd only).

``packed_prefill_attention`` + ``make_packed_sched`` are the ragged-batch
variant: R requests of mixed lengths concatenated along S, attended
block-diagonally in ONE launch over the core/packing PackedSchedule grid
(forward-only — the serving engine's bulk-admission prefill).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.tri_attn import kernel as K
from repro.kernels.tri_attn import ref as R
from repro.kernels.tri_attn import scan_impl as SC
from repro.kernels.tri_attn.kernel import PackedTriSched, TriSched


def make_sched(s_len: int, *, block_q: int, block_k: int, window=None,
               prefix: int = 0) -> TriSched:
    bq = min(block_q, s_len)
    bk = min(block_k, s_len)
    if window is not None or prefix:
        bk = bq = min(bq, bk)  # square tiles for band/prefix domains
    assert s_len % bq == 0 and s_len % bk == 0, (
        f"seq {s_len} not divisible by blocks ({bq}, {bk})")
    if window is not None:
        kind = "band"
    elif prefix:
        kind = "prefix"
    else:
        kind = "ltm"
        bk = bq = min(bq, bk)  # triangular domain also needs square tiles
    return TriSched(kind=kind, n=s_len // bq, bq=bq, bk=bk,
                    window=window, prefix=prefix)


def make_packed_sched(seq_lens, *, block: int, window=None,
                      prefix=0) -> PackedTriSched:
    """Packed ragged-batch schedule for per-request sequence lengths.

    seq_lens: token lengths, each already padded to a multiple of ``block``
    (the engine pads prompts; the packed operand is their concatenation).
    window / prefix may be scalars (applied to every member) or
    per-request sequences. Members degrade exactly like make_sched:
    window -> band, prefix -> prefix-causal, else ltm.
    """
    seq_lens = tuple(int(s) for s in seq_lens)
    r = len(seq_lens)
    windows = tuple(window) if isinstance(window, (list, tuple)) \
        else (window,) * r
    prefixes = tuple(prefix) if isinstance(prefix, (list, tuple)) \
        else (prefix,) * r
    assert len(windows) == r and len(prefixes) == r, (
        f"per-request window/prefix lists must match the batch: "
        f"{len(windows)} windows / {len(prefixes)} prefixes for {r} "
        f"requests")
    members = []
    for s_len, w, p in zip(seq_lens, windows, prefixes):
        assert s_len % block == 0, (
            f"member seq {s_len} not padded to block {block}")
        if w is not None:
            kind = "band"
        elif p:
            kind = "prefix"
        else:
            kind = "ltm"
        members.append(TriSched(kind=kind, n=s_len // block, bq=block,
                                bk=block, window=w, prefix=p))
    return PackedTriSched(members=tuple(members))


def packed_prefill_attention(q, k, v, psched: PackedTriSched, *,
                             sm_scale=None, impl: str = "scan",
                             interpret: bool = True):
    """Ragged batched-prefill attention over the packed layout.

    q: (B, H, S_total, D); k, v: (B, Hkv, S_total, D) — every batch row
    shares the same packing (the engine uses B=1). One launch covers all
    requests: sum_r blocks_r grid steps, zero cross-request tiles.
    Forward-only (prefill is inference). Returns (B, H, S_total, D).
    """
    b, h, s_len, d = q.shape
    assert s_len == psched.s_total, (
        f"packed operand has {s_len} rows but the schedule covers "
        f"{psched.s_total}")
    scale = float(sm_scale if sm_scale is not None else 1.0 / (d ** 0.5))
    if impl == "pallas":
        out, _ = K.packed_fwd(q, k, v, psched, sm_scale=scale,
                              interpret=interpret)
        return out
    if impl == "scan":
        return SC.make_packed_scan_attention(psched, scale)(q, k, v)
    if impl == "ref":
        outs = []
        base = 0
        for m in psched.members:
            s_r = m.n * m.bq
            seg = slice(base, base + s_r)
            outs.append(R.mha_reference(q[:, :, seg], k[:, :, seg],
                                        v[:, :, seg], sm_scale=scale,
                                        window=m.window, prefix=m.prefix))
            base += s_r
        return jnp.concatenate(outs, axis=2)
    raise ValueError(f"unknown impl {impl!r}")


@functools.lru_cache(maxsize=None)
def _pallas_attention(sched: TriSched, scale: float, interpret: bool):
    @jax.custom_vjp
    def attn(q, k, v):
        out, _ = K.fwd(q, k, v, sched, sm_scale=scale, interpret=interpret)
        return out

    def attn_fwd(q, k, v):
        out, lse = K.fwd(q, k, v, sched, sm_scale=scale, interpret=interpret)
        return out, (q, k, v, out, lse)

    def attn_bwd(res, do):
        q, k, v, out, lse = res
        return K.bwd(q, k, v, out, lse, do, sched, sm_scale=scale,
                     interpret=interpret)

    attn.defvjp(attn_fwd, attn_bwd)
    return attn


def triangular_attention(q, k, v, *, window=None, prefix: int = 0,
                         sm_scale=None, impl: str = "scan",
                         block_q: int = 256, block_k: int = 256,
                         interpret: bool = True):
    """Causal (optionally windowed / prefix-causal) attention.

    q: (B, H, S, D); k, v: (B, Hkv, S, D), H % Hkv == 0. Returns (B, H, S, D).
    """
    b, h, s_len, d = q.shape
    scale = float(sm_scale if sm_scale is not None else 1.0 / (d ** 0.5))
    if impl == "ref":
        return R.mha_reference(q, k, v, sm_scale=scale, window=window,
                               prefix=prefix)
    sched = make_sched(s_len, block_q=block_q, block_k=block_k,
                       window=window, prefix=prefix)
    if impl == "pallas":
        return _pallas_attention(sched, scale, interpret)(q, k, v)
    if impl == "scan":
        return SC.make_scan_attention(sched, scale)(q, k, v)
    if impl == "bb":
        out, _ = K.fwd_bb(q, k, v, sched, sm_scale=scale, interpret=interpret)
        return out
    raise ValueError(f"unknown impl {impl!r}")
