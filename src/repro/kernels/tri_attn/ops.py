"""Public attention op: schedule-aware triangular-domain flash attention.

``triangular_attention`` is the single entry point the models use. It picks
the schedule kind from the mask parameters, and dispatches between:

  impl='pallas' — the TPU Pallas kernels (kernel.py); interpret=True on CPU.
  impl='scan'   — the pure-XLA LTM scan (scan_impl.py); the dry-run / CPU
                  training path. Differentiable via custom VJP.
  impl='ref'    — the O(S^2)-memory oracle (ref.py); tests only.
  impl='bb'     — the paper's bounding-box baseline Pallas kernel (fwd only).

``packed_prefill_attention`` + ``make_packed_sched`` are the ragged-batch
variant: R requests of mixed lengths concatenated along S, attended
block-diagonally in ONE launch over the core/packing PackedSchedule grid.
It serves the engine's bulk-admission prefill AND — via custom VJP over
the packed dq / dk/dv kernels — ragged document-batch training: jax.grad
issues one packed launch per direction on both the pallas and scan paths.

``packed_decode_attention`` + ``make_decode_table`` + ``DecodeRoundSpec``
are the DECODE-time analogue: one mixed-position decode round per launch,
each live slot attending only its own valid KV prefix. Unlike the prefill
pack the member table is runtime data (positions advance every round), so
it rides as a traced array / scalar-prefetch SMEM operand over a
statically bucketed grid capacity.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.tri_attn import kernel as K
from repro.kernels.tri_attn import ref as R
from repro.kernels.tri_attn import scan_impl as SC
from repro.kernels.tri_attn.kernel import (DECODE_NO_EMIT, PackedTriSched,
                                           TriSched)


def make_sched(s_len: int, *, block_q: int, block_k: int, window=None,
               prefix: int = 0) -> TriSched:
    bq = min(block_q, s_len)
    bk = min(block_k, s_len)
    if window is not None or prefix:
        bk = bq = min(bq, bk)  # square tiles for band/prefix domains
    assert s_len % bq == 0 and s_len % bk == 0, (
        f"seq {s_len} not divisible by blocks ({bq}, {bk})")
    if window is not None:
        kind = "band"
    elif prefix:
        kind = "prefix"
    else:
        kind = "ltm"
        bk = bq = min(bq, bk)  # triangular domain also needs square tiles
    return TriSched(kind=kind, n=s_len // bq, bq=bq, bk=bk,
                    window=window, prefix=prefix)


def make_packed_sched(seq_lens, *, block: int, window=None,
                      prefix=0) -> PackedTriSched:
    """Packed ragged-batch schedule for per-request sequence lengths.

    seq_lens: token lengths, each already padded to a multiple of ``block``
    (the engine pads prompts; the packed operand is their concatenation).
    window / prefix may be scalars (applied to every member) or
    per-request sequences. Members degrade exactly like make_sched:
    window -> band, prefix -> prefix-causal, else ltm.
    """
    seq_lens = tuple(int(s) for s in seq_lens)
    r = len(seq_lens)
    windows = tuple(window) if isinstance(window, (list, tuple)) \
        else (window,) * r
    prefixes = tuple(prefix) if isinstance(prefix, (list, tuple)) \
        else (prefix,) * r
    assert len(windows) == r and len(prefixes) == r, (
        f"per-request window/prefix lists must match the batch: "
        f"{len(windows)} windows / {len(prefixes)} prefixes for {r} "
        f"requests")
    members = []
    for s_len, w, p in zip(seq_lens, windows, prefixes):
        assert s_len % block == 0, (
            f"member seq {s_len} not padded to block {block}")
        if w is not None:
            kind = "band"
        elif p:
            kind = "prefix"
        else:
            kind = "ltm"
        members.append(TriSched(kind=kind, n=s_len // block, bq=block,
                                bk=block, window=w, prefix=p))
    return PackedTriSched(members=tuple(members))


@functools.lru_cache(maxsize=None)
def _packed_pallas_attention(psched: PackedTriSched, scale: float,
                             interpret: bool):
    """Custom-VJP packed Pallas attention for static (psched, scale):
    jax.grad issues ONE packed_bwd launch per direction (dq row-major,
    dk/dv column-major) over the forward's (7, R) member table — no
    fallback to autodiff through the forward, no pad-to-max."""

    @jax.custom_vjp
    def attn(q, k, v):
        out, _ = K.packed_fwd(q, k, v, psched, sm_scale=scale,
                              interpret=interpret)
        return out

    def attn_fwd(q, k, v):
        out, lse = K.packed_fwd(q, k, v, psched, sm_scale=scale,
                                interpret=interpret)
        return out, (q, k, v, out, lse)

    def attn_bwd(res, do):
        q, k, v, out, lse = res
        return K.packed_bwd(q, k, v, out, lse, do, psched, sm_scale=scale,
                            interpret=interpret)

    attn.defvjp(attn_fwd, attn_bwd)
    return attn


def packed_prefill_attention(q, k, v, psched: PackedTriSched, *,
                             sm_scale=None, impl: str = "scan",
                             interpret: bool = True):
    """Ragged batched attention over the packed layout (prefill AND train).

    q: (B, H, S_total, D); k, v: (B, Hkv, S_total, D) — every batch row
    shares the same packing (the engine uses B=1). One launch covers all
    requests: sum_r blocks_r grid steps, zero cross-request tiles.
    Differentiable on the 'pallas' and 'scan' paths via custom VJP (packed
    dq / dk/dv launches over the same member table — the ragged
    document-batch training fast path). Returns (B, H, S_total, D).
    """
    b, h, s_len, d = q.shape
    assert s_len == psched.s_total, (
        f"packed operand has {s_len} rows but the schedule covers "
        f"{psched.s_total}")
    scale = float(sm_scale if sm_scale is not None else 1.0 / (d ** 0.5))
    if impl == "pallas":
        return _packed_pallas_attention(psched, scale, interpret)(q, k, v)
    if impl == "scan":
        return SC.make_packed_scan_attention(psched, scale)(q, k, v)
    if impl == "ref":
        outs = []
        base = 0
        for m in psched.members:
            s_r = m.n * m.bq
            seg = slice(base, base + s_r)
            outs.append(R.mha_reference(q[:, :, seg], k[:, :, seg],
                                        v[:, :, seg], sm_scale=scale,
                                        window=m.window, prefix=m.prefix))
            base += s_r
        return jnp.concatenate(outs, axis=2)
    raise ValueError(f"unknown impl {impl!r}")


# ---------------------------------------------------------------------------
# Packed mixed-position decode (one launch per decode round)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DecodeRoundSpec:
    """STATIC half of a packed decode round (hashable — it is a jit static
    arg). The dynamic half — which slots are live, at which KV lengths —
    is the (5, R) member table built fresh each round by
    ``make_decode_table`` and passed as a traced array, so positions can
    advance every round without recompiling; only a change of capacity
    bucket (or batch geometry) compiles a new program."""

    n_members: int  # table width R: max live slots + 1 (the pad member)
    capacity: int   # static grid size; >= the round's live tiles
    blk: int        # KV tile edge (divides S_cache)
    impl: str = "scan"

    # the dynamic half is the (5, R) member table (make_decode_table):
    # starts / slot / kv_tiles / kv_len / kv_first


def make_decode_table(kv_lens, slots, *, blk: int, n_members: int,
                      n_slots: int, s_cache: int = 0, window=None):
    """Build one decode round's (5, n_members) int32 member table.

    kv_lens[i] is live slot ``slots[i]``'s valid KV prefix in TOKENS
    (min(pos + 1, S_cache) — for rolling sliding-window buffers the valid
    region is always a prefix of the buffer, so one length describes it).
    Unused member columns are empty (0 tiles, skipped by the lambda
    search); the last column is the pad member (slot == n_slots, the
    garbage output row; kv_tiles == DECODE_NO_EMIT so it never emits).

    window (scalar or per-slot sequence, tokens) BAND-limits each member:
    the slot attends only KV tokens [max(0, kv_len - w), kv_len), i.e.
    cache tiles [kv_first // blk, ceil(kv_len / blk)) — at most
    ceil(w / blk) + 1 tiles however deep the position, instead of the full
    ceil(kv_len / blk)-tile prefix. Only valid when cache row index ==
    absolute token position (a NON-rolling cache: a rolling SWA buffer is
    already window-sized and its rows alias positions mod S_cache, so its
    members must keep window=None).

    Returns (table, needed) with ``needed`` the live tile count —
    sum_r member tiles, the number the lockstep pad-to-max round would
    inflate to n_live * max_r tiles.
    """
    kv_lens = [int(s) for s in kv_lens]
    slots = [int(s) for s in slots]
    windows = list(window) if isinstance(window, (list, tuple)) \
        else [window] * len(kv_lens)
    assert len(windows) == len(kv_lens), (
        f"per-slot window list must match the round: {len(windows)} "
        f"windows for {len(kv_lens)} live slots")
    assert len(kv_lens) == len(slots) <= n_members - 1, (
        f"{len(kv_lens)} live members need table width >= "
        f"{len(kv_lens) + 1}, got {n_members}")
    assert all(s >= 1 for s in kv_lens), "live slots attend >= 1 token"
    assert all(w is None or w >= 1 for w in windows), (
        "band-limited slots attend >= 1 token windows")
    # A kv_len beyond the cache would be silently corrupted downstream
    # (the kernel clamps the tile INDEX in-bounds but the token mask
    # would keep admitting the phantom tail) — reject it here, where the
    # lengths are still host ints. Callers with a rolling SWA buffer must
    # pre-clamp to min(pos + 1, S_cache).
    if s_cache:
        assert max(kv_lens) <= s_cache, (
            f"kv_lens {kv_lens} exceed the KV cache ({s_cache} rows); "
            f"clamp to min(pos + 1, S_cache)")
    cols, cur = [], 0
    for kl, sl, w in zip(kv_lens, slots, windows):
        first = 0 if w is None else max(0, kl - int(w))
        t = -(-kl // blk) - first // blk
        cols.append((cur, sl, t, kl, first))
        cur += t
    while len(cols) < n_members - 1:
        cols.append((cur, 0, 0, 0, 0))
    cols.append((cur, n_slots, DECODE_NO_EMIT, 0, 0))
    return np.asarray(cols, np.int32).T.copy(), cur


def packed_decode_attention(q, k_cache, v_cache, tbl,
                            spec: DecodeRoundSpec, *, sm_scale=None,
                            interpret: bool = True):
    """Single-token attention for a whole mixed-position decode round.

    q: (B, H, D) rotated queries (one new token per slot); k_cache,
    v_cache: (B, S_cache, Hkv, D) native cache layout with the new token
    already written. Each live slot attends ONLY its own valid KV prefix:
    sum_r ceil(kv_len_r / blk) tiles in ONE launch, vs the lockstep
    einsum's B * S_cache pad-to-max. Slots without a live member return
    zeros. impl: 'pallas' (member table via scalar-prefetch SMEM),
    'scan' (CPU lax.scan mirror), 'ref' (masked-einsum oracle).
    """
    b, h, d = q.shape
    s_cache = k_cache.shape[1]
    scale = float(sm_scale if sm_scale is not None else 1.0 / (d ** 0.5))
    assert tbl.shape == (5, spec.n_members), (tbl.shape, spec.n_members)
    assert s_cache % spec.blk == 0, (s_cache, spec.blk)
    assert spec.capacity >= 1
    if spec.impl == "pallas":
        full = K.packed_decode_fwd(q, k_cache, v_cache, tbl,
                                   capacity=spec.capacity, blk=spec.blk,
                                   sm_scale=scale, interpret=interpret)
        covered = _covered_slots(tbl, b)
        return jnp.where(covered[:, None, None], full[:b], 0)
    if spec.impl == "scan":
        return SC.packed_decode_scan(q, k_cache, v_cache, tbl,
                                     capacity=spec.capacity, blk=spec.blk,
                                     n_members=spec.n_members, scale=scale)
    if spec.impl == "ref":
        kv_len = _slot_kv_lens(tbl, b)
        kv_first = _slot_kv_firsts(tbl, b)
        srng = jnp.arange(s_cache)[None, :]
        valid = (srng >= kv_first[:, None]) & (srng < kv_len[:, None])
        out = _masked_decode_einsum(q, k_cache, v_cache, valid, scale)
        return jnp.where(kv_len[:, None, None] > 0, out, 0)
    raise ValueError(f"unknown impl {spec.impl!r}")


def _covered_slots(tbl, b):
    """(B,) bool: slots owned by some live member (scatter-max over the
    table; the pad member's slot == B lands in the dropped extra row)."""
    return jnp.zeros((b + 1,), bool).at[tbl[1]].max(tbl[3] > 0)[:b]


def _slot_kv_lens(tbl, b):
    """(B,) int32 valid KV end per slot (0 where no live member)."""
    return jnp.zeros((b + 1,), jnp.int32).at[tbl[1]].max(tbl[3])[:b]


def _slot_kv_firsts(tbl, b):
    """(B,) int32 valid KV start per slot (band-limited members; 0 when
    the member attends its whole prefix or the slot has no member)."""
    return jnp.zeros((b + 1,), jnp.int32).at[tbl[1]].max(tbl[4])[:b]


def _masked_decode_einsum(q, k_cache, v_cache, valid, scale):
    """Lockstep-style full-cache masked attention (the decode oracle):
    q (B, H, D), caches (B, S, Hkv, D), valid (B, S) -> (B, H, D)."""
    b, h, d = q.shape
    hkv = k_cache.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg,
                   k_cache.astype(jnp.float32)) * scale
    s = jnp.where(valid[:, None, None, :], s, R.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Fused continuous-batching step (one launch: prefill members + decode rows)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FusedStepSpec:
    """STATIC half of a fused continuous-batching step (hashable jit
    static arg). One launch carries BOTH the round's newly admitted
    prompts (triangular/band/prefix members over the packed operand) and
    every live decode slot (single-row members over the KV cache). The
    dynamic half is the (8, n_members) fused table from
    ``make_fused_table``; capacity = psched.steps + the bucketed decode
    capacity, so rounds sharing a packing template and a decode bucket
    share one compiled program."""

    n_members: int  # fused table width: prefill members + decode + pad
    capacity: int   # static grid size >= prefill steps + live decode tiles
    blk: int        # tile edge (divides S_pack and S_cache)
    impl: str = "scan"


def make_fused_table(psched: PackedTriSched, kv_lens, slots, *, blk: int,
                     n_members: int, n_slots: int, s_cache: int = 0,
                     window=None):
    """Build one fused step's (8, n_members) int32 member table.

    Prefill columns come first (one per psched member, translated from
    the (7, R) packed-prefill table), then the decode columns
    (make_decode_table rebased by psched.steps), then the shared pad
    member. Row ABI (kernel.py `_fused_step_kernel`):

      0 starts | 1 kind (0=prefill, 1=decode/pad) | 2 n|kv_tiles |
      3 w_b|kv_len | 4 p_b|kv_first | 5 q_off|slot | 6 win|0 | 7 pre|0

    Returns (table, needed_total) with needed_total = psched.steps +
    live decode tiles — the minimum grid the round actually uses.
    """
    pt = np.asarray(psched.table())
    r_p = pt.shape[1]
    assert r_p >= 1, "fused step needs at least one prefill member"
    assert all(m.bq == blk and m.bk == blk for m in psched.members), (
        "fused step requires uniform square tiles == blk")
    dt, needed_dec = make_decode_table(
        list(kv_lens), list(slots), blk=blk, n_members=n_members - r_p,
        n_slots=n_slots, s_cache=s_cache if len(list(kv_lens)) else 0,
        window=window)
    cols = []
    for c in range(r_p):
        t = pt[:, c]
        cols.append((t[0], 0, t[2], t[3], t[4], t[1], t[5], t[6]))
    for c in range(dt.shape[1]):
        dc = dt[:, c]
        cols.append((psched.steps + dc[0], 1, dc[2], dc[3], dc[4],
                     dc[1], 0, 0))
    return np.asarray(cols, np.int32).T.copy(), psched.steps + needed_dec


def fused_step_attention(q_pack, k_pack, v_pack, q_dec, k_cache, v_cache,
                         tbl, psched: PackedTriSched, spec: FusedStepSpec,
                         *, sm_scale=None, interpret: bool = True):
    """One attention launch for a whole continuous-batching engine step.

    q_pack: (1, H, S_pack, D) packed admitted prompts (k_pack/v_pack
    (1, Hkv, S_pack, D) their rotated keys/values); q_dec: (B, H, D) one
    new token per slot; k_cache/v_cache: (B, S_cache, Hkv, D) with the
    decode tokens already written. Prefill members attend
    block-diagonally within the pack; decode members attend their own
    valid cache prefix — all from ONE member table in one launch.
    Returns (out_pack (1, H, S_pack, D), out_dec (B, H, D)); slots
    without a live decode member return zeros.
    """
    b, h, d = q_dec.shape
    s_pack = q_pack.shape[2]
    scale = float(sm_scale if sm_scale is not None else 1.0 / (d ** 0.5))
    assert tbl.shape == (8, spec.n_members), (tbl.shape, spec.n_members)
    assert s_pack == psched.s_total, (s_pack, psched.s_total)
    assert k_cache.shape[1] % spec.blk == 0, (k_cache.shape, spec.blk)
    assert spec.capacity >= psched.steps, (spec.capacity, psched.steps)
    if spec.impl == "pallas":
        o_pack, o_dec = K.fused_step_fwd(
            q_pack, k_pack, v_pack, q_dec, k_cache, v_cache, tbl,
            capacity=spec.capacity, blk=spec.blk,
            n_pack_tiles=s_pack // spec.blk, sm_scale=scale,
            interpret=interpret)
        covered = _fused_covered_slots(tbl, b)
        return (o_pack[:, :, :s_pack],
                jnp.where(covered[:, None, None], o_dec[:b], 0))
    if spec.impl == "scan":
        return SC.fused_step_scan(
            q_pack, k_pack, v_pack, q_dec, k_cache, v_cache, tbl,
            capacity=spec.capacity, blk=spec.blk,
            n_members=spec.n_members, scale=scale)
    raise ValueError(f"unknown impl {spec.impl!r}")


def _fused_covered_slots(tbl, b):
    """(B,) bool: slots owned by a live DECODE member of the fused table
    (prefill columns scatter into the dropped extra row)."""
    return jnp.zeros((b + 1,), bool).at[
        jnp.where(tbl[1] == 1, tbl[5], b)].max(tbl[3] > 0)[:b]


@functools.lru_cache(maxsize=None)
def _pallas_attention(sched: TriSched, scale: float, interpret: bool):
    @jax.custom_vjp
    def attn(q, k, v):
        out, _ = K.fwd(q, k, v, sched, sm_scale=scale, interpret=interpret)
        return out

    def attn_fwd(q, k, v):
        out, lse = K.fwd(q, k, v, sched, sm_scale=scale, interpret=interpret)
        return out, (q, k, v, out, lse)

    def attn_bwd(res, do):
        q, k, v, out, lse = res
        return K.bwd(q, k, v, out, lse, do, sched, sm_scale=scale,
                     interpret=interpret)

    attn.defvjp(attn_fwd, attn_bwd)
    return attn


def triangular_attention(q, k, v, *, window=None, prefix: int = 0,
                         sm_scale=None, impl: str = "scan",
                         block_q: int = 256, block_k: int = 256,
                         interpret: bool = True):
    """Causal (optionally windowed / prefix-causal) attention.

    q: (B, H, S, D); k, v: (B, Hkv, S, D), H % Hkv == 0. Returns (B, H, S, D).
    """
    b, h, s_len, d = q.shape
    scale = float(sm_scale if sm_scale is not None else 1.0 / (d ** 0.5))
    if impl == "ref":
        return R.mha_reference(q, k, v, sm_scale=scale, window=window,
                               prefix=prefix)
    sched = make_sched(s_len, block_q=block_q, block_k=block_k,
                       window=window, prefix=prefix)
    if impl == "pallas":
        return _pallas_attention(sched, scale, interpret)(q, k, v)
    if impl == "scan":
        return SC.make_scan_attention(sched, scale)(q, k, v)
    if impl == "bb":
        out, _ = K.fwd_bb(q, k, v, sched, sm_scale=scale, interpret=interpret)
        return out
    raise ValueError(f"unknown impl {impl!r}")
