"""Public fused WKV6 op. impl='pallas' (TPU kernel; interpret on CPU) or
'ref' (sequential oracle). The training path keeps the chunked
exp-argument formulation in models/rwkv6.py (numerically matched — see
tests); the kernel is the TPU-native replacement the roofline's
wkv-kernel adjustment is backed by."""

from __future__ import annotations

from repro.kernels.wkv_scan import kernel as K
from repro.kernels.wkv_scan import ref as R


def wkv(r, k, v, lw, u, s0=None, *, impl: str = "pallas",
        block_l: int = 64, interpret: bool = True):
    if impl == "pallas":
        return K.wkv(r, k, v, lw, u, s0, block_l=block_l,
                     interpret=interpret)
    if impl == "ref":
        return R.wkv_ref(r, k, v, lw, u, s0)
    raise ValueError(f"unknown impl {impl!r}")
