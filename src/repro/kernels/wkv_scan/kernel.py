"""Pallas TPU kernel: fused WKV6 (RWKV-6) linear-attention scan.

The XLA chunked formulation (models/rwkv6._wkv_chunk) materializes the
(B, t, s, H, hd) intra-chunk decay tensor in HBM — the strictly-lower-
triangular intra-chunk domain the framework's schedule accounting covers.
This kernel keeps the (hd, hd) state and every chunk intermediate in VMEM
and streams only r/k/v/lw in and out through HBM.

Grid: (B, H, L/block_l), time innermost so the state scratch carries across
chunks (the same revisit-friendly ordering as the LTM row-major schedule
and the ssm_scan kernel). Per step: one outer product, one vec-mat and one
per-row decay on (hd, hd) — hd = 64 pads VPU lanes to 128; acceptable for
the state-resident formulation (noted for the roofline).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.obs import launch as OBS


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, out_ref,
                sout_ref, s_s, *, block_l: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_s[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0].astype(jnp.float32)    # (block_l, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    lw = lw_ref[0, :, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (hd,)

    def step(t, carry):
        s, outs = carry
        kt, vt, rt, lwt = k[t], v[t], r[t], lw[t]   # (hd,)
        kv = kt[:, None] * vt[None, :]              # (hd, hd)
        out_t = jnp.sum(rt[:, None] * (s + u[:, None] * kv), axis=0)
        s = jnp.exp(lwt)[:, None] * s + kv
        outs = jax.lax.dynamic_update_slice(outs, out_t[None, :], (t, 0))
        return s, outs

    outs0 = jnp.zeros_like(r)
    s, outs = jax.lax.fori_loop(0, block_l, step, (s_s[...], outs0))
    s_s[...] = s
    out_ref[0, :, 0] = outs.astype(out_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit():
        sout_ref[0, 0] = s.astype(sout_ref.dtype)


def wkv(r, k, v, lw, u, s0=None, *, block_l: int = 64,
        interpret: bool = True):
    """r, k, v, lw: (B, L, H, hd); u: (H, hd); s0: (B, H, hd, hd).

    Returns (out (B, L, H, hd) in r.dtype, s_L (B, H, hd, hd) f32)."""
    b, l, h, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    block_l = min(block_l, l)
    assert l % block_l == 0, (l, block_l)
    n_chunks = l // block_l
    grid = (b, h, n_chunks)

    seq_spec = pl.BlockSpec((1, block_l, 1, hd),
                            lambda bi, hi, ci: (bi, ci, hi, 0))
    out, s_out = OBS.instrumented_pallas_call(
        functools.partial(_wkv_kernel, block_l=block_l, n_chunks=n_chunks),
        meta=OBS.meta_dense("wkv_scan.wkv", "wkv_scan", impl="pallas",
                            grid=(n_chunks,), block_shape=(block_l, hd),
                            tiles_domain=n_chunks, kind="chunked",
                            cells=b * h),
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,           # r, k, v, lw
            pl.BlockSpec((1, hd), lambda bi, hi, ci: (hi, 0)),  # u
            pl.BlockSpec((1, 1, hd, hd),
                         lambda bi, hi, ci: (bi, hi, 0, 0)),  # s0
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, hd, hd),
                         lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, h, hd), r.dtype),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u, s0)
    return out, s_out
