"""Pure-jnp oracle for the fused WKV6 (RWKV-6 Finch) recurrence.

Per head (state S: (hd_k, hd_v)):
    out_t = r_t . (S_{t-1} + diag(u) k_t^T v_t)
    S_t   = diag(w_t) S_{t-1} + k_t^T v_t,   w_t = exp(lw_t), lw_t <= 0

Shapes: r, k, v, lw (B, L, H, hd); u (H, hd); s0 (B, H, hd, hd).
Returns (out (B, L, H, hd), s_L). All math f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, lw, u, s0=None):
    r, k, v, lw = (t.astype(jnp.float32) for t in (r, k, v, lw))
    u = u.astype(jnp.float32)
    b, l, h, hd = r.shape
    s = (jnp.zeros((b, h, hd, hd), jnp.float32) if s0 is None
         else s0.astype(jnp.float32))

    def step(s, args):
        rt, kt, vt, lwt = args  # (B, H, hd) each
        kv = kt[..., :, None] * vt[..., None, :]        # (B, H, hd, hd)
        out = jnp.einsum("bhk,bhkv->bhv", rt, s) + \
            jnp.einsum("bhk,hk,bhk,bhv->bhv", rt, u, kt, vt)
        s = jnp.exp(lwt)[..., None] * s + kv
        return s, out

    sw = lambda t: t.swapaxes(0, 1)
    s_end, outs = jax.lax.scan(step, s, (sw(r), sw(k), sw(v), sw(lw)))
    return sw(outs), s_end
