"""Public fused-selective-scan op.

impl='pallas' — the TPU kernel (interpret=True on CPU) — serving/forward.
impl='ref'    — the sequential jnp oracle (tests).
The training path keeps the chunked associative-scan in models/mamba.py
(measured LOWER traffic than a sequential XLA scan — EXPERIMENTS §Perf);
the kernel is what replaces both on real TPU, and the roofline's
ssm-kernel adjustment is backed by it.
"""

from __future__ import annotations

from repro.kernels.ssm_scan import kernel as K
from repro.kernels.ssm_scan import ref as R


def selective_scan(x, dt, A, Bt, Ct, h0=None, *, impl: str = "pallas",
                   block_d: int = 256, block_l: int = 128,
                   interpret: bool = True):
    if impl == "pallas":
        return K.selective_scan(x, dt, A, Bt, Ct, h0, block_d=block_d,
                                block_l=block_l, interpret=interpret)
    if impl == "ref":
        return R.selective_scan_ref(x, dt, A, Bt, Ct, h0)
    raise ValueError(f"unknown impl {impl!r}")
