"""Pallas TPU kernel: fused selective scan (Mamba-1 recurrence).

Why a kernel (the paper-analogous hot-spot): the pure-XLA chunked scan
materializes (B, L, D, N)-shaped decay/injection tensors in HBM — for
jamba-1.5 train_4k that alone is a 1215 s memory roofline term (§Roofline).
This kernel keeps the (D, N) state AND all (D, N)-shaped intermediates in
VMEM, streaming only the O(L·(D+N)) inputs/outputs through HBM — the same
reduction the original CUDA selective-scan kernel achieves, re-tiled for
TPU: D is blocked to `block_d` lanes (multiple of 128 for VPU lanes), the
time axis is blocked to `block_l` VMEM-resident chunks, and the recurrence
runs as a fori_loop over the chunk with (block_d, N) vector ops.

Grid: (B, D/block_d, L/block_l) — the L axis iterates INNERMOST so the
state scratch carries across chunk steps without HBM round-trips (the same
revisit-friendly ordering argument as the LTM row-major schedule).

HBM traffic per (b, d-block): L·(x + dt + y) + L·(B + C) vs the XLA path's
L·D·N — a ~N/3 ≈ 5x reduction at N=16, and it removes the (B,L,D,N)
temporaries entirely.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.obs import launch as OBS


def _ssm_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref,
                h_s, *, block_l: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_s[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)          # (block_d, N)
    x = x_ref[0].astype(jnp.float32)            # (block_l, block_d)
    dt = dt_ref[0].astype(jnp.float32)          # (block_l, block_d)
    bt = b_ref[0].astype(jnp.float32)           # (block_l, N)
    ct = c_ref[0].astype(jnp.float32)           # (block_l, N)

    def step(t, carry):
        h, ys = carry
        dtt = dt[t][:, None]                    # (block_d, 1)
        decay = jnp.exp(dtt * a)                # (block_d, N)
        h = decay * h + (dtt * x[t][:, None]) * bt[t][None, :]
        y_t = jnp.sum(h * ct[t][None, :], axis=1)   # (block_d,)
        ys = jax.lax.dynamic_update_slice(ys, y_t[None, :], (t, 0))
        return h, ys

    ys0 = jnp.zeros_like(x)
    h, ys = jax.lax.fori_loop(0, block_l, step, (h_s[...], ys0))
    h_s[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit():
        hout_ref[0] = h.astype(hout_ref.dtype)


def selective_scan(x, dt, A, Bt, Ct, h0=None, *, block_d: int = 256,
                   block_l: int = 128, interpret: bool = True):
    """x, dt: (B, L, D); A: (D, N); Bt, Ct: (B, L, N); h0: (B, D, N).

    Returns (y (B, L, D) in x.dtype, h_L (B, D, N) f32).
    """
    b, l, d = x.shape
    n = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((b, d, n), jnp.float32)
    block_d = min(block_d, d)
    block_l = min(block_l, l)
    assert d % block_d == 0 and l % block_l == 0, (d, block_d, l, block_l)
    n_chunks = l // block_l
    grid = (b, d // block_d, n_chunks)

    y, h_out = OBS.instrumented_pallas_call(
        functools.partial(_ssm_kernel, block_l=block_l, n_chunks=n_chunks),
        meta=OBS.meta_dense("ssm_scan.selective_scan", "ssm_scan",
                            impl="pallas", grid=(n_chunks,),
                            block_shape=(block_l, block_d),
                            tiles_domain=n_chunks, kind="chunked",
                            cells=b * (d // block_d)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_l, block_d),
                         lambda bi, di, ci: (bi, ci, di)),   # x
            pl.BlockSpec((1, block_l, block_d),
                         lambda bi, di, ci: (bi, ci, di)),   # dt
            pl.BlockSpec((block_d, n), lambda bi, di, ci: (di, 0)),  # A
            pl.BlockSpec((1, block_l, n),
                         lambda bi, di, ci: (bi, ci, 0)),    # Bt
            pl.BlockSpec((1, block_l, n),
                         lambda bi, di, ci: (bi, ci, 0)),    # Ct
            pl.BlockSpec((1, block_d, n),
                         lambda bi, di, ci: (bi, di, 0)),    # h0
        ],
        out_specs=[
            pl.BlockSpec((1, block_l, block_d),
                         lambda bi, di, ci: (bi, ci, di)),   # y
            pl.BlockSpec((1, block_d, n),
                         lambda bi, di, ci: (bi, di, 0)),    # h_L
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, d), x.dtype),
            jax.ShapeDtypeStruct((b, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bt, Ct, h0)
    return y, h_out
