"""Pure-jnp oracle for the fused selective scan (Mamba-1 recurrence).

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) * B_t
    y_t = C_t . h_t

Shapes: x, dt (B, L, D); A (D, N); Bt, Ct (B, L, N); h0 (B, D, N).
Returns (y (B, L, D), h_L (B, D, N)). All math in f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(x, dt, A, Bt, Ct, h0=None):
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    A = A.astype(jnp.float32)
    Bt = Bt.astype(jnp.float32)
    Ct = Ct.astype(jnp.float32)
    b, l, d = x.shape
    n = A.shape[1]
    h = (jnp.zeros((b, d, n), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))

    def step(h, args):
        xt, dtt, bt, ct = args
        decay = jnp.exp(dtt[..., None] * A)          # (B, D, N)
        h = decay * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    sw = lambda t: t.swapaxes(0, 1)
    h_end, ys = jax.lax.scan(step, h, (sw(x), sw(dt), sw(Bt), sw(Ct)))
    return sw(ys), h_end
