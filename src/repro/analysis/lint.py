"""Block-space contract checker CLI.

    PYTHONPATH=src python -m repro.analysis.lint [--json PATH] [--pass NAME]

Runs the five passes (envelope, contracts, jaxpr, obs, resilience),
prints one line per check, and exits nonzero if any check fails.
``--json`` writes the full report (default path artifacts/lint_report.json
when given without a value). Entirely offline: mapping math runs on host
ints, traced maps run as eager jnp scalar code, ops are only abstractly
traced / compiled-to-text — except the resilience pass, which RUNS the
tiny smoke engine on CPU to prove fault-injected decode stays
token-identical (the contract, not just its plumbing).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
from typing import List

from repro.analysis.contracts import CheckResult

_PASSES = ("envelope", "contracts", "jaxpr", "obs", "resilience")


def run_pass(name: str) -> List[CheckResult]:
    if name == "envelope":
        from repro.analysis import envelope as mod
    elif name == "contracts":
        from repro.analysis import verifier as mod
    elif name == "jaxpr":
        from repro.analysis import jaxpr_lint as mod
    elif name == "obs":
        from repro.analysis import obs_lint as mod
    elif name == "resilience":
        from repro.analysis import resilience_lint as mod
    else:
        raise SystemExit(f"unknown pass {name!r}; choose from {_PASSES}")
    return mod.run()


def run_all(passes=_PASSES) -> List[CheckResult]:
    out: List[CheckResult] = []
    for name in passes:
        out.extend(run_pass(name))
    return out


def report(results: List[CheckResult], *, verbose: bool = True) -> dict:
    by_pass: dict = {}
    for r in results:
        by_pass.setdefault(r.pass_name, []).append(r)
    failures = [r for r in results if not r.ok]
    if verbose:
        for name, rs in by_pass.items():
            n_fail = sum(not r.ok for r in rs)
            print(f"[{name}] {len(rs) - n_fail}/{len(rs)} checks passed")
            for r in rs:
                mark = "  ok " if r.ok else "  FAIL"
                print(f"{mark} {r.rule}: {r.detail}")
    return {
        "passes": {name: {"checks": len(rs),
                          "failures": sum(not r.ok for r in rs)}
                   for name, rs in by_pass.items()},
        "total_checks": len(results),
        "total_failures": len(failures),
        "results": [r.as_dict() for r in results],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="static block-space contract checker")
    ap.add_argument("--json", nargs="?", const="artifacts/lint_report.json",
                    default=None, metavar="PATH",
                    help="write the full report as JSON "
                         "(default artifacts/lint_report.json)")
    ap.add_argument("--pass", dest="only", choices=_PASSES, default=None,
                    help="run a single pass instead of all of them")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="only print the summary line")
    args = ap.parse_args(argv)

    t0 = time.time()
    results = run_all((args.only,) if args.only else _PASSES)
    rep = report(results, verbose=not args.quiet)
    rep["elapsed_s"] = round(time.time() - t0, 2)

    if args.json:
        path = pathlib.Path(args.json)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(rep, indent=2) + "\n")
        print(f"report written to {path}")

    ok = rep["total_failures"] == 0
    print(f"lint: {rep['total_checks']} checks, "
          f"{rep['total_failures']} failures, {rep['elapsed_s']}s "
          f"-> {'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
