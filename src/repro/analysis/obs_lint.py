"""Telemetry-coverage lint — pass 4 of the block-space checker.

The observability layer's core guarantee is *coverage*: every kernel
launch in the repo goes through ``repro.obs.launch`` so every grid, tile
and wasted block is measured. Three rule groups keep that true:

  static coverage   AST walk over src/ + benchmarks/: any reference to
                    the ``pallas_call`` attribute (``pl.pallas_call`` or
                    a from-import) outside obs/launch.py is a failure —
                    an uninstrumented launch site. String literals (the
                    jaxpr primitive name used by jaxpr_lint) don't count.
  counter fidelity  trace the jaxpr_lint fixture ops under a scoped
                    registry and require the emitted ``launches_total``
                    to equal the jaxpr's pallas_call primitive count —
                    the wrapper must fire exactly once per launch, and a
                    launch that bypasses the wrapper shows up as a
                    counter deficit.
  schema self-check obs/schema.py validators accept the events and
                    metrics documents obs itself produces, and the meta
                    constructors agree with core/analysis closed forms
                    (tri(n) launched, n^2 BB bound, utilization 1.0).
"""

from __future__ import annotations

import ast
import pathlib
from typing import List

from repro.analysis.contracts import CheckResult


def _res(rule, ok, detail=""):
    return CheckResult(pass_name="obs", rule=rule, ok=ok, detail=detail)


# ---------------------------------------------------------------------------
# static coverage
# ---------------------------------------------------------------------------

# the one sanctioned pl.pallas_call site (relative to the repo root)
_ALLOWED = ("src/repro/obs/launch.py",)


def _repo_root() -> pathlib.Path:
    # src/repro/analysis/obs_lint.py -> repo root is three parents up
    # from the package dir (src/repro/analysis -> src/repro -> src -> root)
    return pathlib.Path(__file__).resolve().parents[3]


def _pallas_call_refs(path: pathlib.Path) -> List[int]:
    """Line numbers of ``pallas_call`` attribute/name references (not
    string literals) in one source file."""
    try:
        tree = ast.parse(path.read_text(encoding="utf-8"))
    except SyntaxError:
        return [-1]
    lines = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr == "pallas_call":
            lines.append(node.lineno)
        elif isinstance(node, ast.Name) and node.id == "pallas_call":
            lines.append(node.lineno)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                if alias.name == "pallas_call" or \
                        (alias.asname == "pallas_call"):
                    lines.append(node.lineno)
    return sorted(set(lines))


def lint_static_coverage() -> List[CheckResult]:
    root = _repo_root()
    offenders = []
    scanned = 0
    for sub in ("src", "benchmarks", "examples"):
        base = root / sub
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            if rel in _ALLOWED:
                continue
            scanned += 1
            refs = _pallas_call_refs(path)
            if refs:
                offenders.append(f"{rel}:{refs}")
    return [_res(
        "obs.coverage.no_raw_pallas_call",
        not offenders,
        f"{scanned} files scanned; raw pallas_call references outside "
        f"obs/launch.py: {offenders or 'none'}")]


# ---------------------------------------------------------------------------
# counter fidelity: launches_total == jaxpr pallas_call count
# ---------------------------------------------------------------------------


def _traced_launch_count(fn, *args) -> tuple:
    """(launches_total emitted during trace, pallas_call primitives)."""
    import jax

    from repro.analysis import jaxpr_lint as JL
    from repro.obs import metrics as MET

    reg = MET.Registry("obs_lint")
    with MET.scope(reg):
        jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
    emitted = int(reg.counter_total("launches_total"))
    return emitted, JL.count_primitive(jaxpr, "pallas_call")


def lint_counter_fidelity() -> List[CheckResult]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.tri_3body import ops as O3
    from repro.kernels.tri_attn import ops as OPS
    from repro.kernels.tri_edm import ops as OE

    out = []
    x = np.zeros((32, 4), np.float32)

    for label, fn in (
            ("tri_edm.pallas",
             lambda: _traced_launch_count(
                 lambda v: OE.edm(v, block=8, impl="pallas"), x)),
            ("tri_3body.pallas",
             lambda: _traced_launch_count(
                 lambda v: O3.three_body(v, block=8, impl="pallas"), x)),
    ):
        emitted, primitives = fn()
        out.append(_res(
            f"obs.counters.{label}",
            emitted == primitives and emitted >= 1,
            f"launches_total {emitted} vs jaxpr pallas_call {primitives} "
            f"(must match, >= 1)"))

    psched = OPS.make_packed_sched([32, 16, 48], block=16)
    q = np.zeros((1, 2, psched.s_total, 8), np.float32)
    emitted, primitives = _traced_launch_count(
        jax.grad(lambda a, b, c: jnp.sum(
            OPS.packed_prefill_attention(a, b, c, psched, impl="pallas")),
            argnums=(0, 1, 2)),
        q, q, q)
    out.append(_res(
        "obs.counters.packed_prefill.grad",
        emitted == primitives == 3,
        f"packed grad: launches_total {emitted} vs jaxpr pallas_call "
        f"{primitives} (expect exactly 3: fwd + dq + dkv)"))

    # scan fallback: zero pallas primitives, but the launch is still
    # recorded (instrumented scan path == one launch)
    emitted, primitives = _traced_launch_count(
        lambda v: OE.edm(v, block=8, impl="scan"), x)
    out.append(_res(
        "obs.counters.tri_edm.scan",
        emitted == 1 and primitives == 0,
        f"scan fallback: launches_total {emitted} (expect 1), "
        f"pallas_call {primitives} (expect 0)"))
    return out


# ---------------------------------------------------------------------------
# schema + closed-form self-checks
# ---------------------------------------------------------------------------


def lint_schema_selfcheck() -> List[CheckResult]:
    from repro.core import analysis as A
    from repro.core import mapping as M
    from repro.kernels.tri_attn import ops as OPS
    from repro.obs import launch as L
    from repro.obs import metrics as MET
    from repro.obs import schema as SCH

    out = []

    sched = OPS.make_sched(64, block_q=16, block_k=16)
    meta = L.meta_from_trisched("tri_attn.fwd", sched, impl="pallas",
                                cells=2)
    st = A.strategy_stats(sched.n)["ltm"]
    out.append(_res(
        "obs.closed_forms.trisched",
        meta.tiles_launched == st.launched == M.tri(sched.n)
        and meta.tiles_bb == sched.n * sched.n
        and meta.utilization == 1.0
        and abs(meta.improvement_vs_bb - st.block_ratio_vs_bb) < 1e-12,
        f"meta launched={meta.tiles_launched} vs analysis "
        f"{st.launched} (= tri({sched.n})); I={meta.improvement_vs_bb} "
        f"vs block_ratio {st.block_ratio_vs_bb}"))

    ev = meta.as_event(phase="eager", bytes_moved=0)
    errs = SCH.validate_event(ev, envelope=False)
    out.append(_res(
        "obs.schema.launch_event", not errs,
        f"validate_event on meta.as_event: {errs or 'ok'}"))

    reg = MET.Registry("selfcheck")
    reg.counter_inc("launches_total", 1, {"name": "x", "impl": "scan"})
    reg.histogram_observe("span_ms", 1.5, {"name": "s"})
    doc = {"schema": "repro.obs/v1", "kind": "metrics",
           "created_unix": 0.0, "run_id": None,
           "registry": reg.name, **reg.snapshot()}
    errs = SCH.validate_metrics(doc)
    out.append(_res(
        "obs.schema.metrics_doc", not errs,
        f"validate_metrics on registry snapshot doc: {errs or 'ok'}"))

    summary = L.kernel_summary(reg)
    traj = [{"schema": "repro.obs/v1", "created_unix": 0.0,
             "kernels": summary}]
    errs = SCH.validate_trajectory(traj)
    out.append(_res(
        "obs.schema.trajectory", not errs,
        f"validate_trajectory on kernel_summary record: {errs or 'ok'}"))
    return out


def run() -> List[CheckResult]:
    out = []
    for rule_fn in (lint_static_coverage, lint_counter_fidelity,
                    lint_schema_selfcheck):
        try:
            out.extend(rule_fn())
        except Exception as e:  # a crash IS a lint failure
            out.append(_res(f"obs.{rule_fn.__name__}", False,
                            f"exception: {type(e).__name__}: {e}"))
    return out
