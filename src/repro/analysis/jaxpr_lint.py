"""Jaxpr/HLO structural lint — pass 3 of the block-space checker.

Traces every public op (no kernel executes: jax.make_jaxpr only abstracts)
and enforces the launch-structure invariants the runtime tests cannot see:

  pallas-call counts   packed/triangular attention pallas forward = 1
                       launch, grad = exactly 3 (fwd + dq + dk/dv) with NO
                       scan/while in the pallas path — a silent fallback
                       to autodiff-through-scan would be numerically fine
                       and an order of magnitude slower, the worst kind of
                       regression; tri_edm / tri_3body entry points = 1.
  member tables        the scalar-prefetch tables are load-bearing ABI:
                       (7, R) int32 for packed prefill, (5, R) int32 for
                       decode rounds, (8, R) int32 for the fused
                       continuous-batching step (kind row partitioning
                       prefill columns before decode columns), cumulative
                       rows ascending from 0, and the decode/fused pad
                       member owning the garbage output row declared as
                       (cur, n_slots, DECODE_NO_EMIT, 0, 0).
  capacity bucketing   decode grids must be power-of-two capacities
                       (recompile-hazard detection) and the decode launch
                       must carry the b+1-row output (pad garbage row).
  dtype hygiene        no f64/i64 avals anywhere in any traced jaxpr — an
                       accidental promotion doubles scalar-core latency
                       and memory traffic silently.
  HLO launch invariant the compiled scan path contains a while loop with
                       known trip count == the schedule's step count —
                       reusing the HLO walker from roofline/hlo_parse.py
                       (the scan mirror must enumerate exactly the
                       schedule, not a padded or fused variant).
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import CheckResult


def _res(rule, ok, detail=""):
    return CheckResult(pass_name="jaxpr", rule=rule, ok=ok, detail=detail)


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def _subjaxprs(value):
    """Duck-typed: yields any Jaxpr held by an eqn param (ClosedJaxpr,
    bare Jaxpr, or (possibly nested) sequences of either)."""
    if hasattr(value, "eqns"):
        yield value
    elif hasattr(value, "jaxpr"):
        yield value.jaxpr
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _subjaxprs(v)


def iter_eqns(jaxpr):
    """Depth-first over all equations, descending into sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from iter_eqns(sub)


def count_primitive(jaxpr, name: str) -> int:
    return sum(1 for e in iter_eqns(jaxpr) if e.primitive.name == name)


def find_eqns(jaxpr, name: str):
    return [e for e in iter_eqns(jaxpr) if e.primitive.name == name]


def wide_dtypes(jaxpr) -> List[str]:
    """Avals with f64/i64 dtypes anywhere in the jaxpr (should be none:
    the kernels are pinned to f32/int32 grid arithmetic)."""
    bad = []
    for eqn in iter_eqns(jaxpr):
        for var in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(var, "aval", None)
            dt = getattr(aval, "dtype", None)
            if dt is not None and dt in (jnp.float64, jnp.int64):
                bad.append(f"{eqn.primitive.name}:{dt}")
    return bad


def _jaxpr_of(fn, *args):
    return jax.make_jaxpr(fn)(*args).jaxpr


# ---------------------------------------------------------------------------
# fixtures (tiny shapes; tracing only, nothing executes)
# ---------------------------------------------------------------------------


def _attn_fixture():
    from repro.kernels.tri_attn import ops as OPS

    psched = OPS.make_packed_sched([32, 16, 48], block=16,
                                   window=[None, 24, None],
                                   prefix=[0, 0, 16])
    b, h, d = 1, 2, 8
    q = np.zeros((b, h, psched.s_total, d), np.float32)
    return OPS, psched, q


def _decode_fixture():
    from repro.kernels.tri_attn import ops as OPS

    blk, s_cache, n_slots, n_members = 4, 16, 3, 4
    tbl, needed = OPS.make_decode_table([5, 9], [0, 1], blk=blk,
                                        n_members=n_members,
                                        n_slots=n_slots, s_cache=s_cache)
    from repro.serve import decode as D

    capacity = D.round_capacity(needed)
    spec = OPS.DecodeRoundSpec(n_members=n_members, capacity=capacity,
                               blk=blk, impl="pallas")
    q = np.zeros((n_slots, 2, 8), np.float32)
    kc = np.zeros((n_slots, s_cache, 2, 8), np.float32)
    return OPS, tbl, needed, spec, q, kc


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------


def lint_packed_prefill() -> List[CheckResult]:
    OPS, psched, q = _attn_fixture()
    out = []

    fwd = _jaxpr_of(
        lambda a, b, c: OPS.packed_prefill_attention(a, b, c, psched,
                                                     impl="pallas"),
        q, q, q)
    out.append(_res(
        "jaxpr.packed_prefill.fwd_pallas_calls",
        count_primitive(fwd, "pallas_call") == 1
        and count_primitive(fwd, "scan") == 0
        and count_primitive(fwd, "while") == 0,
        f"pallas fwd: {count_primitive(fwd, 'pallas_call')} pallas_call "
        f"(expect 1), {count_primitive(fwd, 'scan')} scan (expect 0)"))

    grad = _jaxpr_of(
        jax.grad(lambda a, b, c: jnp.sum(
            OPS.packed_prefill_attention(a, b, c, psched, impl="pallas")),
            argnums=(0, 1, 2)),
        q, q, q)
    n_pc = count_primitive(grad, "pallas_call")
    n_scan = count_primitive(grad, "scan") + count_primitive(grad, "while")
    out.append(_res(
        "jaxpr.packed_prefill.grad_pallas_calls",
        n_pc == 3 and n_scan == 0,
        f"pallas grad: {n_pc} pallas_call (expect exactly 3: fwd + dq + "
        f"dkv), {n_scan} scan/while (expect 0 — no silent autodiff "
        f"fallback)"))

    out.extend(_table_rules_packed(psched))
    out.append(_res(
        "jaxpr.packed_prefill.no_wide_dtypes", not wide_dtypes(grad),
        f"f64/i64 avals in grad jaxpr: {wide_dtypes(grad) or 'none'}"))
    return out


def _table_rules_packed(psched) -> List[CheckResult]:
    tbl = psched.table()
    r = len(psched.members)
    starts, rows = tbl[0], tbl[1]
    shape_ok = tbl.shape == (7, r) and tbl.dtype == np.int32
    asc_ok = (starts[0] == 0 and rows[0] == 0
              and bool((np.diff(starts) > 0).all())
              and bool((np.diff(rows) > 0).all())
              and bool((np.diff(starts)
                        == [m.rm_steps for m in psched.members[:-1]]).all()))
    return [_res(
        "jaxpr.packed_prefill.member_table",
        shape_ok and asc_ok,
        f"(7, R) int32 scalar-prefetch table: shape {tbl.shape} "
        f"{tbl.dtype}; cumulative rows ascending from 0: {asc_ok}")]


def lint_triangular_attention() -> List[CheckResult]:
    from repro.kernels.tri_attn import ops as OPS

    q = np.zeros((1, 2, 64, 8), np.float32)
    fwd = _jaxpr_of(
        lambda a, b, c: OPS.triangular_attention(a, b, c, impl="pallas",
                                                 block_q=16, block_k=16),
        q, q, q)
    grad = _jaxpr_of(
        jax.grad(lambda a, b, c: jnp.sum(
            OPS.triangular_attention(a, b, c, impl="pallas",
                                     block_q=16, block_k=16)),
            argnums=(0, 1, 2)),
        q, q, q)
    n_f, n_g = (count_primitive(fwd, "pallas_call"),
                count_primitive(grad, "pallas_call"))
    return [
        _res("jaxpr.tri_attn.fwd_pallas_calls", n_f == 1,
             f"pallas fwd: {n_f} pallas_call (expect 1)"),
        _res("jaxpr.tri_attn.grad_pallas_calls",
             n_g == 3 and count_primitive(grad, "scan") == 0,
             f"pallas grad: {n_g} pallas_call (expect 3), "
             f"{count_primitive(grad, 'scan')} scan (expect 0)"),
    ]


def lint_packed_decode() -> List[CheckResult]:
    from repro.core.mapping import INT32_MAX
    from repro.kernels.tri_attn import kernel as K

    OPS, tbl, needed, spec, q, kc = _decode_fixture()
    out = []

    jx = _jaxpr_of(
        lambda a, b, c, t: OPS.packed_decode_attention(a, b, c, t, spec),
        q, kc, kc, tbl)
    pcs = find_eqns(jx, "pallas_call")
    out.append(_res(
        "jaxpr.packed_decode.pallas_calls",
        len(pcs) == 1 and count_primitive(jx, "scan") == 0,
        f"pallas decode: {len(pcs)} pallas_call (expect 1), "
        f"{count_primitive(jx, 'scan')} scan (expect 0)"))

    # pad garbage row: the launch writes (b+1, h, d); row b belongs to the
    # pad member and is dropped by the caller.
    b, h, d = q.shape
    pad_row_ok = bool(pcs) and any(
        tuple(v.aval.shape) == (b + 1, h, d) for v in pcs[0].outvars)
    out.append(_res(
        "jaxpr.packed_decode.pad_garbage_row", pad_row_ok,
        f"decode launch out avals "
        f"{[tuple(v.aval.shape) for v in pcs[0].outvars] if pcs else []} "
        f"must include (b+1, h, d) = {(b + 1, h, d)}"))

    # capacity bucketing: static grid is a power of two >= needed
    cap = spec.capacity
    out.append(_res(
        "jaxpr.packed_decode.capacity_pow2",
        cap >= needed and cap & (cap - 1) == 0,
        f"capacity {cap} for {needed} live tiles (power-of-two bucket)"))

    # (5, R) int32 member table with the declared pad-member column
    n_live = 2
    pad_col = tuple(int(v) for v in tbl[:, -1])
    expect_pad = (int(tbl[0, n_live]), q.shape[0], K.DECODE_NO_EMIT, 0, 0)
    tbl_ok = (tbl.shape == (5, spec.n_members) and tbl.dtype == np.int32
              and int(tbl[0, 0]) == 0
              and bool((np.diff(tbl[0]) >= 0).all())
              and pad_col == expect_pad
              and K.DECODE_NO_EMIT == 2 ** 30
              and K.DECODE_NO_EMIT > INT32_MAX // (2 * spec.blk))
    out.append(_res(
        "jaxpr.packed_decode.member_table", tbl_ok,
        f"(5, R) int32 decode table; pad column {pad_col} vs declared "
        f"(cur, n_slots, DECODE_NO_EMIT, 0, 0) = {expect_pad}; "
        f"DECODE_NO_EMIT = 2**30 dominates any real tile count"))
    out.append(_res(
        "jaxpr.packed_decode.no_wide_dtypes", not wide_dtypes(jx),
        f"f64/i64 avals: {wide_dtypes(jx) or 'none'}"))
    return out


def lint_tri_kernels() -> List[CheckResult]:
    from repro.kernels.tri_3body import ops as O3
    from repro.kernels.tri_edm import ops as OE

    x = np.zeros((32, 4), np.float32)
    je = _jaxpr_of(lambda v: OE.edm(v, block=8, impl="pallas"), x)
    j3 = _jaxpr_of(lambda v: O3.three_body(v, block=8, impl="pallas"), x)
    ne, n3 = (count_primitive(je, "pallas_call"),
              count_primitive(j3, "pallas_call"))
    return [
        _res("jaxpr.tri_edm.pallas_calls", ne == 1,
             f"edm pallas: {ne} pallas_call (expect 1)"),
        _res("jaxpr.tri_3body.pallas_calls", n3 == 1,
             f"three_body pallas: {n3} pallas_call (expect 1)"),
        _res("jaxpr.tri_kernels.no_wide_dtypes",
             not wide_dtypes(je) and not wide_dtypes(j3),
             f"f64/i64 avals: "
             f"{(wide_dtypes(je) + wide_dtypes(j3)) or 'none'}"),
    ]


def lint_fused_step() -> List[CheckResult]:
    """Fused continuous-batching step: one mixed launch, (8, R) table ABI,
    power-of-two decode bucket, garbage output row/tile — and, traced
    through the whole model, exactly ONE pallas_call per engine step."""
    from repro.core.mapping import INT32_MAX
    from repro.kernels.tri_attn import kernel as K
    from repro.kernels.tri_attn import ops as OPS
    from repro.serve import decode as D

    out = []
    blk, s_cache, b = 4, 16, 3
    psched = OPS.make_packed_sched([8, 4], block=blk)
    r_p = len(psched.members)
    kv_lens, slots = [5, 9], [0, 1]
    n_members = r_p + b + 1
    tbl, needed = OPS.make_fused_table(psched, kv_lens, slots, blk=blk,
                                       n_members=n_members, n_slots=b,
                                       s_cache=s_cache)
    dec_cap = D.round_capacity(needed - psched.steps)
    spec = OPS.FusedStepSpec(n_members=n_members,
                             capacity=psched.steps + dec_cap, blk=blk,
                             impl="pallas")
    h, hkv, d = 4, 2, 8
    qp = np.zeros((1, h, psched.s_total, d), np.float32)
    kp = np.zeros((1, hkv, psched.s_total, d), np.float32)
    qd = np.zeros((b, h, d), np.float32)
    kc = np.zeros((b, s_cache, hkv, d), np.float32)
    jx = _jaxpr_of(
        lambda a, b_, c, e, f, g, t: OPS.fused_step_attention(
            a, b_, c, e, f, g, t, psched, spec), qp, kp, kp, qd, kc, kc,
        tbl)
    pcs = find_eqns(jx, "pallas_call")
    out.append(_res(
        "jaxpr.fused_step.pallas_calls",
        len(pcs) == 1 and count_primitive(jx, "scan") == 0,
        f"fused pallas step: {len(pcs)} pallas_call (expect 1 — prefill "
        f"AND decode members in one launch), "
        f"{count_primitive(jx, 'scan')} scan (expect 0)"))

    # per-kind garbage outputs: the pack output carries an extra garbage
    # TILE (row n_pack_tiles), the decode output the pad garbage ROW b.
    s_pack = psched.s_total
    shapes = ([tuple(v.aval.shape) for v in pcs[0].outvars] if pcs else [])
    out.append(_res(
        "jaxpr.fused_step.garbage_outputs",
        (1, h, s_pack + blk, d) in shapes and (b + 1, h, d) in shapes,
        f"fused launch out avals {shapes} must include the pack buffer "
        f"with its garbage tile {(1, h, s_pack + blk, d)} AND the decode "
        f"buffer with its pad row {(b + 1, h, d)}"))

    # (8, R) fused table ABI: kind row partitions prefill columns (0)
    # before decode columns (1); starts cumulative from 0; the shared pad
    # column is the decode pad member in fused row order.
    pad_col = tuple(int(v) for v in tbl[:, -1])
    expect_pad = (needed, 1, K.DECODE_NO_EMIT, 0, 0, b, 0, 0)
    tbl_ok = (tbl.shape == (8, n_members) and tbl.dtype == np.int32
              and int(tbl[0, 0]) == 0
              and bool((np.diff(tbl[0]) >= 0).all())
              and bool((tbl[1, :r_p] == 0).all())
              and bool((tbl[1, r_p:] == 1).all())
              and int(tbl[0, r_p]) == psched.steps
              and pad_col == expect_pad
              and K.DECODE_NO_EMIT > INT32_MAX // (2 * blk))
    out.append(_res(
        "jaxpr.fused_step.member_table", tbl_ok,
        f"(8, R) int32 fused table: shape {tbl.shape} {tbl.dtype}; kind "
        f"row {tbl[1].tolist()} partitions prefill|decode at {r_p}; pad "
        f"column {pad_col} vs declared {expect_pad}"))

    # decode half of the grid must stay power-of-two bucketed
    out.append(_res(
        "jaxpr.fused_step.capacity_pow2",
        dec_cap >= needed - psched.steps and dec_cap & (dec_cap - 1) == 0
        and spec.capacity == psched.steps + dec_cap,
        f"fused capacity {spec.capacity} = {psched.steps} prefill steps + "
        f"{dec_cap} decode bucket (power of two)"))
    out.append(_res(
        "jaxpr.fused_step.no_wide_dtypes", not wide_dtypes(jx),
        f"f64/i64 avals: {wide_dtypes(jx) or 'none'}"))

    # -- whole-model invariant: ONE pallas_call per engine step ---------
    from repro.configs import registry as REG
    from repro.models import model as MD

    cfg = REG.smoke_config("yi-9b")
    params = MD.init_params(jax.random.key(0), cfg)
    cache = MD.init_cache(cfg, b, s_cache, jnp.float32)
    pack_tokens = np.zeros((1, s_pack), np.int32)
    pack_positions = np.zeros((s_pack,), np.int32)
    dec_tokens = np.zeros((b, 1), np.int32)
    pos = np.zeros((b,), np.int32)
    admit_rows = np.asarray([7, 11], np.int32)
    mj = _jaxpr_of(
        lambda p_, c_, t: MD.fused_step(
            p_, cfg, c_, pack_tokens, pack_positions, dec_tokens, pos,
            psched, t, spec, admit_rows), params, cache, tbl)
    n_pc = count_primitive(mj, "pallas_call")
    out.append(_res(
        "jaxpr.fused_step.one_launch_per_engine_step", n_pc == 1,
        f"model fused_step jaxpr: {n_pc} pallas_call (expect exactly 1 — "
        f"the superlayer scan body carries the single fused launch every "
        f"engine step reuses)"))
    return out


def lint_hlo_scan_invariant() -> List[CheckResult]:
    """Compiled scan-path attention: the while loop's known trip count
    must equal the schedule's step count (reuses roofline/hlo_parse)."""
    from repro.kernels.tri_attn import ops as OPS
    from repro.roofline import hlo_parse as HLO

    psched = OPS.make_packed_sched([32, 16], block=16)
    q = np.zeros((1, 2, psched.s_total, 8), np.float32)
    compiled = (
        jax.jit(lambda a, b, c: OPS.packed_prefill_attention(
            a, b, c, psched, impl="scan"))
        .lower(q, q, q).compile())
    comps = HLO.parse_computations(compiled.as_text())
    trips = []
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "while":
                t, known = HLO._trip_count(op, comps)
                if known:
                    trips.append(int(t))
    ok = psched.steps in trips
    return [_res(
        "jaxpr.hlo.scan_trip_count", ok,
        f"compiled scan path while trip counts {trips} must include "
        f"schedule steps {psched.steps} (exact block-space enumeration, "
        f"no pad/fuse drift)")]


def run() -> List[CheckResult]:
    out = []
    for rule_fn in (lint_packed_prefill, lint_triangular_attention,
                    lint_packed_decode, lint_fused_step, lint_tri_kernels,
                    lint_hlo_scan_invariant):
        try:
            out.extend(rule_fn())
        except Exception as e:  # a trace crash IS a lint failure
            out.append(_res(f"jaxpr.{rule_fn.__name__}", False,
                            f"exception: {type(e).__name__}: {e}"))
    return out
