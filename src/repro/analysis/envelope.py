"""Envelope certification — pass 1 of the block-space contract checker.

core/mapping.py DECLARES traced-exactness envelopes as named constants
(ISQRT_TRACED_MAX_X, LTM_TRACED_MAX_LAM, TET_TRACED_MAX_LAM, probe
counts). This pass DERIVES each bound from first principles and fails if
declaration and derivation disagree:

  * float-error interval analysis over the correction-probe logic — a
    float32 op chain of length L has relative error < L * u + O(u^2)
    (u = 2^-24); the derived absolute error at the top of the envelope
    bounds how far the floor()ed candidate can sit from the true root,
    which lower-bounds the number of integer probes each direction;
  * int32 overflow analysis of every intermediate (8*lam + 1, the probe
    squares/cubes, tri(i) in the j computation) — the binding constraint
    for both the 2D and 3D envelopes;
  * empirical certification at the closed-form boundary points (x = r^2,
    lam = tri(i), lam = tet(i) and their predecessors) where float
    rounding actually bites — vectorized, one jit per map, no kernels.

The derivations are deliberately conservative (candidate error rounded up
to whole integers): a DECLARED probe count below the DERIVED requirement
fails the check, which is exactly how the mutated-probe-count test in
tests/test_analysis_lint.py breaks the contract on purpose.

History note: this pass is what exposed the pre-clamp bug where
``_isqrt_traced``'s up-probe squared 46341 into int32 wrap-around,
silently corrupting ltm_map for ~11k lambdas below the then-claimed
``lam < 2**31`` envelope. The probes are now clamped at ISQRT_MAX_R and
the declared envelope is the honest, certified one.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import CheckResult
from repro.core import mapping as M

U32 = 2.0 ** -24  # float32 unit roundoff

# Conservative op-chain lengths (each op correctly rounded or better):
# isqrt: int->f32 conversion + sqrt. cbrt: conversion + multiply + cbrt,
# with cbrt itself allowed a few ulp (XLA lowers it via pow/exp-log on
# some backends) — 8 rounding steps is a generous ceiling.
_SQRT_CHAIN_OPS = 2
_CBRT_CHAIN_OPS = 8


def _res(rule, ok, detail=""):
    return CheckResult(pass_name="envelope", rule=rule, ok=ok,
                       detail=detail)


# ---------------------------------------------------------------------------
# isqrt
# ---------------------------------------------------------------------------


def derive_isqrt():
    """Derived facts about _isqrt_traced over int32 inputs."""
    r_cap = math.isqrt(M.INT32_MAX)
    # |sqrt_f32(f32(x)) - sqrt(x)| <= sqrt(x) * (chain * u); at the top of
    # the int32 range that is < 1, so floor() lands within one of truth.
    abs_err = math.sqrt(M.INT32_MAX) * (_SQRT_CHAIN_OPS * U32)
    probes_required = max(1, math.ceil(abs_err))
    # With probes clamped at r_cap, no intermediate square can exceed
    # r_cap^2 <= INT32_MAX, so the envelope is the full int32 range.
    envelope = M.INT32_MAX
    return {"r_cap": r_cap, "abs_err": abs_err,
            "probes_required": probes_required, "envelope": envelope}


def certify_isqrt():
    d = derive_isqrt()
    out = [
        _res("isqrt.float_error",
             d["abs_err"] < 1.0,
             f"derived |err| <= {d['abs_err']:.2e} over int32 (< 1 keeps "
             f"the candidate within one of floor(sqrt))"),
        _res("isqrt.probes",
             M.ISQRT_PROBES >= d["probes_required"],
             f"declared ISQRT_PROBES={M.ISQRT_PROBES}, derived "
             f"requirement {d['probes_required']}"),
        _res("isqrt.probe_clamp",
             M.ISQRT_MAX_R == d["r_cap"]
             and M.ISQRT_MAX_R ** 2 <= M.INT32_MAX
             and (M.ISQRT_MAX_R + 1) ** 2 > M.INT32_MAX,
             f"declared clamp {M.ISQRT_MAX_R}, derived isqrt(INT32_MAX) "
             f"= {d['r_cap']} (squares above it wrap int32)"),
        _res("isqrt.envelope",
             M.ISQRT_TRACED_MAX_X == d["envelope"],
             f"declared {M.ISQRT_TRACED_MAX_X}, derived {d['envelope']}"),
    ]
    # Empirical boundary certification: x = r^2 - 1, r^2, r^2 + 1 — every
    # point where floor(sqrt) changes value, i.e. where a candidate off by
    # one float ulp flips the answer.
    xs = sorted({r * r + dd for r in range(1, d["r_cap"] + 1)
                 for dd in (-1, 0, 1) if 0 <= r * r + dd <= d["envelope"]}
                | {0, 1, 2, d["envelope"]})
    xs = np.asarray(xs, np.int32)
    got = np.asarray(jax.jit(M._isqrt_traced)(jnp.asarray(xs)))
    want = np.asarray([math.isqrt(int(x)) for x in xs])
    bad = int((got != want).sum())
    out.append(_res(
        "isqrt.boundaries", bad == 0,
        f"{len(xs)} floor-boundary probes over [0, {d['envelope']}], "
        f"{bad} mismatches"))
    return out


# ---------------------------------------------------------------------------
# ltm (2D)
# ---------------------------------------------------------------------------


def derive_ltm():
    """Derived facts about traced ltm_map (int32 grid indices)."""
    # Binding constraint: 8*lam + 1 computed in int32.
    max_lam = (M.INT32_MAX - 1) // 8
    max_i = (math.isqrt(8 * max_lam + 1) - 1) // 2
    # tri(i) in the j computation must also fit int32.
    tri_fits = max_i * (max_i + 1) <= M.INT32_MAX
    return {"max_lam": max_lam, "max_i": max_i, "tri_fits": tri_fits}


def certify_ltm():
    d = derive_ltm()
    out = [
        _res("ltm.envelope",
             M.LTM_TRACED_MAX_LAM == d["max_lam"] and d["tri_fits"],
             f"declared {M.LTM_TRACED_MAX_LAM}, derived {d['max_lam']} "
             f"(8*lam+1 int32 bound; tri(i) fits: {d['tri_fits']})"),
        _res("ltm.max_row",
             M.LTM_TRACED_MAX_I == d["max_i"],
             f"declared {M.LTM_TRACED_MAX_I}, derived {d['max_i']}"),
    ]
    # Boundary probes: row starts tri(i) -> (i, 0) and row ends
    # tri(i) - 1 -> (i-1, i-1), for every traced row, plus the envelope lam.
    lams = sorted({t for i in range(1, d["max_i"] + 1)
                   for t in (i * (i + 1) // 2 - 1, i * (i + 1) // 2)}
                  | {0, d["max_lam"]})
    lams = np.asarray(lams, np.int32)
    gi, gj = jax.jit(M.ltm_map)(jnp.asarray(lams))
    wi = np.asarray([(math.isqrt(8 * int(l) + 1) - 1) // 2 for l in lams])
    wj = lams.astype(np.int64) - wi * (wi + 1) // 2
    bad = int(((np.asarray(gi) != wi) | (np.asarray(gj) != wj)).sum())
    out.append(_res(
        "ltm.boundaries", bad == 0,
        f"{len(lams)} row-boundary probes up to lam={d['max_lam']}, "
        f"{bad} mismatches"))
    return out


# ---------------------------------------------------------------------------
# tet (3D)
# ---------------------------------------------------------------------------


def derive_tet():
    """Derived facts about the traced tetrahedral row-finder."""
    # Binding constraint: tet(i)'s int32 intermediate tri(i)*(i+2).
    i = 1
    while (i + 1) * (i + 2) // 2 * (i + 3) <= M.INT32_MAX:
        i += 1
    max_i = i  # largest i with tri(i)*(i+2) <= INT32_MAX
    # Real-arithmetic candidate: for lam in [tet(i), tet(i+1)),
    # i^3 < 6*lam < (i+2)^3 (since i(i+1)(i+2) > i^3 and
    # (i+1)(i+2)(i+3) < (i+2)^3), so floor(cbrt(6 lam)) is i or i+1 —
    # real candidate error in [0, +1].
    real_err_lo, real_err_hi = 0, 1
    # float32 adds at most abs_err, which rounds the floor()ed candidate
    # at most one further step either way.
    abs_err = (max_i + 2) * (_CBRT_CHAIN_OPS * U32)
    float_step = max(1, math.ceil(abs_err)) if abs_err < 1 else None
    probes_up = -real_err_lo + 1    # candidate as low as i - 1
    probes_down = real_err_hi + 1   # candidate as high as i + 2
    return {"max_i": max_i, "abs_err": abs_err,
            "probes_up_required": probes_up,
            "probes_down_required": probes_down,
            "exact_planes": max_i - 1,
            "max_lam": max_i * (max_i + 1) * (max_i + 2) // 6 - 1,
            "float_step_ok": float_step == 1}


def certify_tet():
    d = derive_tet()
    out = [
        _res("tet.float_error",
             d["abs_err"] < 1.0 and d["float_step_ok"],
             f"derived cbrt-chain |err| <= {d['abs_err']:.2e} at "
             f"i={d['max_i']} (< 1 adds at most one floor step)"),
        _res("tet.probes_up",
             M.TET_PROBES_UP >= d["probes_up_required"],
             f"declared TET_PROBES_UP={M.TET_PROBES_UP}, derived "
             f"requirement {d['probes_up_required']}"),
        _res("tet.probes_down",
             M.TET_PROBES_DOWN >= d["probes_down_required"],
             f"declared TET_PROBES_DOWN={M.TET_PROBES_DOWN}, derived "
             f"requirement {d['probes_down_required']} (real candidate "
             f"reaches +1, float rounding one more)"),
        _res("tet.clamp",
             M.TET_TRACED_MAX_I == d["max_i"],
             f"declared clamp {M.TET_TRACED_MAX_I}, derived largest i "
             f"with tri(i)*(i+2) <= INT32_MAX = {d['max_i']}"),
        _res("tet.envelope",
             M.TET_TRACED_EXACT_PLANES == d["exact_planes"]
             and M.TET_TRACED_MAX_LAM == d["max_lam"],
             f"declared planes<={M.TET_TRACED_EXACT_PLANES} / "
             f"lam<={M.TET_TRACED_MAX_LAM}, derived "
             f"{d['exact_planes']} / {d['max_lam']}"),
    ]
    # Boundary probes: plane starts tet(i) -> (i, 0, 0) and plane ends
    # tet(i) - 1 -> (i-1, i-1, i-1) for every exact plane + the envelope.
    tets = [i * (i + 1) * (i + 2) // 6
            for i in range(d["exact_planes"] + 1)]
    lams = sorted({t + dd for t in tets[1:] for dd in (-1, 0)}
                  | {0, d["max_lam"]})
    lams = np.asarray(lams, np.int32)
    gi, gj, gk = jax.jit(M.tet_map)(jnp.asarray(lams))
    want = [M.tet_map(int(l)) for l in lams]
    wi = np.asarray([w[0] for w in want])
    wj = np.asarray([w[1] for w in want])
    wk = np.asarray([w[2] for w in want])
    bad = int(((np.asarray(gi) != wi) | (np.asarray(gj) != wj)
               | (np.asarray(gk) != wk)).sum())
    out.append(_res(
        "tet.boundaries", bad == 0,
        f"{len(lams)} plane-boundary probes up to lam={d['max_lam']}, "
        f"{bad} mismatches"))
    # Tightness: one past the envelope the traced map MUST diverge from
    # host (the final clamp pins it to the last exact plane). If it did
    # not, the declared envelope would be needlessly conservative.
    past = d["max_lam"] + 1  # == tet(TET_TRACED_MAX_I), still fits int32
    t = jax.jit(M.tet_map)(jnp.asarray(past, jnp.int32))
    traced_past = tuple(int(v) for v in t)
    host_past = M.tet_map(past)
    out.append(_res(
        "tet.envelope_tight", traced_past != host_past,
        f"lam={past}: traced {traced_past} vs host {host_past} "
        f"(clamped to plane {M.TET_TRACED_MAX_I - 1} as declared)"))
    return out


def run():
    """All envelope certifications -> list[CheckResult]."""
    return certify_isqrt() + certify_ltm() + certify_tet()
