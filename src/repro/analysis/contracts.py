"""Contract declarations for every registered schedule kind.

A ScheduleContract is the DECLARED side of the block-space checker: an
independent closed-form description of what a schedule promises — how many
blocks it launches, what domain those blocks cover, how the launch range
partitions into segments (rows in 2D, planes in 3D), and the inverse map
that witnesses uniqueness. The formulas here are written out literally
(``n * (n + 1) // 2`` rather than ``schedule.num_blocks``) precisely so
they are NOT the implementation under test: the verifier
(repro.analysis.verifier) proves the schedule and its contract agree via
closed-form counting plus boundary probing, which scales to n ~ 10^4
where the registry fuzz tests' exhaustive enumeration is impossible.

Bijectivity classes
-------------------
  BIJECTION  num_blocks == domain_blocks; host_map is a bijection from
             [0, num_blocks) onto the domain (zero interior waste — the
             paper's g(lambda) property).
  COVER      num_blocks >= domain_blocks; an ``active`` predicate selects
             the useful launches, and host_map restricted to active
             lambdas is a bijection onto the domain (BB / BB-3D / RB).
  MULTIPASS  several dense launches whose useful cells partition the
             domain (REC); verified by pass-level counting + containment.

Adding a schedule kind
----------------------
Declare a ScheduleContract with independent closed forms and register it
in ``schedule_contracts()``; the verifier picks it up automatically and
``python -m repro.analysis.lint`` will fail if the registry grows a kind
with no contract. See src/repro/analysis/README.md for a walk-through.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Iterable, Optional, Tuple

from repro.core import schedule as S

BIJECTION = "bijection"
COVER = "cover"
MULTIPASS = "multipass"


@dataclasses.dataclass(frozen=True)
class CheckResult:
    """One verified (or violated) obligation, across all three passes."""

    pass_name: str  # 'envelope' | 'contracts' | 'jaxpr'
    rule: str       # e.g. 'contract.ltm[n=10000].counting'
    ok: bool
    detail: str = ""

    def as_dict(self):
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Segment:
    """One contiguous lambda-run sharing the outermost coordinate.

    ``first``/``last`` are the closed-form expected coordinates of the
    segment's first and last launch — the boundary cells where off-by-one
    errors in sqrt/cbrt-seeded maps live.
    """

    origin: int
    width: int
    first: Tuple[int, ...]
    last: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class Case:
    """One (n, params) instantiation a contract is verified at."""

    label: str
    n: int
    kw: Tuple[Tuple[str, object], ...] = ()
    exhaustive: bool = False  # full enumeration cross-check (small n only)
    traced: bool = True       # vectorized traced-vs-host at boundary probes

    @property
    def kwargs(self):
        return dict(self.kw)


@dataclasses.dataclass(frozen=True)
class ScheduleContract:
    kind: str
    bijectivity: str
    rank: int
    make: Callable[[Case], S.BlockSchedule]
    launched: Callable[[Case], int]
    domain: Callable[[Case], int]
    segments: Callable[[Case], Iterable[Segment]]
    in_domain: Callable[[Tuple[int, ...], Case], bool]
    # (coords, case) -> lam; the uniqueness witness. COVER contracts invert
    # active cells back to their launch index; None only for MULTIPASS.
    inverse: Optional[Callable[[Tuple[int, ...], Case], int]]
    cases: Tuple[Case, ...]
    # COVER only: closed-form count of active launches inside a segment,
    # and the declared active predicate at a launch offset within it.
    seg_active_count: Optional[Callable[[int, Segment, Case], int]] = None
    active_at: Optional[Callable[[int, Segment, Case], bool]] = None


def _tri(n):
    return n * (n + 1) // 2


def _tet(n):
    return n * (n + 1) * (n + 2) // 6


_SMALL = (1, 2, 3, 5, 8, 33, 64)
_LARGE = (257, 1024, 10000)


def _cases(kw=(), small=_SMALL, large=_LARGE, traced_max=None):
    out = []
    for n in small:
        out.append(Case(label=f"n={n}", n=n, kw=kw, exhaustive=True))
    for n in large:
        traced = traced_max is None or n <= traced_max
        out.append(Case(label=f"n={n}", n=n, kw=kw, traced=traced))
    return tuple(out)


# ---------------------------------------------------------------------------
# Per-kind contracts
# ---------------------------------------------------------------------------


def _ltm_contract() -> ScheduleContract:
    def segments(case):
        for i in range(case.n):
            yield Segment(_tri(i), i + 1, (i, 0), (i, i))

    return ScheduleContract(
        kind="ltm", bijectivity=BIJECTION, rank=2,
        make=lambda c: S.make_schedule("ltm", c.n),
        launched=lambda c: _tri(c.n),
        domain=lambda c: _tri(c.n),
        segments=segments,
        in_domain=lambda ij, c: 0 <= ij[1] <= ij[0] < c.n,
        inverse=lambda ij, c: _tri(ij[0]) + ij[1],
        cases=_cases(),
    )


def _tet_contract() -> ScheduleContract:
    def segments(case):
        for i in range(case.n):
            yield Segment(_tet(i), _tri(i + 1), (i, 0, 0), (i, i, i))

    return ScheduleContract(
        kind="tet", bijectivity=BIJECTION, rank=3,
        make=lambda c: S.make_schedule("tet", c.n),
        launched=lambda c: _tet(c.n),
        domain=lambda c: _tet(c.n),
        segments=segments,
        in_domain=lambda ijk, c: 0 <= ijk[2] <= ijk[1] <= ijk[0] < c.n,
        inverse=lambda ijk, c: _tet(ijk[0]) + _tri(ijk[1]) + ijk[2],
        # traced envelope: planes i <= TET_TRACED_EXACT_PLANES (1624 is the
        # largest n whose every plane stays exact; checked there on purpose)
        cases=_cases(large=(257, 1624, 10000), traced_max=1624),
    )


def _bb_contract() -> ScheduleContract:
    def segments(case):
        n = case.n
        for i in range(n):
            yield Segment(i * n, n, (i, 0), (i, n - 1))

    return ScheduleContract(
        kind="bb", bijectivity=COVER, rank=2,
        make=lambda c: S.make_schedule("bb", c.n),
        launched=lambda c: c.n * c.n,
        domain=lambda c: _tri(c.n),
        segments=segments,
        in_domain=lambda ij, c: 0 <= ij[1] <= ij[0] < c.n,
        inverse=lambda ij, c: ij[0] * c.n + ij[1],
        seg_active_count=lambda si, seg, c: si + 1,  # row i: j <= i
        active_at=lambda off, seg, c: off <= seg.first[0],
        cases=_cases(),
    )


def _bb3_contract() -> ScheduleContract:
    def segments(case):
        n = case.n
        for i in range(n):
            yield Segment(i * n * n, n * n, (i, 0, 0), (i, n - 1, n - 1))

    def active_at(off, seg, case):
        j, k = off // case.n, off % case.n
        return k <= j <= seg.first[0]

    return ScheduleContract(
        kind="bb3", bijectivity=COVER, rank=3,
        make=lambda c: S.make_schedule("bb3", c.n),
        launched=lambda c: c.n ** 3,
        domain=lambda c: _tet(c.n),
        segments=segments,
        in_domain=lambda ijk, c: 0 <= ijk[2] <= ijk[1] <= ijk[0] < c.n,
        inverse=lambda ijk, c: (ijk[0] * c.n + ijk[1]) * c.n + ijk[2],
        seg_active_count=lambda si, seg, c: _tri(si + 1),  # plane simplex
        active_at=active_at,
        # n^3 lambdas exceed int32 beyond n = 1290 — traced probes stop there
        cases=_cases(small=(1, 2, 3, 5, 8, 33), large=(257, 1290, 10000),
                     traced_max=1290),
    )


def _band_contract() -> ScheduleContract:
    def eff_w(case):
        return min(case.kwargs["w"], case.n)

    def segments(case):
        w = eff_w(case)
        for i in range(case.n):
            if i < w - 1:
                yield Segment(_tri(i), i + 1, (i, 0), (i, i))
            else:
                origin = _tri(w - 1) + (i - (w - 1)) * w
                yield Segment(origin, w, (i, i - w + 1), (i, i))

    def inverse(ij, case):
        i, j = ij
        w = eff_w(case)
        if i < w - 1:
            return _tri(i) + j
        return _tri(w - 1) + (i - (w - 1)) * w + (j - (i - (w - 1)))

    return ScheduleContract(
        kind="band", bijectivity=BIJECTION, rank=2,
        make=lambda c: S.make_schedule("band", c.n, **c.kwargs),
        launched=lambda c: _tri(eff_w(c) - 1)
        + (c.n - (eff_w(c) - 1)) * eff_w(c),
        domain=lambda c: _tri(eff_w(c) - 1)
        + (c.n - (eff_w(c) - 1)) * eff_w(c),
        segments=segments,
        in_domain=lambda ij, c: 0 <= ij[1] <= ij[0] < c.n
        and ij[0] - ij[1] < eff_w(c),
        inverse=inverse,
        cases=tuple(case for w in (1, 3, 16)
                    for case in _cases(kw=(("w", w),))),
    )


def _prefix_contract() -> ScheduleContract:
    def eff_p(case):
        return min(case.kwargs["p"], case.n)

    def segments(case):
        p = eff_p(case)
        for i in range(case.n):
            if i < p:
                yield Segment(i * p, p, (i, 0), (i, p - 1))
            else:
                origin = p * p + _tri(i) - _tri(p)
                yield Segment(origin, i + 1, (i, 0), (i, i))

    def inverse(ij, case):
        i, j = ij
        p = eff_p(case)
        if i < p:
            return i * p + j
        return p * p + _tri(i) - _tri(p) + j

    return ScheduleContract(
        kind="prefix", bijectivity=BIJECTION, rank=2,
        make=lambda c: S.make_schedule("prefix", c.n, **c.kwargs),
        launched=lambda c: _tri(c.n) + _tri(eff_p(c) - 1),
        domain=lambda c: _tri(c.n) + _tri(eff_p(c) - 1),
        segments=segments,
        in_domain=lambda ij, c: 0 <= ij[0] < c.n and 0 <= ij[1] < c.n
        and (ij[1] <= ij[0] or ij[1] < eff_p(c)),
        inverse=inverse,
        cases=tuple(case for p in (1, 2, 7)
                    for case in _cases(kw=(("p", p),))),
    )


def _row_contract() -> ScheduleContract:
    return ScheduleContract(
        kind="row", bijectivity=BIJECTION, rank=2,
        make=lambda c: S.make_schedule("row", c.n),
        launched=lambda c: c.n,
        domain=lambda c: c.n,
        segments=lambda c: [Segment(0, c.n, (0, 0), (0, c.n - 1))],
        in_domain=lambda ij, c: ij[0] == 0 and 0 <= ij[1] < c.n,
        inverse=lambda ij, c: ij[1],
        cases=_cases(),
    )


def _utm_contract() -> ScheduleContract:
    # Strictly-lower cells come from the transposed Avril upper-tri map
    # (upper row a, 1-based, holds k in [lo(a), lo(a) + n - a)); the
    # diagonal is the dedicated tail segment.
    def segments(case):
        n = case.n
        for a in range(1, n):
            origin = (a - 1) * (2 * n - a) // 2
            yield Segment(origin, n - a, (a, a - 1), (n - 1, a - 1))
        yield Segment(_tri(n - 1), n, (0, 0), (n - 1, n - 1))

    def inverse(ij, case):
        i, j = ij
        n = case.n
        if i == j:
            return _tri(n - 1) + i
        a, b = j + 1, i + 1  # transpose back to 1-based upper coords
        return (a - 1) * (2 * n - a) // 2 + (b - a - 1)

    return ScheduleContract(
        kind="utm", bijectivity=BIJECTION, rank=2,
        make=lambda c: S.make_schedule("utm", c.n),
        launched=lambda c: _tri(c.n),
        domain=lambda c: _tri(c.n),
        segments=segments,
        in_domain=lambda ij, c: 0 <= ij[1] <= ij[0] < c.n,
        inverse=inverse,
        cases=_cases(),
    )


def _rb_contract() -> ScheduleContract:
    # Folded rectangle H x (n+1), H = ceil(n/2). Cell (x=col, y=row):
    #   x >  y: below-fold image (x-1, y)      -- j = y < H
    #   x <= y: folded-in image (H+y, H+x)     -- j = H+x >= H
    # The two image families are disjoint in j, and each is injective in
    # (x, y), so active cells map 1:1 — the inverse below reconstructs the
    # rectangle cell from the image's j-family.
    def H(case):
        return (case.n + 1) // 2

    def segments(case):
        n, h = case.n, H(case)
        w = n + 1
        for y in range(h):
            # first launch of the row is cell x=0 (folded-in image),
            # last is x=n (below-fold image (n-1, y))
            yield Segment(y * w, w, (h + y, h), (n - 1, y))

    def active_at(off, seg, case):
        n, h = case.n, H(case)
        y = seg.origin // (n + 1)
        x = off
        if x > y:
            i, j = x - 1, y
        else:
            i, j = h + y, h + x
        return 0 <= j <= i < n

    def seg_active_count(si, seg, case):
        n, h = case.n, H(case)
        y = si
        below = n - y                      # x in [y+1, n] -> (x-1, y)
        above = (y + 1) if h + y < n else 0  # x in [0, y] -> (h+y, h+x)
        return below + above

    def inverse(ij, case):
        i, j = ij
        n, h = case.n, H(case)
        if j < h:  # below-fold family
            x, y = i + 1, j
        else:      # folded-in family
            x, y = j - h, i - h
        return y * (n + 1) + x

    return ScheduleContract(
        kind="rb", bijectivity=COVER, rank=2,
        make=lambda c: S.make_schedule("rb", c.n),
        launched=lambda c: ((c.n + 1) // 2) * (c.n + 1),
        domain=lambda c: _tri(c.n),
        segments=segments,
        in_domain=lambda ij, c: 0 <= ij[1] <= ij[0] < c.n,
        inverse=inverse,
        seg_active_count=seg_active_count,
        active_at=active_at,
        # both parities at every scale (the odd-n fold leaves O(n) waste)
        cases=_cases(small=(1, 2, 3, 5, 8, 33, 64),
                     large=(257, 1024, 9999, 10000)),
    )


def _packed_recipe(total_rows: int):
    """Deterministic mixed-member recipe summing ~total_rows tile rows,
    cycling all four supported member kinds (mirrors the registry fuzz
    idiom in tests/test_schedule_registry.py)."""
    sizes = [3, 1, 4, 2, 7, 5]
    kinds = ["ltm", "band", "prefix", "row"]
    members, rows, k = [], 0, 0
    while rows < total_rows:
        n = min(sizes[k % len(sizes)] * (1 + k // len(sizes)),
                total_rows - rows) or 1
        kind = kinds[k % len(kinds)]
        if kind == "ltm":
            members.append(S.TriangularSchedule(n=n))
        elif kind == "band":
            members.append(S.BandSchedule(n=n, w=max(1, n // 2)))
        elif kind == "prefix":
            members.append(S.PrefixSchedule(n=n, p=max(1, n // 3)))
        else:
            members.append(S.RowSchedule(n=n))
        rows += n
        k += 1
    return tuple(members)


@functools.lru_cache(maxsize=None)
def _member_forms(m):
    """(launched, segments-as-(origin, width, first_j, last_j, i)) closed
    forms for one packed member, independent of the member's own code.
    Members are frozen dataclasses, so memoizing on them is sound — the
    10^4-row packed case probes every member thousands of times."""
    if isinstance(m, S.RowSchedule):
        return m.n, [(0, m.n, 0, m.n - 1, 0)]
    if isinstance(m, S.BandSchedule):
        w = min(m.w, m.n)
        segs = []
        for i in range(m.n):
            if i < w - 1:
                segs.append((_tri(i), i + 1, 0, i, i))
            else:
                segs.append((_tri(w - 1) + (i - (w - 1)) * w, w,
                             i - w + 1, i, i))
        return _tri(w - 1) + (m.n - (w - 1)) * w, segs
    if isinstance(m, S.PrefixSchedule):
        p = min(m.p, m.n)
        segs = []
        for i in range(m.n):
            if i < p:
                segs.append((i * p, p, 0, p - 1, i))
            else:
                segs.append((p * p + _tri(i) - _tri(p), i + 1, 0, i, i))
        return _tri(m.n) + _tri(p - 1), segs
    # TriangularSchedule
    return _tri(m.n), [(_tri(i), i + 1, 0, i, i) for i in range(m.n)]


def _packed_contract() -> ScheduleContract:
    recipes = {
        "small": _packed_recipe(13),
        "mixed": _packed_recipe(120),
        "n=10000": _packed_recipe(10000),
    }

    def members(case):
        return recipes[case.label]

    def make(case):
        return S.make_schedule("packed", 0, members=members(case))

    def launched(case):
        return sum(_member_forms(m)[0] for m in members(case))

    def segments(case):
        base = 0
        for r, m in enumerate(members(case)):
            total, segs = _member_forms(m)
            for origin, width, fj, lj, i in segs:
                yield Segment(base + origin, width, (r, i, fj), (r, i, lj))
            base += total

    @functools.lru_cache(maxsize=None)
    def bases(label):
        ms = recipes[label]
        out, cur = [], 0
        for m in ms:
            out.append(cur)
            cur += _member_forms(m)[0]
        return tuple(out)

    def in_domain(rij, case):
        r, i, j = rij
        ms = members(case)
        if not (0 <= r < len(ms)) or not (0 <= i < ms[r].n):
            return False
        _, segs = _member_forms(ms[r])
        _, _, fj, lj, _ = segs[i]
        return fj <= j <= lj

    def inverse(rij, case):
        r, i, j = rij
        ms = members(case)
        origin, _, fj, _, _ = _member_forms(ms[r])[1][i]
        return bases(case.label)[r] + origin + (j - fj)

    return ScheduleContract(
        kind="packed", bijectivity=BIJECTION, rank=3,
        make=make, launched=launched, domain=launched,
        segments=segments, in_domain=in_domain, inverse=inverse,
        cases=(
            Case(label="small", n=13, exhaustive=True),
            Case(label="mixed", n=120, exhaustive=True),
            Case(label="n=10000", n=10000),
        ),
    )


def _mixed_recipe(total_rows: int):
    """Deterministic continuous-batching recipe: ~2/3 of the tile rows go
    to prefill members cycling ltm/band/prefix (never row), the remainder
    to decode kv_tiles — the fused-step shape the engine launches."""
    sizes = [3, 1, 4, 2, 7, 5]
    kinds = ["ltm", "band", "prefix"]
    pre_rows = max(1, (2 * total_rows) // 3)
    prefill, rows, k = [], 0, 0
    while rows < pre_rows:
        n = min(sizes[k % len(sizes)] * (1 + k // len(sizes)),
                pre_rows - rows) or 1
        kind = kinds[k % len(kinds)]
        if kind == "ltm":
            prefill.append(S.TriangularSchedule(n=n))
        elif kind == "band":
            prefill.append(S.BandSchedule(n=n, w=max(1, n // 2)))
        else:
            prefill.append(S.PrefixSchedule(n=n, p=max(1, n // 3)))
        rows += n
        k += 1
    kv_tiles, rem, k = [], total_rows - rows, 0
    while rem > 0:
        t = min(sizes[k % len(sizes)], rem)
        kv_tiles.append(t)
        rem -= t
        k += 1
    return tuple(prefill), tuple(kv_tiles)


def _mixed_contract() -> ScheduleContract:
    """Fused-step schedule kind: same member machinery as "packed" (the
    mixed schedule IS a PackedSchedule), but the membership is the
    continuous-batching shape — prefill members followed by decode row
    members — declared as its own kind so the fused launch cannot ship
    uncontracted."""
    recipes = {label: _mixed_recipe(rows)
               for label, rows in (("small", 9), ("mixed", 120),
                                   ("n=10000", 10000))}

    def members(case):
        prefill, kv_tiles = recipes[case.label]
        return prefill + tuple(S.RowSchedule(n=t) for t in kv_tiles)

    def make(case):
        prefill, kv_tiles = recipes[case.label]
        return S.make_schedule("mixed", 0, prefill_members=prefill,
                               kv_tiles=kv_tiles)

    def launched(case):
        return sum(_member_forms(m)[0] for m in members(case))

    def segments(case):
        base = 0
        for r, m in enumerate(members(case)):
            total, segs = _member_forms(m)
            for origin, width, fj, lj, i in segs:
                yield Segment(base + origin, width, (r, i, fj), (r, i, lj))
            base += total

    @functools.lru_cache(maxsize=None)
    def bases(label):
        prefill, kv_tiles = recipes[label]
        ms = prefill + tuple(S.RowSchedule(n=t) for t in kv_tiles)
        out, cur = [], 0
        for m in ms:
            out.append(cur)
            cur += _member_forms(m)[0]
        return tuple(out)

    def in_domain(rij, case):
        r, i, j = rij
        ms = members(case)
        if not (0 <= r < len(ms)) or not (0 <= i < ms[r].n):
            return False
        _, segs = _member_forms(ms[r])
        _, _, fj, lj, _ = segs[i]
        return fj <= j <= lj

    def inverse(rij, case):
        r, i, j = rij
        ms = members(case)
        origin, _, fj, _, _ = _member_forms(ms[r])[1][i]
        return bases(case.label)[r] + origin + (j - fj)

    return ScheduleContract(
        kind="mixed", bijectivity=BIJECTION, rank=3,
        make=make, launched=launched, domain=launched,
        segments=segments, in_domain=in_domain, inverse=inverse,
        cases=(
            Case(label="small", n=9, exhaustive=True),
            Case(label="mixed", n=120, exhaustive=True),
            Case(label="n=10000", n=10000),
        ),
    )


def _rec_contract() -> ScheduleContract:
    # MULTIPASS: verified by the dedicated engine in verifier.py
    # (pass-level counting + origin-square containment + small-n bitmap).
    cases = []
    for m in (1, 4):
        for k in (0, 1, 3, 6):
            n = m * (1 << k)
            cases.append(Case(label=f"n={n},m={m}", n=n, kw=(("m", m),),
                              exhaustive=n <= 128, traced=False))
        big = m * (1 << 13)  # 8192 / 32768-capped below
        if big <= 10000:
            cases.append(Case(label=f"n={big},m={m}", n=big,
                              kw=(("m", m),), traced=False))
    return ScheduleContract(
        kind="rec", bijectivity=MULTIPASS, rank=2,
        make=lambda c: S.make_schedule("rec", c.n, **c.kwargs),
        launched=lambda c: sum(
            e * e * len(o)
            for e, o, _ in S.make_schedule("rec", c.n,
                                           **c.kwargs).passes()),
        domain=lambda c: _tri(c.n),
        segments=lambda c: [],
        in_domain=lambda ij, c: 0 <= ij[1] <= ij[0] < c.n,
        inverse=None,
        cases=tuple(cases),
    )


def schedule_contracts() -> Dict[str, ScheduleContract]:
    """kind -> contract, for every registered make_schedule kind.

    Aliases in the registry (triangular/dense/...) share the canonical
    kind's contract; the verifier checks the registry and this table stay
    in sync so a new kind cannot land without declaring a contract.
    """
    contracts = [
        _ltm_contract(), _tet_contract(), _bb_contract(), _bb3_contract(),
        _band_contract(), _prefix_contract(), _row_contract(),
        _utm_contract(), _rb_contract(), _rec_contract(),
        _packed_contract(), _mixed_contract(),
    ]
    return {c.kind: c for c in contracts}


# Registry aliases -> canonical contract kind (must mirror make_schedule).
KIND_ALIASES = {
    "triangular": "ltm",
    "tetrahedral": "tet",
    "dense": "bb",
    "dense3d": "bb3",
}

# Every kind make_schedule accepts (the verifier cross-checks this list
# against the registry by construction attempts).
REGISTERED_KINDS = ("ltm", "triangular", "tet", "tetrahedral", "bb",
                    "dense", "bb3", "dense3d", "band", "prefix", "row",
                    "utm", "rb", "rec", "packed", "mixed")
