"""Resilience-contract lint — pass 5 of the block-space checker.

The serving engine's failure handling is only trustworthy if its
vocabulary and its behavior can't drift apart silently. Three rule
groups:

  vocabulary sync   the degradation-ladder registry
                    (repro.resilience.faults.LADDERS) and the trace-event
                    schema (repro.obs.schema.DEGRADE_STAGES) must name
                    exactly the same stages, every registered transition
                    must move strictly DOWN its ladder, and every
                    resilience counter the engine emits (the
                    ``_inc_res("...")`` literals in serve/engine.py) must
                    be declared in schema.RESILIENCE_COUNTERS — and vice
                    versa.
  emission coverage AST walk over src/: ``degrade``/``quarantine`` trace
                    events may only be emitted from serve/engine.py, and
                    the engine's ``_degrade`` method must assert
                    ``is_registered_transition`` before emitting — so an
                    unregistered transition can never reach a trace file.
  dynamic identity  run the tiny smoke engine on CPU under a forced
                    FaultPlan (persistent admission OOM -> ladder
                    descent; one decode poison -> quarantine + replay)
                    and require the output token-identical to the
                    fault-free run, with the degrade and quarantine
                    counters actually firing. The resilience claim, not
                    just its plumbing.
  fleet coverage    the fleet rungs of the same registry: every adjacent
                    transition of the engine/route ladders must be mapped
                    to a schema-registered event in
                    serve.fleet.TRANSITION_EVENTS, the fleet's
                    ``_transition`` gate must assert
                    ``is_registered_transition``, fleet.py must call
                    ``_transition`` with exactly the mapped literals, and
                    the ``_inc("...")`` counter literals must match
                    schema.FLEET_COUNTERS both ways. The fleet event
                    types may never be emitted as raw literals anywhere —
                    the guarded gate is the only path. A dynamic check
                    kills one replica mid-run and requires failover to be
                    token-identical.
"""

from __future__ import annotations

import ast
import pathlib
from typing import List

from repro.analysis.contracts import CheckResult


def _res(rule, ok, detail=""):
    return CheckResult(pass_name="resilience", rule=rule, ok=ok,
                       detail=detail)


def _repo_root() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parents[3]


# ---------------------------------------------------------------------------
# vocabulary sync
# ---------------------------------------------------------------------------


def lint_vocab_sync() -> List[CheckResult]:
    from repro.obs import schema as SCH
    from repro.resilience import faults as F

    out = []
    ladder_stages = {s for ladder in F.LADDERS.values() for s in ladder}
    out.append(_res(
        "resilience.vocab.ladders_match_schema",
        ladder_stages == set(SCH.DEGRADE_STAGES),
        f"LADDERS stages {sorted(ladder_stages)} vs schema.DEGRADE_STAGES "
        f"{sorted(SCH.DEGRADE_STAGES)} (must be identical sets)"))

    bad = []
    for phase, frm, to in F.TRANSITIONS:
        ladder = F.LADDERS[phase]
        if not (frm in ladder and to in ladder
                and ladder.index(frm) < ladder.index(to)):
            bad.append((phase, frm, to))
        if not F.is_registered_transition(phase, frm, to):
            bad.append(("unregistered", phase, frm, to))
    out.append(_res(
        "resilience.vocab.transitions_strictly_down",
        not bad,
        f"{len(F.TRANSITIONS)} transitions checked; violations: "
        f"{bad or 'none'}"))
    return out


# ---------------------------------------------------------------------------
# emission coverage (AST)
# ---------------------------------------------------------------------------

_ENGINE_REL = "src/repro/serve/engine.py"
_FLEET_REL = "src/repro/serve/fleet.py"
_FLEET_EVENTS = ("failover", "engine_quarantine", "rebalance")


def _event_type_literals(call: ast.Call) -> List[str]:
    """String values bound to a literal "type" key in a dict argument of
    an emit_event(...) call."""
    types = []
    for arg in list(call.args) + [kw.value for kw in call.keywords]:
        if not isinstance(arg, ast.Dict):
            continue
        for k, v in zip(arg.keys, arg.values):
            if isinstance(k, ast.Constant) and k.value == "type" \
                    and isinstance(v, ast.Constant):
                types.append(str(v.value))
    return types


def lint_emission_coverage() -> List[CheckResult]:
    root = _repo_root()
    offenders = []
    scanned = 0
    for path in sorted((root / "src").rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        scanned += 1
        tree = ast.parse(path.read_text(encoding="utf-8"))
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and ((isinstance(node.func, ast.Attribute)
                          and node.func.attr == "emit_event")
                         or (isinstance(node.func, ast.Name)
                             and node.func.id == "emit_event"))):
                continue
            for etype in _event_type_literals(node):
                if etype in ("degrade", "quarantine") \
                        and rel != _ENGINE_REL:
                    offenders.append(f"{rel}:{node.lineno}:{etype}")
                if etype in _FLEET_EVENTS:
                    # fleet lifecycle events may ONLY flow through the
                    # fleet's guarded _transition gate (which emits them
                    # via the TRANSITION_EVENTS mapping, never as a raw
                    # literal) — a literal emission anywhere bypasses the
                    # registry check.
                    offenders.append(f"{rel}:{node.lineno}:{etype}")
    out = [_res(
        "resilience.coverage.events_from_engine_only",
        not offenders,
        f"{scanned} files scanned; degrade/quarantine emitted outside "
        f"{_ENGINE_REL} or fleet events emitted as raw literals: "
        f"{offenders or 'none'}")]

    # _degrade must assert is_registered_transition before emitting, and
    # every _inc_res literal must be a declared counter (and vice versa).
    from repro.obs import schema as SCH

    engine_src = (root / _ENGINE_REL).read_text(encoding="utf-8")
    tree = ast.parse(engine_src)
    guard_ok = False
    inc_res: set = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == "_degrade":
            for sub in ast.walk(node):
                if isinstance(sub, ast.Assert):
                    names = {n.attr for n in ast.walk(sub.test)
                             if isinstance(n, ast.Attribute)}
                    names |= {n.id for n in ast.walk(sub.test)
                              if isinstance(n, ast.Name)}
                    if "is_registered_transition" in names:
                        guard_ok = True
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "_inc_res" and node.args \
                and isinstance(node.args[0], ast.Constant):
            inc_res.add(str(node.args[0].value))
    out.append(_res(
        "resilience.coverage.degrade_guarded", guard_ok,
        "_degrade asserts is_registered_transition before emitting"
        if guard_ok else
        "_degrade does NOT assert is_registered_transition"))
    undeclared = inc_res - set(SCH.RESILIENCE_COUNTERS)
    unemitted = set(SCH.RESILIENCE_COUNTERS) - inc_res
    out.append(_res(
        "resilience.coverage.counters_declared",
        not undeclared and not unemitted,
        f"engine emits {sorted(inc_res)}; undeclared: "
        f"{sorted(undeclared) or 'none'}; declared-but-never-emitted: "
        f"{sorted(unemitted) or 'none'}"))
    return out


# ---------------------------------------------------------------------------
# fleet transition emission coverage
# ---------------------------------------------------------------------------


def lint_fleet_coverage() -> List[CheckResult]:
    from repro.obs import schema as SCH
    from repro.resilience import faults as F
    from repro.serve import fleet as FL

    out = []
    # every adjacent rung of the fleet ladders maps to a registered event
    adjacent = {(phase, F.LADDERS[phase][i], F.LADDERS[phase][i + 1])
                for phase in ("engine", "route")
                for i in range(len(F.LADDERS[phase]) - 1)}
    mapped = set(FL.TRANSITION_EVENTS)
    unmapped = adjacent - mapped
    unknown = mapped - adjacent
    bad_events = [e for e in FL.TRANSITION_EVENTS.values()
                  if e not in SCH.EVENT_TYPES]
    out.append(_res(
        "resilience.fleet.transitions_mapped",
        not unmapped and not unknown and not bad_events,
        f"adjacent fleet transitions {sorted(adjacent)}; unmapped: "
        f"{sorted(unmapped) or 'none'}; mapped-but-unregistered: "
        f"{sorted(unknown) or 'none'}; events outside schema: "
        f"{bad_events or 'none'}"))

    # AST over fleet.py: the _transition gate asserts the registry, the
    # call sites cover exactly the mapped transitions, and the counter /
    # gauge literals match the schema declarations both ways.
    tree = ast.parse((_repo_root() / _FLEET_REL).read_text(
        encoding="utf-8"))
    guard_ok = False
    calls: set = set()
    incs: set = set()
    gauges: set = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) \
                and node.name == "_transition":
            for sub in ast.walk(node):
                if isinstance(sub, ast.Assert):
                    names = {n.attr for n in ast.walk(sub.test)
                             if isinstance(n, ast.Attribute)}
                    names |= {n.id for n in ast.walk(sub.test)
                              if isinstance(n, ast.Name)}
                    if "is_registered_transition" in names:
                        guard_ok = True
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)):
            continue
        if node.func.attr == "_transition" and len(node.args) >= 3 and \
                all(isinstance(a, ast.Constant) for a in node.args[:3]):
            calls.add(tuple(str(a.value) for a in node.args[:3]))
        if node.func.attr == "_inc" and node.args \
                and isinstance(node.args[0], ast.Constant):
            incs.add(str(node.args[0].value))
        if node.func.attr == "gauge_set" and node.args \
                and isinstance(node.args[0], ast.Constant):
            gauges.add(str(node.args[0].value))
    out.append(_res(
        "resilience.fleet.transition_gate_guarded", guard_ok,
        "_transition asserts is_registered_transition before emitting"
        if guard_ok else
        "_transition does NOT assert is_registered_transition"))
    out.append(_res(
        "resilience.fleet.transition_sites_cover_mapping",
        calls == mapped,
        f"fleet.py _transition call sites {sorted(calls)} vs "
        f"TRANSITION_EVENTS keys {sorted(mapped)} (must be identical)"))
    undeclared = incs - set(SCH.FLEET_COUNTERS)
    unemitted = set(SCH.FLEET_COUNTERS) - incs
    bad_gauges = gauges - set(SCH.FLEET_GAUGES)
    out.append(_res(
        "resilience.fleet.counters_declared",
        not undeclared and not unemitted and not bad_gauges,
        f"fleet emits {sorted(incs)}; undeclared: "
        f"{sorted(undeclared) or 'none'}; declared-but-never-emitted: "
        f"{sorted(unemitted) or 'none'}; undeclared gauges: "
        f"{sorted(bad_gauges) or 'none'}"))
    return out


# ---------------------------------------------------------------------------
# dynamic token identity under a forced plan
# ---------------------------------------------------------------------------


def lint_dynamic_identity() -> List[CheckResult]:
    import jax
    import numpy as np

    from repro.configs import registry as REG
    from repro.models import model as MD
    from repro.resilience import faults as F
    from repro.serve.engine import Engine

    cfg = REG.smoke_config("yi-9b")
    params = MD.init_params(jax.random.key(0), cfg)
    prompts = [np.array([3, 1, 4, 1], np.int32),
               np.array([2, 7, 1], np.int32),
               np.array([9, 8, 2, 6, 5], np.int32)]

    def run(plan):
        eng = Engine(params, cfg, slots=2, max_len=32, temperature=0.0,
                     prefill_block=4, fault_plan=plan,
                     clock=F.VirtualClock())
        for uid, p in enumerate(prompts):
            eng.submit(p, max_new=3, uid=uid)
        return eng, eng.run()

    _, baseline = run(None)
    # 4 strikes outlast the default 3 retries -> forced ladder descent;
    # the decode poison forces a quarantine + deterministic replay.
    plan = F.FaultPlan([F.Fault("admit_oom", "admit", 0, times=4),
                        F.Fault("poison", "decode", 1, times=1)])
    eng, res = run(plan)
    st = eng.stats
    return [_res(
        "resilience.dynamic.token_identity",
        res == baseline and st["launches_degraded_total"] >= 1
        and st["slots_quarantined_total"] >= 1
        and st["requests_failed_total"] == 0,
        f"faulted == fault-free: {res == baseline}; degraded="
        f"{st['launches_degraded_total']} quarantined="
        f"{st['slots_quarantined_total']} failed="
        f"{st['requests_failed_total']}")]


def lint_dynamic_fleet_failover() -> List[CheckResult]:
    """Kill one replica mid-run (persistent decode launch failure) and
    require the fleet's streams identical to the fault-free single-engine
    run — the failover claim itself, exercised on CPU."""
    import jax
    import numpy as np

    from repro.configs import registry as REG
    from repro.models import model as MD
    from repro.resilience import faults as F
    from repro.serve.engine import Engine
    from repro.serve.fleet import Fleet

    cfg = REG.smoke_config("yi-9b")
    params = MD.init_params(jax.random.key(0), cfg)
    prompts = [np.array([3, 1, 4, 1], np.int32),
               np.array([2, 7, 1], np.int32),
               np.array([9, 8, 2, 6, 5], np.int32)]
    eng = Engine(params, cfg, slots=2, max_len=32, temperature=0.0,
                 prefill_block=4, clock=F.VirtualClock())
    for uid, p in enumerate(prompts):
        eng.submit(p, max_new=3, uid=uid)
    baseline = eng.run()

    plan = F.FaultPlan(
        [F.Fault("launch_error", "decode", 1, times=99, engine=0)])
    fleet = Fleet(params, cfg, engines=2, fault_plan=plan,
                  engine_kw=dict(slots=2, max_len=32, temperature=0.0,
                                 prefill_block=4))
    for uid, p in enumerate(prompts):
        fleet.submit(p, max_new=3, uid=uid)
    res = fleet.run(max_steps=100)
    st = fleet.stats
    identical = all(res.get(u) == baseline[u] for u in baseline)
    return [_res(
        "resilience.fleet.dynamic_failover_identity",
        identical and st["fleet_failovers_total"] >= 1
        and st["fleet_requests_migrated_total"] >= 1,
        f"failed-over fleet == fault-free engine: {identical}; "
        f"failovers={st['fleet_failovers_total']} "
        f"migrated={st['fleet_requests_migrated_total']}")]


def run() -> List[CheckResult]:
    out = []
    for rule_fn in (lint_vocab_sync, lint_emission_coverage,
                    lint_fleet_coverage, lint_dynamic_identity,
                    lint_dynamic_fleet_failover):
        try:
            out.extend(rule_fn())
        except Exception as e:  # a crash IS a lint failure
            out.append(_res(f"resilience.{rule_fn.__name__}", False,
                            f"exception: {type(e).__name__}: {e}"))
    return out
