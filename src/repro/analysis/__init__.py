"""Static block-space contract checker.

Three passes, none of which execute a kernel:

  envelope    certify the int32 envelopes of the traced isqrt/cbrt maps
              from derived float32 error bounds (repro.analysis.envelope)
  contracts   prove every registered schedule's declared contract —
              counting, partition, boundary probes, inverse round-trips,
              traced equivalence — at n up to 10^4
              (repro.analysis.contracts + repro.analysis.verifier)
  jaxpr       structural lint of every public op's jaxpr/HLO: exact
              pallas_call counts, scalar-prefetch table ABI, capacity
              bucketing, dtype hygiene (repro.analysis.jaxpr_lint)

Run with ``python -m repro.analysis.lint`` (add ``--json`` for
``artifacts/lint_report.json``). Wired into scripts/check.sh as a gating
tier ahead of pytest.
"""
