"""Schedule contract verification — pass 2 of the block-space checker.

For every contract declared in repro.analysis.contracts the engine proves,
per case (n up to 10^4, where exhaustive enumeration is impossible):

  counting     num_blocks / domain_blocks equal the contract's independent
               closed forms, and the declared segments PARTITION
               [0, num_blocks) (contiguous, ascending, widths summing to
               the launch count). For COVER kinds the per-segment active
               counts additionally sum to the domain size.
  boundaries   host_map at every segment's first/mid/last launch lands on
               the closed-form expected cell, inside the domain, and the
               declared inverse round-trips it (the uniqueness witness:
               an inverse that is a left inverse at all probes of a
               partition whose widths sum to the domain count leaves no
               room for a collision).
  traced       vectorized index_map at all boundary probes equals host_map
               (single jit per case; only within the certified int32
               envelopes — cases outside set Case.traced=False).
  exhaustive   small-n cross-check (n <= ~64): full enumeration equals the
               domain set exactly — anchors the closed forms to the same
               ground truth the registry fuzz tests use.

MULTIPASS (REC) gets a dedicated engine: pass-level counting identities,
origin-square containment probing, and a small-n coverage bitmap.

The decode-side bucket contract (serve.decode.round_capacity) is also
verified here: power-of-two, >= need, >= floor, minimal and monotone —
the static-grid recompile-hazard guarantees the engine relies on.
"""

from __future__ import annotations

import bisect
from typing import List

import numpy as np

from repro.analysis import contracts as C
from repro.core import mapping as M
from repro.core import schedule as S


def _res(rule, ok, detail=""):
    return C.CheckResult(pass_name="contracts", rule=rule, ok=ok,
                         detail=detail)


def _probe_lams(segs):
    """first / mid / last launch of every segment (deduped, sorted)."""
    out = set()
    for seg in segs:
        out.add(seg.origin)
        out.add(seg.origin + seg.width - 1)
        out.add(seg.origin + seg.width // 2)
    return sorted(out)


def _verify_case(con: C.ScheduleContract, case: C.Case) -> List[C.CheckResult]:
    tag = f"contract.{con.kind}[{case.label}]"
    out = []
    sched = con.make(case)
    launched = con.launched(case)
    domain = con.domain(case)
    segs = list(con.segments(case))

    # -- counting ------------------------------------------------------------
    cursor, widths_ok = 0, True
    for seg in segs:
        if seg.origin != cursor or seg.width <= 0:
            widths_ok = False
            break
        cursor += seg.width
    count_ok = (sched.num_blocks == launched
                and sched.domain_blocks == domain
                and widths_ok and cursor == launched)
    detail = (f"launched {sched.num_blocks} vs closed form {launched}; "
              f"domain {sched.domain_blocks} vs {domain}; "
              f"{len(segs)} segments partition the launch range: "
              f"{widths_ok and cursor == launched}")
    if con.bijectivity == C.COVER:
        active_total = sum(con.seg_active_count(si, seg, case)
                           for si, seg in enumerate(segs))
        count_ok = count_ok and active_total == domain
        detail += f"; active closed-form total {active_total} vs {domain}"
    if con.bijectivity == C.BIJECTION:
        count_ok = count_ok and launched == domain
    out.append(_res(f"{tag}.counting", count_ok, detail))

    # -- boundary probing ----------------------------------------------------
    lams = _probe_lams(segs)
    bad = []
    cells = {}
    origins = [seg.origin for seg in segs]
    for lam in lams:
        cell = sched.host_map(lam)
        cells[lam] = tuple(cell)
        # locate the segment owning lam (origins ascending)
        si = bisect.bisect_right(origins, lam) - 1
        seg = segs[si]
        off = lam - seg.origin
        if lam == seg.origin and tuple(cell) != tuple(seg.first):
            bad.append((lam, cell, "first", seg.first))
            continue
        if (lam == seg.origin + seg.width - 1
                and tuple(cell) != tuple(seg.last)):
            bad.append((lam, cell, "last", seg.last))
            continue
        if con.bijectivity == C.BIJECTION:
            if not con.in_domain(cell, case):
                bad.append((lam, cell, "in_domain", None))
            elif con.inverse(cell, case) != lam:
                bad.append((lam, cell, "inverse", con.inverse(cell, case)))
        else:  # COVER: the declared active predicate must match reality,
            # and active cells must round-trip through the inverse.
            declared = con.active_at(off, seg, case)
            actual = con.in_domain(cell, case)
            if declared != actual:
                bad.append((lam, cell, "active", declared))
            elif actual and con.inverse(cell, case) != lam:
                bad.append((lam, cell, "inverse", con.inverse(cell, case)))
    out.append(_res(
        f"{tag}.boundaries", not bad,
        f"{len(lams)} probes (3 per segment); "
        + (f"first violation {bad[0]}" if bad
           else "all land on closed-form cells and round-trip")))

    # -- traced equivalence --------------------------------------------------
    if case.traced and lams:
        import jax.numpy as jnp

        # eager jnp runs the identical int32/float32 traced arithmetic
        # without paying an XLA compile per case (a jit of the same map is
        # exercised once per kind by the jaxpr pass and the kernel tests)
        arr = np.asarray(lams, np.int32)
        coords = tuple(sched.index_map(jnp.asarray(arr)))
        mism = 0
        for axis in range(len(coords)):
            got = np.asarray(coords[axis])
            exp = np.asarray([cells[l][axis] for l in lams])
            mism += int((got != exp).sum())
        out.append(_res(
            f"{tag}.traced", mism == 0,
            f"index_map == host_map at {len(lams)} boundary probes "
            f"({mism} coordinate mismatches)"))

    # -- exhaustive small-n cross-check --------------------------------------
    if case.exhaustive:
        cells = []
        for lam in range(sched.num_blocks):
            cell = tuple(sched.host_map(lam))
            if con.bijectivity == C.COVER and not con.in_domain(cell, case):
                continue
            cells.append(cell)
        uniq = len(set(cells)) == len(cells)
        full = len(cells) == domain
        dom_ok = all(con.in_domain(c, case) for c in cells)
        out.append(_res(
            f"{tag}.exhaustive", uniq and full and dom_ok,
            f"enumerated {len(cells)} useful cells (expect {domain}); "
            f"unique={uniq}, all in-domain={dom_ok}"))
    return out


def _verify_multipass(con: C.ScheduleContract,
                      case: C.Case) -> List[C.CheckResult]:
    """REC: counting identities + containment probes + small-n bitmap."""
    tag = f"contract.{con.kind}[{case.label}]"
    out = []
    sched = con.make(case)
    n = case.n
    m = case.kwargs.get("m", 1)
    passes = sched.passes()

    # counting: launched = sum of pass areas; useful cells partition tri(n)
    launched = sum(e * e * len(origins) for e, origins, _ in passes)
    useful = sum((len(origins) * e * (e + 1) // 2) if is_diag
                 else len(origins) * e * e
                 for e, origins, is_diag in passes)
    count_ok = (sched.num_blocks == launched
                and useful == M.tri(n)
                and sched.domain_blocks == M.tri(n))
    out.append(_res(
        f"{tag}.counting", count_ok,
        f"launched {launched} (= schedule {sched.num_blocks}); useful "
        f"closed form {useful} vs tri(n) {M.tri(n)}"))

    # containment: every origin square in-bounds; non-diagonal squares
    # entirely below the diagonal (worst cell is the top-right corner).
    bad = []
    for e, origins, is_diag in passes:
        for oi, oj in origins:
            if not (0 <= oi and 0 <= oj and oi + e <= n and oj + e <= n):
                bad.append(("bounds", e, (oi, oj)))
            elif not is_diag and oj + e - 1 > oi:
                bad.append(("diagonal", e, (oi, oj)))
            elif is_diag and oi != oj:
                bad.append(("diag-origin", e, (oi, oj)))
    out.append(_res(
        f"{tag}.containment", not bad,
        f"{sum(len(o) for _, o, _ in passes)} origin squares; "
        + (f"first violation {bad[0]}" if bad else "all inside the domain")))

    # small-n bitmap: every lower-tri cell painted exactly once
    if case.exhaustive:
        paint = np.zeros((n, n), np.int32)
        for i, j in sched.enumerate_host():
            paint[i, j] += 1
        tril = np.tril(np.ones((n, n), bool))
        ok = bool((paint[tril] == 1).all() and (paint[~tril] == 0).all())
        out.append(_res(
            f"{tag}.exhaustive", ok,
            f"bitmap cover at n={n}, m={m}: each of tri(n)={M.tri(n)} "
            f"cells painted exactly once: {ok}"))
    return out


def verify_contract(con: C.ScheduleContract) -> List[C.CheckResult]:
    out = []
    for case in con.cases:
        try:
            if con.bijectivity == C.MULTIPASS:
                out.extend(_verify_multipass(con, case))
            else:
                out.extend(_verify_case(con, case))
        except Exception as e:  # a crash IS a contract violation
            out.append(_res(f"contract.{con.kind}[{case.label}]", False,
                            f"exception: {type(e).__name__}: {e}"))
    return out


def verify_registry_coverage() -> List[C.CheckResult]:
    """Every make_schedule kind must have a contract (directly or via
    alias) — a new kind cannot land without declaring one."""
    cons = C.schedule_contracts()
    missing = [k for k in C.REGISTERED_KINDS
               if C.KIND_ALIASES.get(k, k) not in cons]
    # and the declared registry list must actually match make_schedule
    stale = []
    for k in C.REGISTERED_KINDS:
        try:
            if k == "packed":
                S.make_schedule(k, 0, members=(S.TriangularSchedule(n=2),))
            elif k == "mixed":
                S.make_schedule(
                    k, 0, prefill_members=(S.TriangularSchedule(n=2),),
                    kv_tiles=(3,))
            elif k == "rec":
                S.make_schedule(k, 4, m=1)
            else:
                S.make_schedule(k, 4)
        except KeyError:
            stale.append(k)
    return [_res(
        "contracts.registry_coverage", not missing and not stale,
        f"registered kinds {len(C.REGISTERED_KINDS)}; missing contracts "
        f"{missing or 'none'}; stale registry entries {stale or 'none'}")]


def verify_bucket_contract() -> List[C.CheckResult]:
    """serve.decode.round_capacity: the recompile-hazard guard rails."""
    from repro.serve import decode as D

    bad = []
    prev = 0
    for need in range(0, 4097):
        cap = D.round_capacity(need)
        pow2 = cap & (cap - 1) == 0
        lower = cap >= max(need, 8)
        minimal = cap == 8 or cap // 2 < max(need, 8)
        mono = cap >= prev
        if not (pow2 and lower and minimal and mono):
            bad.append((need, cap))
        prev = cap
    distinct = len({D.round_capacity(v) for v in range(4097)})
    return [_res(
        "contracts.decode_bucket", not bad and distinct <= 10,
        f"round_capacity over [0, 4096]: power-of-two, >= need, minimal, "
        f"monotone; {distinct} distinct buckets (log-bounded); "
        + (f"first violation {bad[0]}" if bad else "ok"))]


def run() -> List[C.CheckResult]:
    out = verify_registry_coverage()
    for con in C.schedule_contracts().values():
        out.extend(verify_contract(con))
    out.extend(verify_bucket_contract())
    return out
