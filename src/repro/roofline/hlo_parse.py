"""Trip-count-aware analysis of optimized (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts every while-loop body
ONCE (verified on jax 0.8.2 / XLA CPU) — but this framework deliberately
wraps layers, microbatches and the triangular-attention tile enumeration in
``lax.scan``, so raw cost_analysis undercounts FLOPs by 2-4 orders of
magnitude. This module re-derives the three roofline inputs by walking the
compiled HLO with loop multipliers taken from XLA's own
``backend_config={"known_trip_count":{"n":...}}`` annotation (falling back
to the loop-condition constant, else 1 with a warning flag):

  * flops            — 2*M*N*K per dot (batch dims included), x trip counts.
  * hbm_bytes        — boundary-op traffic model: every op at the top level
                       of a non-fusion computation reads its operands and
                       writes its output once per execution; ops inside
                       fusions are free (they live in registers/VMEM).
                       Pure-layout ops (tuple plumbing, bitcast) are free.
  * collective_bytes — per-kind operand bytes of all-reduce / all-gather /
                       reduce-scatter / all-to-all / collective-permute
                       (+ async -start forms), x trip counts.

All shapes in a partitioned module are PER-DEVICE, so every figure this
module returns is per-device; roofline/model.py divides by per-chip peaks
directly (equivalent to the brief's global/(chips*peak) form).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

from repro.launch import compat

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1, "f8e3m4": 1, "f8e4m3": 1, "f8e8m0fnu": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[^\]]*\]"
    r"(?:\{[^}]*\})?)\s+([\w\-]+)\((.*)$")

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{\s*$")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

# ops that are pure plumbing/layout: no HBM traffic of their own
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "add-dependency",
    "opt-barrier", "get-dimension-size", "domain",
    # -done halves of async pairs (bytes counted at -start)
    "all-reduce-done", "all-gather-done", "collective-permute-done",
    "async-done", "copy-done", "send-done", "recv-done",
}


def _shape_bytes(type_str: str) -> float:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    out_type: str
    opcode: str
    rest: str  # operand list + attributes (unsplit tail of the line)

    @property
    def operands(self) -> List[str]:
        """Operand op names (strips nested call params; best effort)."""
        depth, buf, names = 0, "", []
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    buf and names.append(buf.strip())
                    break
                depth -= 1
            elif ch == "," and depth == 0:
                names.append(buf.strip())
                buf = ""
                continue
            buf += ch
        out = []
        for n in names:
            n = n.strip()
            # operands look like "%name" or "f32[..]{..} %name"
            m = re.search(r"%([\w.\-]+)\s*$", n)
            if m:
                out.append(m.group(1))
        return out

    def attr(self, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w.\-]+)", self.rest)
        return m.group(1) if m else None

    def attr_list(self, key: str) -> List[str]:
        """e.g. branch_computations={%region_1, %region_2}."""
        m = re.search(key + r"=\{([^}]*)\}", self.rest)
        if not m:
            return []
        return re.findall(r"%([\w.\-]+)", m.group(1))


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    is_entry: bool = False

    def op_map(self) -> Dict[str, Op]:
        return {o.name: o for o in self.ops}


def parse_kernel_frames(hlo_text: str,
                        marker: str = "kernels/tri_attn") -> set:
    """DIAGNOSTIC: stack-frame ids whose file chain touches `marker`.

    Parses the HLO header's FileNames/FileLocations/StackFrames tables.
    NOT used for the kernel-adjusted memory term — custom_vjp re-staging
    collapses source info (measured: the attention interior's frames point
    at unrelated lines), so production detection is the `_KERNEL_REGION_RE`
    op-name match below. Kept for HLO spelunking."""
    file_ids = set()
    m = re.search(r"FileNames\n(.*?)\n\n", hlo_text, re.S)
    if m:
        for line in m.group(1).splitlines():
            fm = re.match(r"(\d+)\s+\"(.*)\"", line.strip())
            if fm and marker in fm.group(2):
                file_ids.add(int(fm.group(1)))
    if not file_ids:
        return set()
    loc_ids = set()
    m = re.search(r"FileLocations\n(.*?)\n\n", hlo_text, re.S)
    if m:
        for line in m.group(1).splitlines():
            lm = re.match(r"(\d+)\s+\{file_name_id=(\d+)", line.strip())
            if lm and int(lm.group(2)) in file_ids:
                loc_ids.add(int(lm.group(1)))
    # frames: frame id -> (file_location_id, parent)
    frames = {}
    m = re.search(r"StackFrames\n(.*?)\n\n", hlo_text, re.S)
    if m:
        for line in m.group(1).splitlines():
            sm = re.match(
                r"(\d+)\s+\{file_location_id=(\d+)"
                r"(?:\s+parent_frame_id=(\d+))?", line.strip())
            if sm:
                frames[int(sm.group(1))] = (
                    int(sm.group(2)),
                    int(sm.group(3)) if sm.group(3) else 0)
    marked = set()
    for fid in frames:
        cur = fid
        seen = set()
        while cur and cur not in seen:
            seen.add(cur)
            loc, parent = frames.get(cur, (0, 0))
            if loc in loc_ids:
                marked.add(fid)
                break
            cur = parent
    return marked


def parse_computations(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo_text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(1), [],
                                  is_entry=line.startswith("ENTRY"))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            cur.ops.append(Op(*m.groups()))
    return comps


# ---------------------------------------------------------------------------
# Call-graph multipliers
# ---------------------------------------------------------------------------


def _trip_count(op: Op, comps: Dict[str, Computation]) -> Tuple[float, bool]:
    """(trips, known?) for a while op."""
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.rest)
    if m:
        return float(m.group(1)), True
    # fallback: find compare-with-constant in the condition computation
    cond_name = op.attr("condition")
    cond = comps.get(cond_name) if cond_name else None
    if cond is not None:
        consts = {o.name: o for o in cond.ops if o.opcode == "constant"}
        for o in cond.ops:
            if o.opcode == "compare":
                for operand in o.operands:
                    c = consts.get(operand)
                    if c is not None:
                        m2 = re.search(r"constant\((\d+)\)", "constant(" +
                                       c.rest)
                        if m2:
                            return float(m2.group(1)), True
    return 1.0, False


def computation_multipliers(comps: Dict[str, Computation]):
    """exec-count multiplier per computation, and which are fusion bodies.

    Walk from ENTRY; while body/cond multiply by trip count; fusion bodies
    inherit the caller's multiplier but are flagged (no HBM boundary)."""
    entry = next(c for c in comps.values() if c.is_entry)
    mult: Dict[str, float] = {}
    fusion_body: Dict[str, bool] = {}
    unknown_loops = [0]

    def visit(comp: Computation, m: float, in_fusion: bool):
        if mult.get(comp.name, 0) >= m and comp.name in mult and \
                fusion_body.get(comp.name, True) <= in_fusion:
            return  # already visited with >= multiplier and <= fusion flag
        mult[comp.name] = max(mult.get(comp.name, 0.0), m)
        fusion_body[comp.name] = fusion_body.get(comp.name, True) and in_fusion
        for op in comp.ops:
            if op.opcode == "while":
                trips, known = _trip_count(op, comps)
                if not known:
                    unknown_loops[0] += 1
                for key in ("condition", "body"):
                    sub = comps.get(op.attr(key))
                    if sub is not None:
                        visit(sub, m * trips, in_fusion)
            elif op.opcode == "fusion":
                sub = comps.get(op.attr("calls"))
                if sub is not None:
                    visit(sub, m, True)
            elif op.opcode in ("call", "custom-call", "async-start"):
                sub = comps.get(op.attr("to_apply") or op.attr("calls") or
                                op.attr("called_computation"))
                if sub is not None:
                    visit(sub, m, in_fusion)
            elif op.opcode == "conditional":
                branches = op.attr_list("branch_computations") or [
                    op.attr("true_computation"), op.attr("false_computation")]
                for name in branches:
                    sub = comps.get(name)
                    if sub is not None:
                        visit(sub, m, in_fusion)
            # map/reduce/sort/scatter to_apply bodies: scalar lambdas —
            # counted via the caller op's own cost, skip.

    visit(entry, 1.0, False)
    return mult, fusion_body, unknown_loops[0]


# ---------------------------------------------------------------------------
# FLOPs / bytes / collectives
# ---------------------------------------------------------------------------


def _dot_flops(op: Op, sym: Dict[str, Op]) -> float:
    """2 * prod(output dims) * prod(contracting dims of lhs)."""
    _, out_dims = _shape_dims(op.out_type)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contract = 1
    if m:
        lhs_name = op.operands[0] if op.operands else None
        lhs = sym.get(lhs_name)
        if lhs is not None:
            _, lhs_dims = _shape_dims(lhs.out_type)
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(lhs_dims):
                    contract *= lhs_dims[idx]
    return 2.0 * out_elems * contract


_FRAME_RE = re.compile(r"stack_frame_id=(\d+)")

# Kernel-fusable interiors, identified by op_name: the scan-attention cell
# (stack-frame tables are unreliable: custom_vjp re-staging collapses
# source info) plus the explicit jax.named_scope markers around scan
# fallbacks with a Pallas twin. The per-JAX-version spellings live in ONE
# tested table — launch/compat.KERNEL_REGION_OP_NAME_SPELLINGS — shared by
# every HLO consumer.
_KERNEL_REGION_RE = compat.kernel_region_regex()


def _op_bytes(op: Op, sym: Dict[str, Op]) -> float:
    """HBM traffic of one boundary op."""
    b_out = _shape_bytes(op.out_type)
    if op.opcode in ("dynamic-slice", "gather", "slice"):
        return 2.0 * b_out  # reads only the sliced region, writes it
    if op.opcode in ("dynamic-update-slice", "scatter"):
        upd = sym.get(op.operands[1]) if len(op.operands) > 1 else None
        b_upd = _shape_bytes(upd.out_type) if upd is not None else b_out
        return 2.0 * min(b_upd, b_out)  # in-place: read update, write region
    b_in = sum(_shape_bytes(sym[o].out_type)
               for o in op.operands if o in sym)
    return b_in + b_out


def analyze(hlo_text: str) -> dict:
    comps = parse_computations(hlo_text)
    mult, fusion_body, unknown = computation_multipliers(comps)

    flops = 0.0
    hbm_bytes = 0.0
    hbm_kernel_interior = 0.0  # attention-scan interior (VMEM under Pallas)
    hbm_kernel_dma = 0.0       # tile loads/stores (the BlockSpec traffic)
    coll: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    coll_count: Dict[str, int] = {k: 0 for k in _COLLECTIVES}

    for comp in comps.values():
        m = mult.get(comp.name)
        if m is None:
            continue  # unreachable (dead computation)
        sym = comp.op_map()
        boundary = not fusion_body.get(comp.name, False)
        for op in comp.ops:
            base = op.opcode.replace("-start", "")
            # flops: dots count wherever they live (fused or not)
            if op.opcode == "dot":
                flops += m * _dot_flops(op, sym)
            elif op.opcode == "convolution":
                # rare here; approximate: 2 * out * (in_ch * k_spatial)
                flops += m * 2.0 * _shape_bytes(op.out_type)
            # collectives
            if base in _COLLECTIVES and not op.opcode.endswith("-done"):
                b = sum(_shape_bytes(sym[o].out_type) for o in op.operands
                        if o in sym)
                if b == 0.0:  # operand defined in another computation scope
                    b = _shape_bytes(op.out_type)
                coll[base] += m * b
                coll_count[base] += int(m)
            # HBM boundary traffic
            if boundary and op.opcode not in _FREE_OPS:
                b = m * _op_bytes(op, sym)
                hbm_bytes += b
                if _KERNEL_REGION_RE.search(op.rest):
                    if op.opcode in ("dynamic-slice",
                                     "dynamic-update-slice"):
                        hbm_kernel_dma += b
                    else:
                        hbm_kernel_interior += b

    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        # kernel-adjusted: on real TPU the Pallas tri_attn kernel keeps the
        # scan interior in VMEM; only the tile DMAs (dynamic-slice/update,
        # == the BlockSpec traffic) hit HBM. CPU cannot compile Pallas, so
        # the dry-run substitutes: adjusted = raw - interior.
        "hbm_bytes_kernel_adj": hbm_bytes - hbm_kernel_interior,
        "hbm_kernel_interior": hbm_kernel_interior,
        "hbm_kernel_dma": hbm_kernel_dma,
        "collective_bytes": {k: v for k, v in coll.items() if v},
        "collective_bytes_total": sum(coll.values()),
        "collective_counts": {k: v for k, v in coll_count.items() if v},
        "unknown_trip_loops": unknown,
        "n_computations": len(comps),
    }


def breakdown(hlo_text: str, top: int = 25) -> list:
    """Largest HBM/collective contributors: (bytes, opcode, comp, op, mult).

    The §Perf profiling probe: shows exactly which op x trip-count products
    drive the memory and collective roofline terms."""
    comps = parse_computations(hlo_text)
    mult, fusion_body, _ = computation_multipliers(comps)
    rows = []
    for comp in comps.values():
        m = mult.get(comp.name)
        if m is None or fusion_body.get(comp.name, False):
            continue
        sym = comp.op_map()
        for op in comp.ops:
            if op.opcode in _FREE_OPS:
                continue
            b_out = _shape_bytes(op.out_type)
            if op.opcode in ("dynamic-slice", "gather", "slice"):
                b = 2.0 * b_out
            elif op.opcode in ("dynamic-update-slice", "scatter"):
                upd = (sym.get(op.operands[1])
                       if len(op.operands) > 1 else None)
                b = 2.0 * min(_shape_bytes(upd.out_type) if upd else b_out,
                              b_out)
            else:
                b = b_out + sum(_shape_bytes(sym[o].out_type)
                                for o in op.operands if o in sym)
            rows.append((m * b, op.opcode, comp.name, op.name, m))
    rows.sort(reverse=True)
    return rows[:top]


def analyze_compiled(compiled) -> dict:
    """Full report for a jax compiled artifact: parser + XLA's own stats."""
    out = analyze(compiled.as_text())
    try:
        from repro.launch.compat import cost_analysis_dict

        ca = cost_analysis_dict(compiled)
        out["xla_cost_analysis"] = {
            "flops": float(ca.get("flops", -1.0)),
            "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
            "transcendentals": float(ca.get("transcendentals", -1.0)),
        }
    except Exception as e:  # pragma: no cover
        out["xla_cost_analysis"] = {"error": str(e)}
    try:
        ma = compiled.memory_analysis()
        out["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
        }
    except Exception as e:  # pragma: no cover
        out["memory"] = {"error": str(e)}
    return out
