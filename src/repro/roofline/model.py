"""Three-term roofline model for TPU v5e.

  compute_s    = flops_per_device / PEAK_FLOPS
  memory_s     = hbm_bytes_per_device / HBM_BW
  collective_s = collective_bytes_per_device / ICI_BW

(All inputs from roofline/hlo_parse.py are per-device, so dividing by
per-chip peaks equals the brief's global/(chips*peak) formulation.)

The dominant term is the bottleneck; step time ~ max(terms) under perfect
overlap, sum(terms) with none. MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D
(MoE) measures how much of the compiled compute is "useful" — remat
recompute, padded vocab and dead masked tiles all show up as ratio < 1.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

# TPU v5e, per chip
PEAK_FLOPS = 197e12     # bf16 FLOP/s
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link (brief's constant)


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float = 0.0
    hlo_flops_per_dev: float = 0.0
    n_chips: int = 1
    # memory term with the attention-scan interior treated as VMEM-resident
    # (what the Pallas tri_attn kernel achieves on real TPU)
    memory_kernel_adj_s: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def dominant_kernel_adj(self) -> str:
        terms = {"compute": self.compute_s,
                 "memory": self.memory_kernel_adj_s or self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops summed over chips)."""
        total = self.hlo_flops_per_dev * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_at_bound(self) -> float:
        """Model FLOPs utilization if the step ran at the dominant term."""
        if self.bound_s == 0:
            return 0.0
        return (self.model_flops / (self.n_chips * PEAK_FLOPS)) / self.bound_s

    @property
    def bound_kernel_adj_s(self) -> float:
        return max(self.compute_s,
                   self.memory_kernel_adj_s or self.memory_s,
                   self.collective_s)

    @property
    def mfu_at_bound_kernel_adj(self) -> float:
        if self.bound_kernel_adj_s == 0:
            return 0.0
        return (self.model_flops / (self.n_chips * PEAK_FLOPS)) \
            / self.bound_kernel_adj_s

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "memory_kernel_adj_s": self.memory_kernel_adj_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "dominant_kernel_adj": self.dominant_kernel_adj,
            "model_flops": self.model_flops,
            "hlo_flops_per_dev": self.hlo_flops_per_dev,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_at_bound": self.mfu_at_bound,
            "mfu_at_bound_kernel_adj": self.mfu_at_bound_kernel_adj,
            "n_chips": self.n_chips,
        }


def terms_from_analysis(an: dict, *, n_chips: int,
                        model_flops: float = 0.0) -> RooflineTerms:
    return RooflineTerms(
        compute_s=an["flops"] / PEAK_FLOPS,
        memory_s=an["hbm_bytes"] / HBM_BW,
        memory_kernel_adj_s=an.get("hbm_bytes_kernel_adj",
                                   an["hbm_bytes"]) / HBM_BW,
        collective_s=an["collective_bytes_total"] / ICI_BW,
        model_flops=model_flops,
        hlo_flops_per_dev=an["flops"],
        n_chips=n_chips,
    )


# ---------------------------------------------------------------------------
# MODEL_FLOPS
# ---------------------------------------------------------------------------


def model_flops(cfg, shape, *, include_backward: Optional[bool] = None
                ) -> float:
    """6*N*D for training (fwd 2ND + bwd 4ND); 2*N*D for inference steps.

    N = active params (MoE counts routed experts only); D = tokens processed
    in the step (decode: one per sequence)."""
    n_active = cfg.param_counts()["active"]
    d_tokens = shape.tokens_per_step
    train = shape.kind == "train" if include_backward is None \
        else include_backward
    return (6.0 if train else 2.0) * n_active * d_tokens
