"""Batched serving engine: slot-based continuous batching (lite).

A fixed batch of B slots decodes in lockstep; each slot carries its own
absolute position (per-sequence pos vector — see decode_attention), so a
finished slot can be refilled with a new request without draining the
batch.

Admission is BULK: each admit round gathers a request per free slot and
prefills them all in ONE packed ragged launch (_admit_batch ->
decode.packed_prefill over the core/packing PackedSchedule grid —
sum_r tri(n_r) tiles, no per-request launches, no pad-to-max), then
splices each request's KV rows out of the packed states into its slot's
cache. Which requests ride together is COST-ordered by default: each
round admits the oldest queued request (aging — no starvation), then
fills the remaining free slots alternating the lightest and heaviest
pending by tile count (tri(ceil(S / block)), the packed cost model), so
successive packed rounds equalize total tiles; admit_order="fifo"
restores strict arrival order. The chosen order is exposed per round in
stats["admit_order_log"] / ["admit_round_tiles"]. Architectures with recurrent token mixers (mamba/rwkv) fall back to
the sequential per-token prefill: their state is not splice-able across a
packed concatenation.

This is the TPU-idiomatic middle ground between static batching and paged
attention: contiguous per-slot caches (DMA-friendly, no page tables), with
slot-level admission. Paged KV a la vLLM is GPU-pointer-chasing-shaped and
intentionally NOT ported (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mapping as M
from repro.models import model as MD
from repro.obs import metrics as MET
from repro.obs import trace as TR
from repro.serve import decode as D


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """In-process engine; submit() then run() until drained."""

    def __init__(self, params, cfg, *, slots: int = 4, max_len: int = 512,
                 cache_dtype=jnp.float32, temperature: float = 0.0,
                 seed: int = 0, prefill_mode: str = "packed",
                 prefill_block: int = 16, prefill_impl: str = "scan",
                 prefill_bucket: int = 0, decode_mode: str = "auto",
                 decode_block: int = 16, decode_impl: str = "scan",
                 admit_order: str = "cost", stats_log_rounds: int = 1024):
        self.params, self.cfg = params, cfg
        self.B, self.max_len = slots, max_len
        self.cache = MD.init_cache(cfg, slots, max_len, cache_dtype)
        self.pos = jnp.zeros((slots,), jnp.int32)
        self.last_tok = jnp.zeros((slots, 1), jnp.int32)
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.remaining = np.zeros((slots,), np.int64)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.temperature = temperature
        self.key = jax.random.key(seed)
        # packed ragged prefill needs splice-able (attention) token mixers;
        # recurrent archs keep the sequential per-token path.
        assert prefill_mode in ("packed", "sequential")
        attn_only = all(k == "attn" for k in cfg.layer_kinds)
        self.prefill_mode = prefill_mode if attn_only else "sequential"
        self.prefill_block = prefill_block
        self.prefill_impl = prefill_impl
        # length-bucketing quantum for the packed forward's static shapes:
        # 0 = exact block padding (one compile per distinct length tuple);
        # set >0 under compile-bound traffic (see decode.packed_prefill).
        self.prefill_bucket = prefill_bucket
        # packed mixed-position decode: position-skewed rounds go through
        # decode.decode_step_packed ("auto"); uniform all-live rounds keep
        # the lockstep einsum (one fused op, no per-tile bookkeeping).
        # Recurrent archs auto-fall back to lockstep like prefill does.
        assert decode_mode in ("auto", "packed", "lockstep")
        self.decode_mode = decode_mode if attn_only else "lockstep"
        self.decode_impl = decode_impl
        # attention KV geometry, read off the ACTUAL cache leaves (the
        # same source decode_step_packed uses — kv_len clamps can never
        # drift from the real buffer size); recurrent-only archs have no
        # KV leaves and only ever take the lockstep path, so the window
        # formula stands in for their (unused) stats bookkeeping. The
        # decode tile edge must divide S_cache (same normalization as
        # decode_step_packed, pre-applied so stats use the real edge).
        self.s_cache = D._attn_cache_len(cfg, self.cache) if any(
            k == "attn" for k in cfg.layer_kinds) else max(
            1, max_len if cfg.sliding_window is None
            else min(cfg.sliding_window, max_len))
        blk = min(decode_block, self.s_cache)
        while self.s_cache % blk:
            blk //= 2
        self.decode_block = blk
        # cost-model-driven admission: order the queue by per-request
        # prefill tile count (tri(n_r) — the packed launch's exact cost
        # model) so successive packed rounds equalize total tiles instead
        # of inheriting arrival-order lumps; "fifo" keeps strict arrival
        # order. The chosen order is exposed per round in stats.
        assert admit_order in ("cost", "fifo")
        self.admit_order = admit_order
        # observability: ONE packed launch per admit round (prefill) and
        # per decode round; prefill vs decode launches counted apart, plus
        # per-round tile accounting for the packed-vs-padded claim.
        # Counters live in a per-engine obs registry (mirrored into the
        # process-global registry as engine_* so metrics.json aggregates
        # them); the per-round admit logs are RingLog-capped at
        # ``stats_log_rounds`` (default 1024) so long-running engines stay
        # O(cap) memory — totals stay exact via RingLog.total_appended,
        # surfaced as stats["admit_rounds_total"] / ["admit_log_dropped"].
        # The legacy ``stats`` dict is now a read-only property view.
        self.registry = MET.Registry("engine")
        # admit_order_log[r] is round r's admitted (uid, tiles) pairs in
        # launch order; admit_round_tiles[r] its packed tile total.
        self._admit_order_log = MET.RingLog(maxlen=stats_log_rounds)
        self._admit_round_tiles = MET.RingLog(maxlen=stats_log_rounds)
        self._decode = jax.jit(
            lambda p, c, t, pos: MD.decode_step(p, cfg, c, t, pos))

    # -- telemetry -----------------------------------------------------------
    _COUNTERS = ("prefill_launches", "prefill_requests", "prefill_tokens",
                 "admit_rounds", "decode_rounds", "decode_packed_launches",
                 "decode_lockstep_launches", "decode_tiles_packed",
                 "decode_tiles_padded")

    def _inc(self, name: str, value: int = 1):
        """Count into the per-engine registry AND the process-global one
        (prefixed engine_* there, so metrics.json aggregates every engine
        without label collisions)."""
        self.registry.counter_inc(name, value)
        MET.counter_inc("engine_" + name, value)

    @property
    def stats(self) -> dict:
        """Read-only compat view of the registry-backed counters (the old
        ad-hoc dict, plus ring-buffer totals). Mutating the returned dict
        does NOT feed back into the engine."""
        st = {name: int(self.registry.counter_value(name))
              for name in self._COUNTERS}
        st["admit_order_log"] = self._admit_order_log.items()
        st["admit_round_tiles"] = self._admit_round_tiles.items()
        st["admit_rounds_total"] = self._admit_order_log.total_appended
        st["admit_log_dropped"] = self._admit_order_log.dropped
        return st

    # -- admission -----------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int, uid: int):
        self.queue.append(Request(uid, np.asarray(prompt, np.int32), max_new))

    def _prefill_into_slot(self, slot: int, req: Request):
        """Run the prompt through decode steps to fill the slot cache.

        Single-slot prefill via the decode path keeps the engine simple and
        exact; bulk prefill via prefill_cache covers the offline path. Other
        slots' cache entries are masked back to their previous values —
        recurrent states (mamba/rwkv) are NOT idempotent under replay."""
        b = self.B
        onehot = jnp.arange(b) == slot  # (B,)

        def merge(new, old):
            m = onehot.reshape((1, b) + (1,) * (new.ndim - 2))
            return jnp.where(m, new, old)

        toks = req.prompt
        for t_idx, tok in enumerate(toks):
            tok_b = self.last_tok.at[slot, 0].set(int(tok))
            pos_b = self.pos.at[slot].set(t_idx)
            logits, cache = self._decode(self.params, self.cache, tok_b,
                                         pos_b)
            self.cache = jax.tree.map(merge, cache, self.cache)
            self.last_tok = tok_b
            self.pos = pos_b
        self.pos = self.pos.at[slot].set(len(toks) - 1)
        self.slot_req[slot] = req
        self.remaining[slot] = req.max_new
        self._inc("prefill_launches", len(toks))
        self._inc("prefill_requests")
        self._inc("prefill_tokens", len(toks))

    def _splice_slot(self, slot: int, states, start: int, length: int):
        """Copy one request's KV rows [start, start+length) out of the
        packed prefill states into this slot's cache.

        KV leaves are (n_sl, 1, S_total, Hkv, hd) against a cache of
        (n_sl, B, S_slots, Hkv, hd). Sliding-window caches are rolling
        buffers (slot p % W holds position p): keep the last W rows and
        roll them into decode's slot order, mirroring prefill_cache."""
        def fill(c, st):
            if not (c.ndim == 5 and st.ndim == 5):
                return c  # non-KV leaf: unreachable on the packed path
            s_slots = c.shape[2]
            seg = st[:, 0, start:start + length]  # (n_sl, len, Hkv, hd)
            if length > s_slots:
                keep = seg[:, length - s_slots:]
                keep = jnp.roll(keep, shift=length % s_slots, axis=1)
                return c.at[:, slot, :s_slots].set(keep.astype(c.dtype))
            return c.at[:, slot, :length].set(seg.astype(c.dtype))

        self.cache = jax.tree.map(fill, self.cache, states)

    def _admit_batch(self, pairs):
        """Bulk admission: ONE packed ragged-prefill launch for every
        (slot, request) pair, then per-slot KV splicing. Replaces the
        O(sum of prompt lengths) sequential decode-step loop with a single
        sum_r tri(n_r)-tile launch (see serve/decode.packed_prefill)."""
        prompts = [req.prompt for _, req in pairs]
        with TR.span("engine.admit_batch", requests=len(pairs)) as sp:
            _, starts, lens, _, states = D.packed_prefill(
                self.params, self.cfg, prompts, block=self.prefill_block,
                attn_impl=self.prefill_impl, bucket=self.prefill_bucket)
            sp.attach(states)
        self._inc("prefill_launches")
        self._inc("prefill_requests", len(pairs))
        self._inc("prefill_tokens", sum(lens))
        for (slot, req), start, length in zip(pairs, starts, lens):
            self._splice_slot(slot, states, start, length)
            self.last_tok = self.last_tok.at[slot, 0].set(
                int(req.prompt[-1]))
            self.pos = self.pos.at[slot].set(length - 1)
            self.slot_req[slot] = req
            self.remaining[slot] = req.max_new

    def _prefill_tiles(self, req: Request) -> int:
        """Packed-prefill cost model for one request: tri(ceil(S / block))
        — exactly the blocks its member contributes to the admit round's
        packed grid (core/packing: num_blocks is the sum of member
        triangles)."""
        return M.tri(-(-len(req.prompt) // self.prefill_block))

    def _pick_requests(self, take: int) -> List[Request]:
        """Pop ``take`` queued requests for this admit round.

        "cost": the OLDEST queued request always rides (aging guarantee —
        every admit round retires the head of the queue, so no request is
        starved however its tile count sits between the ends), then the
        remaining slots alternate the lightest / heaviest pending so each
        packed round's total tiles lands near the queue mean — successive
        rounds equalize instead of inheriting arrival-order lumps (one
        round all-long, the next all-short). Ties keep arrival order.
        "fifo": strict arrival order.
        """
        if self.admit_order != "cost":
            return [self.queue.pop(0) for _ in range(take)]
        tiles = [self._prefill_tiles(r) for r in self.queue]
        heavy = iter(sorted(range(len(tiles)), key=lambda i: (-tiles[i], i)))
        light = iter(sorted(range(len(tiles)), key=lambda i: (tiles[i], i)))
        picked, used = [0], {0}  # aging: head of queue always admitted
        for t in range(take - 1):
            ends = light if t % 2 == 0 else heavy
            i = next(j for j in ends if j not in used)
            picked.append(i)
            used.add(i)
        reqs = [self.queue[i] for i in picked]
        for i in sorted(picked, reverse=True):
            self.queue.pop(i)
        return reqs

    def _admit(self):
        free = [s for s in range(self.B) if self.slot_req[s] is None]
        take = min(len(free), len(self.queue))
        if not take:
            return
        reqs = self._pick_requests(take)
        pairs = list(zip(free, reqs))
        self._inc("admit_rounds")
        self._admit_order_log.append(
            [(r.uid, self._prefill_tiles(r)) for r in reqs])
        self._admit_round_tiles.append(
            sum(self._prefill_tiles(r) for r in reqs))
        if self.prefill_mode == "packed":
            self._admit_batch(pairs)
        else:
            for slot, req in pairs:
                self._prefill_into_slot(slot, req)

    # -- decode loop ---------------------------------------------------------
    def step(self):
        """One decode round across all live slots — packed (mixed-position,
        each slot over its own valid KV prefix) when the batch is
        position-skewed or has retired slots, lockstep otherwise."""
        active = np.array([r is not None for r in self.slot_req])
        if not active.any():
            return
        live = [s for s in range(self.B) if active[s]]
        pos_np = np.asarray(self.pos)
        kv_lens = [int(min(pos_np[s] + 1, self.s_cache)) for s in live]
        # round geometry (recorded every round, whichever path runs): what
        # the packed grid covers vs what pad-to-max lockstep would.
        tiles = [-(-kl // self.decode_block) for kl in kv_lens]
        # skew at TILE granularity: equal tile counts with every slot live
        # means the packed grid equals pad-to-max — lockstep's one fused
        # einsum wins there, the packed grid wins everywhere else.
        skewed = len(live) < self.B or len(set(tiles)) > 1
        use_packed = self.decode_mode == "packed" or (
            self.decode_mode == "auto" and skewed)
        self._inc("decode_rounds")
        self._inc("decode_tiles_packed", sum(tiles))
        self._inc("decode_tiles_padded", len(live) * max(tiles))
        if use_packed:
            with TR.span("engine.decode_round", mode="packed",
                         live=len(live)) as sp:
                logits, cache, _ = D.decode_step_packed(
                    self.params, self.cfg, self.cache, self.last_tok,
                    self.pos, kv_lens, live, block=self.decode_block,
                    impl=self.decode_impl)
                sp.attach(logits)
            self._inc("decode_packed_launches")
        else:
            with TR.span("engine.decode_round", mode="lockstep",
                         live=len(live)) as sp:
                logits, cache = self._decode(self.params, self.cache,
                                             self.last_tok, self.pos)
                sp.attach(logits)
            self._inc("decode_lockstep_launches")
        self.key, k = jax.random.split(self.key)
        nxt = D.sample_logits(k, logits[:, 0], temperature=self.temperature,
                              vocab_size=self.cfg.vocab_size)
        nxt_np = np.asarray(nxt)
        self.cache = cache
        self.pos = self.pos + jnp.asarray(active, jnp.int32)
        self.last_tok = nxt[:, None]
        for slot in range(self.B):
            req = self.slot_req[slot]
            if req is None:
                continue
            req.out.append(int(nxt_np[slot]))
            self.remaining[slot] -= 1
            if self.remaining[slot] <= 0 or \
                    int(self.pos[slot]) >= self.max_len - 1:
                req.done = True
                self.finished.append(req)
                self.slot_req[slot] = None  # slot freed -> refilled next admit

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            self._admit()
            if all(r is None for r in self.slot_req) and not self.queue:
                break
            self.step()
        return {r.uid: r.out for r in self.finished}
