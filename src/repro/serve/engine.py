"""Batched serving engine: slot-based continuous batching (lite).

A fixed batch of B slots decodes in lockstep; each slot carries its own
absolute position (per-sequence pos vector — see decode_attention), so a
finished slot can be refilled with a new request without draining the
batch.

Admission is BULK: each admit round gathers a request per free slot and
prefills them all in ONE packed ragged launch (_admit_batch ->
decode.packed_prefill over the core/packing PackedSchedule grid —
sum_r tri(n_r) tiles, no per-request launches, no pad-to-max), then
splices each request's KV rows out of the packed states into its slot's
cache. Which requests ride together is COST-ordered by default: each
round admits the oldest queued request (aging — no starvation), then
fills the remaining free slots alternating the lightest and heaviest
pending by tile count (tri(ceil(S / block)), the packed cost model), so
successive packed rounds equalize total tiles; admit_order="fifo"
restores strict arrival order. The chosen order is exposed per round in
stats["admit_order_log"] / ["admit_round_tiles"]. Architectures with recurrent token mixers (mamba/rwkv) fall back to
the sequential per-token prefill: their state is not splice-able across a
packed concatenation.

This is the TPU-idiomatic middle ground between static batching and paged
attention: contiguous per-slot caches (DMA-friendly, no page tables), with
slot-level admission. Paged KV a la vLLM is GPU-pointer-chasing-shaped and
intentionally NOT ported (DESIGN.md §2).

Request lifecycle hardening (see src/repro/resilience/README.md)
----------------------------------------------------------------
Every round is allowed to FAIL. The engine then walks a declared
degradation ladder instead of crashing:

  admit   packed -> packed_scan -> sequential   (+ traced -> host when a
          member would exceed the certified LTM_TRACED_MAX_LAM envelope)
  decode  packed -> lockstep

Each stage gets bounded retries with seeded exponential backoff + jitter
(RetryPolicy); each ladder transition is asserted registered against
repro.resilience.faults.LADDERS, counted in ``launches_degraded_total``,
and emitted as a ``degrade`` trace event. Per-request deadlines/TTLs are
checked every loop tick on the engine's clock (injectable — a
VirtualClock makes the whole lifecycle deterministic under test);
overload shedding reuses the tri(n) admission cost ordering and never
sheds the queue head, so backpressure stays starvation-free. A cheap
NaN/Inf guard inspects every round's emitted logits: a poisoned slot is
QUARANTINED (``slots_quarantined_total`` + a ``quarantine`` trace event)
and its request replayed deterministically — re-prefilled from
prompt + tokens-already-emitted into a healthy slot, which reconstructs
the exact pre-fault state because decode is deterministic (greedy decode
therefore resumes token-identically; sampled decode stays replayable but
quarantine reorders RNG-key consumption). A round that fails past the
last ladder rung is attributed to the responsible request uids in
stats["failures"] and the engine keeps serving the unaffected slots —
every submitted request ends in exactly one terminal status
(done / shed / deadline_miss / failed), never silently dropped.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mapping as M
from repro.models import model as MD
from repro.obs import metrics as MET
from repro.obs import schema as SCH
from repro.obs import sinks as SK
from repro.obs import trace as TR
from repro.resilience import faults as F
from repro.resilience import health as H
from repro.serve import decode as D
from repro.serve import kv_cache as KV


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # lifecycle (terminal statuses: done | shed | deadline_miss | failed)
    status: str = "queued"
    deadline_s: Optional[float] = None
    submitted_at: float = 0.0
    replays: int = 0
    error: Optional[str] = None

    @property
    def feed(self) -> np.ndarray:
        """Tokens to prefill on (re)admission: the prompt plus everything
        already emitted. Quarantine replay prefills on this to re-derive
        the exact pre-fault cache state (decode is deterministic)."""
        if not self.out:
            return self.prompt
        return np.concatenate([self.prompt,
                               np.asarray(self.out, np.int32)])


# Decode auto-mode cost model: the packed decode round pays a per-tile
# scheduling overhead (member search + emit gating) that the lockstep
# fused einsum does not — measured at ~2.3x per tile on the bench_packed
# harness (CPU scan and interpreted pallas agree within noise). "auto"
# therefore takes the packed path only when the lockstep pad-to-max
# waste exceeds that premium: RATIO * sum(tiles) < B * max(tiles).
# In particular a uniform all-live batch (skew=1, equal grids) always
# stays lockstep — the old any-skew test sent it packed and lost 2.3x.
PACKED_TILE_COST_RATIO = 2.3


class EngineStepError(RuntimeError):
    """A round failed past the last rung of its degradation ladder."""

    def __init__(self, phase: str, rnd: int, cause: BaseException):
        super().__init__(f"{phase} round {rnd} failed after retries and "
                         f"degradation: {type(cause).__name__}: {cause}")
        self.phase, self.round, self.cause = phase, rnd, cause


class Engine:
    """In-process engine; submit() then run() until drained."""

    def __init__(self, params, cfg, *, slots: int = 4, max_len: int = 512,
                 cache_dtype=jnp.float32, temperature: float = 0.0,
                 seed: int = 0, prefill_mode: str = "packed",
                 prefill_block: int = 16, prefill_impl: str = "scan",
                 prefill_bucket: int = 0, decode_mode: str = "auto",
                 decode_block: int = 16, decode_impl: str = "scan",
                 step_mode: str = "split", auto_cost_measure: bool = False,
                 admit_order: str = "cost", stats_log_rounds: int = 1024,
                 fault_plan: Optional[F.FaultPlan] = None, clock=None,
                 retry: Optional[F.RetryPolicy] = None,
                 deadline_s: Optional[float] = None,
                 max_queue_tiles: int = 0, quarantine_rounds: int = 8,
                 traced_max_lam: Optional[int] = None,
                 guard_output: bool = True,
                 escalate_step_errors: bool = False):
        # ctor kwargs as REQUESTED (pre-downgrade), for snapshot/restore;
        # fault_plan/clock/retry are runtime harness, supplied at restore.
        self._init_kw = dict(
            slots=slots, max_len=max_len, cache_dtype=cache_dtype,
            temperature=temperature, seed=seed, prefill_mode=prefill_mode,
            prefill_block=prefill_block, prefill_impl=prefill_impl,
            prefill_bucket=prefill_bucket, decode_mode=decode_mode,
            decode_block=decode_block, decode_impl=decode_impl,
            step_mode=step_mode, auto_cost_measure=auto_cost_measure,
            admit_order=admit_order, stats_log_rounds=stats_log_rounds,
            deadline_s=deadline_s, max_queue_tiles=max_queue_tiles,
            quarantine_rounds=quarantine_rounds,
            traced_max_lam=traced_max_lam, guard_output=guard_output)
        self.params, self.cfg = params, cfg
        self.B, self.max_len = slots, max_len
        self.cache = MD.init_cache(cfg, slots, max_len, cache_dtype)
        self.pos = jnp.zeros((slots,), jnp.int32)
        self.last_tok = jnp.zeros((slots, 1), jnp.int32)
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.remaining = np.zeros((slots,), np.int64)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.temperature = temperature
        self.key = jax.random.key(seed)
        # packed ragged prefill needs splice-able (attention) token mixers;
        # recurrent archs keep the sequential per-token path.
        assert prefill_mode in ("packed", "sequential")
        attn_only = all(k == "attn" for k in cfg.layer_kinds)
        self.prefill_mode = prefill_mode if attn_only else "sequential"
        self.prefill_block = prefill_block
        self.prefill_impl = prefill_impl
        # length-bucketing quantum for the packed forward's static shapes:
        # 0 = exact block padding (one compile per distinct length tuple);
        # set >0 under compile-bound traffic (see decode.packed_prefill).
        self.prefill_bucket = prefill_bucket
        # packed mixed-position decode: position-skewed rounds go through
        # decode.decode_step_packed ("auto"); uniform all-live rounds keep
        # the lockstep einsum (one fused op, no per-tile bookkeeping).
        # Recurrent archs auto-fall back to lockstep like prefill does.
        assert decode_mode in ("auto", "packed", "lockstep")
        self.decode_mode = decode_mode if attn_only else "lockstep"
        self.decode_impl = decode_impl
        # fused continuous batching: admits AND live decode slots advance
        # in ONE mixed packed launch per engine step (step_fused). Needs
        # splice-able attention mixers, same as packed prefill.
        assert step_mode in ("split", "fused")
        self.step_mode = step_mode if attn_only else "split"
        # auto-mode cost model: the constant PACKED_TILE_COST_RATIO, or —
        # opt-in — a measured per-mode EMA of seconds/tile from this
        # engine's own rounds (only trusted once both modes have run).
        self.auto_cost_measure = auto_cost_measure
        self._mode_cost = {"packed": None, "lockstep": None}
        # attention KV geometry, read off the ACTUAL cache leaves (the
        # same source decode_step_packed uses — kv_len clamps can never
        # drift from the real buffer size); recurrent-only archs have no
        # KV leaves and only ever take the lockstep path, so the window
        # formula stands in for their (unused) stats bookkeeping. The
        # decode tile edge must divide S_cache (same normalization as
        # decode_step_packed, pre-applied so stats use the real edge).
        self.s_cache = D._attn_cache_len(cfg, self.cache) if any(
            k == "attn" for k in cfg.layer_kinds) else max(
            1, max_len if cfg.sliding_window is None
            else min(cfg.sliding_window, max_len))
        blk = min(decode_block, self.s_cache)
        while self.s_cache % blk:
            blk //= 2
        self.decode_block = blk
        # cost-model-driven admission: order the queue by per-request
        # prefill tile count (tri(n_r) — the packed launch's exact cost
        # model) so successive packed rounds equalize total tiles instead
        # of inheriting arrival-order lumps; "fifo" keeps strict arrival
        # order. The chosen order is exposed per round in stats.
        assert admit_order in ("cost", "fifo")
        self.admit_order = admit_order
        # -- resilience harness ------------------------------------------
        # clock is injectable: a resilience.faults.VirtualClock makes
        # deadlines, backoff and straggler delays deterministic under
        # test; anything with a .sleep(dt) method is "slept" through it.
        self.clock = clock if clock is not None else time.monotonic
        self.fault_plan = fault_plan
        self.retry = retry if retry is not None else F.RetryPolicy(seed=seed)
        self.default_deadline_s = deadline_s
        self.max_queue_tiles = max_queue_tiles
        self.quarantine_rounds = quarantine_rounds
        self._traced_max_lam = (M.LTM_TRACED_MAX_LAM if traced_max_lam
                                is None else traced_max_lam)
        self.guard_output = guard_output
        # fleet-replica mode (runtime harness, like fault_plan/clock —
        # NOT part of _init_kw): instead of absorbing a terminal round
        # failure in-engine (fail the round's requests, quarantine a
        # poisoned slot), RAISE it so the owning Fleet can snapshot the
        # replica and migrate its requests token-identically. Requests
        # not yet committed to a slot are requeued at the head before the
        # raise, so the snapshot the fleet captures accounts for every
        # request exactly once.
        self.escalate_step_errors = escalate_step_errors
        self.quarantined: Dict[int, int] = {}  # slot -> release round
        self._rolling = cfg.sliding_window is not None
        self._round_watch = H.RoundWatch()
        self._admit_round_idx = 0
        self._decode_round_idx = 0
        # distinct fused packing templates this engine has compiled under:
        # {(padded-length tuple, capacity)} — the compile-footprint record
        # the snapshot persists (satellite of the bucketing story: the
        # set is bounded by prefill_bucket, and a restored engine knows
        # which programs its predecessor already paid for).
        self.fused_templates: set = set()
        # observability: ONE packed launch per admit round (prefill) and
        # per decode round; prefill vs decode launches counted apart, plus
        # per-round tile accounting for the packed-vs-padded claim.
        # Counters live in a per-engine obs registry (mirrored into the
        # process-global registry as engine_* so metrics.json aggregates
        # them); the per-round admit logs are RingLog-capped at
        # ``stats_log_rounds`` (default 1024) so long-running engines stay
        # O(cap) memory — totals stay exact via RingLog.total_appended,
        # surfaced as stats["admit_rounds_total"] / ["admit_log_dropped"].
        # The legacy ``stats`` dict is now a read-only property view.
        self.registry = MET.Registry("engine")
        # admit_order_log[r] is round r's admitted (uid, tiles) pairs in
        # launch order; admit_round_tiles[r] its packed tile total.
        self._admit_order_log = MET.RingLog(maxlen=stats_log_rounds)
        self._admit_round_tiles = MET.RingLog(maxlen=stats_log_rounds)
        self._failures = MET.RingLog(maxlen=stats_log_rounds)
        self._decode = jax.jit(
            lambda p, c, t, pos: MD.decode_step(p, cfg, c, t, pos))

    # -- telemetry -----------------------------------------------------------
    _COUNTERS = ("prefill_launches", "prefill_requests", "prefill_tokens",
                 "admit_rounds", "decode_rounds", "decode_packed_launches",
                 "decode_lockstep_launches", "decode_tiles_packed",
                 "decode_tiles_padded", "fused_rounds", "fused_launches",
                 "fused_fallbacks", "fused_tiles")

    def _inc(self, name: str, value: int = 1):
        """Count into the per-engine registry AND the process-global one
        (prefixed engine_* there, so metrics.json aggregates every engine
        without label collisions)."""
        self.registry.counter_inc(name, value)
        MET.counter_inc("engine_" + name, value)

    def _inc_res(self, name: str, value: int = 1):
        """Resilience counters keep their CANONICAL *_total names in both
        the per-engine registry and the process-global one — these are
        the issue-facing names schema.RESILIENCE_COUNTERS declares and
        metrics.json carries."""
        self.registry.counter_inc(name, value)
        MET.counter_inc(name, value)

    @property
    def stats(self) -> dict:
        """Read-only compat view of the registry-backed counters (the old
        ad-hoc dict, plus ring-buffer totals). Mutating the returned dict
        does NOT feed back into the engine."""
        st = {name: int(self.registry.counter_value(name))
              for name in self._COUNTERS}
        for name in SCH.RESILIENCE_COUNTERS:
            st[name] = int(self.registry.counter_value(name))
        st["admit_order_log"] = self._admit_order_log.items()
        st["admit_round_tiles"] = self._admit_round_tiles.items()
        st["admit_rounds_total"] = self._admit_order_log.total_appended
        st["admit_log_dropped"] = self._admit_order_log.dropped
        # per-step failures attributed to the responsible request uid
        st["failures"] = self._failures.items()
        return st

    def report(self) -> Dict[int, dict]:
        """Explicit per-request lifecycle report. Every submitted request
        appears with its status (queued / running / done / shed /
        deadline_miss / failed), token count, replay count, and error —
        shed and deadline-missed requests are REPORTED here, never
        silently dropped."""
        reqs = (list(self.finished)
                + [r for r in self.slot_req if r is not None]
                + list(self.queue))
        return {r.uid: {"status": r.status, "tokens": len(r.out),
                        "replays": r.replays, "error": r.error}
                for r in reqs}

    # -- resilience plumbing -------------------------------------------------
    def _sleep(self, dt: float):
        """Advance the injectable clock (VirtualClock.sleep) or really
        sleep, capped so a mis-sized backoff cannot stall a live engine."""
        if dt <= 0.0:
            return
        sleeper = getattr(self.clock, "sleep", None)
        if sleeper is not None:
            sleeper(dt)
        else:
            time.sleep(min(dt, self.retry.cap_s))

    def _finish(self, req: Request, status: str,
                error: Optional[str] = None):
        req.status = status
        req.done = True
        req.error = error
        self.finished.append(req)

    def _record_failure(self, req: Request, phase: str, rnd: int,
                        err: BaseException):
        msg = f"{type(err).__name__}: {err}"
        self._finish(req, "failed", error=msg)
        self._inc_res("requests_failed_total")
        self._failures.append({"uid": req.uid, "phase": phase,
                               "round": rnd, "error": msg})

    def _degrade(self, phase: str, rnd: int, frm: str, to: str,
                 reason: str):
        """One rung down the declared ladder: counted, traced, and
        runtime-checked against the transition registry (an unregistered
        transition is a bug — the resilience lint pass proves the
        registry matches schema.DEGRADE_STAGES)."""
        assert F.is_registered_transition(phase, frm, to), (
            f"unregistered degradation {phase}: {frm} -> {to}; declare it "
            f"in repro.resilience.faults.LADDERS")
        self._inc_res("launches_degraded_total")
        if SK.trace_enabled():
            SK.emit_event({"type": "degrade", "phase": phase, "from": frm,
                           "to": to, "round": rnd, "reason": reason[:200]})

    def _attempt(self, fn, n_affected: int):
        """Run one ladder stage with bounded retries + seeded backoff.
        Returns (ok, result, err)."""
        err: Optional[BaseException] = None
        for attempt in range(self.retry.max_retries + 1):
            try:
                return True, fn(attempt), None
            except Exception as e:  # noqa: BLE001 — hardening boundary
                if self.escalate_step_errors and \
                        isinstance(e, (EngineStepError, F.PoisonedOutput)):
                    # fleet replica: a nested terminal failure or a
                    # poisoned round is not retried here — it escalates
                    # so the fleet can quarantine + migrate.
                    raise
                err = e
                if attempt < self.retry.max_retries:
                    self._inc_res("requests_retried_total", n_affected)
                    self._sleep(self.retry.delay(attempt))
        return False, None, err

    def _run_ladder(self, phase: str, rnd: int, stages: List[str],
                    runner, n_affected: int):
        """Walk the phase's degradation ladder: bounded retries within a
        stage, a registered degrade transition between stages. Returns
        (result, stage) or raises EngineStepError carrying the cause."""
        err: Optional[BaseException] = None
        for si, stage in enumerate(stages):
            ok, result, err = self._attempt(
                lambda a, s=stage: runner(s, a), n_affected)
            if ok:
                return result, stage
            if si + 1 < len(stages):
                self._degrade(phase, rnd, stage, stages[si + 1],
                              reason=f"{type(err).__name__}: {err}")
        raise EngineStepError(phase, rnd, err)

    # -- admission -----------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int, uid: int,
               deadline_s: Optional[float] = None):
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            raise ValueError(f"request {uid}: empty prompt")
        if prompt.size > self.max_len:
            raise ValueError(
                f"request {uid}: prompt of {prompt.size} tokens exceeds "
                f"max_len={self.max_len} — its KV splice would overflow "
                f"the slot cache (raise max_len or truncate)")
        req = Request(uid, prompt, max_new,
                      deadline_s=(self.default_deadline_s
                                  if deadline_s is None else deadline_s),
                      submitted_at=float(self.clock()))
        self.queue.append(req)
        self._shed_overload()

    def _shed_overload(self):
        """Overload shedding on the tri(n) cost ordering: while the
        queue's packed-prefill tile total exceeds ``max_queue_tiles``,
        shed the HEAVIEST request that is not the queue head. The aging
        guarantee (the head always rides the next admit round) is what
        keeps backpressure starvation-free — so the head is never shed,
        however heavy, and shedding is deterministic in arrival order."""
        if not self.max_queue_tiles:
            return
        while len(self.queue) > 1 and \
                sum(self._prefill_tiles(r) for r in self.queue) \
                > self.max_queue_tiles:
            victim_i = max(range(1, len(self.queue)),
                           key=lambda i: (self._prefill_tiles(self.queue[i]),
                                          i))
            victim = self.queue.pop(victim_i)
            self._inc_res("requests_shed_total")
            self._finish(victim, "shed", error=(
                f"shed: queue over capacity ({self.max_queue_tiles} "
                f"tiles) and this was the heaviest non-head request"))

    def _expire_deadlines(self):
        """TTL sweep on the engine clock: queued AND running requests past
        their deadline are retired explicitly (status deadline_miss, the
        tokens emitted so far preserved) — a request never occupies a
        slot or a queue position beyond its deadline."""
        now = float(self.clock())

        def missed(req):
            return req.deadline_s is not None and \
                now - req.submitted_at > req.deadline_s

        for req in [r for r in self.queue if missed(r)]:
            self.queue.remove(req)
            self._miss(req, now)
        for slot in range(self.B):
            req = self.slot_req[slot]
            if req is not None and missed(req):
                self.slot_req[slot] = None
                self._miss(req, now)

    def _miss(self, req: Request, now: float):
        self._inc_res("deadline_misses_total")
        self._finish(req, "deadline_miss", error=(
            f"deadline {req.deadline_s}s exceeded after "
            f"{now - req.submitted_at:.3f}s"))

    def _prefill_into_slot(self, slot: int, req: Request):
        """Run the request's feed through decode steps to fill the slot
        cache — the sequential HOST-map path (also the last rung of the
        admit ladder and the traced-envelope fallback).

        Single-slot prefill via the decode path keeps the engine simple and
        exact; bulk prefill via prefill_cache covers the offline path. Other
        slots' cache entries are masked back to their previous values —
        recurrent states (mamba/rwkv) are NOT idempotent under replay."""
        b = self.B
        onehot = jnp.arange(b) == slot  # (B,)

        def merge(new, old):
            m = onehot.reshape((1, b) + (1,) * (new.ndim - 2))
            return jnp.where(m, new, old)

        toks = req.feed
        for t_idx, tok in enumerate(toks):
            tok_b = self.last_tok.at[slot, 0].set(int(tok))
            pos_b = self.pos.at[slot].set(t_idx)
            logits, cache = self._decode(self.params, self.cache, tok_b,
                                         pos_b)
            self.cache = jax.tree.map(merge, cache, self.cache)
            self.last_tok = tok_b
            self.pos = pos_b
        self.pos = self.pos.at[slot].set(len(toks) - 1)
        self.slot_req[slot] = req
        self.remaining[slot] = req.max_new - len(req.out)
        self._inc("prefill_launches", len(toks))
        self._inc("prefill_requests")
        self._inc("prefill_tokens", len(toks))

    def _splice_slot(self, slot: int, states, start: int, length: int):
        """Validated splice of one request's packed KV rows into a slot
        cache — bounds checking lives in serve/kv_cache.splice_slot."""
        self.cache = KV.splice_slot(self.cache, slot, states, start,
                                    length, rolling=self._rolling)

    def _admit_stages(self, rnd: int, reqs: List[Request]) -> List[str]:
        """The admit round's degradation ladder, from the configured
        fast path down to the sequential host path."""
        if self.prefill_mode != "packed":
            return ["sequential"]
        if not D.traced_prefill_ok([len(r.feed) for r in reqs],
                                   self.prefill_block,
                                   self._traced_max_lam):
            # certified-envelope guard: the traced isqrt block map is only
            # exact up to LTM_TRACED_MAX_LAM; past it, take the host map.
            self._degrade("admit", rnd, "traced", "host", reason=(
                "member exceeds the certified traced-isqrt envelope "
                f"(traced_max_lam={self._traced_max_lam})"))
            return ["sequential"]
        stages = ["packed"]
        if self.prefill_impl == "pallas":
            stages.append("packed_scan")
        stages.append("sequential")
        return stages

    def _admit_packed(self, pairs, rnd: int, impl: str):
        """Bulk admission: ONE packed ragged-prefill launch for every
        (slot, request) pair, then per-slot KV splicing — committed only
        after the output guard passes, so a retried round never leaves
        half-spliced state behind."""
        if self.fault_plan is not None:
            self._sleep(self.fault_plan.maybe_fail("admit", rnd))
        prompts = [req.feed for _, req in pairs]
        with TR.span("engine.admit_batch", requests=len(pairs)) as sp:
            _, starts, lens, _, states = D.packed_prefill(
                self.params, self.cfg, prompts, block=self.prefill_block,
                attn_impl=impl, bucket=self.prefill_bucket)
            sp.attach(states)
        if self.fault_plan is not None and \
                self.fault_plan.poisons_admit(rnd):
            # injected corruption lands at the host boundary the guard
            # below inspects — the detection path is the real one.
            states = jax.tree.map(
                lambda x: jnp.full_like(x, jnp.nan)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, states)
        if self.guard_output and not D.states_finite(states):
            raise F.PoisonedOutput(
                f"admit round {rnd}: non-finite packed prefill states")
        # commit
        self._inc("prefill_launches")
        self._inc("prefill_requests", len(pairs))
        self._inc("prefill_tokens", sum(lens))
        for (slot, req), start, length in zip(pairs, starts, lens):
            self._splice_slot(slot, states, start, length)
            self.last_tok = self.last_tok.at[slot, 0].set(
                int(req.feed[-1]))
            self.pos = self.pos.at[slot].set(length - 1)
            self.slot_req[slot] = req
            self.remaining[slot] = req.max_new - len(req.out)

    def _admit_sequential(self, pairs, rnd: int):
        """Per-request sequential prefill (host-map path): each request
        is retried and, on exhaustion, failed INDIVIDUALLY — one bad
        request cannot take down its round-mates."""
        for member, (slot, req) in enumerate(pairs):
            def one(attempt, s=slot, r=req, m=member):
                if self.fault_plan is not None:
                    self._sleep(self.fault_plan.maybe_fail(
                        "admit", rnd, member=m))
                self._prefill_into_slot(s, r)

            ok, _, err = self._attempt(one, n_affected=1)
            if not ok:
                if self.escalate_step_errors:
                    # last rung of the admit ladder exhausted on a fleet
                    # replica: the engine is out of fallbacks — escalate.
                    raise EngineStepError("admit", rnd, err)
                self._record_failure(req, "admit", rnd, err)

    def _prefill_tiles(self, req: Request) -> int:
        """Packed-prefill cost model for one request: tri(ceil(S / block))
        — exactly the blocks its member contributes to the admit round's
        packed grid (core/packing: num_blocks is the sum of member
        triangles). S counts the feed (prompt + replayed tokens)."""
        return M.tri(-(-len(req.feed) // self.prefill_block))

    def _pick_requests(self, take: int) -> List[Request]:
        """Pop ``take`` queued requests for this admit round.

        "cost": the OLDEST queued request always rides (aging guarantee —
        every admit round retires the head of the queue, so no request is
        starved however its tile count sits between the ends), then the
        remaining slots alternate the lightest / heaviest pending so each
        packed round's total tiles lands near the queue mean — successive
        rounds equalize instead of inheriting arrival-order lumps (one
        round all-long, the next all-short). Ties keep arrival order.
        "fifo": strict arrival order.
        """
        if self.admit_order != "cost":
            return [self.queue.pop(0) for _ in range(take)]
        tiles = [self._prefill_tiles(r) for r in self.queue]
        heavy = iter(sorted(range(len(tiles)), key=lambda i: (-tiles[i], i)))
        light = iter(sorted(range(len(tiles)), key=lambda i: (tiles[i], i)))
        picked, used = [0], {0}  # aging: head of queue always admitted
        for t in range(take - 1):
            ends = light if t % 2 == 0 else heavy
            i = next(j for j in ends if j not in used)
            picked.append(i)
            used.add(i)
        reqs = [self.queue[i] for i in picked]
        for i in sorted(picked, reverse=True):
            self.queue.pop(i)
        return reqs

    def _release_quarantine(self):
        """Return quarantined slots to service once their hold expires —
        and immediately when the engine would otherwise deadlock (queue
        waiting, nothing running, every slot quarantined)."""
        rnd = self._decode_round_idx
        for slot in [s for s, rel in list(self.quarantined.items())
                     if rnd >= rel]:
            del self.quarantined[slot]
        if self.queue and self.quarantined \
                and not any(r is not None for r in self.slot_req) \
                and len(self.quarantined) >= self.B:
            first = min(self.quarantined,
                        key=lambda s: (self.quarantined[s], s))
            del self.quarantined[first]

    def _admit(self):
        self._release_quarantine()
        free = [s for s in range(self.B) if self.slot_req[s] is None
                and s not in self.quarantined]
        take = min(len(free), len(self.queue))
        if not take:
            return
        reqs = self._pick_requests(take)
        pairs = list(zip(free, reqs))
        for req in reqs:
            req.status = "running"
        self._inc("admit_rounds")
        self._admit_order_log.append(
            [(r.uid, self._prefill_tiles(r)) for r in reqs])
        self._admit_round_tiles.append(
            sum(self._prefill_tiles(r) for r in reqs))
        rnd = self._admit_round_idx
        self._admit_round_idx += 1
        stages = self._admit_stages(rnd, reqs)

        def runner(stage, attempt):
            if stage == "sequential":
                return self._admit_sequential(pairs, rnd)
            impl = "scan" if stage == "packed_scan" else self.prefill_impl
            return self._admit_packed(pairs, rnd, impl)

        try:
            self._run_ladder("admit", rnd, stages, runner,
                             n_affected=len(pairs))
        except (EngineStepError, F.PoisonedOutput) as e:
            if self.escalate_step_errors:
                # fleet replica: requeue the round's uncommitted requests
                # at the head (committed slots ride the snapshot as
                # in-flight) and hand the failure to the fleet.
                requeue = [req for slot, req in pairs
                           if self.slot_req[slot] is not req]
                for req in requeue:
                    req.status = "queued"
                self.queue[0:0] = requeue
                raise
            # even the sequential rung raised for the whole round: fail
            # every request of the round explicitly and keep serving.
            for slot, req in pairs:
                if self.slot_req[slot] is req:
                    self.slot_req[slot] = None
                self._record_failure(req, "admit", rnd,
                                     getattr(e, "cause", e))

    # -- decode loop ---------------------------------------------------------
    def _decode_stage(self, stage: str, rnd: int, live, kv_lens):
        """One decode-round launch at a given ladder stage."""
        if self.fault_plan is not None:
            self._sleep(self.fault_plan.maybe_fail("decode", rnd))
        if stage == "packed":
            with TR.span("engine.decode_round", mode="packed",
                         live=len(live)) as sp:
                logits, cache, info = D.decode_step_packed(
                    self.params, self.cfg, self.cache, self.last_tok,
                    self.pos, kv_lens, live, block=self.decode_block,
                    impl=self.decode_impl)
                sp.attach(logits)
            if info.get("rebucketed"):
                self._degrade("capacity", rnd, "requested", "rebucketed",
                              reason=(f"pinned capacity below the round's "
                                      f"{info['tiles']} live tiles"))
        else:
            with TR.span("engine.decode_round", mode="lockstep",
                         live=len(live)) as sp:
                logits, cache = self._decode(self.params, self.cache,
                                             self.last_tok, self.pos)
                sp.attach(logits)
        return logits, cache

    def step(self):
        """One decode round across all live slots — packed (mixed-position,
        each slot over its own valid KV prefix) when the batch is
        position-skewed or has retired slots, lockstep otherwise. Runs
        under the decode degradation ladder; emitted logits pass the
        NaN/Inf guard before any state commits, and poisoned slots are
        quarantined + replayed instead of emitting garbage."""
        active = np.array([r is not None for r in self.slot_req])
        if not active.any():
            return
        live = [s for s in range(self.B) if active[s]]
        pos_np = np.asarray(self.pos)
        kv_lens = [int(min(pos_np[s] + 1, self.s_cache)) for s in live]
        # round geometry (recorded every round, whichever path runs): what
        # the packed grid covers vs what pad-to-max lockstep would.
        tiles = [-(-kl // self.decode_block) for kl in kv_lens]
        # COST CROSSOVER (not the old any-skew test): the packed round
        # does RATIO times more work per tile than lockstep's fused
        # einsum, so "auto" goes packed only when pad-to-max waste beats
        # that premium. Lockstep always launches the full batch
        # (B * max tiles), so mild skew — or a uniform batch, where the
        # old test already lost 2.3x by going packed — stays lockstep.
        ratio = PACKED_TILE_COST_RATIO
        if self.auto_cost_measure and all(self._mode_cost.values()):
            ratio = self._mode_cost["packed"] / self._mode_cost["lockstep"]
        use_packed = self.decode_mode == "packed" or (
            self.decode_mode == "auto"
            and ratio * sum(tiles) < self.B * max(tiles))
        self._inc("decode_rounds")
        self._inc("decode_tiles_packed", sum(tiles))
        self._inc("decode_tiles_padded", len(live) * max(tiles))
        rnd = self._decode_round_idx
        self._decode_round_idx += 1
        stages = ["packed", "lockstep"] if use_packed else ["lockstep"]
        t0 = float(self.clock())
        try:
            (logits, cache), stage = self._run_ladder(
                "decode", rnd, stages,
                lambda s, a: self._decode_stage(s, rnd, live, kv_lens),
                n_affected=len(live))
        except EngineStepError as e:
            if self.escalate_step_errors:
                # fleet replica: nothing committed this round — the live
                # slots ride the snapshot as in-flight and migrate.
                raise
            # unrecoverable round: attribute the failure to every live
            # request uid, free the slots, keep the engine serving.
            for slot in live:
                req = self.slot_req[slot]
                self.slot_req[slot] = None
                self._record_failure(req, "decode", rnd, e.cause)
            return
        self._inc("decode_packed_launches" if stage == "packed"
                  else "decode_lockstep_launches")
        dur = float(self.clock()) - t0
        if self._round_watch.observe(dur):
            self._inc_res("rounds_straggler_total")
        if self.auto_cost_measure:
            done = sum(tiles) if stage == "packed" else self.B * max(tiles)
            per_tile = dur / max(1, done)
            prev = self._mode_cost[stage]
            self._mode_cost[stage] = per_tile if prev is None \
                else 0.8 * prev + 0.2 * per_tile
        # NaN/Inf guard at the host boundary (+ injected poison lands in
        # the same place the guard inspects).
        bad: List[int] = []
        if self.guard_output or self.fault_plan is not None:
            logits_np = np.array(logits[:, 0], np.float32)  # host copy
            if self.fault_plan is not None:
                for s in self.fault_plan.poison_slots(rnd, live):
                    logits_np[s] = np.nan
            if self.guard_output:
                bad = D.poisoned_slots(logits_np, live)
        if bad and self.escalate_step_errors:
            # fleet replica: a poisoned round escalates BEFORE any state
            # commits (no cache/pos/token writes happened yet) — the
            # fleet quarantines the whole replica instead of this engine
            # quarantining one slot, and every live request's feed still
            # excludes the poisoned round, so migration re-prefills the
            # exact pre-fault state.
            raise F.PoisonedOutput(
                f"decode round {rnd}: non-finite logits in slots {bad}")
        replays: List[Request] = []
        for slot in bad:
            req = self.slot_req[slot]
            self.slot_req[slot] = None
            self.quarantined[slot] = rnd + 1 + self.quarantine_rounds
            req.replays += 1
            req.status = "queued"
            replays.append(req)
            self._inc_res("slots_quarantined_total")
            if SK.trace_enabled():
                SK.emit_event({"type": "quarantine", "slot": slot,
                               "uid": req.uid, "round": rnd,
                               "reason": "nonfinite_logits"})
        if replays:
            # front of the queue: the aging guarantee readmits replayed
            # requests next round, prefilled on prompt + emitted tokens
            # (Request.feed) into a healthy slot.
            self.queue[0:0] = replays
        self.key, k = jax.random.split(self.key)
        nxt = D.sample_logits(k, logits[:, 0], temperature=self.temperature,
                              vocab_size=self.cfg.vocab_size)
        nxt_np = np.asarray(nxt)
        self.cache = cache
        adv = active.copy()
        for slot in bad:
            adv[slot] = False  # quarantined: state reset at readmission
        self.pos = self.pos + jnp.asarray(adv, jnp.int32)
        self.last_tok = nxt[:, None]
        for slot in range(self.B):
            req = self.slot_req[slot]
            if req is None:
                continue
            req.out.append(int(nxt_np[slot]))
            self.remaining[slot] -= 1
            if self.remaining[slot] <= 0 or \
                    int(self.pos[slot]) >= self.max_len - 1:
                self._finish(req, "done")
                self.slot_req[slot] = None  # slot freed -> refilled next admit

    # -- fused continuous batching -------------------------------------------
    def step_fused(self):
        """One FUSED engine round: admit every queued request a free slot
        can take AND advance every live decode slot, in ONE mixed packed
        launch per attention layer (decode.fused_step over the "mixed"
        schedule kind). Rounds with nothing to admit delegate to step()
        — the split decode round is already a single launch.

        Any failure inside the fused attempt (injected fault, poisoned
        states, traced-envelope overflow, real launch error) takes the
        registered step: fused -> split rung: the admits are requeued at
        the head and the round re-runs through the split machinery, whose
        own admit/decode ladders then absorb the fault. Greedy decode is
        token-identical either way."""
        self._release_quarantine()
        free = [s for s in range(self.B) if self.slot_req[s] is None
                and s not in self.quarantined]
        take = min(len(free), len(self.queue))
        if not take:
            return self.step()
        reqs = self._pick_requests(take)
        pairs = list(zip(free, reqs))
        for req in reqs:
            req.status = "running"
        a_rnd = self._admit_round_idx
        d_rnd = self._decode_round_idx
        live = [s for s in range(self.B) if self.slot_req[s] is not None
                and s not in [sl for sl, _ in pairs]]
        pos_np = np.asarray(self.pos)
        kv_lens = [int(min(pos_np[s] + 1, self.s_cache)) for s in live]
        self._inc("fused_rounds")
        try:
            if self.fault_plan is not None:
                self._sleep(self.fault_plan.maybe_fail("admit", a_rnd))
                if live:
                    self._sleep(self.fault_plan.maybe_fail("decode", d_rnd))
            prompts = [req.feed for _, req in pairs]
            if not D.traced_prefill_ok([len(p) for p in prompts],
                                       self.decode_block,
                                       self._traced_max_lam):
                raise RuntimeError(
                    "admit member exceeds the certified traced-isqrt "
                    f"envelope (traced_max_lam={self._traced_max_lam})")
            with TR.span("engine.fused_step", requests=len(pairs),
                         live=len(live)) as sp:
                (logits_admit, logits_dec, cache, states, _, starts, lens,
                 info) = D.fused_step(
                    self.params, self.cfg, self.cache, prompts,
                    self.last_tok, self.pos, kv_lens, live,
                    block=self.decode_block, impl=self.decode_impl,
                    bucket=self.prefill_bucket)
                sp.attach(logits_dec)
            if self.fault_plan is not None and \
                    self.fault_plan.poisons_admit(a_rnd):
                states = jax.tree.map(
                    lambda x: jnp.full_like(x, jnp.nan)
                    if jnp.issubdtype(x.dtype, jnp.floating) else x, states)
            if self.guard_output and not D.states_finite(states):
                raise F.PoisonedOutput(
                    f"fused round {d_rnd}: non-finite packed states")
        except Exception as e:  # noqa: BLE001 — hardening boundary
            # fused -> split: requeue the admits at the head (aging keeps
            # them first) and re-run the round through the split ladders,
            # which own retries / further degradation for this fault.
            self._inc("fused_fallbacks")
            self._degrade("step", d_rnd, "fused", "split",
                          reason=f"{type(e).__name__}: {e}")
            for req in reqs:
                req.status = "queued"
            self.queue[0:0] = reqs
            self._admit()
            self.step()
            return
        # -- commit (the exact split order: admit splice, then decode) ---
        self._admit_round_idx += 1
        self._decode_round_idx += 1
        self._inc("admit_rounds")
        self._admit_order_log.append(
            [(r.uid, self._prefill_tiles(r)) for r in reqs])
        self._admit_round_tiles.append(
            sum(self._prefill_tiles(r) for r in reqs))
        self._inc("fused_launches")
        self._inc("fused_tiles", info["tiles"])
        self.fused_templates.add(
            (tuple(info["template"]), int(info["capacity"])))
        self._inc("prefill_requests", len(pairs))
        self._inc("prefill_tokens", sum(lens))
        if live:
            self._inc("decode_rounds")
        self.cache = cache
        for (slot, req), start, length in zip(pairs, starts, lens):
            self._splice_slot(slot, states, start, length)
            self.slot_req[slot] = req
            self.remaining[slot] = req.max_new - len(req.out)
        # decode-half poison guard, identical to step()
        bad: List[int] = []
        logits_np = np.array(logits_dec, np.float32)
        if self.fault_plan is not None:
            for s in self.fault_plan.poison_slots(d_rnd, live):
                logits_np[s] = np.nan
        if self.guard_output:
            bad = D.poisoned_slots(logits_np, live)
        if bad and self.escalate_step_errors:
            # fleet replica: escalate instead of slot-quarantining. The
            # fused cache/splice commits above are discarded with the
            # replica — no token was appended for any slot this round, so
            # every request's feed is still pre-fault and migration
            # re-prefills the exact state.
            raise F.PoisonedOutput(
                f"fused round {d_rnd}: non-finite decode logits in "
                f"slots {bad}")
        replays: List[Request] = []
        for slot in bad:
            req = self.slot_req[slot]
            self.slot_req[slot] = None
            self.quarantined[slot] = d_rnd + 1 + self.quarantine_rounds
            req.replays += 1
            req.status = "queued"
            replays.append(req)
            self._inc_res("slots_quarantined_total")
            if SK.trace_enabled():
                SK.emit_event({"type": "quarantine", "slot": slot,
                               "uid": req.uid, "round": d_rnd,
                               "reason": "nonfinite_logits"})
        if replays:
            self.queue[0:0] = replays
        # ONE key split per fused round (the admits' first tokens and the
        # decode tokens share it; at temperature=0 both are pure argmax).
        self.key, k = jax.random.split(self.key)
        nxt_np = np.asarray(D.sample_logits(
            k, logits_dec, temperature=self.temperature,
            vocab_size=self.cfg.vocab_size))
        adm_np = np.asarray(D.sample_logits(
            k, logits_admit, temperature=self.temperature,
            vocab_size=self.cfg.vocab_size))
        new_pos = np.asarray(self.pos).copy()
        new_last = np.asarray(self.last_tok).copy()
        for slot in live:
            if slot in bad:
                continue
            req = self.slot_req[slot]
            req.out.append(int(nxt_np[slot]))
            new_pos[slot] += 1
            new_last[slot, 0] = int(nxt_np[slot])
            self.remaining[slot] -= 1
            if self.remaining[slot] <= 0 or \
                    int(new_pos[slot]) >= self.max_len - 1:
                self._finish(req, "done")
                self.slot_req[slot] = None
        for (slot, req), length, tok in zip(pairs, lens, adm_np):
            req.out.append(int(tok))
            new_pos[slot] = length  # the sampled token's position
            new_last[slot, 0] = int(tok)
            self.remaining[slot] -= 1
            if self.remaining[slot] <= 0 or \
                    int(new_pos[slot]) >= self.max_len - 1:
                self._finish(req, "done")
                self.slot_req[slot] = None
        self.pos = jnp.asarray(new_pos)
        self.last_tok = jnp.asarray(new_last)

    def idle(self) -> bool:
        """True iff the engine holds no work (empty queue, no live slot)."""
        return not self.queue and all(r is None for r in self.slot_req)

    def round(self):
        """ONE full scheduling round — the unit a fleet driver advances a
        replica by: deadline sweep, then either a fused step or a split
        admit + decode pair. run() is this in a drain loop; a Fleet calls
        it directly so it can heartbeat/watch each replica per round."""
        self._expire_deadlines()
        if self.step_mode == "fused":
            self._release_quarantine()
            if not self.idle():
                self.step_fused()
            return
        self._admit()
        self.step()

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Drive admission + decode until drained (or max_steps rounds).

        step_mode="fused" folds each round's admission INTO its decode
        launch (step_fused); "split" keeps the separate packed-admit and
        decode rounds. Returns {uid: tokens} for every request that
        reached a terminal state — including the partial outputs of shed /
        deadline-missed / failed requests (see report() for statuses).
        Per-step failures never abort unaffected slots."""
        for _ in range(max_steps):
            self._expire_deadlines()
            if self.step_mode == "fused":
                self._release_quarantine()
                if all(r is None for r in self.slot_req) and not self.queue:
                    break
                self.step_fused()
                continue
            self._admit()
            if all(r is None for r in self.slot_req) and not self.queue:
                break
            self.step()
        return {r.uid: r.out for r in self.finished}

    # -- crash safety --------------------------------------------------------
    def snapshot(self):
        """Serialize slot table + KV cache + RNG/clock state into an
        EngineSnapshot (resilience/snapshot.py)."""
        from repro.resilience import snapshot as SNAP

        return SNAP.snapshot(self)

    @classmethod
    def restore(cls, snap, **overrides):
        """Rebuild an engine from an EngineSnapshot so run() resumes
        token-identically after a crash (params/cfg ride in the snapshot;
        pass fault_plan=/clock=/retry= overrides for the new process)."""
        from repro.resilience import snapshot as SNAP

        return SNAP.restore(snap, **overrides)
