"""Sampling and generation loops.

`generate` drives models/model.decode_step over a fixed number of tokens
with per-sequence positions (a (B,) pos vector — sequences at different
offsets decode in the same batch, the substrate for continuous batching in
engine.py). The loop is a lax.scan so the whole generation compiles to one
program (no per-token dispatch overhead).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model as MD


def sample_logits(key, logits, *, temperature: float = 1.0,
                  top_k: Optional[int] = None, vocab_size: int = 0):
    """logits: (B, Vp) f32 -> (B,) int32 tokens."""
    if vocab_size and logits.shape[-1] > vocab_size:
        neg = jnp.finfo(jnp.float32).min
        pad = jnp.arange(logits.shape[-1]) >= vocab_size
        logits = jnp.where(pad, neg, logits)
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k is not None:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, jnp.finfo(jnp.float32).min, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def generate(params, cfg, cache, first_tokens, start_pos, n_tokens: int, *,
             key=None, temperature: float = 0.0, top_k: Optional[int] = None,
             active=None):
    """Decode n_tokens greedily/sampled.

    first_tokens: (B, 1) int32 — the first input token of each sequence.
    start_pos: (B,) int32 — absolute position of that token.
    active: optional (B,) bool — inactive slots keep emitting pad(0) and do
    not advance their cache (engine slot-masking).
    Returns (tokens (B, n_tokens), final cache, final pos).
    """
    b = first_tokens.shape[0]
    key = jax.random.key(0) if key is None else key
    start_pos = jnp.broadcast_to(jnp.asarray(start_pos, jnp.int32), (b,))
    act = jnp.ones((b,), bool) if active is None else active

    def step(carry, k):
        cache, tok, pos = carry
        logits, new_cache = MD.decode_step(params, cfg, cache, tok, pos)
        nxt = sample_logits(k, logits[:, 0], temperature=temperature,
                            top_k=top_k, vocab_size=cfg.vocab_size)
        nxt = jnp.where(act, nxt, 0)
        # inactive slots: keep old cache values
        new_cache = jax.tree.map(
            lambda n, o: jnp.where(
                act.reshape((1, b) + (1,) * (n.ndim - 2)), n, o),
            new_cache, cache)
        return (new_cache, nxt[:, None], pos + act.astype(jnp.int32)), nxt

    keys = jax.random.split(key, n_tokens)
    (cache, _, pos), toks = jax.lax.scan(
        step, (cache, first_tokens, start_pos), keys)
    return toks.T, cache, pos  # (B, n_tokens)


@functools.partial(jax.jit, static_argnames=("cfg", "n_tokens",
                                             "temperature", "top_k"))
def jit_generate(params, cfg, cache, first_tokens, start_pos, n_tokens,
                 key, temperature=0.0, top_k=None):
    return generate(params, cfg, cache, first_tokens, start_pos, n_tokens,
                    key=key, temperature=temperature, top_k=top_k)
