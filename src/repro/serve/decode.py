"""Sampling, generation loops, and the batched ragged prefill.

`generate` drives models/model.decode_step over a fixed number of tokens
with per-sequence positions (a (B,) pos vector — sequences at different
offsets decode in the same batch, the substrate for continuous batching in
engine.py). The loop is a lax.scan so the whole generation compiles to one
program (no per-token dispatch overhead).

`packed_prefill` prefills a ragged batch of prompts in ONE packed forward:
prompts are padded to a tile multiple, concatenated along S, and attention
runs block-diagonally over the PackedSchedule grid (core/packing.py) —
sum_r tri(n_r) tiles instead of R separate launches or R * tri(n_max)
padded ones. The engine splices the returned per-layer KV states into its
slot caches (Engine._admit_batch).

`decode_step_packed` is the decode-time analogue: a position-skewed batch
advances one token per live slot in one packed launch per attention layer,
each slot attending only its own valid KV prefix (core/packing's
decode_round of RowSchedule members) — sum_r ceil(kv_len_r / blk) tiles
instead of the lockstep einsum's pad-to-max B * S_cache.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mapping as M
from repro.kernels.tri_attn import ops as attn_ops
from repro.models import model as MD


def sample_logits(key, logits, *, temperature: float = 1.0,
                  top_k: Optional[int] = None, vocab_size: int = 0):
    """logits: (B, Vp) f32 -> (B,) int32 tokens."""
    if vocab_size and logits.shape[-1] > vocab_size:
        neg = jnp.finfo(jnp.float32).min
        pad = jnp.arange(logits.shape[-1]) >= vocab_size
        logits = jnp.where(pad, neg, logits)
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k is not None:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, jnp.finfo(jnp.float32).min, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def generate(params, cfg, cache, first_tokens, start_pos, n_tokens: int, *,
             key=None, temperature: float = 0.0, top_k: Optional[int] = None,
             active=None):
    """Decode n_tokens greedily/sampled.

    first_tokens: (B, 1) int32 — the first input token of each sequence.
    start_pos: (B,) int32 — absolute position of that token.
    active: optional (B,) bool — inactive slots keep emitting pad(0) and do
    not advance their cache (engine slot-masking).
    Returns (tokens (B, n_tokens), final cache, final pos).
    """
    b = first_tokens.shape[0]
    key = jax.random.key(0) if key is None else key
    start_pos = jnp.broadcast_to(jnp.asarray(start_pos, jnp.int32), (b,))
    act = jnp.ones((b,), bool) if active is None else active

    def step(carry, k):
        cache, tok, pos = carry
        logits, new_cache = MD.decode_step(params, cfg, cache, tok, pos)
        nxt = sample_logits(k, logits[:, 0], temperature=temperature,
                            top_k=top_k, vocab_size=cfg.vocab_size)
        nxt = jnp.where(act, nxt, 0)
        # inactive slots: keep old cache values
        new_cache = jax.tree.map(
            lambda n, o: jnp.where(
                act.reshape((1, b) + (1,) * (n.ndim - 2)), n, o),
            new_cache, cache)
        return (new_cache, nxt[:, None], pos + act.astype(jnp.int32)), nxt

    keys = jax.random.split(key, n_tokens)
    (cache, _, pos), toks = jax.lax.scan(
        step, (cache, first_tokens, start_pos), keys)
    return toks.T, cache, pos  # (B, n_tokens)


@functools.partial(jax.jit, static_argnames=("cfg", "n_tokens",
                                             "temperature", "top_k"))
def jit_generate(params, cfg, cache, first_tokens, start_pos, n_tokens,
                 key, temperature=0.0, top_k=None):
    return generate(params, cfg, cache, first_tokens, start_pos, n_tokens,
                    key=key, temperature=temperature, top_k=top_k)


# ---------------------------------------------------------------------------
# Output guards + traced-envelope check (request lifecycle hardening)
# ---------------------------------------------------------------------------


def poisoned_slots(logits_np: np.ndarray, live: Sequence[int]) -> List[int]:
    """Cheap host-side NaN/Inf guard on a decode round's emitted logits:
    the live batch rows whose logit vector contains a non-finite value
    (a poisoned output tile). logits_np: (B, V) after squeezing the
    length-1 axis. O(B*V) numpy — the detection cost the engine pays per
    round so corruption becomes a quarantine instead of a silent garbage
    token stream."""
    return [s for s in live
            if not bool(np.isfinite(logits_np[s]).all())]


def states_finite(states) -> bool:
    """NaN/Inf guard over packed prefill state leaves (float leaves only;
    token/table int leaves can't be poisoned by arithmetic)."""
    for leaf in jax.tree.leaves(states):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        if not bool(jnp.isfinite(leaf).all()):
            return False
    return True


def traced_prefill_ok(lens: Sequence[int], block: int,
                      max_lam: Optional[int] = None) -> bool:
    """True iff every member of a packed admit round stays inside the
    certified traced-isqrt envelope: the member's largest lambda is
    tri(ceil(S_r / block)) - 1, which must be <= LTM_TRACED_MAX_LAM for
    the traced block mapping to be exact. Beyond it the engine must take
    the host-map (sequential) path — the traced -> host rung of the
    degradation ladder."""
    cap = M.LTM_TRACED_MAX_LAM if max_lam is None else max_lam
    return all(M.tri(-(-int(s) // block)) - 1 <= cap for s in lens)


# ---------------------------------------------------------------------------
# Packed mixed-position decode (one launch per decode round)
# ---------------------------------------------------------------------------


def round_capacity(needed: int, floor: int = 8) -> int:
    """Bucket a round's live tile count to a static grid size (next power
    of two, floored) so position skew does not recompile every round: at
    most log2(B * S_cache / blk) distinct programs per engine."""
    return max(floor, 1 << max(0, int(needed) - 1).bit_length())


@functools.partial(jax.jit, static_argnames=("cfg", "spec"))
def _packed_decode_forward(params, cfg, cache, tokens, pos, tbl, spec):
    return MD.decode_step(params, cfg, cache, tokens, pos, decode_tbl=tbl,
                          decode_spec=spec)


def make_decode_table(kv_lens, slots, *, blk: int, n_members: int,
                      n_slots: int, s_cache: int = 0, window=None):
    """Serve-side decode-table builder — the band-limited variant.

    Delegates to ops.make_decode_table; ``window`` (tokens, scalar or
    per-slot) caps each slot's attended region at its LAST w tokens, so
    per-slot kv_tiles stays near ceil(w / blk) however deep the position —
    the decode-round analogue of the band member (a RowSchedule that keeps
    only its rightmost tiles). Only valid when cache row index == absolute
    position (a non-rolling cache, e.g. a max_len prefix cache serving a
    windowed policy); rolling SWA buffers are already window-sized and
    must keep window=None.
    """
    return attn_ops.make_decode_table(
        kv_lens, slots, blk=blk, n_members=n_members, n_slots=n_slots,
        s_cache=s_cache, window=window)


def decode_step_packed(params, cfg, cache, tokens, pos, kv_lens, slots, *,
                       block: int = 16, impl: str = "scan",
                       n_members: int = 0, capacity: int = 0, window=None):
    """One PACKED decode round: every live slot advances one token in ONE
    launch per attention layer, each attending only its own valid KV
    prefix — sum_r ceil(kv_len_r / blk) tiles instead of the lockstep
    pad-to-max B * S_cache.

    tokens: (B, 1) int32; pos: (B,) int32 (stale entries for retired slots
    are fine — they are not in ``slots``). kv_lens/slots: host lists — live
    slots' valid KV token counts (min(pos + 1, S_cache)) and batch rows.
    n_members/capacity pin the table width / grid bucket (0 = derive:
    B + 1 members, power-of-two capacity). window band-limits each slot to
    its last w tokens (see make_decode_table — non-rolling caches only).
    Returns (logits, new_cache, info) with info the round's tile
    accounting: {"tiles": live tiles, "tiles_padded": n_live * max tiles,
     "capacity": static grid size}.

    Only attention layers change behavior; recurrent mixers decode their
    own slot's state independently either way. Retired slots still run the
    (idempotent) k/v cache rewrite and get zero attention output — the
    engine discards their sampled tokens, so token streams are unaffected.
    """
    b = tokens.shape[0]
    n_members = n_members or b + 1
    # Band-limiting assumes cache row index == absolute position; a
    # rolling SWA cache (layers._decode_qkv writes slot pos % S_cache)
    # breaks that once any slot wraps, silently attending the wrong
    # token subset — reject here, where cfg is known.
    assert cfg.sliding_window is None or window is None, (
        "window= band-limiting is invalid over a rolling sliding-window "
        "cache (rows alias positions mod S_cache); the rolling buffer is "
        "already window-sized — keep window=None")
    # every attention layer shares one cache geometry (cfg-global S_cache)
    s_cache = _attn_cache_len(cfg, cache)
    blk = min(block, s_cache)
    while s_cache % blk:
        blk //= 2
    tbl, needed = attn_ops.make_decode_table(
        kv_lens, slots, blk=blk, n_members=n_members, n_slots=b,
        s_cache=s_cache, window=window)
    capacity = capacity or round_capacity(needed)
    rebucketed = False
    if capacity < needed:
        # A pinned capacity the round outgrew is a RECOVERABLE sizing
        # miss, not a crash: rebucket to the canonical power-of-two grid
        # (one extra compile) and report it so the engine can emit the
        # registered capacity: requested -> rebucketed degrade event.
        capacity = round_capacity(needed)
        rebucketed = True
    spec = attn_ops.DecodeRoundSpec(n_members=n_members, capacity=capacity,
                                    blk=blk, impl=impl)
    logits, new_cache = _packed_decode_forward(
        params, cfg, cache, tokens, jnp.asarray(pos, jnp.int32),
        jnp.asarray(tbl), spec)
    tiles_max = int(np.max(tbl[2, :len(list(kv_lens))])) if kv_lens else 0
    info = {"tiles": needed, "tiles_padded": len(list(kv_lens)) * tiles_max,
            "capacity": capacity, "blk": blk, "rebucketed": rebucketed}
    return logits, new_cache, info


def _attn_cache_len(cfg, cache):
    """S_cache shared by every attention layer's KV leaves — identified by
    the (n_sl, B, S, Hkv, hd) shape signature so recurrent-state leaves of
    the same rank can never be mistaken for KV; cfg.sliding_window caps
    it. This is the single source of truth for the decode-round geometry
    (the engine reads it too, so kv_len clamps cannot drift from the
    actual cache sizing)."""
    for leaf in jax.tree.leaves(cache):
        if leaf.ndim == 5 and leaf.shape[3:] == (cfg.n_kv_heads,
                                                 cfg.head_dim):
            return leaf.shape[2]
    raise ValueError("no attention KV leaves in cache (recurrent-only "
                     "arch cannot take the packed decode path)")


# ---------------------------------------------------------------------------
# Batched ragged prefill (one packed launch for R prompts)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "psched", "attn_impl"))
def _packed_forward(params, cfg, tokens, positions, psched, attn_impl):
    """Jitted packed forward: (1, S_total) tokens + per-request-restarting
    positions -> (hidden, per-layer states). Compiled once per distinct
    packing (psched is static); MoE runs drop-free (serving semantics)."""
    hidden, _, states = MD.forward(
        params, cfg, {"tokens": tokens}, attn_impl=attn_impl, remat=False,
        collect_state=True, positions=positions, packed=psched,
        full_capacity=True)
    return hidden, states


def packed_prefill(params, cfg, prompts, *, block: int = 16,
                   attn_impl: str = "scan", bucket: int = 0):
    """Prefill a ragged prompt batch in ONE packed launch.

    prompts: list of (S_r,) int token arrays (arbitrary mixed lengths).
    Each is zero-padded to a multiple of ``block`` (padding sits at the
    request's causal tail: real tokens never attend to it and its rows are
    never spliced out). Returns (psched, starts, lens, hidden, states):
    request r's tokens occupy packed rows [starts[r], starts[r] + lens[r])
    of hidden and of every (n_sl, 1, S_total, ...) KV state leaf.

    The forward is jitted with the packing STATIC, so every distinct tuple
    of padded lengths compiles (and caches) a new program. ``bucket`` > 0
    rounds each padded length up to a multiple of it, trading a bounded
    amount of extra (inert) tail padding for far fewer distinct shapes —
    set it under compile-bound serving traffic (e.g. bucket = 4 * block).

    Only valid for attention token mixers: recurrent state (mamba/rwkv)
    carries across the packed concatenation and would leak between
    requests — Engine gates on cfg.layer_kinds before calling this.
    """
    assert all(k == "attn" for k in cfg.layer_kinds), (
        "packed_prefill requires attention-only token mixers; recurrent "
        "state would leak across the packed request boundary")
    lens = [int(len(p)) for p in prompts]
    quantum = max(block, -(-bucket // block) * block if bucket else block)
    pads = [-(-s // quantum) * quantum for s in lens]
    starts = list(np.cumsum([0] + pads[:-1]))
    s_total = sum(pads)
    tokens = np.zeros((1, s_total), np.int32)
    positions = np.zeros((s_total,), np.int32)
    for st, pad, p in zip(starts, pads, prompts):
        tokens[0, st:st + len(p)] = np.asarray(p, np.int32)
        positions[st:st + pad] = np.arange(pad)
    psched = attn_ops.make_packed_sched(pads, block=block,
                                        window=cfg.sliding_window)
    hidden, states = _packed_forward(params, cfg, jnp.asarray(tokens),
                                     jnp.asarray(positions), psched,
                                     attn_impl)
    return psched, starts, lens, hidden, states


# ---------------------------------------------------------------------------
# Fused continuous-batching step (admits + live decode slots, one launch)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "psched", "spec"))
def _fused_forward(params, cfg, cache, pack_tokens, pack_positions,
                   dec_tokens, pos, tbl, admit_rows, psched, spec):
    """Jitted fused step: compiled once per (packing template, decode
    capacity bucket) — the fused table rides as a traced array so
    positions advancing every round never recompile."""
    return MD.fused_step(params, cfg, cache, pack_tokens, pack_positions,
                         dec_tokens, pos, psched, tbl, spec, admit_rows)


def fused_step(params, cfg, cache, prompts, tokens, pos, kv_lens, slots, *,
               block: int = 16, impl: str = "scan", bucket: int = 0,
               capacity: int = 0):
    """ONE fused engine round: prefill the newly admitted ``prompts``
    (packed block-diagonal members) AND advance every live decode slot
    (row members over its own valid KV prefix) in a single mixed launch
    per attention layer.

    prompts: list of (S_r,) int token feeds to admit (>= 1 — decode-only
    rounds take decode_step_packed instead). tokens: (B, 1) int32 last
    tokens; pos: (B,) int32 (stale entries for slots being admitted /
    retired are fine); kv_lens/slots: host lists for the LIVE decode
    slots, exactly as decode_step_packed takes them. ``bucket`` rounds
    each padded prompt length up to a multiple of it — the length-bucketed
    packing templates that bound the number of distinct compiled programs.
    ``capacity`` optionally pins the total grid; a pin the round outgrew
    is rebucketed (info["rebucketed"]) rather than crashing.

    Returns (logits_admit (A, Vp) f32 — one row per admitted prompt, from
    its last real token; logits_dec (B, Vp) f32 — live slots only, others
    garbage; new_cache — decode KV writes applied, admit KV NOT yet
    spliced; states — per-layer pack k/v for kv_cache.splice_slot;
    psched, starts, lens, info).
    """
    assert all(k == "attn" for k in cfg.layer_kinds), (
        "fused_step requires attention-only token mixers; recurrent state "
        "has no packed-member notion")
    assert len(prompts) >= 1, "fused_step needs at least one admit"
    b = tokens.shape[0]
    s_cache = _attn_cache_len(cfg, cache)
    blk = min(block, s_cache)
    while s_cache % blk:
        blk //= 2
    lens = [int(len(p)) for p in prompts]
    quantum = max(blk, -(-bucket // blk) * blk if bucket else blk)
    pads = [-(-s // quantum) * quantum for s in lens]
    starts = list(np.cumsum([0] + pads[:-1]))
    s_total = sum(pads)
    pack_tokens = np.zeros((1, s_total), np.int32)
    pack_positions = np.zeros((s_total,), np.int32)
    for st, pad, p in zip(starts, pads, prompts):
        pack_tokens[0, st:st + len(p)] = np.asarray(p, np.int32)
        pack_positions[st:st + pad] = np.arange(pad)
    psched = attn_ops.make_packed_sched(pads, block=blk,
                                        window=cfg.sliding_window)
    admit_rows = np.asarray([st + ln - 1 for st, ln in zip(starts, lens)],
                            np.int32)
    n_members = len(pads) + b + 1
    tbl, needed = attn_ops.make_fused_table(
        psched, kv_lens, slots, blk=blk, n_members=n_members, n_slots=b,
        s_cache=s_cache)
    needed_dec = needed - psched.steps
    dec_capacity = round_capacity(needed_dec) if len(list(kv_lens)) else 0
    rebucketed = False
    if capacity:
        if capacity < psched.steps + needed_dec:
            rebucketed = True  # same graceful rebucket as decode_step_packed
        else:
            dec_capacity = capacity - psched.steps
    spec = attn_ops.FusedStepSpec(
        n_members=n_members, capacity=psched.steps + dec_capacity,
        blk=blk, impl=impl)
    logits_admit, logits_dec, new_cache, states = _fused_forward(
        params, cfg, cache, jnp.asarray(pack_tokens),
        jnp.asarray(pack_positions), tokens,
        jnp.asarray(pos, jnp.int32), jnp.asarray(tbl),
        jnp.asarray(admit_rows), psched, spec)
    tiles_max = int(np.max(tbl[2, len(pads):len(pads) + len(list(kv_lens))])
                    ) if len(list(kv_lens)) else 0
    info = {"tiles": needed, "capacity": spec.capacity, "blk": blk,
            "s_pack": s_total, "rebucketed": rebucketed,
            "tiles_padded": psched.steps + len(list(kv_lens)) * tiles_max,
            # the length-bucketed packing template this round compiled
            # under: the padded prompt lengths that, with the capacity,
            # pin the fused program's static shapes. The engine records
            # the distinct set (compile-footprint accounting, persisted
            # across snapshot/restore).
            "template": tuple(int(p) for p in pads)}
    return (logits_admit[0], logits_dec[:, 0], new_cache, states, psched,
            starts, lens, info)
