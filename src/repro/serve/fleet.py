"""Fleet front end: N engine replicas, tile-cost routing, deterministic
failover.

The paper's block-space accounting gives serving an EXACT, hardware-
independent cost model — a request of S prompt tokens costs
tri(ceil(S / block)) tiles in its admit round's packed grid (core/
packing). PR 5 uses it to order admission inside one engine and PR 8 to
shed overload; this module uses the same number to run a FLEET: requests
route to the replica with the fewest outstanding tiles (queued +
in-flight), so the balance property is the scheduling-theory one —
greedy least-loaded keeps per-replica tile totals within one maximal
request of each other — and it is starvation-free for the same reason
single-engine admission is (each engine's queue head always rides its
next admit round; migration splices at the head).

Failover is DETERMINISTIC, the fleet-scale version of PR 8's quarantine
+ re-prefill replay:

    active ──fault──> quarantined ──probation──> restored     (engine)
    primary ──migrate──────────────────────────> failover     (route)

Each replica runs with ``escalate_step_errors=True``: a round failure
its own ladders cannot absorb (retries exhausted past the last rung, or
a poisoned output) RAISES instead of failing requests in place. The
fleet then (1) captures an on-fault ``EngineSnapshot`` (falling back to
the last periodic one), (2) moves the snapshot's finished requests into
the fleet's terminal set and MIGRATES its queued + in-flight requests —
spliced at a healthy replica's queue head, in slot order then queue
order — and (3) parks the victim as a cleaned snapshot
(``strip_for_restart``: empty slots/queue, round indices and RNG kept)
until its probation window elapses. Because ``Request.feed`` is
prompt + tokens-already-emitted and greedy decode is deterministic, the
target replica re-prefills the EXACT pre-fault state: the fleet's final
per-request token streams are identical to a fault-free single-engine
run (property-tested under the full fault matrix, split and fused).

A circuit breaker stretches the probation window: K consecutive faulted
rounds (no successful working round between them) quarantines the
replica for ``probation_rounds`` fleet rounds instead of one. Liveness
is watched per round with ``HeartbeatMonitor`` (a straggler delay longer
than ``heartbeat_timeout_s`` kills the replica even though its round
committed — migration is still token-identical because the committed
tokens ARE the deterministic ones) and per-replica ``RoundWatch``
medians flag slow rounds. Every transition is a counted metric
(schema.FLEET_COUNTERS / FLEET_GAUGES) and a schema-validated trace
event — ``failover``, ``engine_quarantine``, ``rebalance`` — emitted
through the single ``_transition`` guard, which runtime-checks the move
against faults.LADDERS exactly like the engine's ``_degrade`` does (the
resilience lint pass proves the coverage statically).

Everything runs off one shared clock (default: a fresh ``VirtualClock``,
so fleet runs — fault injection, deadlines, heartbeats, probation — are
bitwise-replayable offline on CPU; pass ``clock=time.monotonic`` for
wall-clock serving).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import mapping as M
from repro.obs import metrics as MET
from repro.obs import schema as SCH
from repro.obs import sinks as SK
from repro.resilience import faults as F
from repro.resilience import health as H
from repro.resilience import snapshot as SNAP
from repro.serve.engine import Engine, Request

# Registered fleet transitions -> the trace event each one emits. The
# single source the ``_transition`` guard consults; the resilience lint
# pass proves (a) every adjacent rung of the engine/route ladders is
# covered here, (b) every mapped event type is schema-registered, and
# (c) fleet.py calls _transition with exactly these literals.
TRANSITION_EVENTS: Dict[Tuple[str, str, str], str] = {
    ("engine", "active", "quarantined"): "engine_quarantine",
    ("engine", "quarantined", "restored"): "rebalance",
    ("route", "primary", "failover"): "failover",
}


class Fleet:
    """N engine replicas behind tile-cost routing with deterministic
    failover. submit() then run() until drained, like a single Engine."""

    def __init__(self, params, cfg, *, engines: int = 2,
                 engine_kw: Optional[dict] = None, clock=None,
                 fault_plan: Optional[F.FaultPlan] = None,
                 heartbeat_timeout_s: float = 60.0,
                 snapshot_every: int = 4, breaker_k: int = 3,
                 probation_rounds: int = 8, max_fleet_tiles: int = 0):
        assert engines >= 1
        assert breaker_k >= 1 and probation_rounds >= 1
        self.params, self.cfg = params, cfg
        self.n = engines
        self.clock = clock if clock is not None else F.VirtualClock()
        self.engine_kw = dict(engine_kw or {})
        # per-replica fault sub-plans: each replica gets the faults scoped
        # to it (engine == -1 applies everywhere) with its OWN strike
        # bookkeeping, held here so strikes persist across restores — a
        # consumed fault never re-fires on the restored replica.
        self._plans: Dict[int, Optional[F.FaultPlan]] = {
            e: (fault_plan.for_engine(e) if fault_plan is not None
                else None)
            for e in range(engines)}
        self.engines: List[Optional[Engine]] = [
            Engine(params, cfg, fault_plan=self._plans[e],
                   clock=self.clock, escalate_step_errors=True,
                   **self.engine_kw)
            for e in range(engines)]
        self.monitor = H.HeartbeatMonitor(
            range(engines), timeout_s=heartbeat_timeout_s)
        self.watches: Dict[int, H.RoundWatch] = {
            e: H.RoundWatch() for e in range(engines)}
        self.snapshot_every = snapshot_every
        self.breaker_k = breaker_k
        self.probation_rounds = probation_rounds
        self.max_fleet_tiles = max_fleet_tiles
        self._snaps: Dict[int, SNAP.EngineSnapshot] = {}
        # engine -> (cleaned snapshot, fleet round it may restore at)
        self._pending_restore: Dict[int, Tuple[SNAP.EngineSnapshot,
                                               int]] = {}
        self._consecutive: Dict[int, int] = {e: 0 for e in range(engines)}
        # requests the FLEET holds terminally: a victim's finished set
        # (salvaged from its snapshot at failover) and fleet-shed
        # requests. Disjoint from every live engine's requests by
        # construction — report() merges without collisions.
        self._terminal: List[Request] = []
        self._round = 0
        self.registry = MET.Registry("fleet")
        self.quarantine_log: List[dict] = []
        self._set_quarantine_gauge()

    # -- telemetry -----------------------------------------------------------
    def _inc(self, name: str, value: int = 1,
             engine: Optional[int] = None):
        """Fleet counters keep their canonical schema.FLEET_COUNTERS
        names in the fleet registry AND the process-global one (the names
        are already fleet_-prefixed — no collision with engine_*)."""
        labels = None if engine is None else {"engine": str(engine)}
        self.registry.counter_inc(name, value, labels)
        MET.counter_inc(name, value, labels)

    def _set_quarantine_gauge(self):
        n = len(self._pending_restore)
        self.registry.gauge_set("engines_quarantined", n)
        MET.gauge_set("engines_quarantined", n)

    @property
    def stats(self) -> dict:
        st = {name: int(self.registry.counter_total(name))
              for name in SCH.FLEET_COUNTERS}
        st["engines_quarantined"] = int(self.registry.gauge_value(
            "engines_quarantined", default=0))
        st["rounds"] = self._round
        st["quarantine_log"] = list(self.quarantine_log)
        return st

    def _transition(self, phase: str, frm: str, to: str, payload: dict):
        """The one gate every fleet lifecycle move passes through:
        runtime-checked against the LADDERS registry (like the engine's
        _degrade) and emitted as its mapped, schema-validated event."""
        assert F.is_registered_transition(phase, frm, to), (
            f"unregistered fleet transition {phase}: {frm} -> {to}; "
            f"declare it in repro.resilience.faults.LADDERS")
        etype = TRANSITION_EVENTS[(phase, frm, to)]
        if SK.trace_enabled():
            SK.emit_event({"type": etype, **payload})

    # -- routing -------------------------------------------------------------
    def _outstanding_tiles(self, eng: Engine) -> int:
        """The replica's load in the admission cost model: tri(n) tiles
        of everything it still owes — queued and in-flight."""
        reqs = list(eng.queue) + [r for r in eng.slot_req if r is not None]
        return sum(eng._prefill_tiles(r) for r in reqs)

    def _live(self) -> List[int]:
        return [e for e in range(self.n) if self.engines[e] is not None]

    def submit(self, prompt: np.ndarray, max_new: int, uid: int,
               deadline_s: Optional[float] = None):
        """Route to the live replica with the fewest outstanding tiles
        (ties to the lowest engine index — deterministic). Greedy
        least-loaded on an exact cost model: per-replica totals stay
        within one maximal request of each other."""
        if not self._live():
            # every replica is parked: restore the earliest immediately
            # rather than refuse work.
            self._restore_due(force=True)
        target = min(self._live(), key=lambda e: (
            self._outstanding_tiles(self.engines[e]), e))
        eng = self.engines[target]
        eng.submit(prompt, max_new, uid, deadline_s=deadline_s)
        tiles = M.tri(-(-int(np.asarray(prompt).size) // eng.prefill_block))
        self._inc("fleet_requests_routed_total", engine=target)
        self._inc("fleet_routed_tiles_total", tiles, engine=target)
        self._shed_fleet_overload()

    def _shed_fleet_overload(self):
        """Fleet-wide backpressure on the same tri(n) ordering as
        engine-level shedding: while the GLOBAL queued-tile total exceeds
        ``max_fleet_tiles``, shed the heaviest request that is not any
        replica's queue head — every head still rides its engine's next
        admit round, so fleet backpressure stays starvation-free."""
        if not self.max_fleet_tiles:
            return
        while True:
            live = self._live()
            total = sum(
                sum(self.engines[e]._prefill_tiles(r)
                    for r in self.engines[e].queue) for e in live)
            if total <= self.max_fleet_tiles:
                return
            candidates = [
                (self.engines[e]._prefill_tiles(r), e, i)
                for e in live
                for i, r in enumerate(self.engines[e].queue) if i > 0]
            if not candidates:
                return  # only heads remain: never shed those
            _, e, i = max(candidates)
            victim = self.engines[e].queue.pop(i)
            victim.status = "shed"
            victim.done = True
            victim.error = (
                f"fleet shed: global queue over capacity "
                f"({self.max_fleet_tiles} tiles) and this was the "
                f"heaviest non-head request")
            self._terminal.append(victim)
            self._inc("fleet_requests_shed_total", engine=e)

    # -- drive loop ----------------------------------------------------------
    def tick(self):
        """One fleet round: restore replicas whose probation elapsed,
        then advance every live replica one engine round under the
        heartbeat/round watch."""
        if self._pending_restore:
            self._restore_due(force=not self._live())
        for e in range(self.n):
            eng = self.engines[e]
            if eng is not None:
                self._drive(e, eng)
        self._round += 1

    def _drive(self, e: int, eng: Engine):
        working = not eng.idle()
        t0 = float(self.clock())
        self.monitor.beat(e, self._round, now=t0)
        try:
            eng.round()
        except Exception as err:  # noqa: BLE001 — failover boundary
            self._on_engine_fault(e, eng,
                                  f"{type(err).__name__}: {err}")
            return
        now = float(self.clock())
        if working and self.watches[e].observe(now - t0):
            self._inc("fleet_rounds_straggler_total", engine=e)
        if e in self.monitor.failed(now=now):
            # the round COMMITTED (its tokens are the deterministic
            # ones) but took longer than the liveness budget — treat the
            # replica as dead and migrate what it still owes.
            self._on_engine_fault(e, eng, (
                f"heartbeat timeout: round took {now - t0:.3f}s > "
                f"{self.monitor.timeout_s}s"))
            return
        if working:
            self._consecutive[e] = 0
            if self.snapshot_every and \
                    self._round % self.snapshot_every == 0:
                self._snaps[e] = SNAP.snapshot(eng)

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Drive the fleet until drained — including ticking out any
        remaining probation windows so parked replicas rejoin. Returns
        {uid: tokens} for every terminal request, like Engine.run."""
        for _ in range(max_steps):
            if self._drained() and not self._pending_restore:
                break
            self.tick()
        return self.results()

    def _drained(self) -> bool:
        return all(eng is None or eng.idle() for eng in self.engines)

    # -- failover ------------------------------------------------------------
    def _on_engine_fault(self, e: int, eng: Engine, reason: str):
        """Deterministic failover: snapshot the victim, salvage its
        terminal requests, migrate the rest to a healthy replica, park
        the victim for its probation window."""
        self._consecutive[e] += 1
        consec = self._consecutive[e]
        try:
            snap = SNAP.snapshot(eng)
        except Exception:  # noqa: BLE001 — salvage from the periodic one
            snap = self._snaps.get(e)
        if snap is None:
            snap = self._snaps.get(e)
        assert snap is not None, (
            f"engine {e} died before any snapshot could be captured")
        # quarantine: the breaker stretches the probation window after K
        # consecutive faulted rounds.
        window = (self.probation_rounds if consec >= self.breaker_k
                  else 1)
        self.engines[e] = None
        self._pending_restore[e] = (SNAP.strip_for_restart(snap),
                                    self._round + window)
        self._set_quarantine_gauge()
        self.quarantine_log.append(
            {"engine": e, "round": self._round, "consecutive": consec,
             "probation_rounds": window, "reason": reason})
        self._transition(
            "engine", "active", "quarantined",
            {"engine": e, "round": self._round, "consecutive": consec,
             "probation_rounds": window, "reason": reason[:200]})
        # salvage + migrate: finished requests are terminal at the fleet;
        # in-flight (slot order) then queued requests move to the least
        # loaded healthy replica's queue head. Ages are rebased exactly
        # like Engine.restore does, so deadlines keep measuring elapsed
        # age across the move.
        shift = float(self.clock()) - snap.clock_now
        self._terminal.extend(
            SNAP._req_from_dict(d, shift) for d in snap.finished)
        inflight = [SNAP._req_from_dict(d, shift)
                    for d in snap.slot_req if d is not None]
        queued = [SNAP._req_from_dict(d, shift) for d in snap.queue]
        for r in inflight:
            r.replays += 1
        moved = inflight + queued
        for r in moved:
            r.status = "queued"
            r.done = False
        live = self._live()
        if not live:
            # no healthy peer to take the work: restore THIS replica now
            # (probation waived — liveness beats hygiene) and migrate to
            # it.
            self._restore_engine(e)
            live = [e]
        target = min(live, key=lambda t: (
            self._outstanding_tiles(self.engines[t]), t))
        self.engines[target].queue[0:0] = moved
        self._inc("fleet_failovers_total", engine=e)
        self._inc("fleet_requests_migrated_total", len(moved), engine=e)
        self._transition(
            "route", "primary", "failover",
            {"engine": e, "target": target, "round": self._round,
             "migrated": len(moved), "reason": reason[:200]})
        self._shed_fleet_overload()

    def _restore_due(self, force: bool = False):
        for e in sorted(self._pending_restore):
            if force or self._round >= self._pending_restore[e][1]:
                self._restore_engine(e)
                force = False  # liveness needs ONE replica back, not all

    def _restore_engine(self, e: int):
        snap, _release = self._pending_restore.pop(e)
        self.engines[e] = SNAP.restore(
            snap, params=self.params, fault_plan=self._plans[e],
            clock=self.clock, escalate_step_errors=True)
        self._set_quarantine_gauge()
        self._inc("fleet_engine_restores_total", engine=e)
        self._transition(
            "engine", "quarantined", "restored",
            {"engine": e, "round": self._round,
             "reason": "probation_elapsed"})

    # -- results -------------------------------------------------------------
    def results(self) -> Dict[int, List[int]]:
        res = {r.uid: list(r.out) for r in self._terminal}
        for eng in self.engines:
            if eng is not None:
                res.update({r.uid: r.out for r in eng.finished})
        return res

    def report(self) -> Dict[int, dict]:
        """Per-request lifecycle report across the whole fleet: every
        submitted request appears exactly once, with the engine currently
        holding it (None for fleet-held terminal requests)."""
        rep: Dict[int, dict] = {}
        for r in self._terminal:
            rep[r.uid] = {"status": r.status, "tokens": len(r.out),
                          "replays": r.replays, "error": r.error,
                          "engine": None}
        for e, eng in enumerate(self.engines):
            if eng is None:
                continue
            for uid, entry in eng.report().items():
                assert uid not in rep, (
                    f"request {uid} reported by engine {e} AND the fleet "
                    f"terminal set — failover double-accounted it")
                rep[uid] = dict(entry, engine=e)
        return rep
