"""KV / recurrent-state cache utilities for serving.

The cache pytree itself is built by models/transformer.init_cache (stacked
(n_superlayers, ...) so the decode scan streams it); this module adds the
serving-side bookkeeping: byte accounting (capacity planning), sharding
(via parallel/sharding.cache_shardings) and rolling-window semantics notes.

Cache kinds per layer:
  attn  : k/v (B, S_slots, Hkv, hd). S_slots = min(window, max_len) for
          sliding-window archs (rolling buffer, slot = pos % W) else max_len.
  mamba : h (B, d_inner, d_state) f32 + conv tail (B, d_conv-1, d_inner).
  rwkv  : shift (B, d), s (B, H, hd, hd) f32, shift_c (B, d).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model import init_cache  # re-export
from repro.models.transformer import init_layer_cache  # re-export

__all__ = ["init_cache", "init_layer_cache", "cache_bytes",
           "cache_bytes_per_token"]


def cache_bytes(cache) -> int:
    """Total bytes of a cache pytree (global, pre-sharding)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def cache_bytes_per_token(cfg, dtype=jnp.bfloat16) -> int:
    """Marginal KV bytes per generated token per sequence (attn layers only;
    recurrent layers are O(1) in sequence)."""
    itm = jnp.dtype(dtype).itemsize
    n_attn = sum(1 for k in cfg.layer_kinds if k == "attn")
    if cfg.sliding_window is not None:
        return 0  # rolling buffer: no marginal growth past the window
    return n_attn * 2 * cfg.n_kv_heads * cfg.head_dim * itm
