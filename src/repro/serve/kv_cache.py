"""KV / recurrent-state cache utilities for serving.

The cache pytree itself is built by models/transformer.init_cache (stacked
(n_superlayers, ...) so the decode scan streams it); this module adds the
serving-side bookkeeping: byte accounting (capacity planning), sharding
(via parallel/sharding.cache_shardings) and rolling-window semantics notes.

Cache kinds per layer:
  attn  : k/v (B, S_slots, Hkv, hd). S_slots = min(window, max_len) for
          sliding-window archs (rolling buffer, slot = pos % W) else max_len.
  mamba : h (B, d_inner, d_state) f32 + conv tail (B, d_conv-1, d_inner).
  rwkv  : shift (B, d), s (B, H, hd, hd) f32, shift_c (B, d).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model import init_cache  # re-export
from repro.models.transformer import init_layer_cache  # re-export

__all__ = ["init_cache", "init_layer_cache", "cache_bytes",
           "cache_bytes_per_token", "splice_slot", "validate_splice"]


def cache_bytes(cache) -> int:
    """Total bytes of a cache pytree (global, pre-sharding)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def validate_splice(cache, slot: int, start: int, length: int, *,
                    rolling: bool = False):
    """Bounds-check a packed-prefill -> slot-cache splice BEFORE any write.

    Raises ValueError with an actionable message when the splice would
    read outside the packed states or write outside the slot: an
    over-length splice against a non-rolling cache would otherwise
    silently truncate the prompt's KV (and an out-of-range slot index
    would corrupt a NEIGHBORING request's cache — the worst serving bug
    there is, because the victim's outputs go wrong, not the offender's).
    """
    if length <= 0:
        raise ValueError(f"splice length must be positive, got {length} "
                         f"(empty prompts are rejected at submit)")
    if start < 0:
        raise ValueError(f"splice start must be >= 0, got {start}")
    for leaf in jax.tree.leaves(cache):
        if leaf.ndim != 5:
            continue  # non-KV leaf (recurrent state): not spliced
        n_slots, s_slots = leaf.shape[1], leaf.shape[2]
        if not 0 <= slot < n_slots:
            raise ValueError(
                f"splice slot {slot} out of range for a {n_slots}-slot "
                f"cache — writing would corrupt slot {slot % n_slots}'s "
                f"KV rows (a neighboring request)")
        if length > s_slots and not rolling:
            raise ValueError(
                f"splice of {length} KV rows overflows the slot cache "
                f"(S_slots={s_slots}, non-rolling): the request is longer "
                f"than max_len — reject it at submit or raise max_len")


def splice_slot(cache, slot: int, states, start: int, length: int, *,
                rolling: bool = False):
    """Copy one request's KV rows [start, start+length) out of packed
    prefill ``states`` into ``slot`` of ``cache``, validated.

    KV leaves are (n_sl, 1, S_total, Hkv, hd) against a cache of
    (n_sl, B, S_slots, Hkv, hd). Rolling (sliding-window) caches are
    rolling buffers (slot p % W holds position p): keep the last W rows
    and roll them into decode's slot order. Returns the new cache pytree.
    """
    validate_splice(cache, slot, start, length, rolling=rolling)
    for leaf in jax.tree.leaves(states):
        if leaf.ndim == 5 and start + length > leaf.shape[2]:
            raise ValueError(
                f"splice [{start}, {start + length}) reads past the "
                f"packed states (S_total={leaf.shape[2]}): start/length "
                f"disagree with the packing — the rows would belong to "
                f"the NEXT packed request")

    def fill(c, st):
        if not (c.ndim == 5 and st.ndim == 5):
            return c  # non-KV leaf: unreachable on the packed path
        s_slots = c.shape[2]
        seg = st[:, 0, start:start + length]  # (n_sl, len, Hkv, hd)
        if length > s_slots:
            keep = seg[:, length - s_slots:]
            keep = jnp.roll(keep, shift=length % s_slots, axis=1)
            return c.at[:, slot, :s_slots].set(keep.astype(c.dtype))
        return c.at[:, slot, :length].set(seg.astype(c.dtype))

    return jax.tree.map(fill, cache, states)


def cache_bytes_per_token(cfg, dtype=jnp.bfloat16) -> int:
    """Marginal KV bytes per generated token per sequence (attn layers only;
    recurrent layers are O(1) in sequence)."""
    itm = jnp.dtype(dtype).itemsize
    n_attn = sum(1 for k in cfg.layer_kinds if k == "attn")
    if cfg.sliding_window is not None:
        return 0  # rolling buffer: no marginal growth past the window
    return n_attn * 2 * cfg.n_kv_heads * cfg.head_dim * itm
