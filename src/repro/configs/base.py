"""Configuration schema: architectures, input shapes, reduced smoke configs."""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture. Layer kinds: 'attn' (transformer block), 'mamba',
    'rwkv'. layer_pattern is tiled to n_layers."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # MoE (0 experts -> dense MLP everywhere)
    n_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1  # MoE on layers where (idx % moe_every == moe_offset)
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # attention
    sliding_window: Optional[int] = None
    rope_theta: float = 10_000.0
    mlp_activation: str = "swiglu"  # swiglu | relu2 | gelu
    layer_pattern: Tuple[str, ...] = ("attn",)

    # SSM dims (mamba)
    d_state: int = 16
    d_conv: int = 4
    ssm_expand: int = 2

    # rwkv
    rwkv_head_dim: int = 64
    rwkv_lora_dim: int = 64

    # modality frontend stub: 'none' | 'audio_frames' | 'vision_patches'
    frontend: str = "none"
    n_patches: int = 0  # vlm: visual prefix length (precomputed embeddings)

    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived -----------------------------------------------------------
    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        reps = -(-self.n_layers // len(self.layer_pattern))
        return (self.layer_pattern * reps)[: self.n_layers]

    @property
    def superlayer(self) -> int:
        """Layers per scan step (== len(layer_pattern) when mixed)."""
        return len(self.layer_pattern)

    @property
    def n_superlayers(self) -> int:
        assert self.n_layers % self.superlayer == 0
        return self.n_layers // self.superlayer

    @property
    def padded_vocab(self) -> int:
        """Vocab padded for clean TP sharding + MXU lane alignment."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:  # mamba
        return self.ssm_expand * self.d_model

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def is_moe_layer(self, idx: int) -> bool:
        if self.n_experts == 0:
            return False
        return idx % self.moe_every == self.moe_offset

    def is_pure_full_attention(self) -> bool:
        """True if every token-mixing layer is unwindowed full attention
        (-> quadratic; long_500k is skipped per the brief)."""
        return (all(k == "attn" for k in self.layer_kinds)
                and self.sliding_window is None)

    # ---- parameter counting (for roofline MODEL_FLOPS = 6*N*D) -------------
    def param_counts(self) -> dict:
        d, hd = self.d_model, self.head_dim
        qkvo = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        if self.mlp_activation == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        total = 0
        active = 0
        for idx, kind in enumerate(self.layer_kinds):
            if kind == "attn":
                total += qkvo
                active += qkvo
            elif kind == "mamba":
                din, ds = self.d_inner, self.d_state
                m = (d * 2 * din          # in_proj (x, z)
                     + din * self.d_conv  # depthwise conv
                     + din * (ds * 2 + 1) # x_proj -> B, C, dt(rank1 simplif.)
                     + din                # dt bias / A diag handled below
                     + din * ds           # A_log
                     + din                # D
                     + din * d)           # out_proj
                total += m
                active += m
            elif kind == "rwkv":
                h = self.n_rwkv_heads
                m = 4 * d * d + d * d  # r,k,v,g,out projections (approx wkv6)
                m += 2 * self.rwkv_lora_dim * d + h * self.rwkv_head_dim
                total += m
                active += m
            if kind in ("attn", "mamba", "rwkv"):
                if self.is_moe_layer(idx):
                    total += self.n_experts * mlp + d * self.n_experts
                    active += self.experts_per_token * mlp + d * self.n_experts
                elif kind == "rwkv":
                    cm = 2 * d * self.d_ff + d * d  # channel mix k, v, r
                    total += cm
                    active += cm
                else:
                    total += mlp
                    active += mlp
            total += 2 * d  # norms
            active += 2 * d
        emb = self.padded_vocab * d
        head = 0 if self.tie_embeddings else self.padded_vocab * d
        total += emb + head + d
        active += emb + head + d
        return {"total": total, "active": active}


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (paired with an architecture)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode

    @property
    def is_decode(self) -> bool:
        return self.kind in ("decode", "long_decode")

    @property
    def tokens_per_step(self) -> int:
        if self.is_decode:
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "long_decode"),
}


def reduced(cfg: ModelConfig, *, layers: Optional[int] = None) -> ModelConfig:
    """Smoke-test config: same family/topology, tiny dims."""
    sl = cfg.superlayer
    n_layers = layers if layers is not None else 2 * sl
    n_layers = _round_up(n_layers, sl)
    heads = min(cfg.n_heads, 4)
    kv = max(1, min(cfg.n_kv_heads, heads))
    while heads % kv:
        kv -= 1
    hd = 16
    d_model = heads * hd * 2  # keep d_model a multiple of rwkv head dim
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=d_model,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=hd,
        d_ff=4 * d_model if cfg.n_experts == 0 else 64,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        experts_per_token=(min(cfg.experts_per_token, 2)
                           if cfg.n_experts else 0),
        # no capacity drops at smoke scale: keeps batched-forward ==
        # incremental-decode exactly testable (full scale keeps 1.25)
        capacity_factor=8.0,
        sliding_window=(64 if cfg.sliding_window is not None else None),
        d_state=8,
        rwkv_head_dim=16,
        rwkv_lora_dim=8,
        n_patches=8 if cfg.n_patches else 0,
        dtype="float32",
    )
