"""musicgen-large [audio] — decoder-only over EnCodec tokens.

[arXiv:2306.05284; hf facebook/musicgen-large]
48L d_model=2048 32H (kv=32, i.e. MHA) d_ff=8192 vocab=2048.
Backbone only: the EnCodec frontend is a STUB — input_specs() provides
precomputed frame embeddings (B, S, d_model); text conditioning omitted.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    mlp_activation="gelu",
    layer_pattern=("attn",),
    frontend="audio_frames",
)
