"""granite-moe-3b-a800m [moe] — 40 experts top-8 (brief's structured field;
its free text says 32e — discrepancy noted in DESIGN.md §6), GQA kv=8.

[hf:ibm-granite/granite-3.0-3b-a800m-base]
32L d_model=1536 24H (GQA kv=8) d_ff=512 (per-expert) vocab=49155.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    n_experts=40,
    experts_per_token=8,
    moe_every=1,
    mlp_activation="swiglu",
    layer_pattern=("attn",),
)
