"""rwkv6-1.6b [ssm] — Finch, data-dependent decay; attention-free.

[arXiv:2404.05892] 24L d_model=2048 d_ff=7168 vocab=65536.
The paper's triangular mapping is inapplicable to the token mixer (no
attention); the chunked WKV6 intra-chunk decay matrix is itself a strictly
lower-triangular domain — see DESIGN.md §6. n_heads below is the WKV head
count (d_model / 64).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,       # wkv heads (= d_model / rwkv_head_dim)
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    layer_pattern=("rwkv",),
    rwkv_head_dim=64,
    rwkv_lora_dim=64,
)
