"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP, 256k vocab.

[arXiv:2402.16819 (Nemotron-4 15B report; 340B scales it)]
96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.
head_dim = 192 (pads MXU lanes to 256 — noted in roofline analysis).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    mlp_activation="relu2",
    layer_pattern=("attn",),
)
