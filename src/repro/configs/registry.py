"""Architecture registry: --arch <id> -> ModelConfig, shape cells, and
ShapeDtypeStruct input specs for the dry-run (no device allocation).
"""

from __future__ import annotations

import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, reduced

ARCH_IDS = (
    "mixtral-8x7b",
    "granite-moe-3b-a800m",
    "rwkv6-1.6b",
    "yi-9b",
    "nemotron-4-340b",
    "llama3-405b",
    "granite-34b",
    "musicgen-large",
    "internvl2-1b",
    "jamba-1.5-large-398b",
)


def _module_name(arch: str) -> str:
    return "repro.configs." + arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_module_name(arch)).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


# ---------------------------------------------------------------------------
# Cell support matrix (arch x shape)
# ---------------------------------------------------------------------------


def supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether this (arch, shape) cell runs; reason when skipped."""
    if shape.kind == "long_decode" and cfg.is_pure_full_attention():
        return False, ("pure full-attention arch: 500k decode is quadratic "
                       "with an unbounded KV cache; skipped per brief "
                       "(sub-quadratic archs run it)")
    return True, ""


def runnable_cells():
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, why = supported(cfg, shape)
            out.append((arch, sname, ok, why))
    return out


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; weak-type-correct, shardable)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                act_dtype=jnp.bfloat16) -> dict:
    """Train/prefill batch: the model inputs for one global step."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.frontend == "audio_frames":
        # EnCodec frame embeddings precomputed by the stub frontend.
        return {
            "embeds": _sds((b, s, cfg.d_model), act_dtype),
            "labels": _sds((b, s), jnp.int32),
        }
    if cfg.frontend == "vision_patches":
        p = cfg.n_patches
        return {
            "embeds": _sds((b, p, cfg.d_model), act_dtype),
            "tokens": _sds((b, s - p), jnp.int32),
            "labels": _sds((b, s), jnp.int32),
            "mask": _sds((b, s), jnp.float32),
        }
    return {
        "tokens": _sds((b, s), jnp.int32),
        "labels": _sds((b, s), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, shape: ShapeConfig,
                cache_dtype=jnp.bfloat16) -> dict:
    """Decode-state pytree spec (KV cache of seq_len / recurrent states)."""
    from repro.models import model as MD
    b, s = shape.global_batch, shape.seq_len
    return jax.eval_shape(
        lambda: MD.init_cache(cfg, b, s, cache_dtype))


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b = shape.global_batch
    return {
        "tokens": _sds((b, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
    }


def params_specs(cfg: ModelConfig):
    from repro.models import model as MD
    key = jax.random.key(0)
    return jax.eval_shape(lambda: MD.init_params(key, cfg))


def input_specs(arch: str, shape_name: str) -> dict:
    """Everything dryrun.py needs for one cell, as ShapeDtypeStructs."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    specs = {"params": params_specs(cfg)}
    if shape.is_decode:
        specs["cache"] = cache_specs(cfg, shape)
        specs.update(decode_specs(cfg, shape))
    else:
        specs["batch"] = batch_specs(cfg, shape)
    return specs


def smoke_config(arch: str, **kw) -> ModelConfig:
    return reduced(get_config(arch), **kw)
