"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e
top-2 on every other layer.

[arXiv:2403.19887 / Jamba-1.5; hf ai21labs/AI21-Jamba-1.5-Large]
72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Superlayer = the 8-layer Jamba block (attention at in-block index 3); MoE on
odd in-block indices (every 2nd layer). The paper's triangular mapping
applies to the 9 attention layers; the 63 Mamba layers are attention-free
(inapplicable — DESIGN.md §6).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    n_experts=16,
    experts_per_token=2,
    moe_every=2,
    moe_offset=1,
    mlp_activation="swiglu",
    layer_pattern=("mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba", "mamba"),
    d_state=16,
    d_conv=4,
    ssm_expand=2,
)
