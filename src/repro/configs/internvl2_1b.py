"""internvl2-1b [vlm] — InternViT-300M + Qwen2-0.5B LM backbone.

[arXiv:2404.16821; hf OpenGVLab/InternVL2-1B]
24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
Backbone only: the InternViT frontend is a STUB — input_specs() provides
precomputed patch embeddings (B, 256, d_model). The image prefix attends
bidirectionally => prefix-causal attention domain (PrefixSchedule,
beyond-paper triangular∪rectangle mapping).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    rope_theta=1e6,
    mlp_activation="swiglu",
    layer_pattern=("attn",),
    frontend="vision_patches",
    n_patches=256,
)
