"""granite-34b [dense] — code model, MQA (kv=1).

[arXiv:2405.04324; hf ibm-granite/granite-34b-code-base]
88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
Original is GPTBigCode (learned positions, gelu 2-matrix MLP); we keep the
gelu MLP and use RoPE (framework-uniform position encoding — adaptation
noted in DESIGN.md).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    mlp_activation="gelu",
    layer_pattern=("attn",),
)
