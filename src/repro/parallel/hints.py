"""Sharding hints: scoped, optional layout constraints for model internals.

The baseline model code is layout-agnostic (XLA SPMD propagates from the
param/batch shardings alone). The §Perf hill-climb showed propagation makes
three costly choices at scale:

  * attention contracts the model-sharded head_dim -> per-TILE score
    all-reduces (x T(n) trips),
  * the MoE dispatch ranks tokens with a GLOBAL cumsum -> cross-device
    serialization + replicated (E, C, d) buffers,
  * the TP MLP emits full-sequence f32 activation all-reduces per layer.

Rather than hard-coding fixes (which would impose mesh knowledge on model
code), optimization passes set hints inside a context; model code applies
them via `constrain`/`get` when present. Traced-once semantics: dryrun.py
sets hints around jit(...).lower(), so the constraints are baked into each
lowered cell. No hint -> exactly the baseline program.

Hints used:
  attn_qkv   : PartitionSpec for (B, H, S, D) attention tensors (head TP)
  act_seq    : PartitionSpec for the (B, S, d) residual carry
  moe_groups : int — dispatch-group count for local (per-shard) MoE routing
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Dict, Optional

import jax

_HINTS: contextvars.ContextVar[Dict[str, Any]] = contextvars.ContextVar(
    "sharding_hints", default={})


@contextlib.contextmanager
def hints(**kw):
    merged = dict(_HINTS.get())
    merged.update({k: v for k, v in kw.items() if v is not None})
    token = _HINTS.set(merged)
    try:
        yield
    finally:
        _HINTS.reset(token)


def get(name: str, default=None):
    return _HINTS.get().get(name, default)


def constrain(x, name: str):
    """Apply with_sharding_constraint if the hint is set (else identity)."""
    spec = get(name)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
