"""Sharding rules: params / batch / cache pytrees -> NamedSharding.

Scheme (single-pod mesh ("data", "model") = 16 x 16; multi-pod prepends
"pod"):

  * batch dim            -> ("pod", "data")   (pure DP across pods composes
                                               with in-pod DP/FSDP)
  * TP dims (heads, d_ff,
    vocab, d_inner)      -> "model"
  * FSDP dim (the other
    large param dim)     -> "data"            (Zero-3 style; XLA all-gathers
                                               per layer inside the scan)
  * experts              -> "data" when E % |data| == 0 (EP), else FSDP
                            fallback on the next dim
  * decode KV sequence   -> "model" (flash-decode style split of the
                            softmax reduction), batch on ("pod","data");
                            long-context B=1 shards seq over everything

Every rule degrades to replication when the dimension is not divisible by
the axis size (the "divisibility fallback") — this is what lets one rule set
serve 10 architectures from 0.9 B to 405 B parameters unchanged.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def _axis_size(mesh: Mesh, axis) -> int:
    """Product of mesh-axis sizes; 0 if any axis is absent from the mesh
    (signals fallback() to drop the entry — e.g. restoring a TP-sharded
    checkpoint onto a data-only elastic mesh)."""
    if axis is None:
        return 1
    axes = axis if isinstance(axis, tuple) else (axis,)
    if any(a not in mesh.shape for a in axes):
        return 0
    return int(np.prod([mesh.shape[a] for a in axes]))


def dp_axes(mesh: Mesh):
    """The composite batch axis: ("pod", "data") when a pod axis exists."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fallback(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop any spec entry whose axis size does not divide the dim."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    fixed = []
    for dim, axis in zip(shape, entries):
        size = _axis_size(mesh, axis) if axis else 1
        fixed.append(axis if axis and size and dim % size == 0 else None)
    return P(*fixed)


def named(mesh: Mesh, spec: P, shape) -> NamedSharding:
    return NamedSharding(mesh, fallback(spec, tuple(shape), mesh))


# ---------------------------------------------------------------------------
# Parameter rules (keyed by leaf name; stacked superlayer dim handled by
# rank: specs are written for the UNstacked rank and left-padded with None)
# ---------------------------------------------------------------------------

# name -> spec for the param's intrinsic rank
_PARAM_RULES = {
    # top level
    "embed": P("model", "data"),        # (vocab, d): vocab TP, d FSDP
    "lm_head": P("data", "model"),      # (d, vocab)
    # attention
    "wq": P("data", "model"),
    "wk": P("data", "model"),
    "wv": P("data", "model"),
    "wo": P("model", "data"),
    # dense mlp
    "wi": P("data", "model"),
    "wg": P("data", "model"),
    # mamba
    "in_proj": P("data", "model"),
    "conv_w": P(None, "model"),
    "x_proj": P("model", None),
    "dt_bias": P("model"),
    "a_log": P("model", None),
    "d_skip": P("model"),
    "out_proj": P("model", "data"),
    # rwkv
    "wr": P("data", "model"),
    "w_lora_a": P("data", None),
    "w_lora_b": P(None, "data"),
    "cm_wk": P("data", "model"),
    "cm_wv": P("model", "data"),
    "cm_wr": P("data", "model"),
    # moe (rank-3; expert dim resolved in _param_spec)
    "router": P("data", None),
}

_MOE_NAMES = {"wi", "wg", "wo"}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def _param_spec(path, leaf, mesh: Mesh) -> P:
    name = _leaf_name(path)
    rank = leaf.ndim
    path_keys = [str(e.key) for e in path
                 if isinstance(e, jax.tree_util.DictKey)]
    stacked = "layers" in path_keys  # leading n_superlayers dim

    base_rank = rank - (1 if stacked else 0)

    if name in _MOE_NAMES and base_rank == 3:  # moe expert weights (E, a, b)
        e = leaf.shape[1] if stacked else leaf.shape[0]
        ep_ok = e % _axis_size(mesh, "data") == 0
        if name == "wo":  # (E, f, d)
            spec = P("data", "model", None) if ep_ok else P(None, "model",
                                                            "data")
        else:  # wi/wg (E, d, f)
            spec = P("data", None, "model") if ep_ok else P(None, "data",
                                                            "model")
    elif name in _PARAM_RULES and len(_PARAM_RULES[name]) == base_rank:
        spec = _PARAM_RULES[name]
    else:
        spec = P()  # norms, biases, mu, u, w0, ln_x_*: replicate

    if stacked:
        spec = P(*((None,) + tuple(spec)))
    return fallback(spec, leaf.shape, mesh)


def param_shardings(mesh: Mesh, params_tree, overrides=None):
    """NamedSharding pytree for a params (or ShapeDtypeStruct) pytree.

    overrides: {leaf_name: PartitionSpec} replacing the rule for that leaf
    (stacked leading dim handled; divisibility fallback still applies) —
    used by §Perf passes, e.g. embed -> P(None, all-axes) so the token
    gather and its scatter-add gradient are collective-free."""

    def spec(path, leaf):
        name = _leaf_name(path)
        if overrides and name in overrides:
            s = overrides[name]
            return NamedSharding(mesh, fallback(s, leaf.shape, mesh))
        return NamedSharding(mesh, _param_spec(path, leaf, mesh))

    return jax.tree_util.tree_map_with_path(spec, params_tree)


def param_specs_tree(mesh: Mesh, params_tree):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_spec(path, leaf, mesh), params_tree)


# ---------------------------------------------------------------------------
# Batch / cache rules
# ---------------------------------------------------------------------------


def batch_shardings(mesh: Mesh, batch_tree):
    dp = dp_axes(mesh)

    def spec(path, leaf):
        s = P(dp, *([None] * (leaf.ndim - 1)))
        return NamedSharding(mesh, fallback(s, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec, batch_tree)


def cache_shardings(mesh: Mesh, cache_tree):
    """Decode state: (n_sl, B, ...) pytree.

    KV caches (rank 5: n_sl, B, S, Hkv, hd): batch over dp when divisible,
    otherwise (long-context B=1) shard the KV sequence over every mesh axis;
    when batch IS sharded, additionally shard KV seq over "model"
    (flash-decode style partial-softmax split, resolved by XLA collectives).
    Recurrent states: batch over dp, feature dim over "model".
    """
    dp = dp_axes(mesh)
    all_axes = tuple(mesh.axis_names)

    def spec(path, leaf):
        shape = leaf.shape
        if leaf.ndim == 5:  # KV cache
            b_ok = shape[1] % _axis_size(mesh, dp) == 0
            if b_ok:
                s = P(None, dp, "model", None, None)
            else:
                s = P(None, None, all_axes, None, None)
        elif leaf.ndim >= 3:  # mamba h / rwkv s / conv
            s = P(None, dp, "model", *([None] * (leaf.ndim - 3)))
        else:
            s = P(None, dp)
        return NamedSharding(mesh, fallback(s, shape, mesh))

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def scalar_sharding(mesh: Mesh):
    return NamedSharding(mesh, P())


def token_shardings(mesh: Mesh, tree):
    """Decode-step tokens (B, 1) / pos scalars."""
    dp = dp_axes(mesh)

    def spec(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        s = P(dp, *([None] * (leaf.ndim - 1)))
        return NamedSharding(mesh, fallback(s, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec, tree)
