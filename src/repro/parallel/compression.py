"""Gradient compression: int8-quantized all-reduce with error feedback.

Distributed-optimization trick for bandwidth-bound gradient exchange (the
cross-pod all-reduce at multi-pod scale is DCI-bound; int8 quarters the
bytes). Error feedback (Seide et al. / EF-SGD) keeps the compression
*unbiased over time*: the quantization residual is carried and re-added to
the next step's gradient, so the scheme provably converges at the full-
precision rate for smooth objectives.

Two entry points:
  * quantize/dequantize — the per-tensor int8 codec (symmetric, per-tensor
    scale; tested for exactness bounds + error-feedback telescoping).
  * compressed_psum — shard_map collective: quantize locally, all-reduce the
    int8 payload (summed in int32 to avoid overflow), dequantize. Used by
    train/train_step.py when cfg.grad_compression == "int8"; off by default.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8. Returns (q int8, scale f32 scalar)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(g: jax.Array, err: jax.Array):
    """Error-feedback step: compress (g + err), return (q, scale, new_err)."""
    target = g.astype(jnp.float32) + err
    q, scale = quantize_int8(target)
    new_err = target - dequantize_int8(q, scale)
    return q, scale, new_err


def init_error_state(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, err_state, mesh: Mesh, axis: str = "data"):
    """All-reduce `grads` over `axis` in int8 with error feedback.

    grads leaves must be identically replicated-shaped per shard along the
    reduce axis (i.e. this runs on the per-device local gradient inside a
    shard_map over the DP axis). Returns (mean_grads f32, new_err_state).
    """

    def _one(g, e):
        q, scale, new_e = ef_compress(g, e)
        # sum int8 payloads in int32; scales are per-shard -> psum the
        # dequantized contribution instead (scale * q) to stay exact.
        contrib = dequantize_int8(q, scale)
        total = jax.lax.psum(contrib, axis)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
        return total / n, new_e

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    out = [_one(g, e) for g, e in zip(flat_g, flat_e)]
    means = tree.unflatten([o[0] for o in out])
    errs = tree.unflatten([o[1] for o in out])
    return means, errs


def make_compressed_allreduce(mesh: Mesh, axis: str = "data"):
    """shard_map wrapper: (grads, err) -> (mean grads, err'), DP over axis.

    Gradient leaves enter replicated on every other axis; the DP axis holds
    per-microshard partial gradients (i.e. call this INSTEAD of letting the
    partitioner emit the f32 all-reduce).
    """
    from jax.experimental.shard_map import shard_map

    def fn(grads, err):
        return compressed_psum(grads, err, mesh, axis)

    spec = P()  # per-leaf replicated layout inside the DP group

    def wrapped(grads, err):
        specs_g = jax.tree.map(lambda _: spec, grads)
        specs_e = jax.tree.map(lambda _: spec, err)
        return shard_map(
            fn, mesh=mesh,
            in_specs=(specs_g, specs_e),
            out_specs=(specs_g, specs_e),
            check_rep=False,
        )(grads, err)

    return wrapped
