"""Block-mapping functions for triangular-domain problems (the paper's core).

Implements the paper's g(lambda) (LTM) plus every competitor strategy it
benchmarks (BB, UTM, RB, REC), as pure functions usable both:

  * traced inside Pallas ``BlockSpec.index_map`` / kernel bodies (jnp scalar ops
    on the TPU scalar core), and
  * eagerly on host (numpy ints) for schedule construction and analysis.

Conventions
-----------
The triangular domain is the *lower* triangle of an ``n x n`` block grid:
blocks ``(i, j)`` with ``0 <= j <= i < n`` (diagonal included unless stated).
``T(n) = n(n+1)/2`` is the number of useful blocks. ``lambda`` (``lam``) is a
linear block index in ``[0, T)`` enumerated row-major: ``lam = i(i+1)/2 + j``.

Exactness: the paper's LTM-R uses ``x*rsqrtf(x) + eps`` and is exact only for
``N < 30,720``. On TPU the map runs once per grid step on the scalar core, so
we use float sqrt followed by integer corrections (the paper's own
"e <= 1 fixable by conditionals" observation) with overflow-clamped probes.
The traced envelopes are DECLARED as named module constants below
(``ISQRT_TRACED_MAX_X``, ``LTM_TRACED_MAX_LAM``, ``TET_TRACED_MAX_LAM``, ...)
and CERTIFIED against derived float-error bounds by
``repro.analysis.envelope`` — do not restate the numbers in prose; import
the constants. Host ints are exact unboundedly (math.isqrt / python ints).

The 2D/3D map zoo
-----------------
Row-major lower-triangle maps (launch-index -> tile coords):
  ``ltm_map``        g(lambda) -> (i, j), diagonal included  (paper eq. 2)
  ``ltm_map_nodiag`` strictly-lower variant                  (paper eq. 10)
  ``band_map``       sliding-window trapezoid (beyond-paper)
  ``prefix_full_map`` causal triangle + bidirectional prefix rectangle
  ``tet_map``        lambda -> (i, j, k) over the discrete TETRAHEDRON
                     ``0 <= k <= j <= i < n`` (3D simplex; beyond-paper,
                     after Navarro et al. arXiv 1606.08881 / 1610.07394).
                     BB-3D waste grows O(n^3) so the exact map pays off
                     even more than in 2D.
Column-major variants (backward-pass enumerations): ``cm_map``,
``band_cm_map``, ``prefix_cm_map``.
Competitors at block level: ``utm_map`` (Avril), ``rb_map`` (Jung fold),
``rec_schedule`` (Ries recursive), ``bb_map`` (bounding box).

The 3D row-finder uses the same repair pattern as ``_isqrt_traced``: a
float32 ``cbrt`` candidate followed by ``TET_PROBES_UP``/``TET_PROBES_DOWN``
integer corrections (overflow-clamped probes). Traced exactness envelope:
int32 intermediates of ``tet(i) = tri(i)*(i+2)/3`` fit below 2**31 for
``i <= TET_TRACED_MAX_I``, so the map is exact for planes
``i <= TET_TRACED_EXACT_PLANES`` (``lam <= TET_TRACED_MAX_LAM``); host ints
are exact unboundedly.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray

# ---------------------------------------------------------------------------
# Triangular numbers
# ---------------------------------------------------------------------------


def tri(n):
    """T(n) = n(n+1)/2, the n-th triangular number (works traced or host)."""
    return (n * (n + 1)) // 2


def tri_blocks(n: int) -> int:
    """Number of blocks LTM launches for an n-block-per-side domain."""
    return tri(n)


def bb_blocks(n: int) -> int:
    """Number of blocks the bounding-box strategy launches."""
    return n * n


def wasted_blocks_bb(n: int) -> int:
    """Paper: BB wastes n(n-1)/2 (strictly-upper) blocks."""
    return (n * (n - 1)) // 2


def wasted_blocks_ltm(n: int) -> int:
    """Paper: LTM wastes only the intra-diagonal-block upper halves => O(n).

    At block granularity no whole block is wasted; the n diagonal blocks each
    run half-masked, so the *block-equivalent* waste is n/2 (we report n to
    stay integer, matching the paper's O(n) claim).
    """
    return n


# ---------------------------------------------------------------------------
# Tetrahedral numbers (3D simplex)
# ---------------------------------------------------------------------------


def tet(i):
    """T3(i) = i(i+1)(i+2)/6, the i-th tetrahedral number (traced or host).

    Computed as (tri(i) * (i+2)) // 3 — each division is exact (i(i+1)/2 is
    an integer; i(i+1)(i+2)/2 is divisible by 3 since one of three
    consecutive integers is) and the int32 intermediate tri(i)*(i+2) stays
    below 2**31 for i <= TET_TRACED_MAX_I, the traced exactness envelope.
    """
    return (tri(i) * (i + 2)) // 3


def tet_blocks(n: int) -> int:
    """Blocks the tetrahedral map launches: exactly the domain size."""
    return tet(n)


def bb3_blocks(n: int) -> int:
    """Blocks the 3D bounding-box strategy launches (full n^3 cube)."""
    return n * n * n


def wasted_blocks_bb3(n: int) -> int:
    """BB-3D waste: n^3 - n(n+1)(n+2)/6 -> (5/6) n^3, i.e. O(n^3).

    In 2D the bounding box wastes ~half the launch; in 3D it wastes ~5/6 of
    it, which is why the exact simplex map pays off even more here.
    """
    return n * n * n - tet(n)


# ---------------------------------------------------------------------------
# Traced-exactness envelopes — DECLARED here, CERTIFIED by repro.analysis
# ---------------------------------------------------------------------------
#
# Single source of truth for every "exact up to ..." claim in this module.
# The static verifier (repro.analysis.envelope) re-derives each bound from
# float32 error analysis of the correction-probe logic and fails the lint
# tier if a declared constant drifts from the derived one, so edits to the
# probe code below must keep these in sync (the checker tells you how).

INT32_MAX = 2**31 - 1

# floor(sqrt(INT32_MAX)): the largest root whose square fits int32.
# Correction probes clamp at this value so the repair itself cannot
# overflow (probing (r+1)^2 for r = 46340 would wrap negative and accept
# a too-large root — the failure mode the clamp exists to prevent).
ISQRT_MAX_R = 46340

# Integer correction probes applied in each direction after the float32
# sqrt candidate. The float error bound derived by the verifier is < 1,
# so one probe each way suffices.
ISQRT_PROBES = 1

# _isqrt_traced(x) == floor(sqrt(x)) for all 0 <= x <= ISQRT_TRACED_MAX_X.
ISQRT_TRACED_MAX_X = INT32_MAX

# ltm_map computes 8*lam + 1 in the index dtype; int32 caps lam here.
# Largest exactly-mapped traced lambda and the row it lands in.
LTM_TRACED_MAX_LAM = (INT32_MAX - 1) // 8  # 268,435,455
LTM_TRACED_MAX_I = 23169  # row of LTM_TRACED_MAX_LAM

# 3D row-finder: float32 cbrt candidate error spans [-1, +2] relative to
# the true plane (real-arithmetic candidate sits in [i, i+1]; float
# rounding adds at most one more either way), so two probes up and two
# down repair it with margin.
TET_PROBES_UP = 2
TET_PROBES_DOWN = 2

# Largest argument whose tet() int32 intermediate tri(i)*(i+2) fits in
# 2**31. Correction probes clamp here, so the traced map is exact for
# planes i <= TET_TRACED_EXACT_PLANES, i.e. lam <= TET_TRACED_MAX_LAM.
TET_TRACED_MAX_I = 1624
TET_TRACED_EXACT_PLANES = TET_TRACED_MAX_I - 1  # 1623
TET_TRACED_MAX_LAM = tet(TET_TRACED_MAX_I) - 1  # 715,168,999

# Kept for callers that predate the public names.
_TET_TRACED_MAX_I = TET_TRACED_MAX_I


# ---------------------------------------------------------------------------
# Exact integer sqrt usable in traced code
# ---------------------------------------------------------------------------


def _isqrt_traced(x: Array) -> Array:
    """floor(sqrt(x)) for non-negative int32/int64 scalars, traced.

    float32 sqrt gives a candidate within +-1 of the true root over the
    whole int32 range (paper's observation); ISQRT_PROBES where-corrections
    in each direction make it exact. Branch-free on the TPU scalar core.
    Probes are overflow-clamped at ISQRT_MAX_R: without the clamp,
    (r+1)^2 wraps negative for r >= ISQRT_MAX_R and the up-probe accepts a
    too-large root, which is exactly what happened for
    x >= 2,147,395,599 before the clamp existed.
    """
    xf = x.astype(jnp.float32)
    r = jnp.floor(jnp.sqrt(xf)).astype(x.dtype)
    r = jnp.minimum(r, ISQRT_MAX_R)
    # r may be off by one in either direction after float rounding.
    for _ in range(ISQRT_PROBES):
        up = jnp.minimum(r + 1, ISQRT_MAX_R)
        r = jnp.where((up * up <= x) & (up == r + 1), r + 1, r)
    for _ in range(ISQRT_PROBES):
        r = jnp.where(r * r > x, r - 1, r)
    return r


def isqrt(x):
    """Exact floor-sqrt: host ints use math.isqrt, traced arrays use repair."""
    if isinstance(x, (int, np.integer)):
        return math.isqrt(int(x))
    return _isqrt_traced(x)


# ---------------------------------------------------------------------------
# LTM — the paper's g(lambda)  (eq. 2)
# ---------------------------------------------------------------------------


def ltm_map(lam):
    """g(lambda) -> (i, j), lower-triangular row-major, diagonal included.

    i = floor(sqrt(1/4 + 2 lam) - 1/2)  computed exactly as
    i = floor((isqrt(8 lam + 1) - 1) / 2), j = lam - i(i+1)/2.
    """
    if isinstance(lam, (int, np.integer)):
        i = (math.isqrt(8 * int(lam) + 1) - 1) // 2
        return i, int(lam) - tri(i)
    lam = lam.astype(jnp.int32) if lam.dtype not in (jnp.int32, jnp.int64) else lam
    i = (isqrt(8 * lam + 1) - 1) // 2
    j = lam - (i * (i + 1)) // 2
    return i, j


def ltm_map_nodiag(lam):
    """Paper eq. (10): strictly-lower triangle (diagonal excluded).

    Equivalent to mapping into row i+1: i = floor(sqrt(1/4+2lam) + 1/2),
    j = lam - i(i-1)/2 with the returned row shifted so (i, j) satisfies
    j < i.
    """
    if isinstance(lam, (int, np.integer)):
        i = (math.isqrt(8 * int(lam) + 1) + 1) // 2
        return i, int(lam) - tri(i - 1)
    i = (isqrt(8 * lam + 1) + 1) // 2
    j = lam - (i * (i - 1)) // 2
    return i, j


def ltm_inverse(i, j):
    """(i, j) -> lambda for the row-major lower-tri enumeration."""
    return tri(i) + j


def ltm_map_float_r(lam, eps: float = 1e-4):
    """Paper's LTM-R: sqrt via x*rsqrt(x) + eps repair (faithful reproduction).

    Exactness only guaranteed for lam within the paper's envelope
    (N < 30,720 with rho=16 => lam < ~1.8M). Kept for the faithful benchmark;
    production code uses ltm_map.
    """
    lamf = jnp.asarray(lam, jnp.float32)
    x = 0.25 + 2.0 * lamf
    sq = x * jax_rsqrt(x)
    i = jnp.floor(sq - 0.5 + eps).astype(jnp.int32)
    j = jnp.asarray(lam, jnp.int32) - (i * (i + 1)) // 2
    return i, j


def jax_rsqrt(x: Array) -> Array:
    return jnp.asarray(1.0, x.dtype) / jnp.sqrt(x)  # lowered to rsqrt on TPU


# ---------------------------------------------------------------------------
# TET — tetrahedral map over the discrete 3D simplex (beyond-paper)
# ---------------------------------------------------------------------------
#
# Domain: {(i, j, k): 0 <= k <= j <= i < n}, |domain| = tet(n).
# Enumeration is "row-major" in the outermost coordinate: all tiles of
# plane i precede plane i+1, and within plane i the (j, k) sub-triangle is
# enumerated by g(mu) with mu = lam - tet(i). Hence
#     lam = tet(i) + tri(j) + k.
# Plane boundaries are contiguous (lam in [tet(i), tet(i+1))), the property
# per-plane accumulation kernels rely on — the 3D analogue of LTM's
# row-major contiguity.


def _tet_row_traced(lam: Array) -> Array:
    """Largest i with tet(i) <= lam, traced (the 3D analogue of the sqrt
    row-finder).

    float32 cbrt(6 lam) gives a candidate within [-1, +2] of the true plane
    over the whole int32 envelope; TET_PROBES_UP/TET_PROBES_DOWN branch-free
    corrections make it exact with margin, mirroring ``_isqrt_traced``.
    Probe arguments are clamped to TET_TRACED_MAX_I so the repair itself
    cannot overflow.
    """
    probe = lambda x: tet(jnp.minimum(x, TET_TRACED_MAX_I))
    c = jnp.floor(jnp.cbrt(6.0 * lam.astype(jnp.float32))).astype(lam.dtype)
    for _ in range(TET_PROBES_UP):
        c = jnp.where(probe(c + 1) <= lam, c + 1, c)
    for _ in range(TET_PROBES_DOWN):
        c = jnp.where(probe(c) > lam, c - 1, c)
    return jnp.minimum(c, TET_TRACED_MAX_I - 1)


def tet_map(lam):
    """lambda -> (i, j, k) over the discrete tetrahedron k <= j <= i < n.

    i = the unique plane with tet(i) <= lam < tet(i+1), found by
    integer-corrected cube root; (j, k) = g(lam - tet(i)) reuses the 2D map.
    Exact: host unboundedly (python ints), traced for planes
    i <= TET_TRACED_EXACT_PLANES (lam <= TET_TRACED_MAX_LAM, int32).
    """
    if isinstance(lam, (int, np.integer)):
        lam = int(lam)
        # host: float cbrt seeds, integer loop repairs (exact for any lam)
        i = round((6 * lam) ** (1.0 / 3.0))
        while tet(i + 1) <= lam:
            i += 1
        while i > 0 and tet(i) > lam:
            i -= 1
        j, k = ltm_map(lam - tet(i))
        return i, j, k
    lam = lam.astype(jnp.int32) if lam.dtype not in (jnp.int32, jnp.int64) else lam
    i = _tet_row_traced(lam)
    j, k = ltm_map(lam - tet(i))
    return i, j, k


def tet_inverse(i, j, k):
    """(i, j, k) -> lambda for the plane-major tetrahedral enumeration."""
    return tet(i) + tri(j) + k


def bb3_map(lam, n):
    """BB-3D: row-major linear index over the full n^3 cube -> (i, j, k).

    The 3D bounding-box baseline's decode (traced or host); the single
    definition shared by Dense3DSchedule, the bb3 scan baseline, and the
    benchmarks. Block (i,j,k) is useful iff k <= j <= i (see bb3_active).
    """
    return lam // (n * n), (lam // n) % n, lam % n


def bb3_active(i, j, k):
    """Whether a BB-3D block lies inside the simplex (traced or host)."""
    if isinstance(i, (int, np.integer)):
        return k <= j <= i
    return (k <= j) & (j <= i)


# ---------------------------------------------------------------------------
# UTM — Avril et al. thread-level upper-triangular map (competitor)
# ---------------------------------------------------------------------------


def utm_map(k, n):
    """UTM: thread index k -> (a, b) in the strictly-upper triangle of n x n.

    a = floor((-(2n+1) + sqrt(4n^2 - 4n - 8k + 1)) / -2), 1-based rows;
    b = (a+1) + k - (a-1)(2n-a)/2.  We return 0-based (a-1, b-1).
    Exact via integer sqrt + repair (the original uses float sqrt + two
    conditionals).
    """
    if isinstance(k, (int, np.integer)):
        k = int(k)
        disc = 4 * n * n - 4 * n - 8 * k + 1
        s = math.isqrt(disc)
        a = int(math.floor((-(2 * n + 1) + s) / -2.0))
        # repair (paper: two conditionals)
        while (a - 1) * (2 * n - a) // 2 > k:
            a -= 1
        while a * (2 * n - a - 1) // 2 <= k:
            a += 1
        b = (a + 1) + k - (a - 1) * (2 * n - a) // 2
        return a - 1, b - 1
    disc = 4 * n * n - 4 * n - 8 * k + 1
    s = isqrt(disc)
    a = (2 * n + 1 - s) // 2
    # repair in both directions (e <= 1)
    lo = lambda a: ((a - 1) * (2 * n - a)) // 2  # first k of row a
    a = jnp.where(lo(a) > k, a - 1, a)
    a = jnp.where(lo(a + 1) <= k, a + 1, a)
    b = (a + 1) + k - lo(a)
    return a - 1, b - 1


def utm_inverse(a, b, n):
    """0-based (a,b), b>a -> k."""
    a1, b1 = a + 1, b + 1
    return (a1 - 1) * (2 * n - a1) // 2 + (b1 - a1 - 1)


# ---------------------------------------------------------------------------
# RB — Jung et al. rectangular-box fold (competitor)
# ---------------------------------------------------------------------------


def rb_grid_shape(n: int) -> Tuple[int, int]:
    """RB folds the triangle into a (n+1)//2 x (n+1) rectangle (even n shown
    in the paper; odd n partitions at floor(n/2)). We use ceil(n/2) rows by
    (n+1) cols which covers both parities with n(n+1)/2 <= rows*cols."""
    return ((n + 1) // 2, n + 1)


def rb_map(x, y, n):
    """RB: folded-rectangle coords (x=col in [0, n], y=row in [0, H)) ->
    lower-tri (i, j), with H = ceil(n/2).

    Jung et al. fold the triangle into a half-size rectangle with O(1) index
    arithmetic (the paper reimplements it arithmetically, no texture). We use
    a coverage-equivalent fold:
      x >  y : (i, j) = (x - 1, y)          -- the complete columns j < H
      x <= y : (i, j) = (H + y, H + x)      -- residual triangle, folded in
    Even n: zero waste (H*(n+1) == T(n)). Odd n: H cells fall outside and are
    filtered at runtime -- O(n) waste, exactly the paper's odd-N partition.
    """
    H = (n + 1) // 2
    below = x > y
    i_b, j_b = x - 1, y
    i_a, j_a = H + y, H + x
    if isinstance(x, (int, np.integer)):
        return (i_b, j_b) if below else (i_a, j_a)
    i = jnp.where(below, i_b, i_a)
    j = jnp.where(below, j_b, j_a)
    return i, j


def rb_valid(x, y, n):
    """Whether rectangle cell maps inside the lower triangle (odd-n edge)."""
    i, j = rb_map(x, y, n)
    if isinstance(x, (int, np.integer)):
        return 0 <= j <= i < n
    return (j >= 0) & (j <= i) & (i < n)


# ---------------------------------------------------------------------------
# REC — Ries et al. recursive partition (competitor)
# ---------------------------------------------------------------------------


def rec_levels(n: int, m: int) -> int:
    """n = m * 2**k; returns k (requires n divisible by m and n/m a pow2)."""
    assert m >= 1 and n >= m and n % m == 0, (
        f"REC needs n = m*2^k with m >= 1, got n={n} m={m}")
    q = n // m
    assert q & (q - 1) == 0, (
        f"REC needs n = m*2^k, got n={n} m={m} (n/m={q} is not a power of 2)")
    return q.bit_length() - 1


def rec_schedule(n: int, m: int):
    """REC: list of passes [(edge_blocks, origins, is_diag)].

    Pass 0 covers the n/m diagonal sub-triangles of side m with BB-style
    m x m squares (Ries's extra diagonal pass; upper halves masked =>
    O(n*m) waste). Level l in [1, k] launches 2**(k-l) square grids of edge
    m*2**(l-1) fully inside the domain (zero waste).
    """
    k = rec_levels(n, m)
    passes = [(m, [(d * m, d * m) for d in range(n // m)], True)]
    for lvl in range(1, k + 1):
        edge = m * (1 << (lvl - 1))
        step = 2 * edge
        origins = [(s * step + edge, s * step) for s in range(n // step)]
        passes.append((edge, origins, False))
    return passes


def rec_total_blocks(n: int, m: int) -> int:
    """Tiles LAUNCHED by REC (diagonal squares count fully: masked waste)."""
    total = 0
    for edge, origins, is_diag in rec_schedule(n, m):
        total += len(origins) * edge * edge
    return total


def rec_useful_blocks(n: int, m: int) -> int:
    return tri(n)


# ---------------------------------------------------------------------------
# BB — bounding box (baseline)
# ---------------------------------------------------------------------------


def bb_map(x, y):
    """BB: identity map; block (x, y) used iff y >= x (lower triangle).

    Paper's optimized BB: discard by *block* coordinates (B_x > B_y => return)
    before any thread-level work."""
    return y, x  # (i, j) = (row=y, col=x)


def bb_active(x, y):
    return y >= x


# ---------------------------------------------------------------------------
# Band (sliding-window) mapping — beyond-paper extension
# ---------------------------------------------------------------------------


def band_blocks(n: int, w: int) -> int:
    """Blocks in the banded lower triangle: rows i keep j in [max(0,i-w+1), i].

    Rows 0..w-2 are triangular (i+1 blocks), rows >= w-1 have w blocks.
    """
    w = min(w, n)
    return tri(w - 1) + (n - (w - 1)) * w


def band_map(lam, w):
    """lambda -> (i, j) for the banded lower triangle, row-major.

    Triangular head for lam < T(w-1) reuses g(lambda); the parallelogram tail
    is a closed-form div/mod. Exact; traced-friendly.
    """
    head = tri(w - 1)
    if isinstance(lam, (int, np.integer)):
        lam = int(lam)
        if lam < head:
            return ltm_map(lam)
        r, c = divmod(lam - head, w)
        i = (w - 1) + r
        return i, i - (w - 1) + c
    i_t, j_t = ltm_map(lam)
    q = (lam - head) // w
    c = (lam - head) - q * w
    i_b = (w - 1) + q
    j_b = i_b - (w - 1) + c
    in_head = lam < head
    return jnp.where(in_head, i_t, i_b), jnp.where(in_head, j_t, j_b)


def band_inverse(i, j, w):
    if i < w - 1:
        return ltm_inverse(i, j)
    return tri(w - 1) + (i - (w - 1)) * w + (j - (i - (w - 1)))


# ---------------------------------------------------------------------------
# Prefix-causal mapping (rectangle ∪ triangle) — beyond-paper, for VLM
# ---------------------------------------------------------------------------


# Prefix-causal (PrefixLM / VLM image-prefix) domain: cells (i, j) with
# (j <= i) OR (j < p) — the full causal lower triangle plus the rectangle of
# bidirectional-prefix columns above the diagonal. Count = T(n) + T(p-1).
def prefix_full_blocks(n: int, p: int) -> int:
    p = min(p, n)
    return tri(n) + tri(p - 1)


def prefix_full_map(lam, n, p):
    """Row-major enumeration of {(i,j): j <= i or j < p}. Row i has
    width(i) = max(i+1, p). Closed form: rows < p-? have width p (flat),
    rows >= p-1 have i+1 (triangular tail). Flat head: rows 0..p-1 width p
    => lam < p*p? No: width(i) = p for i <= p-1, else i+1.
    head = p*p for rows [0, p). For lam >= head: triangular with offset.
    """
    head = p * p
    if isinstance(lam, (int, np.integer)):
        lam = int(lam)
        if lam < head:
            return lam // p, lam % p
        rem = lam - head
        # rows i >= p, width i+1; rem indexes triangle rows shifted by p:
        # sum over rows p..i-1 of (r+1) = T(i) - T(p)
        i = (math.isqrt(8 * (rem + tri(p)) + 1) - 1) // 2
        j = rem + tri(p) - tri(i)
        return i, j
    in_head = lam < head
    i_h, j_h = lam // p, lam % p
    rem = lam - head + tri(p)
    i_t = (isqrt(8 * rem + 1) - 1) // 2
    j_t = rem - (i_t * (i_t + 1)) // 2
    return jnp.where(in_head, i_h, i_t), jnp.where(in_head, j_h, j_t)


# ---------------------------------------------------------------------------
# Column-major triangular maps (for attention BACKWARD dk/dv accumulation)
# ---------------------------------------------------------------------------


def cm_map(lam, n):
    """Column-major lower-tri (diag incl): column j holds rows i in [j, n).

    off(j) = j(2n+1-j)/2; j = floor(((2n+1) - sqrt((2n+1)^2 - 8 lam)) / 2)
    with <=2 integer corrections; i = j + lam - off(j). Needed so backward
    kernels visit all lambdas of a k-column contiguously (dk/dv scratch).
    """
    off = lambda j: (j * (2 * n + 1 - j)) // 2
    if isinstance(lam, (int, np.integer)):
        lam = int(lam)
        disc = (2 * n + 1) ** 2 - 8 * lam
        j = (2 * n + 1 - math.isqrt(disc)) // 2
        while off(j + 1) <= lam:
            j += 1
        while off(j) > lam:
            j -= 1
        return j + lam - off(j), j
    disc = (2 * n + 1) ** 2 - 8 * lam
    j = (2 * n + 1 - isqrt(disc)) // 2
    j = jnp.where(off(j + 1) <= lam, j + 1, j)
    j = jnp.where(off(j) > lam, j - 1, j)
    return j + lam - off(j), j


def cm_inverse(i, j, n):
    return (j * (2 * n + 1 - j)) // 2 + (i - j)


def band_cm_map(lam, n, w):
    """Column-major banded lower-tri: column j holds rows [j, min(j+w, n)).

    Full columns j <= n - w (w rows each) form a flat head; the shrinking
    tail (cols n-w+1 .. n-1) is a reversed triangle mapped via ltm_map on the
    mirrored index. Exact; zero waste.
    """
    # min must stay traced-friendly: the packed backward gathers (n, w)
    # from a runtime member table, so they may be traced scalars here.
    w = min(w, n) if isinstance(w, (int, np.integer)) and \
        isinstance(n, (int, np.integer)) else jnp.minimum(w, n)
    head_cols = n - w + 1
    head = head_cols * w
    if isinstance(lam, (int, np.integer)):
        lam = int(lam)
        if lam < head:
            j, r = divmod(lam, w)
            return j + r, j
        mu = tri(w - 1) - 1 - (lam - head)
        a, b = ltm_map(mu)
        c = (w - 2) - a
        j = head_cols + c
        return j + a - b, j
    j_h = lam // w
    i_h = j_h + (lam - j_h * w)
    mu = tri(w - 1) - 1 - (lam - head)
    a, b = ltm_map(jnp.maximum(mu, 0))
    c = (w - 2) - a
    j_t = head_cols + c
    i_t = j_t + a - b
    in_head = lam < head
    return jnp.where(in_head, i_h, i_t), jnp.where(in_head, j_h, j_t)


def prefix_cm_map(lam, n, p):
    """Column-major prefix-causal: cols j < p hold all n rows; cols j >= p
    hold rows [j, n) (delegates to cm_map on the shifted triangle)."""
    head = p * n
    if isinstance(lam, (int, np.integer)):
        lam = int(lam)
        if lam < head:
            return lam % n, lam // n
        i, j = cm_map(lam - head, n - p)
        return i + p, j + p
    i_h, j_h = lam % n, lam // n
    i_t, j_t = cm_map(jnp.maximum(lam - head, 0), n - p)
    in_head = lam < head
    return (
        jnp.where(in_head, i_h, i_t + p),
        jnp.where(in_head, j_h, j_t + p),
    )
