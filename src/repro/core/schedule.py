"""BlockSchedule — the space-of-computation abstraction.

A BlockSchedule describes how a 1-D (or multi-D) launch grid covers a tile
domain of ``rank`` dimensions (2 for triangles, 3 for tetrahedra). It is
the framework-level generalization of the paper's g(lambda): every schedule
exposes

  * ``num_blocks``        — grid size actually launched,
  * ``index_map(lam)``    — traced lambda -> tile coordinates (rank-tuple),
  * ``host_map(lam)``     — same, eager python ints (for tests/analysis),
  * ``domain_blocks``     — number of *useful* tiles,
  * ``seg_start(lam)``    — traced predicate: first tile of the contiguous
                            run sharing the outermost coordinate (a *row*
                            in 2D, a *plane* in 3D) — accumulator reset,
  * ``seg_end(lam)``      — traced predicate: last tile of that run (emit).

Segment bookkeeping is shared between 2D and 3D through
``segment_origin(i)`` (lambda of the first tile of outer coordinate i);
it is the ONLY row/plane mechanism — kernels needing "last useful tile of
a causal row" derive it from index_map directly.

Schedules provided:
  TriangularSchedule  — the paper's LTM (diagonal included), O(n) waste -> 0.
  TetrahedralSchedule — 3D simplex k <= j <= i (beyond-paper; Navarro et
                        al. arXiv 1606.08881): tet(n) tiles vs BB-3D's n^3.
  DenseSchedule       — BB baseline (2-D bounding box linearized row-major).
  Dense3DSchedule     — BB-3D baseline (full n^3 cube, simplex guard).
  BandSchedule        — sliding-window trapezoid (beyond-paper).
  PrefixSchedule      — prefix-causal (VLM image prefix; beyond-paper).
  RowSchedule         — single query row over n KV tiles (decode-round
                        member: one token vs its valid KV prefix).
  PackedSchedule      — concatenation of mixed ltm/band/prefix members into
                        one 1-D grid for ragged batches (core/packing.py;
                        register via make_schedule("packed", 0, members=...)).
  UTMSchedule         — Avril-style upper-tri map at *block* level (competitor).
  RBSchedule          — Jung rectangular fold (competitor).
  RECSchedule         — Ries recursive partition (competitor, multi-pass).

All maps are exact (integer-corrected sqrt/cbrt), cost O(1) scalar work per
grid step, and are evaluated on the TPU scalar core inside Pallas
index_maps.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax.numpy as jnp

from repro.core import mapping as M


@dataclasses.dataclass(frozen=True)
class BlockSchedule:
    """Base: dense row-major simplex-aware schedule over n-per-side tiles."""

    n: int  # tiles per side of the (square/cubic) bounding box

    rank = 2  # coordinates returned by index_map (2 = (i,j), 3 = (i,j,k))

    # -- interface -----------------------------------------------------------
    @property
    def num_blocks(self) -> int:
        raise NotImplementedError

    @property
    def domain_blocks(self) -> int:
        raise NotImplementedError

    def index_map(self, lam):
        raise NotImplementedError

    def host_map(self, lam: int) -> Tuple[int, ...]:
        raise NotImplementedError

    # -- segment bookkeeping (shared 2D/3D) ----------------------------------
    # A *segment* is the contiguous lambda-run of tiles sharing the
    # outermost coordinate: a row in 2D, a plane in 3D. Kernels use
    # seg_start to reset accumulators and seg_end to emit (flash-attention
    # online state, per-plane 3-body reductions). Schedules whose
    # enumeration is segment-contiguous implement ``segment_origin``; the
    # predicates below then work both traced and host.
    def segment_origin(self, i):
        """lambda of the first tile whose outermost coordinate is i."""
        raise NotImplementedError

    def seg_start(self, lam):
        i = self.index_map(lam)[0]
        return lam == self.segment_origin(i)

    def seg_end(self, lam):
        i = self.index_map(lam)[0]
        return lam == self.segment_origin(i + 1) - 1

    @property
    def waste_fraction(self) -> float:
        return 1.0 - self.domain_blocks / max(self.num_blocks, 1)

    def enumerate_host(self) -> List[Tuple[int, ...]]:
        return [self.host_map(l) for l in range(self.num_blocks)]


# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TriangularSchedule(BlockSchedule):
    """The paper's LTM: 1-D grid of T(n) tiles, g(lambda) index map."""

    include_diagonal: bool = True

    @property
    def num_blocks(self) -> int:
        return M.tri(self.n) if self.include_diagonal else M.tri(self.n - 1)

    @property
    def domain_blocks(self) -> int:
        return self.num_blocks

    def index_map(self, lam):
        # trace-time guard against the certified traced-isqrt envelope
        # (constant derived + certified by repro.analysis.envelope)
        assert self.num_blocks - 1 <= M.LTM_TRACED_MAX_LAM, (
            f"n={self.n} launches {self.num_blocks} blocks, past the "
            f"ltm_map int32 envelope (max lam {M.LTM_TRACED_MAX_LAM})")
        return M.ltm_map(lam) if self.include_diagonal else M.ltm_map_nodiag(lam)

    def host_map(self, lam: int):
        return (
            M.ltm_map(int(lam))
            if self.include_diagonal
            else M.ltm_map_nodiag(int(lam))
        )

    def segment_origin(self, i):
        return M.tri(i) if self.include_diagonal else M.tri(i - 1)


@dataclasses.dataclass(frozen=True)
class TetrahedralSchedule(BlockSchedule):
    """3D simplex {(i,j,k): k <= j <= i < n}: 1-D grid of tet(n) tiles.

    The 3D analogue of the paper's LTM — lambda -> (i,j,k) via the
    integer-corrected cube root (mapping.tet_map). BB-3D launches n^3 and
    wastes ~5/6 of it; this launches exactly the domain. Plane boundaries
    are contiguous (segment bookkeeping inherited from the base)."""

    rank = 3

    @property
    def num_blocks(self) -> int:
        return M.tet(self.n)

    @property
    def domain_blocks(self) -> int:
        return self.num_blocks

    def index_map(self, lam):
        # trace-time guard against the certified traced-cbrt envelope
        assert self.num_blocks - 1 <= M.TET_TRACED_MAX_LAM, (
            f"n={self.n} launches {self.num_blocks} blocks, past the "
            f"tet_map int32 envelope (max lam {M.TET_TRACED_MAX_LAM})")
        return M.tet_map(lam)

    def host_map(self, lam: int):
        return M.tet_map(int(lam))

    def segment_origin(self, i):
        return M.tet(i)


@dataclasses.dataclass(frozen=True)
class Dense3DSchedule(BlockSchedule):
    """BB-3D baseline: full n^3 cube row-major; tiles outside the simplex
    k <= j <= i are dead work (guarded out by ``active``)."""

    rank = 3
    causal: bool = True  # guard to the simplex; False = full cube

    @property
    def num_blocks(self) -> int:
        return self.n ** 3

    @property
    def domain_blocks(self) -> int:
        return M.tet(self.n) if self.causal else self.n ** 3

    def index_map(self, lam):
        return M.bb3_map(lam, self.n)

    def host_map(self, lam: int):
        return M.bb3_map(int(lam), self.n)

    def active(self, lam):
        i, j, k = self.index_map(lam)
        if not self.causal:
            return True if isinstance(i, int) else jnp.ones_like(i, bool)
        return M.bb3_active(i, j, k)

    def segment_origin(self, i):
        return i * self.n * self.n


@dataclasses.dataclass(frozen=True)
class DenseSchedule(BlockSchedule):
    """BB baseline: n*n tiles row-major; upper-tri tiles are dead work.

    causal=True marks upper tiles inactive (the paper's optimized-BB block
    filter); causal=False is a plain full-rectangle schedule."""

    causal: bool = True

    @property
    def num_blocks(self) -> int:
        return self.n * self.n

    @property
    def domain_blocks(self) -> int:
        return M.tri(self.n) if self.causal else self.n * self.n

    def index_map(self, lam):
        return lam // self.n, lam % self.n

    def host_map(self, lam: int):
        return int(lam) // self.n, int(lam) % self.n

    def active(self, lam):
        i, j = self.index_map(lam)
        return (j <= i) if self.causal else (j == j)

    def segment_origin(self, i):
        return i * self.n


@dataclasses.dataclass(frozen=True)
class BandSchedule(BlockSchedule):
    """Sliding-window causal band: row i keeps j in [max(0, i-w+1), i].

    Beyond-paper: closed-form trapezoid mapping (triangular head + div/mod
    parallelogram tail). Zero waste."""

    w: int = 1  # band width in tiles (>=1); w >= n degrades to triangular

    @property
    def num_blocks(self) -> int:
        return M.band_blocks(self.n, min(self.w, self.n))

    @property
    def domain_blocks(self) -> int:
        return self.num_blocks

    def index_map(self, lam):
        return M.band_map(lam, min(self.w, self.n))

    def host_map(self, lam: int):
        return M.band_map(int(lam), min(self.w, self.n))

    def segment_origin(self, i):
        w = min(self.w, self.n)
        head = M.tri(w - 1)
        flat = head + (i - (w - 1)) * w
        if isinstance(i, int):
            return M.tri(i) if i < w - 1 else flat
        return jnp.where(i < w - 1, M.tri(i), flat)


@dataclasses.dataclass(frozen=True)
class RowSchedule(BlockSchedule):
    """A single query row over n KV tiles: the 1 x n rectangle {(0, j)}.

    The decode-round member (beyond-paper): one new token attending its own
    valid KV prefix of n tiles. Degenerate but load-bearing — a
    PackedSchedule of RowSchedule members IS one packed mixed-position
    decode round (PackedSchedule.decode_round), the single-token analogue
    of the ragged-prefill concatenation. ``n`` is the KV extent in tiles
    (the row length), not a square side."""

    @property
    def num_blocks(self) -> int:
        return self.n

    @property
    def domain_blocks(self) -> int:
        return self.n

    def index_map(self, lam):
        return lam * 0, lam  # (0, lam), traced-or-host polymorphic

    def host_map(self, lam: int):
        return 0, int(lam)

    def segment_origin(self, i):
        return i * self.n  # row 0 starts at 0; sentinel row 1 at n (seg_end)


@dataclasses.dataclass(frozen=True)
class PrefixSchedule(BlockSchedule):
    """Prefix-causal: causal triangle + bidirectional prefix rectangle.

    Domain {(i, j): j <= i or j < p}. Rows are row-major with width
    max(i+1, p); closed-form flat-head + triangular-tail map."""

    p: int = 0  # prefix width in tiles

    @property
    def num_blocks(self) -> int:
        return M.prefix_full_blocks(self.n, self.p)

    @property
    def domain_blocks(self) -> int:
        return self.num_blocks

    def index_map(self, lam):
        return M.prefix_full_map(lam, self.n, min(self.p, self.n))

    def host_map(self, lam: int):
        return M.prefix_full_map(int(lam), self.n, min(self.p, self.n))

    def segment_origin(self, i):
        # row widths are max(i+1, p): flat head of p-wide rows, then
        # triangular tail (matches mapping.prefix_full_map's enumeration)
        p = min(self.p, self.n)
        tail = p * p + M.tri(i) - M.tri(p)
        if isinstance(i, int):
            return i * p if i < p else tail
        return jnp.where(i < p, i * p, tail)


@dataclasses.dataclass(frozen=True)
class UTMSchedule(BlockSchedule):
    """Avril et al. upper-triangular map lifted to block level (competitor).

    Maps lam over the strictly-upper triangle then transposes to lower
    (the paper notes UTM solves lower domains 'via transposition'). Diagonal
    handled by a dedicated tail segment (UTM excludes it natively)."""

    @property
    def num_blocks(self) -> int:
        return M.tri(self.n)

    @property
    def domain_blocks(self) -> int:
        return M.tri(self.n)

    def index_map(self, lam):
        strict = M.tri(self.n - 1)
        in_tail = lam >= strict
        a, b = M.utm_map(jnp.minimum(lam, strict - 1), self.n)
        d = lam - strict
        i = jnp.where(in_tail, d, b)
        j = jnp.where(in_tail, d, a)
        return i, j

    def host_map(self, lam: int):
        strict = M.tri(self.n - 1)
        if lam >= strict:
            d = lam - strict
            return d, d
        a, b = M.utm_map(int(lam), self.n)
        return b, a  # transpose upper -> lower


@dataclasses.dataclass(frozen=True)
class RBSchedule(BlockSchedule):
    """Jung rectangular fold at block level (competitor). Grid is the folded
    rectangle ceil(n/2) x (n+1); odd-n leaves O(n) invalid cells."""

    @property
    def grid_shape(self) -> Tuple[int, int]:
        return M.rb_grid_shape(self.n)

    @property
    def num_blocks(self) -> int:
        h, w = self.grid_shape
        return h * w

    @property
    def domain_blocks(self) -> int:
        return M.tri(self.n)

    def index_map(self, lam):
        h, w = self.grid_shape
        y, x = lam // w, lam % w
        return M.rb_map(x, y, self.n)

    def host_map(self, lam: int):
        h, w = self.grid_shape
        y, x = int(lam) // w, int(lam) % w
        return M.rb_map(x, y, self.n)

    def active(self, lam):
        h, w = self.grid_shape
        y, x = lam // w, lam % w
        return M.rb_valid(x, y, self.n)

    def host_active(self, lam: int) -> bool:
        h, w = self.grid_shape
        y, x = int(lam) // w, int(lam) % w
        return bool(M.rb_valid(x, y, self.n))


@dataclasses.dataclass(frozen=True)
class RECSchedule(BlockSchedule):
    """Ries recursive partition (competitor): k+1 passes, each a dense square
    multi-grid. Exposed as a list of per-pass DenseSchedules with origins;
    host-only (multi-pass launches do not fit a single pallas grid)."""

    m: int = 1  # base tile multiple; requires n = m * 2**k

    def passes(self):
        return M.rec_schedule(self.n, self.m)

    @property
    def num_blocks(self) -> int:
        return M.rec_total_blocks(self.n, self.m)

    @property
    def domain_blocks(self) -> int:
        return M.tri(self.n)

    def enumerate_host(self):
        """Useful tiles only (diagonal squares keep the lower halves)."""
        out = []
        for edge, origins, is_diag in self.passes():
            for oi, oj in origins:
                for a in range(edge):
                    for b in range(a + 1 if is_diag else edge):
                        out.append((oi + a, oj + b))
        return out

    def host_map(self, lam: int):
        return self.enumerate_host()[lam]


def make_schedule(kind: str, n: int, **kw) -> BlockSchedule:
    if kind == "packed":
        # Packed multi-domain grid (core/packing.py): members is the list of
        # rank-2 schedules to concatenate; n is derived, pass 0 (or the
        # summed member rows) for uniformity with the other kinds.
        from repro.core.packing import PackedSchedule

        members = tuple(kw.pop("members"))
        total = sum(m.n for m in members)
        if n not in (0, total):
            raise ValueError(f"packed n must be 0 or {total}, got {n}")
        return PackedSchedule(n=total, members=members, **kw)
    if kind == "mixed":
        # Continuous-batching fused step (core/packing.py mixed_step):
        # prefill_members are the newly admitted prompts' rank-2 schedules,
        # kv_tiles the live decode slots' KV prefixes in tiles; n is
        # derived exactly like "packed".
        from repro.core.packing import PackedSchedule

        sched = PackedSchedule.mixed_step(kw.pop("prefill_members", ()),
                                          kw.pop("kv_tiles", ()))
        if kw:
            raise TypeError(f"unexpected mixed kwargs: {sorted(kw)}")
        if n not in (0, sched.n):
            raise ValueError(f"mixed n must be 0 or {sched.n}, got {n}")
        return sched
    kinds = {
        "ltm": TriangularSchedule,
        "triangular": TriangularSchedule,
        "tet": TetrahedralSchedule,
        "tetrahedral": TetrahedralSchedule,
        "bb": DenseSchedule,
        "dense": DenseSchedule,
        "bb3": Dense3DSchedule,
        "dense3d": Dense3DSchedule,
        "band": BandSchedule,
        "prefix": PrefixSchedule,
        "row": RowSchedule,
        "utm": UTMSchedule,
        "rb": RBSchedule,
        "rec": RECSchedule,
    }
    return kinds[kind](n=n, **kw)
