"""PackedSchedule — one 1-D grid over the CONCATENATION of simplex domains.

The paper's g(lambda) removes the O(n^2) wasted blocks of a bounding-box
launch for ONE triangular domain. A serving system faces MANY triangular
domains of different sizes at once (a ragged prefill batch: R prompts, each
its own causal triangle). The obvious options are R separate launches
(per-launch overhead, no cross-request occupancy) or one launch padded to
the largest member (O(R * n_max^2) blocks, mostly waste for mixed sizes).
This module provides the third: concatenate the members' block enumerations
into a single 1-D grid of exactly ``sum_r num_blocks_r`` steps, and map the
packed lambda back to (request, i, j) with O(log R) scalar work — the
natural ragged-batch extension of the paper's map, in the spirit of Navarro
et al.'s later non-linear block maps (arXiv 1609.01490).

Offset-table layout
-------------------
For members m_0 .. m_{R-1} the schedule precomputes two cumulative tables,
both of length R + 1 and strictly derived from the members:

  ``offsets[r]``     = sum_{s < r} m_s.num_blocks   (block offsets)
                       offsets[R] == num_blocks == total grid size.
                       Member r owns the half-open lambda range
                       [offsets[r], offsets[r+1]); ranges are contiguous
                       and ascending, so ``request_of(lam)`` is the
                       largest r with offsets[r] <= lam — found by a
                       fixed-trip-count binary search (ceil(log2 R) steps,
                       branch-free, scalar-core friendly).
  ``row_offsets[r]`` = sum_{s < r} m_s.n            (tile-ROW offsets)
                       Members are also concatenated along the tile axis of
                       the packed operand: member r's tile row i lives at
                       packed row ``row_offsets[r] + i``. Kernels turn the
                       member-local (i, j) into packed-operand block
                       coordinates with this table.

Delegation without branching
----------------------------
After the binary search finds r, the member map must run on the local
lambda. Instead of tracing R different member maps and selecting (O(R)
jaxpr growth), every supported member kind is normalized into ONE closed
form parameterized by integers gathered from per-member tables:

  * TriangularSchedule(n)      ->  band family, w = n  (band_map(lam, n)
                                   degenerates to g(lambda) exactly)
  * BandSchedule(n, w)         ->  band family, w = min(w, n)
  * PrefixSchedule(n, p), p>0  ->  prefix family (flat head + tri tail)
  * PrefixSchedule(n, p=0)     ->  band family, w = n (pure triangle)
  * RowSchedule(n)             ->  prefix family, p = n (the member owns
                                   only n lambdas, so the map never leaves
                                   the flat head's row 0 — a 1 x n
                                   rectangle, the decode-round member)

``band_map`` and ``prefix_full_map`` (core.mapping) are already exact for
traced parameters, so the traced index_map is: binary search (O(log R)) +
two O(1) closed-form evaluations + one select. Host calls delegate to the
members directly (python ints, exact unboundedly).

Zero interior waste: num_blocks == domain_blocks == sum of member domains;
the only masking left is the paper's O(n) intra-diagonal-tile kind, inside
each member.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import mapping as M
from repro.core.schedule import (
    BandSchedule,
    BlockSchedule,
    PrefixSchedule,
    RowSchedule,
    TriangularSchedule,
)

# Member kinds the parametric (branch-free traced) delegation covers.
SUPPORTED_MEMBERS = (TriangularSchedule, BandSchedule, PrefixSchedule,
                     RowSchedule)


def _member_params(m: BlockSchedule) -> Tuple[int, int, int]:
    """Normalize a member into (n, w, p) for the unified two-family map.

    w is the band-family width in TILES (w == n for full triangles), p the
    prefix-family width in TILES (p == 0 selects the band family).
    """
    if isinstance(m, RowSchedule):
        # Single query row over n KV tiles: literally the first row of a
        # full-width prefix member ((n, n, n)); locals never leave row 0
        # because the member owns only n lambdas.
        return m.n, m.n, m.n
    if isinstance(m, BandSchedule):
        return m.n, min(m.w, m.n), 0
    if isinstance(m, PrefixSchedule):
        p = min(m.p, m.n)
        if p == 0:  # pure triangle; band family handles it exactly
            return m.n, m.n, 0
        return m.n, m.n, p
    if isinstance(m, TriangularSchedule):
        if not m.include_diagonal:
            raise ValueError(
                "PackedSchedule members must include the diagonal "
                "(attention tiles always have a causal diagonal)")
        return m.n, m.n, 0
    raise TypeError(
        f"unsupported member schedule {type(m).__name__}; supported: "
        + ", ".join(t.__name__ for t in SUPPORTED_MEMBERS))


# ---------------------------------------------------------------------------
# Table-parameterized traced primitives. ``starts`` / the per-member
# parameter vectors may be ANY scalar-indexable: a baked jnp constant array
# (host-built schedules) or a Pallas SMEM scalar-prefetch Ref (kernels,
# where index_maps must not capture constants). Only scalar indexing is
# used, so both work unchanged.
# ---------------------------------------------------------------------------


def request_from_starts(lam, starts, num_requests: int):
    """Largest r with starts[r] <= lam: fixed-trip-count binary search.

    ceil(log2 R) probes, branch-free (where-selects), scalar-core friendly.
    starts must be ascending with starts[0] == 0 and lam < total blocks.
    """
    # zeros_like(lam): keep lam's shape so a single-member schedule (R = 1,
    # zero search trips) still returns r broadcast against vectorized lam
    lo = jnp.zeros_like(jnp.asarray(lam), jnp.int32)
    hi = jnp.asarray(num_requests - 1, jnp.int32)
    for _ in range((num_requests - 1).bit_length()):
        mid = (lo + hi + 1) // 2
        take = starts[mid] <= lam
        lo = jnp.where(take, mid, lo)
        hi = jnp.where(take, hi, mid - 1)
    return lo


def member_map_params(local, n_r, w_r, p_r):
    """Member-local lambda -> (i, j) from normalized (n, w, p) params.

    Both closed forms are evaluated (O(1) each) and selected — no R-way
    branching. p_r is clamped to >= 1 for the prefix evaluation so its
    flat-head division is defined; the select ignores it when p_r == 0.
    """
    bi, bj = M.band_map(local, w_r)
    pi, pj = M.prefix_full_map(local, n_r, jnp.maximum(p_r, 1))
    is_p = p_r > 0
    return jnp.where(is_p, pi, bi), jnp.where(is_p, pj, bj)


def first_col_params(i, w_r):
    """First j of row i for a (w, p)-normalized member (band left edge;
    0 for unbanded rows). The kernels' accumulator-reset predicate."""
    return jnp.maximum(0, i - w_r + 1)


def last_col_params(i, p_r):
    """Last j of row i (prefix rows are at least p wide; i otherwise).
    The kernels' emit predicate."""
    return jnp.maximum(i, p_r - 1)


def member_cm_map_params(local, n_r, w_r, p_r):
    """COLUMN-major member-local lambda -> (i, j) from normalized (n, w, p).

    The backward dk/dv kernels enumerate each member's domain column-major
    so per-column accumulators stay resident across the member's rows; this
    is the cm counterpart of ``member_map_params`` (same two-family select,
    same O(1) closed forms — core.mapping's band_cm_map / prefix_cm_map).
    Both enumerations cover the same domain, so the packed ``offsets``
    table is shared between directions."""
    bi, bj = M.band_cm_map(local, n_r, w_r)
    pi, pj = M.prefix_cm_map(local, n_r, jnp.maximum(p_r, 1))
    is_p = p_r > 0
    return jnp.where(is_p, pi, bi), jnp.where(is_p, pj, bj)


def cm_first_row_params(j, p_r):
    """First i of column j (prefix columns < p span every row; i == j
    otherwise). The backward kernels' dk/dv accumulator-reset predicate."""
    return jnp.where(j < p_r, 0, j)


def cm_last_row_params(j, n_r, w_r):
    """Last i of column j (band columns end w - 1 rows below the diagonal;
    unbanded members have w == n so this is n - 1). The dk/dv emit
    predicate."""
    return jnp.minimum(j + w_r - 1, n_r - 1)


def segment_origin_params(i, w_r, p_r):
    """Member-local lambda of the first tile of row i (both families)."""
    band = jnp.where(i < w_r - 1, M.tri(jnp.minimum(i, w_r - 1)),
                     M.tri(w_r - 1) + (i - (w_r - 1)) * w_r)
    pre = jnp.where(i < p_r, i * p_r, p_r * p_r + M.tri(i) - M.tri(p_r))
    return jnp.where(p_r > 0, pre, band)


def _member_inverse(m: BlockSchedule, i: int, j: int) -> int:
    """(i, j) -> member-local lambda (host ints; the testing inverse)."""
    n, w, p = _member_params(m)
    if p:  # prefix family: rows < p are p wide, then triangular tail
        return i * p + j if i < p else p * p + M.tri(i) - M.tri(p) + j
    if i < w - 1:
        return M.tri(i) + j
    return M.tri(w - 1) + (i - (w - 1)) * w + (j - (i - (w - 1)))


@dataclasses.dataclass(frozen=True)
class PackedSchedule(BlockSchedule):
    """Concatenation of rank-2 member schedules into one 1-D grid.

    ``n`` is the packed tile-axis size (sum of member n): the packed
    operand has ``n * block`` rows when every member uses the same block
    edge. index_map returns rank-3 coordinates (request, i, j) with (i, j)
    member-local.
    """

    members: Tuple[BlockSchedule, ...] = ()

    rank = 3  # (request, i, j)

    def __post_init__(self):
        if not self.members:
            raise ValueError("PackedSchedule needs at least one member")
        for m in self.members:
            _member_params(m)  # raises on unsupported kinds
        total_rows = sum(m.n for m in self.members)
        if self.n != total_rows:
            raise ValueError(
                f"n={self.n} must equal the summed member rows {total_rows}")

    @classmethod
    def from_members(cls, members) -> "PackedSchedule":
        members = tuple(members)
        return cls(n=sum(m.n for m in members), members=members)

    @classmethod
    def decode_round(cls, kv_tiles) -> "PackedSchedule":
        """One packed mixed-position DECODE round.

        kv_tiles[r] is active slot r's valid KV prefix in tiles; member r
        becomes the RowSchedule over it (its one new token vs its own KV).
        num_blocks == sum_r kv_tiles_r — the round's exact tile count,
        against the lockstep decode's R * max_r kv_tiles_r pad-to-max:
        the same O(pad) -> 0 step the paper's g(lambda) takes for one
        triangle, applied to the decode batch."""
        return cls.from_members(RowSchedule(n=int(t)) for t in kv_tiles)

    @classmethod
    def mixed_step(cls, prefill_members, kv_tiles) -> "PackedSchedule":
        """One CONTINUOUS-BATCHING engine step: newly admitted prompts
        (triangular/band/prefix members) AND live decode slots (row
        members) concatenated into a single 1-D grid.

        This is the fused-step schedule kind ("mixed" in the registry):
        the admit round and the decode round that today cost two grids
        collapse into one launch of exactly
        ``sum_r prefill_blocks_r + sum_s kv_tiles_s`` steps. Prefill
        members come first (their tile rows own the packed operand), the
        decode row members follow — the fused kernel routes each member's
        output by kind (prefill members splice KV + emit last-row logits,
        decode rows emit logits against the KV cache)."""
        prefill_members = tuple(prefill_members)
        for m in prefill_members:
            if isinstance(m, RowSchedule):
                raise ValueError(
                    "mixed_step prefill members must be triangular/band/"
                    "prefix (row members are the decode half)")
        decode = tuple(RowSchedule(n=int(t)) for t in kv_tiles)
        if not prefill_members and not decode:
            raise ValueError("mixed_step needs at least one member")
        return cls.from_members(prefill_members + decode)

    # -- static tables -------------------------------------------------------
    @property
    def num_requests(self) -> int:
        return len(self.members)

    @property
    def offsets(self) -> Tuple[int, ...]:
        """Cumulative BLOCK offsets, length R+1 (see module docstring)."""
        offs = [0]
        for m in self.members:
            offs.append(offs[-1] + m.num_blocks)
        return tuple(offs)

    @property
    def row_offsets(self) -> Tuple[int, ...]:
        """Cumulative tile-ROW offsets, length R+1."""
        offs = [0]
        for m in self.members:
            offs.append(offs[-1] + m.n)
        return tuple(offs)

    def _tables(self):
        """(starts, rows, n, w, p) int32 arrays gathered by request id."""
        prm = [_member_params(m) for m in self.members]
        return (
            jnp.asarray(self.offsets[:-1], jnp.int32),
            jnp.asarray(self.row_offsets[:-1], jnp.int32),
            jnp.asarray([q[0] for q in prm], jnp.int32),
            jnp.asarray([q[1] for q in prm], jnp.int32),
            jnp.asarray([q[2] for q in prm], jnp.int32),
        )

    # -- interface -----------------------------------------------------------
    @property
    def num_blocks(self) -> int:
        return self.offsets[-1]

    @property
    def domain_blocks(self) -> int:
        return sum(m.domain_blocks for m in self.members)

    # -- request lookup ------------------------------------------------------
    def host_request(self, lam: int) -> int:
        """Largest r with offsets[r] <= lam (host ints)."""
        return bisect.bisect_right(self.offsets, int(lam)) - 1

    def request_of(self, lam):
        """Traced O(log R) branch-free binary search over ``offsets``."""
        return request_from_starts(lam, self._tables()[0],
                                   self.num_requests)

    # -- the packed map ------------------------------------------------------
    def index_map(self, lam):
        """lambda -> (request, i, j); (i, j) member-local, traced."""
        starts, _, n_t, w_t, p_t = self._tables()
        r = self.request_of(lam)
        local = lam - starts[r]
        i, j = member_map_params(local, n_t[r], w_t[r], p_t[r])
        return r, i, j

    def host_map(self, lam: int) -> Tuple[int, int, int]:
        r = self.host_request(int(lam))
        i, j = self.members[r].host_map(int(lam) - self.offsets[r])
        return r, i, j

    def pack_lambda(self, r: int, i: int, j: int) -> int:
        """(request, i, j) -> packed lambda (host round-trip inverse)."""
        return self.offsets[r] + _member_inverse(self.members[r], i, j)

    # -- packed-operand coordinates ------------------------------------------
    def packed_rows(self, lam):
        """lambda -> (q_row, k_row) block coords into the packed tile axis
        (row_offsets[r] + member-local i / j), traced or host."""
        if isinstance(lam, (int, np.integer)):
            r, i, j = self.host_map(lam)
            base = self.row_offsets[r]
            return base + i, base + j
        _, rows, _, _, _ = self._tables()
        r, i, j = self.index_map(lam)
        return rows[r] + i, rows[r] + j

    # -- per-request row bounds (kernel accumulator reset / emit) ------------
    def first_col(self, r, i):
        """First j of member r's row i (band family: sliding left edge)."""
        return first_col_params(i, self._tables()[3][r])

    def last_col(self, r, i):
        """Last j of member r's row i (prefix family: >= p - 1)."""
        return last_col_params(i, self._tables()[4][r])

    def host_first_col(self, r: int, i: int) -> int:
        _, w, _ = _member_params(self.members[r])
        return max(0, i - w + 1)

    def host_last_col(self, r: int, i: int) -> int:
        _, _, p = _member_params(self.members[r])
        return max(i, p - 1)

    # -- segment bookkeeping -------------------------------------------------
    # A segment is one contiguous row of one member: seg_start resets the
    # online-softmax accumulator, seg_end emits. Parametric segment_origin
    # covers both families with traced table gathers.
    def seg_start(self, lam):
        starts, _, _, w_t, p_t = self._tables()
        r, i, _ = self.index_map(lam)
        return lam == starts[r] + segment_origin_params(i, w_t[r], p_t[r])

    def seg_end(self, lam):
        starts, _, _, w_t, p_t = self._tables()
        r, i, _ = self.index_map(lam)
        so = segment_origin_params(i + 1, w_t[r], p_t[r])
        return lam == starts[r] + so - 1

    def host_seg_start(self, lam: int) -> bool:
        r, i, j = self.host_map(lam)
        return j == self.host_first_col(r, i)

    def host_seg_end(self, lam: int) -> bool:
        r, i, j = self.host_map(lam)
        return j == self.host_last_col(r, i)

    # -- host enumeration ----------------------------------------------------
    def enumerate_host(self) -> List[Tuple[int, int, int]]:
        return [self.host_map(l) for l in range(self.num_blocks)]


def padded_bb_blocks(members) -> int:
    """Blocks a pad-to-max bounding-box launch would issue for the same
    batch: R * n_max^2 — the baseline bench_packed compares against."""
    n_max = max(m.n for m in members)
    return len(tuple(members)) * n_max * n_max
