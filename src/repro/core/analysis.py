"""Space-of-computation accounting (paper §II-B reproduced structurally).

On CPU we cannot measure Kepler wall-clock; the structural analogues are:
  * launched vs useful blocks per strategy (paper Fig. 3 right),
  * the improvement-factor model I = 2*beta/tau (paper eq. 11-15) with the
    block-ratio as the hardware-independent component,
  * per-schedule grid-step counts that feed the roofline compute term.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core import mapping as M


@dataclasses.dataclass(frozen=True)
class StrategyStats:
    name: str
    launched: int
    useful: int
    wasted: int
    waste_fraction: float
    block_ratio_vs_bb: float  # BB launched / this launched (paper's I at k=1)


def strategy_stats(n: int, band_w: int | None = None, rec_m: int = 1) -> Dict[str, StrategyStats]:
    """Launched/useful/wasted blocks for every strategy at n tiles/side."""
    bb = n * n
    out: Dict[str, StrategyStats] = {}

    def add(name: str, launched: int, useful: int):
        out[name] = StrategyStats(
            name=name,
            launched=launched,
            useful=useful,
            wasted=launched - useful,
            waste_fraction=1.0 - useful / max(launched, 1),
            block_ratio_vs_bb=bb / max(launched, 1),
        )

    t = M.tri(n)
    add("bb", bb, t)
    add("ltm", t, t)
    add("utm", t, t)
    h, w = M.rb_grid_shape(n)
    # Every lower-triangle cell appears exactly once in the fold (below-
    # diagonal cells contribute H*n - tri(H-1), folded-in cells tri(n - H);
    # the two sum to tri(n) for both parities), so the valid count is
    # closed-form — pinned against the O(n^2) host_active loop in
    # tests/test_analysis_lint.py.
    add("rb", h * w, M.tri(n))
    try:
        add("rec", M.rec_total_blocks(n, rec_m), t)
    except AssertionError:
        pass  # n not m*2^k
    if band_w is not None:
        b = M.band_blocks(n, band_w)
        add("band", b, b)
        add("bb_band", bb, b)
    return out


def improvement_factor(n: int, k_cost: float = 1.0) -> float:
    """Paper eq. (11): I = beta*n^2 / (tau * T(n)) with tau = k*beta.

    k_cost is the mapping-overhead ratio k = tau/beta. The paper measures
    k ~ 1.74 on Kepler (I ~ 1.15); on TPU the index_map runs on the scalar
    core overlapped with DMA, so the effective k -> 1 and I -> the pure
    block ratio n^2/T(n) -> 2.
    """
    return (n * n) / (k_cost * M.tri(n))


def flops_saved_fraction(n: int, band_w: int | None = None) -> float:
    """Fraction of BB tile-FLOPs eliminated by the domain-exact schedule."""
    useful = M.band_blocks(n, band_w) if band_w else M.tri(n)
    return 1.0 - useful / (n * n)
