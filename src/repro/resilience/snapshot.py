"""Crash-safe engine snapshot / restore.

``snapshot(engine)`` captures EVERYTHING the serving loop's determinism
depends on — the slot table (per-slot Request, pos, last token, remaining
budget), the KV cache, the queue and finished lists, the RNG key (via
jax.random.key_data) and the engine clock reading — into host memory.
``restore(snap)`` rebuilds a fresh Engine from the snapshot's recorded
ctor kwargs and overwrites its state, so ``Engine.restore(snap).run()``
resumes TOKEN-IDENTICALLY to the engine that never stopped (greedy
decode; sampled decode resumes on the identical key stream). Request
ages survive the move between clocks: submitted_at is rebased so each
request's elapsed age — what deadlines measure — is preserved even when
a VirtualClock run is restored onto the wall clock or vice versa.

Persistence (``to_dir`` / ``from_dir``) follows train/checkpoint.py's
crash-safety argument: everything is written into ``<dir>.tmp`` and
os.replace'd into place, so a crash mid-save leaves only a .tmp the
loader ignores. Arrays land in one flat .npz (dot-joined tree paths —
params and cache are pure nested dicts, so paths rebuild the tree
exactly); non-numpy-native dtypes (bfloat16) are stored as their exact
float32 widening and cast back on load. No pickle: the format is
inspectable and version-diffable like the training checkpoints.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

SNAPSHOT_VERSION = "repro.resilience.snapshot/v1"

# dtypes np.savez round-trips natively; anything else (bfloat16, fp8) is
# widened to float32 (exact for <=32-bit floats) and cast back on load.
_NATIVE = ("float64", "float32", "float16", "int64", "int32", "int16",
           "int8", "uint64", "uint32", "uint16", "uint8", "bool")


@dataclasses.dataclass
class EngineSnapshot:
    """Host-side image of a serving engine (see module docstring)."""

    cfg: ModelConfig
    params: dict
    cache: dict
    init_kw: dict
    pos: np.ndarray
    last_tok: np.ndarray
    remaining: np.ndarray
    key_data: np.ndarray
    clock_now: float
    admit_round_idx: int
    decode_round_idx: int
    quarantined: Dict[int, int]
    slot_req: List[Optional[dict]]
    queue: List[dict]
    finished: List[dict]
    # -- fused-mode state (PR 9 seam; defaults keep v1 files loadable) --
    # the EFFECTIVE step mode at capture (init_kw carries the REQUESTED
    # one; they differ only when a recurrent arch forced "split").
    step_mode: str = "split"
    # auto_cost_measure's per-mode seconds/tile EMA — without it a
    # restored auto engine re-learns the crossover from scratch.
    mode_cost: Dict[str, Optional[float]] = dataclasses.field(
        default_factory=dict)
    # distinct fused packing templates compiled so far, as JSON-safe
    # [[padded lens...], capacity] pairs (Engine.fused_templates).
    fused_templates: List = dataclasses.field(default_factory=list)


def _req_to_dict(req) -> dict:
    return {"uid": int(req.uid), "prompt": [int(t) for t in req.prompt],
            "max_new": int(req.max_new), "out": list(req.out),
            "done": bool(req.done), "status": req.status,
            "deadline_s": req.deadline_s,
            "submitted_at": float(req.submitted_at),
            "replays": int(req.replays), "error": req.error}


def _req_from_dict(d: dict, shift: float):
    from repro.serve.engine import Request

    return Request(uid=d["uid"], prompt=np.asarray(d["prompt"], np.int32),
                   max_new=d["max_new"], out=list(d["out"]),
                   done=d["done"], status=d["status"],
                   deadline_s=d["deadline_s"],
                   submitted_at=d["submitted_at"] + shift,
                   replays=d["replays"], error=d["error"])


def _host(tree):
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)


def snapshot(engine) -> EngineSnapshot:
    """Capture ``engine`` into host memory (the engine keeps running)."""
    return EngineSnapshot(
        cfg=engine.cfg,
        params=_host(engine.params),
        cache=_host(engine.cache),
        init_kw=dict(engine._init_kw),
        pos=np.asarray(engine.pos),
        last_tok=np.asarray(engine.last_tok),
        remaining=np.asarray(engine.remaining).copy(),
        key_data=np.asarray(jax.random.key_data(engine.key)),
        clock_now=float(engine.clock()),
        admit_round_idx=engine._admit_round_idx,
        decode_round_idx=engine._decode_round_idx,
        quarantined=dict(engine.quarantined),
        slot_req=[None if r is None else _req_to_dict(r)
                  for r in engine.slot_req],
        queue=[_req_to_dict(r) for r in engine.queue],
        finished=[_req_to_dict(r) for r in engine.finished],
        step_mode=engine.step_mode,
        mode_cost=dict(engine._mode_cost),
        fused_templates=sorted(
            [[int(p) for p in tpl], int(cap)]
            for tpl, cap in engine.fused_templates))


def restore(snap: EngineSnapshot, *, params=None, fault_plan=None,
            clock=None, retry=None, escalate_step_errors: bool = False):
    """Rebuild an Engine from ``snap``; run() resumes token-identically.

    ``params`` overrides the snapshot's weights (e.g. to share one
    device copy across engines); fault_plan/clock/retry/
    escalate_step_errors are the runtime harness of the NEW process and
    default to a clean stand-alone engine (a Fleet restores its replicas
    with escalate_step_errors=True)."""
    from repro.serve.engine import Engine

    eng = Engine(snap.params if params is None else params, snap.cfg,
                 fault_plan=fault_plan, clock=clock, retry=retry,
                 escalate_step_errors=escalate_step_errors,
                 **snap.init_kw)
    if snap.step_mode != eng.step_mode:
        raise ValueError(
            f"snapshot captured effective step_mode={snap.step_mode!r} "
            f"but the rebuilt engine resolved {eng.step_mode!r} — the "
            "config drifted between capture and restore")
    eng._mode_cost.update(snap.mode_cost)
    eng.fused_templates = {(tuple(tpl), int(cap))
                           for tpl, cap in snap.fused_templates}
    eng.cache = jax.tree.map(jnp.asarray, snap.cache)
    eng.pos = jnp.asarray(snap.pos)
    eng.last_tok = jnp.asarray(snap.last_tok)
    eng.remaining = np.asarray(snap.remaining).copy()
    eng.key = jax.random.wrap_key_data(jnp.asarray(snap.key_data))
    eng.quarantined = dict(snap.quarantined)
    eng._admit_round_idx = snap.admit_round_idx
    eng._decode_round_idx = snap.decode_round_idx
    # rebase request ages onto the new clock: elapsed age (what deadlines
    # measure) is preserved across the restore.
    shift = float(eng.clock()) - snap.clock_now
    eng.slot_req = [None if d is None else _req_from_dict(d, shift)
                    for d in snap.slot_req]
    eng.queue = [_req_from_dict(d, shift) for d in snap.queue]
    eng.finished = [_req_from_dict(d, shift) for d in snap.finished]
    return eng


def strip_for_restart(snap: EngineSnapshot) -> EngineSnapshot:
    """A cleaned copy for fleet failover restoration: the victim's
    requests are migrated to a healthy replica, so the restored engine
    starts EMPTY — but keeps its round indices (round-addressed faults it
    already struck never re-fire, making recovery deterministic), RNG
    key, clock base, cost EMA and compile-footprint records."""
    return dataclasses.replace(
        snap,
        slot_req=[None] * len(snap.slot_req),
        queue=[], finished=[], quarantined={},
        remaining=np.zeros_like(snap.remaining))


# ---------------------------------------------------------------------------
# Atomic on-disk persistence
# ---------------------------------------------------------------------------


def _flatten(tree, prefix: str) -> Dict[str, np.ndarray]:
    """Dot-join a pure nested-dict tree (params/cache are exactly that —
    str keys, no dots) into {path: leaf}."""
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            assert "." not in str(k), f"tree key {k!r} would break paths"
            out.update(_flatten(v, f"{prefix}.{k}"))
    else:
        out[prefix] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> dict:
    tree: dict = {}
    for path, leaf in flat.items():
        parts = path.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return tree


def to_dir(snap: EngineSnapshot, path: str) -> str:
    """Atomically persist ``snap`` at ``path`` (a directory): written to
    ``path.tmp`` first, os.replace'd into place — a crash mid-save never
    leaves a half-written snapshot visible."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(snap.params, "params")
    flat.update(_flatten(snap.cache, "cache"))
    flat.update({"pos": snap.pos, "last_tok": snap.last_tok,
                 "remaining": snap.remaining, "key_data": snap.key_data})
    arrays, dtypes = {}, {}
    for key, arr in flat.items():
        dtypes[key] = str(arr.dtype)
        arrays[key] = (arr if arr.dtype.name in _NATIVE
                       else arr.astype(np.float32))
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)

    kw = dict(snap.init_kw)
    kw["cache_dtype"] = str(np.dtype(kw["cache_dtype"]))
    meta = {
        "schema": SNAPSHOT_VERSION,
        "cfg": dataclasses.asdict(snap.cfg),
        "init_kw": kw,
        "dtypes": dtypes,
        "clock_now": snap.clock_now,
        "admit_round_idx": snap.admit_round_idx,
        "decode_round_idx": snap.decode_round_idx,
        "quarantined": {str(k): v for k, v in snap.quarantined.items()},
        "slot_req": snap.slot_req,
        "queue": snap.queue,
        "finished": snap.finished,
        "step_mode": snap.step_mode,
        "mode_cost": snap.mode_cost,
        "fused_templates": snap.fused_templates,
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)

    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)
    return path


def from_dir(path: str) -> EngineSnapshot:
    """Load a snapshot persisted by to_dir. Ignores any sibling .tmp."""
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    if meta.get("schema") != SNAPSHOT_VERSION:
        raise ValueError(f"snapshot at {path}: schema "
                         f"{meta.get('schema')!r} != {SNAPSHOT_VERSION}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    for key, arr in flat.items():
        want = meta["dtypes"][key]
        if str(arr.dtype) != want:
            flat[key] = np.asarray(jnp.asarray(arr).astype(want))
    cfg_d = meta["cfg"]
    cfg_d["layer_pattern"] = tuple(cfg_d["layer_pattern"])
    kw = dict(meta["init_kw"])
    params = _unflatten({k[len("params."):]: v for k, v in flat.items()
                         if k.startswith("params.")})
    cache = _unflatten({k[len("cache."):]: v for k, v in flat.items()
                        if k.startswith("cache.")})
    return EngineSnapshot(
        cfg=ModelConfig(**cfg_d), params=params, cache=cache, init_kw=kw,
        pos=flat["pos"], last_tok=flat["last_tok"],
        remaining=flat["remaining"], key_data=flat["key_data"],
        clock_now=meta["clock_now"],
        admit_round_idx=meta["admit_round_idx"],
        decode_round_idx=meta["decode_round_idx"],
        quarantined={int(k): v for k, v in meta["quarantined"].items()},
        slot_req=meta["slot_req"], queue=meta["queue"],
        finished=meta["finished"],
        # pre-fused-seam files lack these keys (same v1 schema): default
        # step_mode to the EFFECTIVE mode the engine would resolve from
        # the recorded kwargs (recurrent mixers force "split").
        step_mode=meta.get("step_mode", (
            kw.get("step_mode", "split")
            if all(k == "attn" for k in cfg_d["layer_pattern"])
            else "split")),
        mode_cost=meta.get("mode_cost", {}),
        fused_templates=meta.get("fused_templates", []))
