"""repro.resilience — deterministic fault injection, health tracking, and
crash-safe engine snapshots for the serving stack.

Submodules (see README.md in this directory for the full tour):

  faults    seeded ``FaultPlan`` (launch errors, NaN-poisoned outputs,
            stragglers, OOM-style admission failures), ``VirtualClock``,
            ``RetryPolicy`` (bounded exponential backoff + seeded jitter),
            and the registered degradation-ladder ``TRANSITIONS``.
  health    ``HeartbeatMonitor`` / straggler detection (moved here from
            train/fault_tolerance.py, which re-exports) plus ``RoundWatch``
            for flagging slow engine decode rounds.
  snapshot  ``EngineSnapshot``: serialize slot table + KV cache + RNG/clock
            state so ``Engine.restore(snap).run(...)`` resumes
            token-identically after a crash.
  smoke     CLI fault-injection smoke tiers (``python -m
            repro.resilience.smoke`` for the single engine, ``--fleet``
            for multi-replica failover), wired into scripts/check.sh.

The fleet front end (serve/fleet.py) composes these pieces at replica
granularity: ``Fault(engine=...)`` / ``FaultPlan.for_engine`` scope
injection to one replica, ``HeartbeatMonitor``/``RoundWatch`` watch each
replica's rounds, and ``snapshot.strip_for_restart`` turns a victim's
snapshot into its clean re-entry state after probation.

Everything is host-side and deterministic: every fault a plan injects is
a pure function of (seed, phase, round, attempt), so a faulted run is
bitwise-replayable offline on CPU — the same discipline
train/fault_tolerance.py proves for training replay.
"""

from repro.resilience import faults, health, snapshot  # noqa: F401
