"""Fault-injection smoke tier.

    PYTHONPATH=src:scripts python -m repro.resilience.smoke [--plans N]
    PYTHONPATH=src:scripts python -m repro.resilience.smoke --fleet

Runs the tiny smoke engine offline (the scripts/_offline_guard socket
guard is installed when importable) under N seeded random FaultPlans and
checks the resilience contract end to end:

  * every request reaches exactly one terminal status — nothing is
    silently dropped;
  * every request that COMPLETES under faults is token-identical to the
    fault-free baseline (greedy decode);
  * a mid-run snapshot restores and finishes token-identically;
  * the ``degrade`` / ``quarantine`` trace events written during the
    faulted runs are schema-valid and move down registered ladders, and
    the flushed metrics document (resilience counters included) passes
    ``repro.obs.schema.validate_metrics``.

``--fleet`` runs the fleet tier instead: a two-replica Fleet under an
engine-killing FaultPlan, in BOTH step modes, checking deterministic
failover end to end — migrated requests finish token-identically to the
fault-free single-engine baseline, every request lands in exactly one
terminal status, the ``failover`` / ``engine_quarantine`` /
``rebalance`` events are schema-valid, and the flushed metrics carry
integral fleet counters.

Exit code 0 iff every check passes — scripts/check.sh gates on both
tiers, so the failure handling cannot rot between the occasions someone
actually pulls a cable.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np


def _install_offline_guard() -> bool:
    try:
        import _offline_guard  # scripts/ on PYTHONPATH via check.sh
    except ImportError:
        return False
    _offline_guard.install()
    return True


def _build():
    import jax

    from repro.configs import registry as REG
    from repro.models import model as MD

    cfg = REG.smoke_config("yi-9b")
    params = MD.init_params(jax.random.key(0), cfg)
    prompts = [np.array([3, 1, 4, 1, 5], np.int32),
               np.array([2, 7, 1], np.int32),
               np.array([9, 9, 8, 2, 6, 5], np.int32),
               np.array([11, 2, 3, 5, 8, 13, 1], np.int32)]
    return cfg, params, prompts


def _run(cfg, params, prompts, *, plan=None, max_new=4):
    from repro.resilience import faults as F
    from repro.serve.engine import Engine

    eng = Engine(params, cfg, slots=2, max_len=48, temperature=0.0,
                 prefill_block=4, fault_plan=plan, clock=F.VirtualClock())
    for uid, p in enumerate(prompts):
        eng.submit(p, max_new=max_new, uid=uid)
    return eng, eng.run()


def _fleet_tier(args, check) -> None:
    """Two replicas, an engine-killing plan, both step modes: the fleet
    failover contract, end to end through the real sinks."""
    import json as _json

    from repro.obs import schema as SCH
    from repro.obs import sinks as SK
    from repro.resilience import faults as F
    from repro.serve.fleet import Fleet

    cfg, params, prompts = _build()
    trace_path = SK.enable(
        trace_dir=os.path.join(args.artifacts, "trace"),
        metrics_path=os.path.join(args.artifacts, "metrics_fleet.json"),
        run_id=f"fleet-smoke-{args.seed}")
    try:
        _, baseline = _run(cfg, params, prompts)
        for step_mode in ("split", "fused"):
            plan = F.FaultPlan([F.Fault("launch_error", "decode", 1,
                                        times=99, engine=0)])
            fleet = Fleet(
                params, cfg, engines=2, fault_plan=plan,
                engine_kw=dict(slots=2, max_len=48, temperature=0.0,
                               prefill_block=4, step_mode=step_mode),
                heartbeat_timeout_s=5.0, snapshot_every=2)
            for uid, p in enumerate(prompts):
                fleet.submit(p, max_new=4, uid=uid)
            res = fleet.run(max_steps=200)
            rep = fleet.report()
            terminal = {"done", "shed", "deadline_miss", "failed"}
            check(set(rep) == set(range(len(prompts)))
                  and all(r["status"] in terminal for r in rep.values()),
                  f"fleet[{step_mode}]: every request terminal: "
                  f"{ {u: r['status'] for u, r in rep.items()} }")
            check(all(res.get(u) == baseline[u] for u in baseline),
                  f"fleet[{step_mode}]: failed-over run token-identical "
                  f"to fault-free single engine")
            st = fleet.stats
            check(st["fleet_failovers_total"] >= 1
                  and st["fleet_requests_migrated_total"] >= 1
                  and st["fleet_engine_restores_total"] >= 1
                  and st["engines_quarantined"] == 0,
                  f"fleet[{step_mode}]: failover fired and drained: "
                  f"failovers={st['fleet_failovers_total']} "
                  f"migrated={st['fleet_requests_migrated_total']} "
                  f"restores={st['fleet_engine_restores_total']}")
        metrics_path = SK.flush_metrics()
    finally:
        SK.disable()

    kinds = {"failover": 0, "engine_quarantine": 0, "rebalance": 0}
    with open(trace_path, encoding="utf-8") as fh:
        for line in fh:
            ev = _json.loads(line)
            if ev.get("type") not in kinds:
                continue
            kinds[ev["type"]] += 1
            errs = SCH.validate_event(ev)
            if errs:
                check(False, f"fleet trace event invalid: {errs}")
    check(all(v >= 1 for v in kinds.values()),
          f"fleet lifecycle events traced and validated: {kinds}")

    with open(metrics_path, encoding="utf-8") as fh:
        doc = _json.load(fh)
    errs = SCH.validate_metrics(doc)
    check(not errs, f"metrics doc {metrics_path}: {errs or 'schema-valid'}")
    present = [c for c in SCH.FLEET_COUNTERS
               if any(k.split("{", 1)[0] == c for k in doc["counters"])]
    check(len(present) >= 4,
          f"fleet counters present in metrics.json: {present}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.resilience.smoke",
        description="offline fault-injection smoke for the serving engine")
    ap.add_argument("--plans", type=int, default=3,
                    help="number of seeded random FaultPlans (default 3)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--artifacts", default="artifacts",
                    help="directory for the trace/metrics outputs")
    ap.add_argument("--fleet", action="store_true",
                    help="run the fleet failover tier instead of the "
                         "single-engine tier")
    args = ap.parse_args(argv)

    guarded = _install_offline_guard()
    print(f"offline guard: {'installed' if guarded else 'unavailable'}")

    failures = []

    def check(ok, what):
        print(("  ok   " if ok else "  FAIL ") + what)
        if not ok:
            failures.append(what)

    if args.fleet:
        _fleet_tier(args, check)
        print(f"fleet resilience smoke: {len(failures)} failures")
        return 1 if failures else 0

    from repro.obs import metrics as MET
    from repro.obs import schema as SCH
    from repro.obs import sinks as SK
    from repro.resilience import faults as F
    from repro.resilience import snapshot as SNAP
    from repro.serve.engine import Engine

    cfg, params, prompts = _build()
    trace_path = SK.enable(
        trace_dir=os.path.join(args.artifacts, "trace"),
        metrics_path=os.path.join(args.artifacts,
                                  "metrics_resilience.json"),
        run_id=f"resilience-smoke-{args.seed}")
    try:
        _, baseline = _run(cfg, params, prompts)
        for i in range(args.plans):
            plan = F.FaultPlan.random(args.seed + i, n_rounds=6, rate=0.5,
                                      delay_s=0.01)
            eng, res = _run(cfg, params, prompts, plan=plan)
            rep = eng.report()
            terminal = {"done", "shed", "deadline_miss", "failed"}
            check(set(rep) == set(range(len(prompts)))
                  and all(r["status"] in terminal for r in rep.values()),
                  f"plan {i}: every request terminal: "
                  f"{ {u: r['status'] for u, r in rep.items()} }")
            done = [u for u, r in rep.items() if r["status"] == "done"]
            check(all(res[u] == baseline[u] for u in done),
                  f"plan {i}: {len(done)} completed requests "
                  f"token-identical to fault-free")
        # forced ladder descent + quarantine: 4 strikes outlast the
        # default 3 retries (degrade event guaranteed), and one decode
        # poison guarantees a quarantine + replay.
        forced = F.FaultPlan([F.Fault("admit_oom", "admit", 0, times=4),
                              F.Fault("poison", "decode", 1, times=1)])
        eng, res = _run(cfg, params, prompts, plan=forced)
        check(res == baseline and
              eng.stats["launches_degraded_total"] >= 1 and
              eng.stats["slots_quarantined_total"] >= 1,
              "forced plan: degrade + quarantine fire, tokens identical")
        # snapshot/restore mid-flight
        eng = Engine(params, cfg, slots=2, max_len=48, temperature=0.0,
                     prefill_block=4, clock=F.VirtualClock())
        for uid, p in enumerate(prompts):
            eng.submit(p, max_new=4, uid=uid)
        eng._expire_deadlines()
        eng._admit()
        eng.step()
        resumed = Engine.restore(SNAP.snapshot(eng)).run()
        check(resumed == baseline,
              "snapshot mid-flight -> restore -> run token-identical")
        metrics_path = SK.flush_metrics()
    finally:
        SK.disable()

    # the trace written above must validate, and every degrade must move
    # down a registered ladder.
    n_events = 0
    with open(trace_path, encoding="utf-8") as fh:
        for line in fh:
            ev = json.loads(line)
            if ev.get("type") not in ("degrade", "quarantine"):
                continue
            n_events += 1
            errs = SCH.validate_event(ev)
            if errs:
                check(False, f"trace event invalid: {errs}")
            if ev["type"] == "degrade" and not F.is_registered_transition(
                    ev["phase"], ev["from"], ev["to"]):
                check(False, f"unregistered degrade: {ev}")
    check(n_events >= 1,
          f"{n_events} degrade/quarantine events traced and validated")

    with open(metrics_path, encoding="utf-8") as fh:
        doc = json.load(fh)
    errs = SCH.validate_metrics(doc)
    check(not errs, f"metrics doc {metrics_path}: {errs or 'schema-valid'}")
    present = [c for c in SCH.RESILIENCE_COUNTERS
               if any(k.split("{", 1)[0] == c for k in doc["counters"])]
    check(len(present) >= 2,
          f"resilience counters present in metrics.json: {present}")
    # engines also aggregate into the process-global registry
    g = MET.global_registry()
    check(g.counter_total("engine_decode_rounds") > 0,
          "global registry carries engine_* counters")

    print(f"resilience smoke: {len(failures)} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
