"""Liveness and straggler detection, shared by training and serving.

``WorkerHealth`` / ``HeartbeatMonitor`` moved here from
train/fault_tolerance.py (which re-exports them — no API break): the
monitor consumes (worker, step, timestamp) events from any transport and
is deliberately host-side and deterministic, so it unit-tests on CPU and
drops onto jax.distributed unchanged.

``RoundWatch`` is the serving-side analogue for a SINGLE worker: the
engine feeds it per-round wall-clock durations (measured on the engine's
own clock, so injected straggler delays from a FaultPlan register) and it
flags rounds slower than ``factor`` x the running median — the decode
round's straggler signal, surfaced as the ``rounds_straggler_total``
metric.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, Optional, Sequence, Set


# ---------------------------------------------------------------------------
# Heartbeats & stragglers (moved from train/fault_tolerance.py)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class WorkerHealth:
    last_beat: Optional[float] = None
    last_step: int = -1
    step_times: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=16))


class HeartbeatMonitor:
    """Tracks per-worker liveness and step latency.

    failed(): no heartbeat for `timeout_s`.
    stragglers(): recent mean step time > `straggler_factor` x fleet median —
    the mitigation hook re-plans those workers' shards (deterministically)
    rather than waiting on them.
    """

    def __init__(self, workers: Sequence[int], *, timeout_s: float = 60.0,
                 straggler_factor: float = 1.5):
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.health: Dict[int, WorkerHealth] = {
            w: WorkerHealth() for w in workers}

    def beat(self, worker: int, step: int, now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        h = self.health[worker]
        if h.last_beat is not None and step > h.last_step:
            h.step_times.append(
                (now - h.last_beat) / max(1, step - h.last_step))
        h.last_beat, h.last_step = now, step

    def failed(self, now: Optional[float] = None) -> Set[int]:
        now = time.monotonic() if now is None else now
        return {w for w, h in self.health.items()
                if h.last_beat is not None
                and now - h.last_beat > self.timeout_s}

    def stragglers(self) -> Set[int]:
        means = {w: sum(h.step_times) / len(h.step_times)
                 for w, h in self.health.items() if h.step_times}
        if len(means) < 2:
            return set()
        med = sorted(means.values())[len(means) // 2]
        return {w for w, m in means.items()
                if m > self.straggler_factor * med}


# ---------------------------------------------------------------------------
# Single-worker round watch (serving decode rounds)
# ---------------------------------------------------------------------------


class RoundWatch:
    """Flags straggler rounds against the engine's own recent history.

    ``observe(duration_s)`` returns True when the round took more than
    ``factor`` x the median of the last ``window`` rounds (needing at
    least ``min_samples`` history first — cold-start rounds, which pay
    JIT compiles, never flag). Purely host-side arithmetic: deterministic
    given the observed durations, so fault-injected delays through a
    VirtualClock produce reproducible straggler flags.
    """

    def __init__(self, *, factor: float = 3.0, window: int = 64,
                 min_samples: int = 5):
        assert factor > 1.0 and min_samples >= 2
        self.factor = factor
        self.min_samples = min_samples
        self._durations: deque = deque(maxlen=window)
        self.flagged = 0

    def median(self) -> Optional[float]:
        if not self._durations:
            return None
        s = sorted(self._durations)
        return s[len(s) // 2]

    def observe(self, duration_s: float) -> bool:
        med = self.median()
        slow = (len(self._durations) >= self.min_samples
                and med is not None and med > 0.0
                and duration_s > self.factor * med)
        self._durations.append(duration_s)
        if slow:
            self.flagged += 1
        return slow
