"""Deterministic fault injection for the serving engine.

A ``FaultPlan`` is a SEEDED, fully explicit list of faults: each fault
names its kind, the engine phase it strikes ("admit" or "decode"), the
phase-local round index, and how many times it fires before clearing (a
transient fault with ``times=1`` succeeds on the first retry; a persistent
fault with a large ``times`` forces the engine down the degradation
ladder). Because matching is a pure function of (phase, round, attempt
history), a faulted run is bitwise-replayable offline on CPU — the same
discipline train/fault_tolerance.py proves for training replay.

Fault kinds
-----------
  launch_error  the round's launch raises ``InjectedLaunchError`` — the
                transient-infrastructure failure (driver hiccup, lost
                device, preempted kernel).
  admit_oom     the packed admission launch raises ``InjectedOOM`` — the
                allocation-style failure whose correct mitigation is a
                SMALLER footprint (the ladder degrades the round to the
                sequential host path), not a blind retry forever.
  poison        the round's output tile is NaN/Inf-corrupted. Injection
                happens at the host boundary where outputs land (the same
                place the engine's cheap finite-guard inspects them), so
                detection -> quarantine -> deterministic re-prefill replay
                is exercised end to end without un-deterministic device
                state. Decode poison hits ``slot`` (-1 = first live slot);
                admit poison corrupts the packed prefill states.
  straggler     the round completes but takes ``delay_s`` longer — applied
                through the engine's clock (advance a ``VirtualClock``, or
                really sleep), so deadlines and ``RoundWatch`` straggler
                flags observe it.

Launch-level hook
-----------------
``install_launch_hook(plan)`` additionally registers the plan with
``repro.obs.launch`` so EVERY instrumented launch (Pallas or scan
fallback) consults it before running: faults with ``phase="launch"``
raise at the launch site itself, matching on the launch's sequential
index. Under jit the hook fires at trace time (once per compile) — the
engine-phase hooks above are the per-round injection surface; the launch
hook covers eager kernel paths and proves the wrapper is wrap-able.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

FAULT_KINDS = ("launch_error", "admit_oom", "poison", "straggler")
PHASES = ("admit", "decode", "launch")


class InjectedLaunchError(RuntimeError):
    """A deterministic stand-in for a failed kernel launch."""


class InjectedOOM(RuntimeError):
    """A deterministic stand-in for an out-of-memory admission failure."""


class PoisonedOutput(RuntimeError):
    """Raised by the engine's finite-guard when a round's output tile
    contains NaN/Inf (injected or real)."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected fault occurrence.

    ``round`` is phase-local (the engine counts admit and decode rounds
    separately). ``times`` is the total number of strikes across retries
    AND ladder stages before the fault clears. ``member`` scopes
    admit-phase faults to one request of the round on the sequential
    path (-1 = whole round, any member). ``slot`` scopes decode poison to
    a batch row (-1 = first live slot). ``delay_s`` is the straggler
    delay. ``engine`` scopes the fault to one replica of a fleet
    (serve/fleet.py hands each replica ``plan.for_engine(e)``); -1 keeps
    the single-engine behavior — the fault applies to every engine."""

    kind: str
    phase: str
    round: int
    times: int = 1
    member: int = -1
    slot: int = -1
    delay_s: float = 0.0
    engine: int = -1

    def __post_init__(self):
        assert self.kind in FAULT_KINDS, self.kind
        assert self.phase in PHASES, self.phase
        assert self.round >= 0 and self.times >= 1


class FaultPlan:
    """A seeded, replayable set of faults plus their strike bookkeeping.

    The plan is consulted by the engine at its injection points:

      delay = plan.maybe_fail(phase, round, member=...)   # may raise
      slots = plan.poison_slots(round, live)              # decode poison
      plan.poisons_admit(round)                           # admit poison

    ``maybe_fail`` raises for error-kind faults, accumulates and returns
    the straggler delay otherwise. Every match advances that fault's
    strike count, so a fault fires exactly ``times`` times however the
    engine interleaves retries and ladder stages. ``reset()`` re-arms
    everything for a fresh replay of the same plan.
    """

    def __init__(self, faults: Sequence[Fault] = (), *, seed: int = 0):
        self.faults: Tuple[Fault, ...] = tuple(faults)
        self.seed = seed
        self._fired: Dict[int, int] = {}
        self._launch_calls = 0

    # -- construction --------------------------------------------------------
    @classmethod
    def random(cls, seed: int, *, n_rounds: int = 8, rate: float = 0.25,
               kinds: Sequence[str] = FAULT_KINDS,
               phases: Sequence[str] = ("admit", "decode"),
               delay_s: float = 1.0,
               engines: Sequence[int] = (-1,)) -> "FaultPlan":
        """Generate a plan deterministically from ``seed``: each (phase,
        round) cell independently faults with probability ``rate``."""
        rng = np.random.default_rng(seed)
        faults: List[Fault] = []
        for phase in phases:
            for rnd in range(n_rounds):
                if rng.random() >= rate:
                    continue
                kind = str(rng.choice(list(kinds)))
                if kind == "admit_oom" and phase != "admit":
                    kind = "launch_error"
                faults.append(Fault(
                    kind=kind, phase=phase, round=rnd,
                    times=int(rng.integers(1, 3)),
                    delay_s=delay_s if kind == "straggler" else 0.0,
                    engine=(int(rng.choice(list(engines)))
                            if tuple(engines) != (-1,) else -1)))
        return cls(faults, seed=seed)

    def for_engine(self, engine: int) -> "FaultPlan":
        """The sub-plan a fleet hands replica ``engine``: faults scoped to
        it plus every engine-agnostic fault (``engine == -1``). The
        sub-plan is a FRESH object with its own strike bookkeeping — two
        replicas never race for the same fault's strikes, so a fleet run
        is as replayable as a single-engine one."""
        return FaultPlan(
            [f for f in self.faults if f.engine in (-1, engine)],
            seed=self.seed)

    # -- bookkeeping ---------------------------------------------------------
    def reset(self):
        self._fired.clear()
        self._launch_calls = 0

    def _strike(self, idx: int) -> bool:
        """True (and consume one strike) while fault idx has strikes left."""
        fired = self._fired.get(idx, 0)
        if fired >= self.faults[idx].times:
            return False
        self._fired[idx] = fired + 1
        return True

    def _matches(self, f: Fault, phase: str, rnd: int,
                 member: Optional[int]) -> bool:
        if f.phase != phase or f.round != rnd:
            return False
        if member is not None and f.member not in (-1, member):
            return False
        return True

    # -- engine-phase injection points ---------------------------------------
    def maybe_fail(self, phase: str, rnd: int, *,
                   member: Optional[int] = None) -> float:
        """Raise for error-kind faults matching this (phase, round,
        member); return the summed straggler delay otherwise."""
        delay = 0.0
        for idx, f in enumerate(self.faults):
            if f.kind == "poison" or not self._matches(f, phase, rnd, member):
                continue
            if f.kind == "straggler":
                if self._strike(idx):
                    delay += f.delay_s
                continue
            if self._strike(idx):
                if f.kind == "admit_oom":
                    raise InjectedOOM(
                        f"injected OOM: {phase} round {rnd}")
                raise InjectedLaunchError(
                    f"injected launch failure: {phase} round {rnd}")
        return delay

    def poison_slots(self, rnd: int, live: Sequence[int]) -> List[int]:
        """Decode-phase poison: batch rows whose logits this round's
        injected corruption hits (resolved against the live set)."""
        out: List[int] = []
        for idx, f in enumerate(self.faults):
            if f.kind != "poison" or f.phase != "decode" or f.round != rnd:
                continue
            slot = f.slot if f.slot >= 0 else (live[0] if live else -1)
            if slot in live and slot not in out and self._strike(idx):
                out.append(slot)
        return out

    def poisons_admit(self, rnd: int) -> bool:
        """Admit-phase poison: whether this round's packed prefill states
        come back NaN-corrupted."""
        for idx, f in enumerate(self.faults):
            if f.kind == "poison" and f.phase == "admit" \
                    and f.round == rnd and self._strike(idx):
                return True
        return False

    # -- launch-level hook ---------------------------------------------------
    def on_launch(self, meta) -> None:
        """obs.launch hook: consult phase="launch" faults, matching on the
        sequential index of instrumented launches seen by this plan."""
        idx = self._launch_calls
        self._launch_calls += 1
        for f_i, f in enumerate(self.faults):
            if f.phase != "launch" or f.round != idx:
                continue
            if f.kind in ("launch_error", "admit_oom") and self._strike(f_i):
                raise InjectedLaunchError(
                    f"injected launch failure at launch #{idx} "
                    f"({meta.name})")


@contextlib.contextmanager
def install_launch_hook(plan: FaultPlan):
    """Register ``plan`` with repro.obs.launch for the dynamic extent of
    the block, so every instrumented launch consults it."""
    from repro.obs import launch as L

    prev = L.set_launch_hook(plan.on_launch)
    try:
        yield plan
    finally:
        L.set_launch_hook(prev)


# ---------------------------------------------------------------------------
# Deterministic time: virtual clock + seeded backoff
# ---------------------------------------------------------------------------


class VirtualClock:
    """A monotone clock the engine can own: ``clock()`` reads it,
    ``clock.sleep(dt)`` advances it instantly. Deadlines, backoff and
    straggler delays all become deterministic functions of the plan."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float):
        assert dt >= 0.0
        self.t += dt

    sleep = advance


@dataclasses.dataclass
class RetryPolicy:
    """Bounded retry with exponential backoff + seeded jitter.

    ``delay(attempt)`` = base * factor^attempt * (1 + jitter * u) with u
    drawn from a private seeded generator — the delay SEQUENCE is a pure
    function of (seed, draw order), so backoff timing replays exactly
    under a VirtualClock. ``cap_s`` bounds any single delay (important
    when the engine really sleeps)."""

    max_retries: int = 3
    base_s: float = 0.005
    factor: float = 2.0
    jitter: float = 0.5
    cap_s: float = 0.25
    seed: int = 0

    def __post_init__(self):
        assert self.max_retries >= 0 and self.base_s >= 0.0
        self._rng = np.random.default_rng(self.seed)

    def delay(self, attempt: int) -> float:
        u = float(self._rng.random())
        d = self.base_s * (self.factor ** attempt) * (1.0 + self.jitter * u)
        return min(d, self.cap_s)


# ---------------------------------------------------------------------------
# Degradation-ladder registry
# ---------------------------------------------------------------------------

# Per-phase ladders, ordered fastest -> most conservative. Stage names are
# the canonical vocabulary of ``degrade`` trace events
# (repro.obs.schema.DEGRADE_STAGES) and the resilience lint pass proves
# every transition the engine emits is registered here AND moves strictly
# down its ladder.
LADDERS: Dict[str, Tuple[str, ...]] = {
    # packed ragged prefill -> packed with scan kernels -> per-request
    # sequential host path (the REC-style host fallback).
    "admit": ("packed", "packed_scan", "sequential"),
    # packed mixed-position decode -> lockstep pad-to-max einsum.
    "decode": ("packed", "lockstep"),
    # traced isqrt block mapping -> host-side mapping (taken when a round
    # would exceed the certified LTM_TRACED_MAX_LAM envelope).
    "map": ("traced", "host"),
    # fused continuous-batching step (admits + decode in one launch) ->
    # the split admit + decode machinery (each with its own ladder).
    "step": ("fused", "split"),
    # a pinned decode-round grid the round outgrew -> rebucketed to the
    # canonical power-of-two capacity (one extra compile, no crash).
    "capacity": ("requested", "rebucketed"),
    # fleet replica lifecycle: a healthy engine -> quarantined after a
    # fault (circuit breaker may stretch the probation window) ->
    # restored from a cleaned snapshot once the window elapses.
    "engine": ("active", "quarantined", "restored"),
    # fleet routing: the request's primary replica -> a healthy peer it
    # was migrated to by deterministic failover.
    "route": ("primary", "failover"),
}

TRANSITIONS: Tuple[Tuple[str, str, str], ...] = tuple(
    (phase, ladder[i], ladder[j])
    for phase, ladder in LADDERS.items()
    for i in range(len(ladder))
    for j in range(i + 1, len(ladder)))


def is_registered_transition(phase: str, frm: str, to: str) -> bool:
    """True iff (phase, frm, to) moves strictly DOWN a declared ladder.
    The "map" ladder's transitions ride on the admit phase (the envelope
    check happens at admission)."""
    if (phase, frm, to) in TRANSITIONS:
        return True
    return phase == "admit" and ("map", frm, to) in TRANSITIONS
