"""Event sinks — JSONL trace stream + aggregated metrics.json.

Two outputs, both OFF by default (zero file I/O until ``enable()``):

  * trace sink: one JSON object per line appended to
    ``artifacts/trace/trace-<run_id>.jsonl``. Every event carries
    ``seq`` (monotone per-run) and ``ts_unix``; the payload is whatever
    the producer built (``launch`` events from obs/launch.py, ``span``
    events from obs/trace.py). Schema: obs/schema.py.
  * metrics sink: ``flush_metrics()`` writes the global registry
    snapshot as a schema-versioned document to
    ``artifacts/metrics.json`` (path set at ``enable()`` time or
    per-call).

``emit_event`` is always safe to call — when the trace sink is disabled
it is a single boolean check. Producers that build expensive payloads
should guard on ``trace_enabled()`` first (obs/launch.py does)."""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional

from repro.obs import metrics as MET

SCHEMA_VERSION = "repro.obs/v1"

_lock = threading.Lock()
_trace_fh = None
_trace_path: Optional[str] = None
_metrics_path: Optional[str] = None
_seq = 0
_run_id: Optional[str] = None


def enable(trace_dir: Optional[str] = "artifacts/trace",
           metrics_path: Optional[str] = "artifacts/metrics.json",
           run_id: Optional[str] = None) -> Optional[str]:
    """Open the sinks. ``trace_dir=None`` keeps the trace sink off while
    still setting the metrics path. Returns the trace file path."""
    global _trace_fh, _trace_path, _metrics_path, _seq, _run_id
    with _lock:
        if _trace_fh is not None:
            _trace_fh.close()
            _trace_fh = None
        _metrics_path = metrics_path
        _seq = 0
        _run_id = run_id or time.strftime("%Y%m%d-%H%M%S") + f"-{os.getpid()}"
        _trace_path = None
        if trace_dir is not None:
            os.makedirs(trace_dir, exist_ok=True)
            _trace_path = os.path.join(trace_dir, f"trace-{_run_id}.jsonl")
            _trace_fh = open(_trace_path, "a", encoding="utf-8")
        return _trace_path


def disable():
    """Close the trace sink and forget the metrics path."""
    global _trace_fh, _trace_path, _metrics_path
    with _lock:
        if _trace_fh is not None:
            _trace_fh.close()
        _trace_fh = None
        _trace_path = None
        _metrics_path = None


def trace_enabled() -> bool:
    return _trace_fh is not None


def current_trace_path() -> Optional[str]:
    return _trace_path


def run_id() -> Optional[str]:
    return _run_id


def emit_event(event: dict):
    """Append one event line to the trace sink (no-op when disabled)."""
    global _seq
    if _trace_fh is None:
        return
    with _lock:
        if _trace_fh is None:  # racing disable()
            return
        _seq += 1
        record = {"schema": SCHEMA_VERSION, "seq": _seq,
                  "ts_unix": time.time(), "run_id": _run_id}
        record.update(event)
        _trace_fh.write(json.dumps(record) + "\n")
        _trace_fh.flush()
        MET.global_registry().counter_inc("obs_events_written", 1)


def flush_metrics(path: Optional[str] = None,
                  registry: Optional["MET.Registry"] = None) -> Optional[str]:
    """Write the registry snapshot as a metrics.json document. Uses the
    path given at ``enable()`` time unless overridden; no-op (returns
    None) when neither is set."""
    target = path or _metrics_path
    if target is None:
        return None
    reg = registry or MET.global_registry()
    doc = {"schema": SCHEMA_VERSION, "kind": "metrics",
           "created_unix": time.time(), "run_id": _run_id,
           "registry": reg.name, **reg.snapshot()}
    d = os.path.dirname(target)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = target + ".tmp"
    with open(tmp, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, target)
    return target
