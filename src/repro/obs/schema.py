"""Hand-rolled schema validation for the obs sink formats.

No jsonschema dependency — each validator walks the document and returns
a list of human-readable problems (empty list == valid). Used by
tests/test_obs.py, the ``obs`` lint pass, and the ``repro.obs.validate``
CLI that scripts/check.sh runs after the benchmark smoke tier."""

from __future__ import annotations

from typing import List

from repro.obs.sinks import SCHEMA_VERSION

EVENT_TYPES = ("launch", "span", "degrade", "quarantine",
               "failover", "engine_quarantine", "rebalance")

# Canonical vocabulary of the serving degradation ladder (see
# repro.resilience.faults.LADDERS — the resilience lint pass proves the
# two stay in sync). ``degrade`` events may only move between these.
DEGRADE_STAGES = ("packed", "packed_scan", "sequential", "lockstep",
                  "traced", "host", "fused", "split", "requested",
                  "rebucketed", "active", "quarantined", "restored",
                  "primary", "failover")

# Resilience counters (emitted by serve/engine.py under these exact
# names, globally and in the per-engine registry). Counts of discrete
# events — validate_metrics requires them integral when present.
RESILIENCE_COUNTERS = (
    "requests_retried_total", "deadline_misses_total",
    "launches_degraded_total", "requests_shed_total",
    "slots_quarantined_total", "requests_failed_total",
    "rounds_straggler_total",
)

# Fleet counters (emitted by serve/fleet.py under these exact names, in
# the fleet registry and mirrored globally). Discrete-event counts —
# validate_metrics requires them integral when present. The
# ``engines_quarantined`` GAUGE (current quarantine-set size) rides
# alongside and must be an integral non-negative value.
FLEET_COUNTERS = (
    "fleet_failovers_total", "fleet_requests_migrated_total",
    "fleet_engine_restores_total", "fleet_rounds_straggler_total",
    "fleet_requests_routed_total", "fleet_routed_tiles_total",
    "fleet_requests_shed_total",
)
FLEET_GAUGES = ("engines_quarantined",)

# Required fields per event type (beyond the envelope added by sinks).
_LAUNCH_FIELDS = {
    "name": str, "family": str, "impl": str, "kind": str, "phase": str,
    "grid": list, "cells": int, "block_shape": list,
    "tiles_launched": int, "bytes_moved": int,
}
_LAUNCH_OPTIONAL_INT = ("tiles_domain", "tiles_bb", "tiles_wasted")
_LAUNCH_OPTIONAL_FLOAT = ("utilization", "improvement_vs_bb")
_SPAN_FIELDS = {
    "name": str, "path": str, "depth": int, "duration_ms": (int, float),
}
_DEGRADE_FIELDS = {
    "phase": str, "from": str, "to": str, "round": int, "reason": str,
}
_QUARANTINE_FIELDS = {
    "slot": int, "uid": int, "round": int, "reason": str,
}
_FAILOVER_FIELDS = {
    "engine": int, "target": int, "round": int, "migrated": int,
    "reason": str,
}
_ENGINE_QUARANTINE_FIELDS = {
    "engine": int, "round": int, "consecutive": int,
    "probation_rounds": int, "reason": str,
}
_REBALANCE_FIELDS = {
    "engine": int, "round": int, "reason": str,
}


def _check(errors: List[str], cond: bool, msg: str):
    if not cond:
        errors.append(msg)


def validate_event(ev: dict, *, envelope: bool = True) -> List[str]:
    """Validate one trace event. ``envelope=True`` also requires the sink
    fields (schema/seq/ts_unix) present on persisted JSONL lines."""
    errors: List[str] = []
    if not isinstance(ev, dict):
        return [f"event is not an object: {type(ev).__name__}"]
    if envelope:
        _check(errors, ev.get("schema") == SCHEMA_VERSION,
               f"schema != {SCHEMA_VERSION}: {ev.get('schema')!r}")
        _check(errors, isinstance(ev.get("seq"), int) and ev.get("seq") >= 1,
               f"seq must be int >= 1: {ev.get('seq')!r}")
        _check(errors, isinstance(ev.get("ts_unix"), (int, float)),
               "ts_unix missing or non-numeric")
    etype = ev.get("type")
    _check(errors, etype in EVENT_TYPES,
           f"unknown event type {etype!r} (want one of {EVENT_TYPES})")
    if etype == "launch":
        for field, ftype in _LAUNCH_FIELDS.items():
            _check(errors, isinstance(ev.get(field), ftype),
                   f"launch.{field} missing or not {ftype}: "
                   f"{ev.get(field)!r}")
        for field in _LAUNCH_OPTIONAL_INT:
            v = ev.get(field)
            _check(errors, v is None or isinstance(v, int),
                   f"launch.{field} must be int or null: {v!r}")
        for field in _LAUNCH_OPTIONAL_FLOAT:
            v = ev.get(field)
            _check(errors, v is None or isinstance(v, (int, float)),
                   f"launch.{field} must be numeric or null: {v!r}")
        if not errors:
            # Internal consistency: the paper's identities must hold.
            lau, dom = ev["tiles_launched"], ev.get("tiles_domain")
            if dom is not None:
                _check(errors, ev.get("tiles_wasted") == lau - dom,
                       "tiles_wasted != tiles_launched - tiles_domain")
                if lau > 0 and ev.get("utilization") is not None:
                    _check(errors,
                           abs(ev["utilization"] - dom / lau) < 1e-9,
                           "utilization != tiles_domain/tiles_launched")
            _check(errors, ev["phase"] in ("eager", "trace"),
                   f"launch.phase must be eager|trace: {ev['phase']!r}")
    elif etype == "span":
        for field, ftype in _SPAN_FIELDS.items():
            _check(errors, isinstance(ev.get(field), ftype),
                   f"span.{field} missing or not {ftype}: {ev.get(field)!r}")
    elif etype == "degrade":
        for field, ftype in _DEGRADE_FIELDS.items():
            _check(errors, isinstance(ev.get(field), ftype),
                   f"degrade.{field} missing or not {ftype}: "
                   f"{ev.get(field)!r}")
        if not errors:
            _check(errors, ev["from"] in DEGRADE_STAGES,
                   f"degrade.from not a registered stage: {ev['from']!r}")
            _check(errors, ev["to"] in DEGRADE_STAGES,
                   f"degrade.to not a registered stage: {ev['to']!r}")
            _check(errors, ev["from"] != ev["to"],
                   "degrade.from == degrade.to (not a transition)")
            _check(errors, ev["round"] >= 0,
                   f"degrade.round must be >= 0: {ev['round']!r}")
    elif etype == "quarantine":
        for field, ftype in _QUARANTINE_FIELDS.items():
            _check(errors, isinstance(ev.get(field), ftype),
                   f"quarantine.{field} missing or not {ftype}: "
                   f"{ev.get(field)!r}")
        if not errors:
            _check(errors, ev["slot"] >= 0,
                   f"quarantine.slot must be >= 0: {ev['slot']!r}")
            _check(errors, ev["round"] >= 0,
                   f"quarantine.round must be >= 0: {ev['round']!r}")
    elif etype == "failover":
        for field, ftype in _FAILOVER_FIELDS.items():
            _check(errors, isinstance(ev.get(field), ftype),
                   f"failover.{field} missing or not {ftype}: "
                   f"{ev.get(field)!r}")
        if not errors:
            for field in ("engine", "target", "round", "migrated"):
                _check(errors, ev[field] >= 0,
                       f"failover.{field} must be >= 0: {ev[field]!r}")
    elif etype == "engine_quarantine":
        for field, ftype in _ENGINE_QUARANTINE_FIELDS.items():
            _check(errors, isinstance(ev.get(field), ftype),
                   f"engine_quarantine.{field} missing or not {ftype}: "
                   f"{ev.get(field)!r}")
        if not errors:
            for field in ("engine", "round"):
                _check(errors, ev[field] >= 0,
                       f"engine_quarantine.{field} must be >= 0: "
                       f"{ev[field]!r}")
            for field in ("consecutive", "probation_rounds"):
                _check(errors, ev[field] >= 1,
                       f"engine_quarantine.{field} must be >= 1: "
                       f"{ev[field]!r}")
    elif etype == "rebalance":
        for field, ftype in _REBALANCE_FIELDS.items():
            _check(errors, isinstance(ev.get(field), ftype),
                   f"rebalance.{field} missing or not {ftype}: "
                   f"{ev.get(field)!r}")
        if not errors:
            for field in ("engine", "round"):
                _check(errors, ev[field] >= 0,
                       f"rebalance.{field} must be >= 0: {ev[field]!r}")
    return errors


def validate_metrics(doc: dict) -> List[str]:
    """Validate an artifacts/metrics.json document."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return [f"metrics doc is not an object: {type(doc).__name__}"]
    _check(errors, doc.get("schema") == SCHEMA_VERSION,
           f"schema != {SCHEMA_VERSION}: {doc.get('schema')!r}")
    _check(errors, doc.get("kind") == "metrics",
           f"kind != 'metrics': {doc.get('kind')!r}")
    _check(errors, isinstance(doc.get("created_unix"), (int, float)),
           "created_unix missing or non-numeric")
    for section in ("counters", "gauges", "histograms"):
        _check(errors, isinstance(doc.get(section), dict),
               f"{section} missing or not an object")
    for name, v in (doc.get("counters") or {}).items():
        _check(errors, isinstance(v, (int, float)) and v >= 0,
               f"counter {name} must be non-negative number: {v!r}")
        base = name.split("{", 1)[0]
        if base in RESILIENCE_COUNTERS or \
                base.removeprefix("engine_") in RESILIENCE_COUNTERS:
            _check(errors, float(v) == int(v),
                   f"resilience counter {name} must be integral "
                   f"(counts discrete events): {v!r}")
        if base in FLEET_COUNTERS:
            _check(errors, float(v) == int(v),
                   f"fleet counter {name} must be integral "
                   f"(counts discrete events): {v!r}")
    for name, v in (doc.get("gauges") or {}).items():
        if name.split("{", 1)[0] in FLEET_GAUGES:
            _check(errors,
                   isinstance(v, (int, float)) and v >= 0
                   and float(v) == int(v),
                   f"fleet gauge {name} must be integral >= 0: {v!r}")
    for name, h in (doc.get("histograms") or {}).items():
        if not isinstance(h, dict):
            errors.append(f"histogram {name} is not an object")
            continue
        for field in ("count", "sum", "min", "max", "mean",
                      "buckets", "bucket_counts"):
            _check(errors, field in h, f"histogram {name} missing {field}")
        if "buckets" in h and "bucket_counts" in h:
            _check(errors,
                   len(h["bucket_counts"]) == len(h["buckets"]) + 1,
                   f"histogram {name}: bucket_counts must have "
                   "len(buckets)+1 entries")
            _check(errors, sum(h["bucket_counts"]) == h.get("count"),
                   f"histogram {name}: bucket_counts do not sum to count")
    return errors


def validate_trajectory(records: list) -> List[str]:
    """Validate BENCH_trajectory.json: a JSON array of run records, each
    with a timestamp, a run id, and per-kernel block-space geometry."""
    errors: List[str] = []
    if not isinstance(records, list):
        return [f"trajectory is not an array: {type(records).__name__}"]
    _check(errors, len(records) >= 1, "trajectory is empty")
    for r_i, rec in enumerate(records):
        where = f"trajectory[{r_i}]"
        if not isinstance(rec, dict):
            errors.append(f"{where} is not an object")
            continue
        _check(errors, rec.get("schema") == SCHEMA_VERSION,
               f"{where}.schema != {SCHEMA_VERSION}")
        _check(errors, isinstance(rec.get("created_unix"), (int, float)),
               f"{where}.created_unix missing")
        kernels = rec.get("kernels")
        if not isinstance(kernels, dict) or not kernels:
            errors.append(f"{where}.kernels missing or empty")
            continue
        for kname, k in kernels.items():
            kw = f"{where}.kernels[{kname}]"
            if not isinstance(k, dict):
                errors.append(f"{kw} is not an object")
                continue
            for field in ("tiles_launched", "tiles_bb", "utilization"):
                _check(errors, field in k, f"{kw} missing {field}")
            lau = k.get("tiles_launched")
            _check(errors, isinstance(lau, int) and lau >= 0,
                   f"{kw}.tiles_launched must be int >= 0: {lau!r}")
            util = k.get("utilization")
            _check(errors,
                   util is None or (isinstance(util, (int, float))
                                    and 0.0 <= util <= 1.0 + 1e-9),
                   f"{kw}.utilization out of [0,1]: {util!r}")
    return errors
