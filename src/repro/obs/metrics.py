"""Metrics registry — counters, gauges, histograms with labels.

The paper's claim is quantitative (wasted blocks O(n^2) -> O(n), I ~ 1.15
on Kepler), so the reproduction keeps every launch/tile/waste quantity as
a *named metric* instead of ad-hoc dict bookkeeping. Three instrument
kinds, all label-aware:

  Counter    monotone float/int accumulator (launches, tiles, tokens).
  Gauge      last-write-wins value (capacity buckets, queue depth).
  Histogram  fixed-boundary bucket counts + sum/count/min/max (latencies,
             per-round tile totals). Boundaries default to powers of two.

A ``Registry`` holds instrument values keyed by (name, sorted labels).
There is one process-global registry (``global_registry()``) and a stack
of *scoped* collectors: ``with metrics.scope(reg): ...`` routes every
emission inside the block to ``reg`` AND to all outer scopes including
the global one — an Engine can own its per-instance registry while the
process totals keep accumulating. Emission helpers (``counter_inc`` et
al.) write to every active registry; the instrument handle classes are
thin sugar over them.

Everything here is plain-Python dict arithmetic: no JAX imports, so the
overhead per emission is O(1) dict ops and the instrumented hot paths
(see obs/launch.py) stay well under the 5%% telemetry budget even before
jit removes them from the compiled path entirely.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, List, Optional, Sequence, Tuple

# Default histogram boundaries: powers of two spanning sub-ms wall clocks
# to large tile counts. A value lands in the first bucket whose upper
# bound is >= value; the overflow bucket is +inf.
DEFAULT_BUCKETS: Tuple[float, ...] = tuple(
    float(2 ** e) for e in range(-10, 21))


def _key(name: str, labels: Optional[dict]) -> Tuple:
    if not labels:
        return (name,)
    return (name,) + tuple(sorted(labels.items()))


class Registry:
    """One collection of instrument values. Thread-safe (single lock; the
    engine emits from Python callbacks, sinks may drain from elsewhere)."""

    def __init__(self, name: str = ""):
        self.name = name
        self._lock = threading.Lock()
        self._counters: Dict[Tuple, float] = {}
        self._gauges: Dict[Tuple, float] = {}
        self._hists: Dict[Tuple, dict] = {}
        self._hist_bounds: Dict[str, Tuple[float, ...]] = {}

    # -- emission ------------------------------------------------------------
    def counter_inc(self, name: str, value: float = 1.0,
                    labels: Optional[dict] = None):
        assert value >= 0, f"counter {name} must be monotone (got {value})"
        k = _key(name, labels)
        with self._lock:
            self._counters[k] = self._counters.get(k, 0) + value

    def gauge_set(self, name: str, value: float,
                  labels: Optional[dict] = None):
        with self._lock:
            self._gauges[_key(name, labels)] = value

    def histogram_observe(self, name: str, value: float,
                          labels: Optional[dict] = None,
                          buckets: Optional[Sequence[float]] = None):
        bounds = tuple(buckets) if buckets else \
            self._hist_bounds.get(name, DEFAULT_BUCKETS)
        k = _key(name, labels)
        with self._lock:
            self._hist_bounds.setdefault(name, bounds)
            h = self._hists.get(k)
            if h is None:
                h = {"count": 0, "sum": 0.0, "min": float("inf"),
                     "max": float("-inf"),
                     "bucket_counts": [0] * (len(bounds) + 1)}
                self._hists[k] = h
            h["count"] += 1
            h["sum"] += value
            h["min"] = min(h["min"], value)
            h["max"] = max(h["max"], value)
            for b_i, bound in enumerate(bounds):
                if value <= bound:
                    h["bucket_counts"][b_i] += 1
                    break
            else:
                h["bucket_counts"][-1] += 1

    # -- reads ---------------------------------------------------------------
    def counter_value(self, name: str, labels: Optional[dict] = None):
        return self._counters.get(_key(name, labels), 0)

    def counter_total(self, name: str) -> float:
        """Sum of a counter over every label combination."""
        with self._lock:
            return sum(v for k, v in self._counters.items() if k[0] == name)

    def gauge_value(self, name: str, labels: Optional[dict] = None,
                    default=None):
        return self._gauges.get(_key(name, labels), default)

    def histogram_value(self, name: str, labels: Optional[dict] = None):
        return self._hists.get(_key(name, labels))

    @staticmethod
    def _fmt(k: Tuple) -> str:
        if len(k) == 1:
            return k[0]
        inner = ",".join(f"{lk}={lv}" for lk, lv in k[1:])
        return f"{k[0]}{{{inner}}}"

    def snapshot(self) -> dict:
        """Aggregated view of every instrument — the metrics.json payload
        body (see obs/schema.py for the enclosing document format)."""
        with self._lock:
            hists = {}
            for k, h in self._hists.items():
                bounds = self._hist_bounds[k[0]]
                hists[self._fmt(k)] = {
                    "count": h["count"], "sum": h["sum"],
                    "min": h["min"], "max": h["max"],
                    "mean": h["sum"] / max(h["count"], 1),
                    "buckets": list(bounds),
                    "bucket_counts": list(h["bucket_counts"]),
                }
            return {
                "counters": {self._fmt(k): v
                             for k, v in sorted(self._counters.items())},
                "gauges": {self._fmt(k): v
                           for k, v in sorted(self._gauges.items())},
                "histograms": hists,
            }

    def reset(self):
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self._hist_bounds.clear()


# ---------------------------------------------------------------------------
# Process-global registry + scoped-collector stack
# ---------------------------------------------------------------------------

_GLOBAL = Registry("global")
_SCOPES: List[Registry] = []
_scope_lock = threading.Lock()


def global_registry() -> Registry:
    return _GLOBAL


def active_registries() -> List[Registry]:
    """Every registry an emission should land in: global + open scopes."""
    return [_GLOBAL] + list(_SCOPES)


@contextlib.contextmanager
def scope(registry: Registry):
    """Route emissions inside the block to ``registry`` too (nestable)."""
    with _scope_lock:
        _SCOPES.append(registry)
    try:
        yield registry
    finally:
        with _scope_lock:
            _SCOPES.remove(registry)


def counter_inc(name: str, value: float = 1.0,
                labels: Optional[dict] = None):
    for reg in active_registries():
        reg.counter_inc(name, value, labels)


def gauge_set(name: str, value: float, labels: Optional[dict] = None):
    for reg in active_registries():
        reg.gauge_set(name, value, labels)


def histogram_observe(name: str, value: float,
                      labels: Optional[dict] = None,
                      buckets: Optional[Sequence[float]] = None):
    for reg in active_registries():
        reg.histogram_observe(name, value, labels, buckets)


# ---------------------------------------------------------------------------
# Instrument handles (sugar for registry-backed named metrics)
# ---------------------------------------------------------------------------


class Counter:
    """Handle bound to one registry (engine-style exact bookkeeping) or to
    the active-scope fan-out when registry=None."""

    def __init__(self, name: str, registry: Optional[Registry] = None,
                 labels: Optional[dict] = None):
        self.name, self.registry, self.labels = name, registry, labels

    def inc(self, value: float = 1.0):
        if self.registry is not None:
            self.registry.counter_inc(self.name, value, self.labels)
        else:
            counter_inc(self.name, value, self.labels)

    @property
    def value(self):
        reg = self.registry or _GLOBAL
        return reg.counter_value(self.name, self.labels)


class Gauge:
    def __init__(self, name: str, registry: Optional[Registry] = None,
                 labels: Optional[dict] = None):
        self.name, self.registry, self.labels = name, registry, labels

    def set(self, value: float):
        if self.registry is not None:
            self.registry.gauge_set(self.name, value, self.labels)
        else:
            gauge_set(self.name, value, self.labels)

    @property
    def value(self):
        reg = self.registry or _GLOBAL
        return reg.gauge_value(self.name, self.labels)


class Histogram:
    def __init__(self, name: str, registry: Optional[Registry] = None,
                 labels: Optional[dict] = None,
                 buckets: Optional[Sequence[float]] = None):
        self.name, self.registry = name, registry
        self.labels, self.buckets = labels, buckets

    def observe(self, value: float):
        if self.registry is not None:
            self.registry.histogram_observe(self.name, value, self.labels,
                                            self.buckets)
        else:
            histogram_observe(self.name, value, self.labels, self.buckets)

    @property
    def value(self):
        reg = self.registry or _GLOBAL
        return reg.histogram_value(self.name, self.labels)


class RingLog:
    """Bounded append-only log: the capped replacement for the engine's
    unbounded ``admit_order_log`` / ``admit_round_tiles`` lists. Keeps the
    last ``maxlen`` entries (default 1024 rounds) plus the TOTAL number of
    appends, so long-running engines stay O(maxlen) memory while the
    counters stay exact."""

    def __init__(self, maxlen: int = 1024):
        from collections import deque

        assert maxlen >= 1
        self.maxlen = maxlen
        self._dq = deque(maxlen=maxlen)
        self.total_appended = 0

    def append(self, item):
        self._dq.append(item)
        self.total_appended += 1

    @property
    def dropped(self) -> int:
        return self.total_appended - len(self._dq)

    def items(self) -> list:
        return list(self._dq)

    def __len__(self):
        return len(self._dq)

    def __getitem__(self, idx):
        return list(self._dq)[idx]
