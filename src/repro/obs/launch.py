"""Instrumented launch wrapper — every kernel launch becomes a measured event.

``instrumented_pallas_call`` is the ONLY place in the repo that invokes
``pl.pallas_call`` (enforced statically by the ``obs_coverage`` lint
pass): kernel families construct a ``LaunchMeta`` from their schedule and
route the launch through here, so each launch emits

  * counters: ``launches_total`` / ``tiles_launched_total`` /
    ``tiles_domain_total`` / ``tiles_bb_total`` / ``tiles_wasted_total``
    / ``launch_bytes_total`` (labels: name, impl),
  * a ``launch`` trace event (obs/sinks.py) carrying the full geometry:
    schedule kind, grid, block shape, tile counts, bytes moved, and the
    paper's waste metrics (utilization = domain/launched, improvement
    I = BB-bound/launched) computed from the schedule contract.

``instrumented_call`` is the same discipline for scan-fallback launches
(one lax.scan over the schedule enumeration == one launch).

Semantics under jit: the wrapper body runs at TRACE time (once per
compile), so events fired from inside a jitted program are tagged
``phase="trace"`` — launch *geometry* is static per compile, which is
exactly the quantity the paper compares. Eager launches (direct op calls,
interpret-mode benchmarks) are tagged ``phase="eager"`` and fire per
call. Runtime per-round accounting (decode tiles vs pad-to-max) stays
with the engine's registry-backed counters, which see host-side truth.

Overhead budget: with sinks disabled an emission is a handful of dict
increments (obs/metrics.py, no JAX imports) — and on jitted hot paths it
is removed from the compiled program entirely. ``set_enabled(False)``
kills even that.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from repro.obs import metrics as MET

_ENABLED = True
_LAUNCH_HOOK = None


def set_enabled(flag: bool):
    """Global kill switch for launch telemetry (counters + events)."""
    global _ENABLED
    _ENABLED = bool(flag)


def enabled() -> bool:
    return _ENABLED


def set_launch_hook(hook):
    """Install a pre-launch hook: ``hook(meta)`` runs before every
    instrumented launch (Pallas or scan fallback) and may raise to abort
    it — the injection surface for repro.resilience fault plans. Returns
    the previously installed hook (None when there was none) so callers
    can restore it. Under jit the hook fires at trace time, like the
    telemetry emission itself."""
    global _LAUNCH_HOOK
    prev, _LAUNCH_HOOK = _LAUNCH_HOOK, hook
    return prev


def launch_hook():
    return _LAUNCH_HOOK


@dataclasses.dataclass(frozen=True)
class LaunchMeta:
    """Static description of one launch's block-space geometry.

    ``tiles_launched`` counts the schedule-enumerated lambda-grid steps of
    ONE grid cell (one (batch, head) pair for attention); ``cells`` is the
    product of the prefix grid dims, so total grid steps = cells * tiles.
    ``tiles_domain`` is the useful-tile count from the schedule contract
    (tri(n) for ltm, band_blocks for band, ...); ``tiles_bb`` the
    bounding-box baseline bound the paper compares against (n^2 dense,
    R * n_max^2 pad-to-max for packed). None = unknown at wrap time
    (runtime-table decode rounds)."""

    name: str                     # e.g. "tri_attn.fwd"
    family: str                   # kernel family: tri_attn | tri_edm | ...
    impl: str                     # "pallas" | "scan"
    kind: str                     # schedule kind: ltm | band | packed | ...
    grid: Tuple[int, ...]         # full launch grid (or (steps,) for scans)
    block_shape: Tuple[int, ...]  # tile edge(s)
    tiles_launched: int
    tiles_domain: Optional[int] = None
    tiles_bb: Optional[int] = None
    cells: int = 1
    extra: tuple = ()             # ((key, value), ...) — hashable

    # -- derived paper quantities -------------------------------------------
    @property
    def tiles_wasted(self) -> Optional[int]:
        if self.tiles_domain is None:
            return None
        return self.tiles_launched - self.tiles_domain

    @property
    def utilization(self) -> Optional[float]:
        if self.tiles_domain is None or self.tiles_launched == 0:
            return None
        return self.tiles_domain / self.tiles_launched

    @property
    def improvement_vs_bb(self) -> Optional[float]:
        if self.tiles_bb is None or self.tiles_launched == 0:
            return None
        return self.tiles_bb / self.tiles_launched

    def as_event(self, *, phase: str, bytes_moved: int) -> dict:
        ev = {"type": "launch", "name": self.name, "family": self.family,
              "impl": self.impl, "kind": self.kind, "phase": phase,
              "grid": list(self.grid), "cells": self.cells,
              "block_shape": list(self.block_shape),
              "tiles_launched": self.tiles_launched,
              "tiles_domain": self.tiles_domain,
              "tiles_bb": self.tiles_bb,
              "tiles_wasted": self.tiles_wasted,
              "utilization": self.utilization,
              "improvement_vs_bb": self.improvement_vs_bb,
              "bytes_moved": bytes_moved}
        if self.extra:
            ev["extra"] = {str(k): v for k, v in self.extra}
        return ev


# -- meta constructors (schedule contract -> geometry) -----------------------


def meta_from_trisched(name: str, sched, *, impl: str, cells: int = 1,
                       grid=None) -> LaunchMeta:
    """From a kernel-layer TriSched: launched == domain (exact schedules);
    BB bound is the n x n dense grid the paper's baseline would launch."""
    if grid is None:
        grid = (cells, sched.rm_steps) if cells > 1 else (sched.rm_steps,)
    return LaunchMeta(
        name=name, family="tri_attn", impl=impl, kind=sched.kind,
        grid=tuple(grid), block_shape=(sched.bq, sched.bk),
        tiles_launched=sched.rm_steps, tiles_domain=sched.rm_steps,
        tiles_bb=sched.n * sched.n, cells=cells)


def meta_from_packed(name: str, psched, *, impl: str, cells: int = 1,
                     grid=None) -> LaunchMeta:
    """From a PackedTriSched: BB bound is the pad-to-max batch the packed
    launch replaces — R * n_max^2 dense tiles."""
    r = len(psched.members)
    n_max = max(m.n for m in psched.members)
    if grid is None:
        grid = (cells, psched.steps) if cells > 1 else (psched.steps,)
    return LaunchMeta(
        name=name, family="tri_attn", impl=impl, kind="packed",
        grid=tuple(grid), block_shape=(psched.blk, psched.blk),
        tiles_launched=psched.steps, tiles_domain=psched.steps,
        tiles_bb=r * n_max * n_max, cells=cells,
        extra=(("members", r),))


def meta_dense(name: str, family: str, *, impl: str, grid, block_shape,
               tiles_domain: Optional[int] = None, kind: str = "bb",
               cells: int = 1, extra: tuple = ()) -> LaunchMeta:
    """Dense/bounding-box grids (and recurrent chunk scans): launched is
    the full grid product over the lambda dims; BB bound == launched."""
    launched = 1
    for g in grid:
        launched *= int(g)
    return LaunchMeta(
        name=name, family=family, impl=impl, kind=kind, grid=tuple(grid),
        block_shape=tuple(block_shape), tiles_launched=launched,
        tiles_domain=tiles_domain, tiles_bb=launched, cells=cells,
        extra=extra)


def meta_exact(name: str, family: str, *, impl: str, kind: str, steps: int,
               block_shape, bb_bound: Optional[int], cells: int = 1,
               extra: tuple = ()) -> LaunchMeta:
    """Exact 1-D schedules (ltm/tet EDM & 3-body, decode rounds): launched
    == domain == steps."""
    return LaunchMeta(
        name=name, family=family, impl=impl, kind=kind, grid=(steps,),
        block_shape=tuple(block_shape), tiles_launched=steps,
        tiles_domain=steps, tiles_bb=bb_bound, cells=cells, extra=extra)


# -- emission ----------------------------------------------------------------


def _is_tracer(x) -> bool:
    try:
        import jax

        return isinstance(x, jax.core.Tracer)
    except Exception:
        return False


def _operand_bytes(operands) -> int:
    total = 0
    for x in operands:
        shape = getattr(x, "shape", None)
        dtype = getattr(x, "dtype", None)
        if shape is None or dtype is None:
            continue
        itemsize = getattr(dtype, "itemsize", None)
        if itemsize is None:
            continue
        total += int(math.prod(shape)) * int(itemsize)
    return total


def record_launch(meta: LaunchMeta, operands=()):
    """Emit one launch's counters + trace event (no-op when disabled).
    An installed launch hook runs FIRST and may raise to abort the launch
    (deterministic fault injection — see repro.resilience.faults)."""
    if _LAUNCH_HOOK is not None:
        _LAUNCH_HOOK(meta)
    if not _ENABLED:
        return
    phase = "trace" if any(_is_tracer(x) for x in operands) else "eager"
    labels = {"name": meta.name, "impl": meta.impl}
    MET.counter_inc("launches_total", 1, labels)
    MET.counter_inc("tiles_launched_total",
                    meta.tiles_launched * meta.cells, labels)
    if meta.tiles_domain is not None:
        MET.counter_inc("tiles_domain_total",
                        meta.tiles_domain * meta.cells, labels)
        MET.counter_inc("tiles_wasted_total",
                        meta.tiles_wasted * meta.cells, labels)
    if meta.tiles_bb is not None:
        MET.counter_inc("tiles_bb_total", meta.tiles_bb * meta.cells,
                        labels)
    bytes_moved = _operand_bytes(operands)
    MET.counter_inc("launch_bytes_total", bytes_moved, labels)

    from repro.obs import sinks as SK

    if SK.trace_enabled():
        SK.emit_event(meta.as_event(phase=phase, bytes_moved=bytes_moved))


_SUMMARY_FIELDS = {
    "launches_total": "launches",
    "tiles_launched_total": "tiles_launched",
    "tiles_domain_total": "tiles_domain",
    "tiles_wasted_total": "tiles_wasted",
    "tiles_bb_total": "tiles_bb",
    "launch_bytes_total": "bytes_moved",
}


def kernel_summary(registry=None) -> dict:
    """Per-kernel aggregate of the launch counters, keyed by launch name:

        {"tri_edm.ltm": {"launches": .., "tiles_launched": ..,
                         "tiles_domain": .., "tiles_wasted": ..,
                         "tiles_bb": .., "bytes_moved": ..,
                         "utilization": .., "improvement_vs_bb": ..,
                         "impls": ["scan", ...]}, ...}

    Sums over impl labels; utilization/improvement recomputed from the
    summed tiles — this is the ``kernels`` body of a BENCH_trajectory.json
    record (obs/schema.py validate_trajectory)."""
    reg = registry or MET.global_registry()
    snap = reg.snapshot()["counters"]
    out: dict = {}
    for key, value in snap.items():
        if "{" not in key:
            continue
        cname, rest = key.split("{", 1)
        if cname not in _SUMMARY_FIELDS:
            continue
        labels = dict(p.split("=", 1) for p in rest.rstrip("}").split(","))
        name = labels.get("name")
        if name is None:
            continue
        d = out.setdefault(name, {f: 0 for f in _SUMMARY_FIELDS.values()})
        d[_SUMMARY_FIELDS[cname]] += int(value)
        if "impl" in labels:
            d.setdefault("impls", [])
            if labels["impl"] not in d["impls"]:
                d["impls"].append(labels["impl"])
    for d in out.values():
        launched = d["tiles_launched"]
        d["utilization"] = (d["tiles_domain"] / launched) if launched else 0.0
        d["improvement_vs_bb"] = \
            (d["tiles_bb"] / launched) if launched else 0.0
        d.setdefault("impls", [])
        d["impls"].sort()
    return out


def instrumented_pallas_call(kernel_fn, *, meta: LaunchMeta, **pallas_kw):
    """The repo's single ``pl.pallas_call`` site. Same signature contract
    as pallas_call (grid/grid_spec/in_specs/out_specs/... forwarded
    verbatim); the returned callable records the launch before running."""
    from jax.experimental import pallas as pl

    inner = pl.pallas_call(kernel_fn, **pallas_kw)

    def launch(*operands):
        record_launch(meta, operands)
        return inner(*operands)

    return launch


def instrumented_call(fn, meta: LaunchMeta):
    """Wrap a scan-fallback (or any single-launch callable) so each call
    emits the same launch telemetry as a Pallas launch."""

    def launch(*args, **kw):
        record_launch(meta, args)
        return fn(*args, **kw)

    return launch
