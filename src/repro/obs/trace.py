"""Span/trace API — nested wall-clock regions with device-sync semantics.

    with obs.trace.span("prefill", requests=4) as sp:
        out = engine_prefill(...)
        sp.attach(out)          # block_until_ready(out) before t1 is taken

Spans nest (a thread-local stack records parent names and depth), survive
exceptions (the finally path closes the span and marks ``error``), and
emit two things on close:

  * a ``span`` event to the trace sinks (obs/sinks.py JSONL schema),
  * a ``span_ms`` histogram observation labeled by span name.

Wall clock is host ``time.perf_counter``. Because JAX dispatch is async,
a span around a jitted call measures *dispatch* unless the result is
attached: ``sp.attach(x)`` registers pytrees to ``jax.block_until_ready``
immediately before the end timestamp, so the span covers device work —
the same discipline obs/timing.py uses for benchmark medians.

When the optional ``jax.profiler`` is importable, each span also opens a
``TraceAnnotation`` so device profiles show the same region names; this
is best-effort and never required (offline/test environments).
"""

from __future__ import annotations

import threading
import time
from typing import Any, List, Optional

from repro.obs import metrics as MET

_tls = threading.local()


def _stack() -> List["Span"]:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def current_span() -> Optional["Span"]:
    st = _stack()
    return st[-1] if st else None


class Span:
    """One open trace region. Use via ``span(...)``; not self-registering."""

    def __init__(self, name: str, attrs: Optional[dict] = None):
        parent = current_span()
        self.name = name
        self.attrs = dict(attrs or {})
        self.parent = parent.name if parent else None
        self.depth = parent.depth + 1 if parent else 0
        self.path = (parent.path + "/" + name) if parent else name
        self.error: Optional[str] = None
        self._sync: List[Any] = []
        self._annotation = None
        self.t0 = self.t1 = None

    # -- lifecycle (driven by the ``span`` context manager) ------------------
    def _open(self):
        _stack().append(self)
        try:  # best-effort device-profiler annotation
            import jax.profiler as _prof

            self._annotation = _prof.TraceAnnotation(self.name)
            self._annotation.__enter__()
        except Exception:
            self._annotation = None
        self.t0 = time.perf_counter()
        return self

    def _close(self):
        if self._sync:
            try:
                import jax

                jax.block_until_ready(self._sync)
            except Exception:
                pass
        self.t1 = time.perf_counter()
        if self._annotation is not None:
            try:
                self._annotation.__exit__(None, None, None)
            except Exception:
                pass
        st = _stack()
        if st and st[-1] is self:
            st.pop()
        MET.histogram_observe("span_ms", self.duration_ms,
                              labels={"name": self.name})
        from repro.obs import sinks as SK

        SK.emit_event(self.as_event())

    # -- user API ------------------------------------------------------------
    def attach(self, *values):
        """Register pytrees to block_until_ready before the span closes."""
        self._sync.extend(values)
        return values[0] if len(values) == 1 else values

    def annotate(self, **attrs):
        self.attrs.update(attrs)

    @property
    def duration_ms(self) -> float:
        if self.t0 is None or self.t1 is None:
            return 0.0
        return (self.t1 - self.t0) * 1e3

    def as_event(self) -> dict:
        ev = {"type": "span", "name": self.name, "path": self.path,
              "parent": self.parent, "depth": self.depth,
              "duration_ms": self.duration_ms}
        if self.attrs:
            ev["attrs"] = _plain(self.attrs)
        if self.error is not None:
            ev["error"] = self.error
        return ev


def _plain(obj):
    """JSON-able copy: tuples -> lists, numpy scalars -> python."""
    if isinstance(obj, dict):
        return {str(k): _plain(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_plain(v) for v in obj]
    if hasattr(obj, "item") and getattr(obj, "ndim", 1) == 0:
        return obj.item()
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return str(obj)


class _SpanCM:
    def __init__(self, name: str, attrs: dict):
        self._span = Span(name, attrs)

    def __enter__(self) -> Span:
        return self._span._open()

    def __exit__(self, exc_type, exc, tb):
        if exc is not None:
            self._span.error = f"{exc_type.__name__}: {exc}"
        self._span._close()
        return False  # never swallow


def span(name: str, **attrs) -> _SpanCM:
    """Open a nested wall-clock span (context manager yielding the Span)."""
    return _SpanCM(name, attrs)


def timed(name: str):
    """Decorator form: run fn under ``span(name)`` and attach its result."""

    def deco(fn):
        import functools

        @functools.wraps(fn)
        def wrapped(*args, **kw):
            with span(name) as sp:
                return sp.attach(fn(*args, **kw))

        return wrapped

    return deco
