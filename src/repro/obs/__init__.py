"""repro.obs — block-space telemetry: metrics, spans, launch tracing.

Submodules (see README.md in this directory for the full tour):

  metrics   counters/gauges/histograms with labels; global + scoped
            registries; RingLog bounded log.
  trace     nestable wall-clock spans (``obs.trace.span("prefill")``)
            with block_until_ready semantics via ``Span.attach``.
  launch    ``instrumented_pallas_call`` / ``instrumented_call`` — the
            only launch sites in the repo; per-launch waste metrics.
  sinks     JSONL trace stream + metrics.json writer (off by default).
  timing    median-of-k benchmark timing (benchmarks/_util.py shim).
  schema    hand-rolled validators for every sink format.
"""

from repro.obs import launch, metrics, schema, sinks, timing, trace  # noqa: F401

span = trace.span
