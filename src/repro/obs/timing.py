"""The one timing code path for every benchmark.

``median_of_k`` is the canonical measurement: ``warmup`` untimed calls
(first one pays JIT compile), then ``reps`` timed calls each synced with
``jax.block_until_ready``, reported as the median — robust to the odd
scheduling hiccup in a way best-of/mean are not. Each measurement also
lands in the ``bench_seconds`` histogram (labeled by ``name``) so the
metrics.json aggregate carries the same numbers the bench tables print.

``best_of`` is kept as a compat alias for the old benchmarks/_util.py
behaviour (min instead of median, 1 warmup) — benchmarks/_util.py is now
a shim over this module."""

from __future__ import annotations

import time
from typing import Optional

from repro.obs import metrics as MET


def _times(fn, args, reps: int, warmup: int, name: Optional[str]):
    import jax

    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args))
    out = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        out.append(dt)
        if name is not None:
            MET.histogram_observe("bench_seconds", dt,
                                  labels={"name": name})
    return out


def median_of_k(fn, *args, reps: int = 5, warmup: int = 1,
                name: Optional[str] = None) -> float:
    """Median wall-clock seconds of fn(*args) over ``reps`` synced calls,
    after ``warmup`` discarded calls."""
    ts = sorted(_times(fn, args, reps, warmup, name))
    k = len(ts)
    mid = k // 2
    return ts[mid] if k % 2 else 0.5 * (ts[mid - 1] + ts[mid])


def best_of(fn, *args, reps: int = 3, warmup: int = 1,
            name: Optional[str] = None) -> float:
    """Best-of-N wall clock (compat with the old benchmarks/_util.py)."""
    return min(_times(fn, args, reps, warmup, name))
