"""CLI: validate obs artifacts against the schemas in obs/schema.py.

    python -m repro.obs.validate artifacts/metrics.json \\
        [BENCH_trajectory.json] [artifacts/trace/*.jsonl]

File role is inferred from shape: a JSON object -> metrics document, a
JSON array -> trajectory, a .jsonl file -> trace event stream. Exit 0
iff every file parses and validates. Wired into scripts/check.sh after
the benchmark smoke tier."""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs import schema


def validate_file(path: str) -> list:
    """Returns a list of '<path>: problem' strings (empty == valid)."""
    if path.endswith(".jsonl"):
        errors = []
        n = 0
        with open(path, encoding="utf-8") as fh:
            for line_no, line in enumerate(fh, 1):
                line = line.strip()
                if not line:
                    continue
                n += 1
                try:
                    ev = json.loads(line)
                except json.JSONDecodeError as e:
                    errors.append(f"{path}:{line_no}: bad JSON: {e}")
                    continue
                errors.extend(f"{path}:{line_no}: {msg}"
                              for msg in schema.validate_event(ev))
        if n == 0:
            errors.append(f"{path}: empty trace stream")
        return errors
    with open(path, encoding="utf-8") as fh:
        try:
            doc = json.load(fh)
        except json.JSONDecodeError as e:
            return [f"{path}: bad JSON: {e}"]
    if isinstance(doc, list):
        return [f"{path}: {msg}" for msg in schema.validate_trajectory(doc)]
    return [f"{path}: {msg}" for msg in schema.validate_metrics(doc)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.validate",
        description="Validate obs metrics/trajectory/trace artifacts.")
    ap.add_argument("paths", nargs="+")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)
    all_errors = []
    for path in args.paths:
        errs = validate_file(path)
        all_errors.extend(errs)
        if not args.quiet:
            status = "FAIL" if errs else "ok"
            print(f"[obs.validate] {status:4s} {path}")
    for e in all_errors:
        print(f"[obs.validate]   {e}", file=sys.stderr)
    return 1 if all_errors else 0


if __name__ == "__main__":
    sys.exit(main())
