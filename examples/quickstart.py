"""Quickstart: train a small LM with LTM-scheduled attention, checkpoint,
restore, and generate — the whole public API in one script.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import registry as REG
from repro.configs.base import ShapeConfig
from repro.models import model as MD
from repro.serve import decode as D
from repro.train import checkpoint as CKPT
from repro.train import data as DATA
from repro.train import optimizer as OPT
from repro.train import train_step as TS


def main():
    # 1. a reduced Yi-9B-family config (GQA llama-arch, LTM attention)
    cfg = REG.smoke_config("yi-9b")
    print(f"arch {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"heads={cfg.n_heads}/{cfg.n_kv_heads} vocab={cfg.vocab_size}")

    # 2. train 30 steps on the synthetic pipeline
    opt = OPT.OptConfig(lr=3e-3, warmup_steps=5, total_steps=30)
    state = TS.init_state(jax.random.key(0), cfg, opt)
    shape = ShapeConfig("quickstart", seq_len=128, global_batch=8,
                        kind="train")
    ds = DATA.SyntheticLM(cfg, shape, seed=0, act_dtype=jnp.float32)
    step = jax.jit(TS.make_train_step(cfg, opt), donate_argnums=(0,))
    first = last = None
    for i in range(30):
        state, metrics = step(state, ds.batch(i))
        if i == 0:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
        if i % 10 == 0:
            print(f"  step {i:3d} loss {float(metrics['loss']):.4f}")
    print(f"loss {first:.3f} -> {last:.3f}")
    assert last < first, "training must reduce loss"

    # 3. checkpoint round-trip
    with tempfile.TemporaryDirectory() as d:
        CKPT.save(d, state, int(state.step))
        target = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        restored, _ = CKPT.restore(d, target)
        print(f"checkpoint round-trip ok (step {int(restored.step)})")

    # 4. greedy generation from the trained params
    cache = MD.init_cache(cfg, 2, 64, jnp.float32)
    toks, cache, pos = D.generate(
        state.params, cfg, cache,
        first_tokens=jnp.array([[1], [2]], jnp.int32),
        start_pos=jnp.zeros((2,), jnp.int32), n_tokens=12)
    print("generated:", toks.tolist())
    print("quickstart OK")


if __name__ == "__main__":
    main()
