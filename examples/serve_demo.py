"""Batched serving with continuous slot refill (see serve/engine.py).

  PYTHONPATH=src python examples/serve_demo.py
"""

from repro.launch import serve


def main():
    results = serve.main(["--arch", "mixtral-8x7b", "--requests", "6",
                          "--slots", "3", "--max-new", "12",
                          "--max-len", "64"])
    assert len(results) == 6
    assert all(len(v) == 12 for v in results.values())
    print("serve_demo OK")


if __name__ == "__main__":
    main()
