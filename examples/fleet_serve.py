"""Fleet serving with deterministic failover (see serve/fleet.py).

Two engine replicas behind tri(n) tile-cost routing serve a small
request mix while a seeded FaultPlan kills replica 0 mid-decode; the
fleet migrates its requests and every stream still comes out identical
to a fault-free single-engine run.

  PYTHONPATH=src python examples/fleet_serve.py
"""

import jax
import numpy as np

from repro.configs import registry as REG
from repro.models import model as MD
from repro.resilience import faults as F
from repro.serve.engine import Engine
from repro.serve.fleet import Fleet


def main():
    cfg = REG.smoke_config("yi-9b")
    params = MD.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, 50, size=int(n)).astype(np.int32)
               for n in rng.integers(3, 12, size=6)]
    engine_kw = dict(slots=2, max_len=48, temperature=0.0,
                     prefill_block=4)

    eng = Engine(params, cfg, clock=F.VirtualClock(), **engine_kw)
    for uid, p in enumerate(prompts):
        eng.submit(p, max_new=4, uid=uid)
    baseline = eng.run()

    kill = F.FaultPlan([F.Fault("launch_error", "decode", 1, times=99,
                                engine=0)])
    fleet = Fleet(params, cfg, engines=2, fault_plan=kill,
                  engine_kw=engine_kw, heartbeat_timeout_s=5.0,
                  snapshot_every=2)
    for uid, p in enumerate(prompts):
        fleet.submit(p, max_new=4, uid=uid)
    results = fleet.run()

    st = fleet.stats
    print(f"failovers={st['fleet_failovers_total']} "
          f"migrated={st['fleet_requests_migrated_total']} "
          f"restores={st['fleet_engine_restores_total']}")
    assert st["fleet_failovers_total"] >= 1
    assert all(results[u] == baseline[u] for u in baseline), (
        "migrated streams must match the fault-free single engine")
    assert all(r["status"] == "done" for r in fleet.report().values())
    print("fleet_serve OK: replica 0 died, every stream token-identical")


if __name__ == "__main__":
    main()
