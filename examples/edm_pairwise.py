"""The paper's own benchmark problem: Euclidean distance matrix over the
lower-triangular domain, scheduled by g(lambda).

Shows: packed output (half the memory), exactness vs the O(N^2) oracle,
and the launched-block accounting vs the bounding-box strategy.

  PYTHONPATH=src python examples/edm_pairwise.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analysis as A
from repro.core import mapping as M
from repro.kernels.tri_edm import ops as E
from repro.kernels.tri_edm import ref as R

N, D, BLOCK = 1024, 3, 64


def main():
    x = jax.random.normal(jax.random.key(7), (N, D), jnp.float32)
    n = N // BLOCK

    # packed LTM EDM (Pallas kernel in interpret mode — TPU tiling semantics)
    packed = E.edm(x, BLOCK, impl="pallas", interpret=True)
    print(f"packed output: {packed.shape} = T(n={n}) x {BLOCK} x {BLOCK} "
          f"({packed.nbytes/2**20:.1f} MiB vs full "
          f"{N*N*4/2**20:.1f} MiB)")

    # exactness vs oracle
    full = E.unpack_tri(np.asarray(packed), N)
    ref = R.edm_full(x)
    err = float(jnp.max(jnp.abs(jnp.tril(full) - jnp.tril(ref))))
    print(f"max |err| vs O(N^2) oracle: {err:.2e}")
    assert err < 1e-4

    # the paper's block accounting
    stats = A.strategy_stats(n)
    for k in ("bb", "ltm", "rb", "utm"):
        s = stats[k]
        print(f"  {k:4s} launched={s.launched:5d} wasted={s.wasted:5d} "
              f"block-ratio vs BB={s.block_ratio_vs_bb:.3f}")
    lam = M.tri(n) - 1
    print(f"g({lam}) = {M.ltm_map(lam)}  (last block -> row n-1, col n-1)")
    print("edm_pairwise OK")


if __name__ == "__main__":
    main()
