"""Packed mixed-position decode: one launch per round, zero pad tiles.

The lockstep decode pads every slot to the same work: each of B slots
attends the full cache buffer whatever its own position, so a batch that
mixes a long sequence with short ones burns tiles exactly like a
bounding-box grid burns blocks. The packed decode round (core/packing's
decode_round of RowSchedule members, serve/decode.decode_step_packed)
gives each live slot only its own valid KV prefix — sum_b ceil(len_b/blk)
tiles — while emitting token-identical streams.

  PYTHONPATH=src python examples/packed_decode.py
"""

import jax
import numpy as np

from repro.configs import registry as REG
from repro.models import model as MD
from repro.serve.engine import Engine


def main():
    cfg = REG.smoke_config("yi-9b")
    params = MD.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    # heavy position skew: one long prompt, several short ones
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (41, 3, 6, 4)]

    results, stats = {}, {}
    for mode in ("packed", "lockstep"):
        eng = Engine(params, cfg, slots=4, max_len=64, temperature=0.0,
                     prefill_block=8, decode_mode=mode, decode_block=8)
        for uid, p in enumerate(prompts):
            eng.submit(p, max_new=8, uid=uid)
        results[mode] = eng.run()
        stats[mode] = eng.stats
        print(f"{mode:9s} decode rounds: {eng.stats['decode_rounds']:3d}  "
              f"packed launches: {eng.stats['decode_packed_launches']:3d}  "
              f"tiles packed/padded: {eng.stats['decode_tiles_packed']}/"
              f"{eng.stats['decode_tiles_padded']}")

    assert results["packed"] == results["lockstep"], \
        "packed decode must be token-for-token identical"
    st = stats["packed"]
    assert st["decode_packed_launches"] == st["decode_rounds"]
    assert st["decode_tiles_packed"] < st["decode_tiles_padded"]
    saved = 1 - st["decode_tiles_packed"] / st["decode_tiles_padded"]
    print(f"packed_decode OK — identical tokens, {saved:.0%} of pad-to-max "
          f"decode tiles eliminated "
          f"({st['decode_tiles_packed']} vs {st['decode_tiles_padded']})")


if __name__ == "__main__":
    main()
