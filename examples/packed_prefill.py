"""Packed ragged prefill: ONE launch admits a whole mixed-length batch.

The engine gathers every free slot's prompt, concatenates them along the
sequence axis, and prefills them together over the PackedSchedule grid
(core/packing.py) — sum_r tri(n_r) tiles instead of one decode-step launch
per prompt token. Outputs are token-for-token identical to the sequential
path; only the launch count changes.

  PYTHONPATH=src python examples/packed_prefill.py
"""

import jax
import numpy as np

from repro.configs import registry as REG
from repro.models import model as MD
from repro.serve.engine import Engine


def main():
    cfg = REG.smoke_config("yi-9b")
    params = MD.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (19, 5, 33, 11)]

    results, stats = {}, {}
    for mode in ("packed", "sequential"):
        eng = Engine(params, cfg, slots=4, max_len=64, temperature=0.0,
                     prefill_mode=mode, prefill_block=8)
        for uid, p in enumerate(prompts):
            eng.submit(p, max_new=8, uid=uid)
        results[mode] = eng.run()
        stats[mode] = eng.stats
        print(f"{mode:10s} prefill launches: "
              f"{eng.stats['prefill_launches']:3d} "
              f"(for {eng.stats['prefill_tokens']} prompt tokens over "
              f"{eng.stats['admit_rounds']} admit round(s))")

    assert results["packed"] == results["sequential"], \
        "packed prefill must be token-for-token identical"
    assert stats["packed"]["prefill_launches"] == \
        stats["packed"]["admit_rounds"]
    print("packed_prefill OK — identical tokens, "
          f"{stats['sequential']['prefill_launches']}x fewer launches -> "
          f"{stats['packed']['prefill_launches']}")


if __name__ == "__main__":
    main()
