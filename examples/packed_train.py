"""Packed ragged-document training: one launch per direction, zero pad.

Training on documents of mixed lengths usually pads every document to the
longest and runs a dense-masked backward — re-buying the O(n^2) bounding
box the paper's g(lambda) eliminates. The packed path bin-packs the
documents onto one PackedSchedule row (train/data.pack_documents), runs
block-diagonal attention per document, and backpropagates through the
packed custom VJP: forward, dq, and dk/dv each walk ONE 1-D grid of
sum_r tri(n_r) tiles for the whole batch.

This demo trains the same tiny model on the same skewed documents through
both layouts and shows (a) identical losses, (b) the tile savings.

  PYTHONPATH=src python examples/packed_train.py
"""

import jax
import numpy as np

from repro.configs import registry as REG
from repro.core import mapping as M
from repro.kernels.tri_attn import ops as OPS
from repro.train import data as DATA
from repro.train import optimizer as OPT
from repro.train import train_step as TS


def main():
    cfg = REG.smoke_config("yi-9b")
    block = 4
    doc_lens = (37, 5, 11, 3)  # heavy length skew
    docs = DATA.PackedDocsLM(cfg, doc_lens, block=block, seed=0)
    psched = OPS.make_packed_sched(docs.member_lens, block=block,
                                   window=cfg.sliding_window)

    opt = OPT.OptConfig()
    packed_step = TS.make_train_step(cfg, opt, packed=psched, block=block,
                                     aux_weight=0.0)
    padded_step = TS.make_train_step(cfg, opt, block=block, aux_weight=0.0)
    state_p = TS.init_state(jax.random.key(0), cfg, opt)
    state_d = TS.init_state(jax.random.key(0), cfg, opt)

    for step in range(3):
        state_p, met_p = packed_step(state_p, docs.batch(step))
        state_d, met_d = padded_step(state_d, docs.padded_batch(step))
        print(f"step {step}: packed loss {float(met_p['loss']):.4f}  "
              f"padded loss {float(met_d['loss']):.4f}")
        assert np.isclose(float(met_p["loss"]), float(met_d["loss"]),
                          rtol=1e-5), "packed training must match padded"

    ns = [s // block for s in docs.member_lens]
    n_max = max(ns)
    tiles_packed = 3 * sum(M.tri(n) for n in ns)
    tiles_bb = 3 * len(ns) * n_max * n_max
    print(f"attention tiles per train step (fwd + dq + dkv): "
          f"packed={tiles_packed} padded-bb={tiles_bb} "
          f"({tiles_bb / tiles_packed:.1f}x saved)")
    assert tiles_packed < tiles_bb
    print("packed_train OK — identical losses, "
          f"{1 - tiles_packed / tiles_bb:.0%} of pad-to-max tiles "
          "eliminated")


if __name__ == "__main__":
    main()
