"""Tetrahedral block-space demo: 3-body interactions with tet(n) launches.

The 2D paper maps a 1-D grid onto the triangle of unique PAIRS; one
dimension up, the unique TRIPLES of tiles form a discrete tetrahedron
{(i,j,k): k <= j <= i < n}. A 3D bounding box launches n^3 tile-triples
and wastes ~5/6 of them; tet_map launches exactly n(n+1)(n+2)/6 and, with
multiset permutation weights, reproduces the full symmetric 3-body sum bit
for bit of algebra (to f32 roundoff).

  PYTHONPATH=src python examples/tet_3body.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mapping as M
from repro.core import schedule as S
from repro.kernels.tri_3body import ops as OPS
from repro.kernels.tri_3body import ref as REF


def main():
    n_rows, block, d = 32, 8, 3
    n = n_rows // block
    x = jax.random.normal(jax.random.key(0), (n_rows, d), jnp.float32)

    # 1. launch-space accounting
    sched = S.TetrahedralSchedule(n=n)
    bb3 = S.Dense3DSchedule(n=n)
    print(f"tiles/side n={n}: tetrahedral launches {sched.num_blocks}, "
          f"BB-3D launches {bb3.num_blocks} "
          f"({100 * bb3.waste_fraction:.1f}% waste)")

    # 2. packed per-triple reductions via the Pallas tet kernel
    packed = OPS.three_body(x, block, impl="pallas")
    print(f"packed output: {packed.shape} (one reduction per unique "
          f"(i,j,k) tile triple)")

    # 3. first few triples with their map
    for lam in range(4):
        i, j, k = M.tet_map(lam)
        print(f"  lambda={lam} -> (i,j,k)=({i},{j},{k})  "
              f"s={float(packed[lam, 0]):+.3f}")

    # 4. exactness: weighted unique-tile total == dense einsum over all
    #    n_rows^3 ordered point triples
    total = float(OPS.three_body_total(x, block, impl="pallas"))
    dense = float(REF.three_body_total_ref(x))
    print(f"weighted total {total:.4f} vs dense einsum {dense:.4f}")
    np.testing.assert_allclose(total, dense, rtol=1e-5)
    print("OK: tet(n) launches reproduce the full 3-body sum")


if __name__ == "__main__":
    main()
