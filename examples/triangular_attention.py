"""Direct use of the triangular-domain attention kernels: causal (LTM),
sliding-window (BandSchedule) and VLM prefix-causal (PrefixSchedule),
validated against the dense oracle, plus the tile accounting for each
domain shape.

  PYTHONPATH=src python examples/triangular_attention.py
"""

import jax
import jax.numpy as jnp

from repro.core import mapping as M
from repro.kernels.tri_attn import ops as AO
from repro.kernels.tri_attn import ref as AR

B, H, HKV, S, DH, BLK = 2, 8, 2, 512, 64, 128


def main():
    key = jax.random.key(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, H, S, DH), jnp.float32)
    k = jax.random.normal(kk, (B, HKV, S, DH), jnp.float32)
    v = jax.random.normal(kv, (B, HKV, S, DH), jnp.float32)
    n = S // BLK

    cases = {
        "causal (LTM)": dict(window=None, prefix=0,
                             tiles=M.tri(n)),
        "sliding-window 128 (Band)": dict(window=128, prefix=0,
                                          tiles=M.band_blocks(n, 2)),
        "prefix-causal 128 (Prefix, VLM)": dict(window=None, prefix=128,
                                                tiles=M.prefix_full_blocks(
                                                    n, 1)),
    }
    for name, c in cases.items():
        for impl in ("scan", "pallas"):
            out = AO.triangular_attention(
                q, k, v, window=c["window"], prefix=c["prefix"], impl=impl,
                block_q=BLK, block_k=BLK, interpret=True)
            ref = AR.mha_reference(q, k, v, window=c["window"],
                                   prefix=c["prefix"])
            err = float(jnp.max(jnp.abs(out - ref)))
            assert err < 2e-3, (name, impl, err)
        print(f"{name:34s} tiles={c['tiles']:3d} (BB grid: {n*n}) "
              f"max|err|={err:.1e}  [scan+pallas vs oracle OK]")

    # gradients flow through the custom VJP (scan path)
    f = lambda q: AO.triangular_attention(q, k, v, impl="scan",
                                          block_q=BLK, block_k=BLK).sum()
    g = jax.grad(f)(q)
    print(f"dq norm through custom VJP: {float(jnp.linalg.norm(g)):.3f}")
    print("triangular_attention OK")


if __name__ == "__main__":
    main()
