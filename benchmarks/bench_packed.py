"""Packed multi-domain launch vs the two serving baselines.

A ragged prefill batch of R prompts with mixed lengths can be attended
three ways:

  packed      — ONE launch over the PackedSchedule grid (core/packing.py):
                sum_r tri(n_r) blocks, zero interior waste.
  per-request — R separate triangular launches: same blocks, R x the
                launch/dispatch overhead and no cross-request overlap.
  padded-BB   — one launch padded to the largest member with a 2-D
                bounding-box grid: R * n_max^2 blocks (the pad-to-max
                batch, what a plain batched dense-mask attention does).
  padded-LTM  — pad-to-max but triangular: R * tri(n_max) blocks (better,
                still O(R * n_max^2) with ~half the constant).

Structural columns are hardware-independent block counts; wall-clock times
the scan impls on CPU (the Pallas kernels time the same schedules on TPU).

  PYTHONPATH=src python -m benchmarks.bench_packed
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp

from benchmarks._util import best_of as _time
from repro.core import mapping as M
from repro.kernels.tri_attn import ops as OPS


def _blocks(lens, block):
    ns = [s // block for s in lens]
    n_max = max(ns)
    r = len(lens)
    return {
        "packed": sum(M.tri(n) for n in ns),
        "per_request": sum(M.tri(n) for n in ns),
        "padded_bb": r * n_max * n_max,
        "padded_ltm": r * M.tri(n_max),
    }


def run(lens=(192, 48, 320, 96), block: int = 16, h: int = 2, hkv: int = 1,
        d: int = 16, out_path: str | None = None) -> dict:
    lens = tuple(int(s) for s in lens)
    assert all(s % block == 0 for s in lens)
    r = len(lens)
    s_total, s_max = sum(lens), max(lens)
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)

    # packed operands (1, H, S_total, D) and padded batch (R, H, S_max, D)
    q = jax.random.normal(kq, (1, h, s_total, d), jnp.float32)
    k = jax.random.normal(kk, (1, hkv, s_total, d), jnp.float32)
    v = jax.random.normal(kv, (1, hkv, s_total, d), jnp.float32)

    psched = OPS.make_packed_sched(lens, block=block)
    packed_fn = jax.jit(lambda a, b, c: OPS.packed_prefill_attention(
        a, b, c, psched, impl="scan"))

    starts = [0]
    for s in lens[:-1]:
        starts.append(starts[-1] + s)
    per_fns = [
        jax.jit(lambda a, b, c, _s=s: OPS.triangular_attention(
            a, b, c, impl="scan", block_q=block, block_k=block))
        for s in lens
    ]

    def per_request(a, b, c):
        outs = []
        for fn, st, s in zip(per_fns, starts, lens):
            seg = slice(st, st + s)
            outs.append(fn(a[:, :, seg], b[:, :, seg], c[:, :, seg]))
        return jnp.concatenate(outs, axis=2)

    def pad(x):
        hh = x.shape[1]
        out = jnp.zeros((r, hh, s_max, d), jnp.float32)
        for i, (st, s) in enumerate(zip(starts, lens)):
            out = out.at[i, :, :s].set(x[0, :, st:st + s])
        return out

    qp, kp, vp = pad(q), pad(k), pad(v)
    padded_fn = jax.jit(lambda a, b, c: OPS.triangular_attention(
        a, b, c, impl="scan", block_q=block, block_k=block))

    t_packed = _time(packed_fn, q, k, v)
    t_per = _time(per_request, q, k, v)
    t_padded = _time(padded_fn, qp, kp, vp)

    rec = {
        "lens": list(lens), "block": block, "h": h, "d": d,
        "launches": {"packed": 1, "per_request": r, "padded_bb": 1,
                     "padded_ltm": 1},
        "blocks": _blocks(lens, block),
        "waste_vs_packed": {
            kind: n / _blocks(lens, block)["packed"]
            for kind, n in _blocks(lens, block).items()
        },
        "times_ms": {"packed": t_packed * 1e3, "per_request": t_per * 1e3,
                     "padded_ltm_batch": t_padded * 1e3},
        "speedup_vs_per_request": t_per / t_packed,
        "speedup_vs_padded": t_padded / t_packed,
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    rec = run(out_path="artifacts/bench_packed.json")
    b = rec["blocks"]
    t = rec["times_ms"]
    print(f"ragged batch {rec['lens']} (block={rec['block']})")
    print(f"  blocks: packed={b['packed']} per-request={b['per_request']} "
          f"padded-bb={b['padded_bb']} padded-ltm={b['padded_ltm']}")
    print(f"  launches: packed=1 per-request={rec['launches']['per_request']}"
          f" padded=1")
    print(f"  wall-clock: packed={t['packed']:.1f}ms "
          f"per-request={t['per_request']:.1f}ms "
          f"padded-ltm={t['padded_ltm_batch']:.1f}ms "
          f"(speedup {rec['speedup_vs_per_request']:.2f}x / "
          f"{rec['speedup_vs_padded']:.2f}x)")


if __name__ == "__main__":
    main()
