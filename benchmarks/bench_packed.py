"""Packed multi-domain launch vs the serving baselines (prefill + decode).

A ragged prefill batch of R prompts with mixed lengths can be attended
three ways:

  packed      — ONE launch over the PackedSchedule grid (core/packing.py):
                sum_r tri(n_r) blocks, zero interior waste.
  per-request — R separate triangular launches: same blocks, R x the
                launch/dispatch overhead and no cross-request overlap.
  padded-BB   — one launch padded to the largest member with a 2-D
                bounding-box grid: R * n_max^2 blocks (the pad-to-max
                batch, what a plain batched dense-mask attention does).
  padded-LTM  — pad-to-max but triangular: R * tri(n_max) blocks (better,
                still O(R * n_max^2) with ~half the constant).

``--decode`` benchmarks the DECODE-time analogue at position-skew ratios
{1x, 4x, 16x}: a packed mixed-position round (each slot over only its own
valid KV prefix — sum_b ceil(len_b / blk) tiles) vs the lockstep
pad-to-max decode (every slot pays max_b tiles; the full-cache masked
einsum is its dense realization).

``--train`` benchmarks the TRAINING step (forward + backward through the
custom VJP) at document-length skew {1x, 4x, 16x}: one packed launch per
direction over a ragged document batch — 3 x sum_r tri(n_r) tiles total —
vs padding every document to the longest (padded-BB: 3 R n_max^2 blocks,
the dense-masked batch; padded-LTM: 3 R tri(n_max), the triangular
pad-to-max), wall-clocked through jax.vjp on the scan impls.

Structural columns are hardware-independent block counts; wall-clock times
the scan impls on CPU (the Pallas kernels time the same schedules on TPU).

  PYTHONPATH=src python -m benchmarks.bench_packed [--decode|--train]
                                                   [--smoke]
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from benchmarks._util import best_of as _time
from repro.core import mapping as M
from repro.kernels.tri_attn import ops as OPS


def _blocks(lens, block):
    ns = [s // block for s in lens]
    n_max = max(ns)
    r = len(lens)
    return {
        "packed": sum(M.tri(n) for n in ns),
        "per_request": sum(M.tri(n) for n in ns),
        "padded_bb": r * n_max * n_max,
        "padded_ltm": r * M.tri(n_max),
    }


def run(lens=(192, 48, 320, 96), block: int = 16, h: int = 2, hkv: int = 1,
        d: int = 16, out_path: str | None = None) -> dict:
    lens = tuple(int(s) for s in lens)
    assert all(s % block == 0 for s in lens)
    r = len(lens)
    s_total, s_max = sum(lens), max(lens)
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)

    # packed operands (1, H, S_total, D) and padded batch (R, H, S_max, D)
    q = jax.random.normal(kq, (1, h, s_total, d), jnp.float32)
    k = jax.random.normal(kk, (1, hkv, s_total, d), jnp.float32)
    v = jax.random.normal(kv, (1, hkv, s_total, d), jnp.float32)

    psched = OPS.make_packed_sched(lens, block=block)
    packed_fn = jax.jit(lambda a, b, c: OPS.packed_prefill_attention(
        a, b, c, psched, impl="scan"))

    starts = [0]
    for s in lens[:-1]:
        starts.append(starts[-1] + s)
    per_fns = [
        jax.jit(lambda a, b, c, _s=s: OPS.triangular_attention(
            a, b, c, impl="scan", block_q=block, block_k=block))
        for s in lens
    ]

    def per_request(a, b, c):
        outs = []
        for fn, st, s in zip(per_fns, starts, lens):
            seg = slice(st, st + s)
            outs.append(fn(a[:, :, seg], b[:, :, seg], c[:, :, seg]))
        return jnp.concatenate(outs, axis=2)

    def pad(x):
        hh = x.shape[1]
        out = jnp.zeros((r, hh, s_max, d), jnp.float32)
        for i, (st, s) in enumerate(zip(starts, lens)):
            out = out.at[i, :, :s].set(x[0, :, st:st + s])
        return out

    qp, kp, vp = pad(q), pad(k), pad(v)
    padded_fn = jax.jit(lambda a, b, c: OPS.triangular_attention(
        a, b, c, impl="scan", block_q=block, block_k=block))

    t_packed = _time(packed_fn, q, k, v)
    t_per = _time(per_request, q, k, v)
    t_padded = _time(padded_fn, qp, kp, vp)

    rec = {
        "lens": list(lens), "block": block, "h": h, "d": d,
        "launches": {"packed": 1, "per_request": r, "padded_bb": 1,
                     "padded_ltm": 1},
        "blocks": _blocks(lens, block),
        "waste_vs_packed": {
            kind: n / _blocks(lens, block)["packed"]
            for kind, n in _blocks(lens, block).items()
        },
        "times_ms": {"packed": t_packed * 1e3, "per_request": t_per * 1e3,
                     "padded_ltm_batch": t_padded * 1e3},
        "speedup_vs_per_request": t_per / t_packed,
        "speedup_vs_padded": t_padded / t_packed,
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


# ---------------------------------------------------------------------------
# Packed mixed-position decode vs lockstep pad-to-max decode
# ---------------------------------------------------------------------------


def run_decode(skews=(1, 4, 16), base_len: int = 256, slots: int = 4,
               block: int = 16, h: int = 2, hkv: int = 1, d: int = 16,
               out_path: str | None = None) -> list:
    """One decode round per skew ratio K: slot 0 sits at KV length
    ``base_len``, the other slots at ``base_len / K`` — the packed round
    covers sum_b ceil(len_b / blk) tiles, the lockstep pad-to-max round
    B * ceil(base_len / blk)."""
    from repro.serve import decode as D

    rows = []
    for skew in skews:
        short = max(1, base_len // skew)
        kv_lens = [base_len] + [short] * (slots - 1)
        s_cache = -(-base_len // block) * block
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(skew), 3)
        q = jax.random.normal(kq, (slots, h, d), jnp.float32)
        kc = jax.random.normal(kk, (slots, s_cache, hkv, d), jnp.float32)
        vc = jax.random.normal(kv, (slots, s_cache, hkv, d), jnp.float32)
        tbl, needed = OPS.make_decode_table(
            kv_lens, list(range(slots)), blk=block, n_members=slots + 1,
            n_slots=slots, s_cache=s_cache)
        cap = D.round_capacity(needed)
        tiles_packed = needed
        tiles_padded = slots * max(-(-kl // block) for kl in kv_lens)

        def timed(impl):
            spec = OPS.DecodeRoundSpec(n_members=slots + 1, capacity=cap,
                                       blk=block, impl=impl)
            fn = jax.jit(lambda a, b, c, t: OPS.packed_decode_attention(
                a, b, c, t, spec))
            return _time(fn, q, kc, vc, jnp.asarray(tbl))

        t_packed = timed("scan")
        # 'ref' IS the lockstep baseline: full-cache masked einsum, every
        # slot padded to S_cache regardless of its own position.
        t_lockstep = timed("ref")
        rows.append({
            "skew": skew, "kv_lens": kv_lens, "block": block,
            "slots": slots,
            "tiles": {"packed": tiles_packed,
                      "lockstep_padded": tiles_padded},
            "waste_vs_packed": tiles_padded / tiles_packed,
            "times_ms": {"packed": t_packed * 1e3,
                         "lockstep": t_lockstep * 1e3},
        })
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def main_decode(smoke: bool = False, out_path="artifacts/bench_packed_decode"
                                              ".json"):
    rows = run_decode(base_len=64 if smoke else 256,
                      block=8 if smoke else 16, out_path=out_path)
    for r in rows:
        t = r["tiles"]
        print(f"  skew {r['skew']:3d}x lens={r['kv_lens']}: "
              f"tiles packed={t['packed']} "
              f"lockstep-padded={t['lockstep_padded']} "
              f"({r['waste_vs_packed']:.2f}x waste) "
              f"t_packed={r['times_ms']['packed']:.2f}ms "
              f"t_lockstep={r['times_ms']['lockstep']:.2f}ms")
    hi = rows[-1]["tiles"]
    assert hi["packed"] < hi["lockstep_padded"], (
        "packed decode must issue fewer tiles than lockstep pad-to-max "
        "under position skew")
    print(f"  OK: {hi['packed']} < {hi['lockstep_padded']} tiles at "
          f"{rows[-1]['skew']}x skew")
    return rows


# ---------------------------------------------------------------------------
# Packed ragged-document TRAINING (fwd + bwd) vs pad-to-max training
# ---------------------------------------------------------------------------


def run_train(skews=(1, 4, 16), base_len: int = 256, docs: int = 4,
              block: int = 16, h: int = 2, hkv: int = 1, d: int = 16,
              out_path: str | None = None) -> list:
    """One training step's attention work per skew ratio K: document 0 has
    ``base_len`` tokens, the other docs ``base_len / K``. The packed path
    runs ONE launch per direction (fwd + dq + dk/dv = 3 x sum_r tri(n_r)
    tiles); the padded baselines pay 3 x R x n_max^2 (BB, the dense-masked
    batch) or 3 x R x tri(n_max) (triangular pad-to-max). Wall-clock times
    jax.vjp through the scan impls on both layouts."""
    rows = []
    for skew in skews:
        short = max(block, base_len // skew)
        lens = [base_len] + [short] * (docs - 1)
        ns = [s // block for s in lens]
        n_max = max(ns)
        s_total = sum(lens)
        psched = OPS.make_packed_sched(lens, block=block)

        kq, kk, kv, ko = jax.random.split(jax.random.PRNGKey(skew), 4)
        q = jax.random.normal(kq, (1, h, s_total, d), jnp.float32)
        k = jax.random.normal(kk, (1, hkv, s_total, d), jnp.float32)
        v = jax.random.normal(kv, (1, hkv, s_total, d), jnp.float32)
        do = jax.random.normal(ko, (1, h, s_total, d), jnp.float32)

        @jax.jit
        def packed_step(q, k, v, do):
            _, vjp = jax.vjp(lambda a, b, c: OPS.packed_prefill_attention(
                a, b, c, psched, impl="scan"), q, k, v)
            return vjp(do)

        qp = jnp.zeros((docs, h, n_max * block, d), jnp.float32)
        kp = jnp.zeros((docs, hkv, n_max * block, d), jnp.float32)
        vp = jnp.zeros((docs, hkv, n_max * block, d), jnp.float32)
        dop = jnp.zeros((docs, h, n_max * block, d), jnp.float32)
        st = 0
        for i, s in enumerate(lens):
            qp = qp.at[i, :, :s].set(q[0, :, st:st + s])
            kp = kp.at[i, :, :s].set(k[0, :, st:st + s])
            vp = vp.at[i, :, :s].set(v[0, :, st:st + s])
            dop = dop.at[i, :, :s].set(do[0, :, st:st + s])
            st += s

        @jax.jit
        def padded_step(q, k, v, do):
            _, vjp = jax.vjp(lambda a, b, c: OPS.triangular_attention(
                a, b, c, impl="scan", block_q=block, block_k=block),
                q, k, v)
            return vjp(do)

        t_packed = _time(packed_step, q, k, v, do)
        t_padded = _time(padded_step, qp, kp, vp, dop)
        rows.append({
            "skew": skew, "doc_lens": lens, "block": block,
            "launches": {"packed": 3, "padded": 3},
            "tiles": {
                "packed": 3 * sum(M.tri(n) for n in ns),
                "padded_bb": 3 * docs * n_max * n_max,
                "padded_ltm": 3 * docs * M.tri(n_max),
            },
            "times_ms": {"packed": t_packed * 1e3,
                         "padded_ltm": t_padded * 1e3},
        })
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def main_train(smoke: bool = False,
               out_path="artifacts/bench_packed_train.json"):
    rows = run_train(base_len=64 if smoke else 256,
                     block=8 if smoke else 16, out_path=out_path)
    for r in rows:
        t = r["tiles"]
        tm = r["times_ms"]
        print(f"  skew {r['skew']:3d}x docs={r['doc_lens']}: "
              f"train tiles packed={t['packed']} "
              f"padded-bb={t['padded_bb']} padded-ltm={t['padded_ltm']} "
              f"t_packed={tm['packed']:.2f}ms "
              f"t_padded-ltm={tm['padded_ltm']:.2f}ms")
    hi = rows[-1]["tiles"]
    assert hi["packed"] < hi["padded_ltm"] < hi["padded_bb"], (
        "packed training must issue strictly fewer tiles than pad-to-max "
        "under document-length skew")
    print(f"  OK: {hi['packed']} < {hi['padded_ltm']} (LTM) < "
          f"{hi['padded_bb']} (BB) train tiles at {rows[-1]['skew']}x skew")
    return rows


def main():
    rec = run(out_path="artifacts/bench_packed.json")
    b = rec["blocks"]
    t = rec["times_ms"]
    print(f"ragged batch {rec['lens']} (block={rec['block']})")
    print(f"  blocks: packed={b['packed']} per-request={b['per_request']} "
          f"padded-bb={b['padded_bb']} padded-ltm={b['padded_ltm']}")
    print(f"  launches: packed=1 per-request={rec['launches']['per_request']}"
          f" padded=1")
    print(f"  wall-clock: packed={t['packed']:.1f}ms "
          f"per-request={t['per_request']:.1f}ms "
          f"padded-ltm={t['padded_ltm_batch']:.1f}ms "
          f"(speedup {rec['speedup_vs_per_request']:.2f}x / "
          f"{rec['speedup_vs_padded']:.2f}x)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--decode", action="store_true",
                    help="benchmark the packed mixed-position decode round")
    ap.add_argument("--train", action="store_true",
                    help="benchmark the packed ragged-document training "
                         "step (fwd + bwd) vs pad-to-max")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes (CI tier, scripts/check.sh)")
    args = ap.parse_args()
    import os

    os.makedirs("artifacts", exist_ok=True)
    if args.decode:
        main_decode(smoke=args.smoke)
    elif args.train:
        main_train(smoke=args.smoke)
    else:
        main()
