"""Shared benchmark helpers — thin shim over repro.obs.timing.

The actual timing discipline (warmup discard, block_until_ready sync,
``bench_seconds`` histogram emission) lives in ``repro.obs.timing``; this
module just re-exports it under the names the bench_*.py scripts import.
"""

from __future__ import annotations

from repro.obs.timing import best_of, median_of_k  # noqa: F401
