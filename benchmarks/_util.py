"""Shared benchmark helpers."""

from __future__ import annotations

import time

import jax


def best_of(fn, *args, reps: int = 3):
    """Best-of-N wall-clock of fn(*args); first call pays JIT compile."""
    fn(*args)  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best
