"""Benchmark harness entry point: one benchmark per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast | --smoke]

  bench_mapping     — paper Fig. 3 (dummy kernel / strategy cost + waste)
  bench_tet_mapping — the 3D analogue: BB-3D (n^3) vs tetrahedral launch
  bench_edm         — paper Fig. 5 (EDM, d = 1..4 features, LTM vs BB)
  bench_attention   — the technique on causal flash attention (tiles/FLOPs/I)
  bench_packed      — packed ragged batch vs per-request vs padded launches,
                      plus --decode: packed mixed-position decode rounds vs
                      lockstep pad-to-max at skew {1x, 4x, 16x}, and
                      --train: packed ragged-document fwd+bwd vs pad-to-max
                      training at document-length skew {1x, 4x, 16x}
  bench_continuous  — continuous batching: the FUSED engine-step launch
                      (admits + live decode slots, one mixed member table)
                      vs the split prefill + decode pair at skew {1,4,16}
  bench_fleet       — replicated engines: tri(n) tile-cost routing balance
                      under skewed arrivals, and failover determinism
                      (migrated requests token-identical) under engine death
  bench_roofline    — §Roofline table from the dry-run artifacts (if present)

--smoke is the CI tier: tiny n, scan impls only, seconds not minutes —
scripts/check.sh runs it so the benchmark scripts cannot rot offline.
"""

from __future__ import annotations

import argparse
import json
import os
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller N ranges (CI-sized)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny n, scan impls: execution check only "
                         "(scripts/check.sh tier)")
    args = ap.parse_args(argv)
    os.makedirs("artifacts", exist_ok=True)

    from repro.obs import launch as OBS_LAUNCH
    from repro.obs import sinks as SK

    trace_path = SK.enable(trace_dir="artifacts/trace",
                           metrics_path="artifacts/metrics.json")
    print(f"obs: trace -> {trace_path}")

    from benchmarks import bench_mapping, bench_tet_mapping, bench_edm, \
        bench_attention, bench_packed, bench_continuous, bench_fleet, \
        bench_roofline

    t0 = time.time()
    print("=" * 72)
    print("bench_mapping (paper Fig. 3)")
    print("=" * 72)
    rows = bench_mapping.run(
        n_values=[16, 64] if args.smoke
        else [64, 256, 1024] if args.fast else None,
        out_path="artifacts/bench_mapping.json")
    for r in rows:
        ii = r["improvement_I_vs_bb"]
        print(f"  N={r['N']:6d} I(ltm)={ii['ltm']:.3f} I(rb)={ii['rb']:.3f} "
              f"I(utm)={ii['utm']:.3f} wasted bb={r['blocks']['bb']['wasted']}"
              f" ltm={r['blocks']['ltm']['wasted']}")
    print("  LTM-R exactness:", bench_mapping.exactness_check(
        256 if args.smoke else 1024 if args.fast else 4096))

    print("=" * 72)
    print("bench_tet_mapping (BB-3D vs tetrahedral launch)")
    print("=" * 72)
    rows = bench_tet_mapping.run(
        n_values=[8, 16] if args.smoke else [16, 64] if args.fast else None,
        out_path="artifacts/bench_tet_mapping.json")
    for r in rows:
        print(f"  N={r['N']:6d} tet={r['launched_tet']} "
              f"bb3={r['launched_bb3']} "
              f"waste={100 * r['waste_fraction_bb3']:.1f}% "
              f"I(map)={r['improvement_I_vs_bb3']:.3f}")

    print("=" * 72)
    print("bench_edm (paper Fig. 5)")
    print("=" * 72)
    rows = bench_edm.run(
        n_values=(256,) if args.smoke else (1024,) if args.fast
        else (1024, 2048, 4096),
        features=(1,) if args.smoke else (1, 4) if args.fast
        else (1, 2, 3, 4),
        out_path="artifacts/bench_edm.json")
    for r in rows:
        print(f"  N={r['N']:6d} d={r['features']} I={r['I']:.3f} "
              f"ltm={r['t_ltm_ms']:.1f}ms bb={r['t_bb_ms']:.1f}ms "
              f"err={r['max_err_vs_oracle']}")

    print("=" * 72)
    print("bench_attention (LTM flash attention vs BB)")
    print("=" * 72)
    rows = bench_attention.run(
        seqs=(256,) if args.smoke else (512,) if args.fast
        else (1024, 2048),
        block=64 if args.smoke else 128,
        out_path="artifacts/bench_attention.json")
    for r in rows:
        print(f"  seq={r['seq']:5d} tiles={r['tiles_ltm']}/{r['tiles_bb']} "
              f"I_wall={r['I_wallclock']:.3f} I_flops={r['I_flops']:.3f}")

    print("=" * 72)
    print("bench_packed (packed ragged batch vs per-request vs padded)")
    print("=" * 72)
    rec = bench_packed.run(
        lens=(64, 16, 96) if args.smoke else (192, 48, 320, 96),
        block=8 if args.smoke else 16,
        out_path="artifacts/bench_packed.json")
    b, t = rec["blocks"], rec["times_ms"]
    print(f"  lens={rec['lens']} blocks packed={b['packed']} "
          f"padded-bb={b['padded_bb']} padded-ltm={b['padded_ltm']} "
          f"t_packed={t['packed']:.1f}ms t_per={t['per_request']:.1f}ms "
          f"t_padded={t['padded_ltm_batch']:.1f}ms")

    print("=" * 72)
    print("bench_packed --decode (packed mixed-position vs lockstep decode)")
    print("=" * 72)
    bench_packed.main_decode(
        smoke=args.smoke or args.fast,
        out_path="artifacts/bench_packed_decode.json")

    print("=" * 72)
    print("bench_packed --train (packed ragged-doc fwd+bwd vs pad-to-max)")
    print("=" * 72)
    bench_packed.main_train(
        smoke=args.smoke or args.fast,
        out_path="artifacts/bench_packed_train.json")

    print("=" * 72)
    print("bench_continuous (fused engine-step launch vs split pair)")
    print("=" * 72)
    bench_continuous.main(
        smoke=args.smoke or args.fast,
        out_path="artifacts/bench_continuous.json")

    print("=" * 72)
    print("bench_fleet (replicated engines: routing balance + failover)")
    print("=" * 72)
    bench_fleet.main(
        smoke=args.smoke or args.fast,
        out_path="artifacts/bench_fleet.json")

    print("=" * 72)
    print("bench_roofline (dry-run artifacts)")
    print("=" * 72)
    recs = bench_roofline.load()
    if recs:
        print(" ", bench_roofline.summary(recs))
    else:
        print("  no dry-run artifacts yet "
              "(run: python -m repro.launch.dryrun --all --mesh both)")

    print("=" * 72)
    print("contract lint (static invariants backing the numbers above)")
    print("=" * 72)
    lint_path = "artifacts/lint_report.json"
    if os.path.exists(lint_path):
        with open(lint_path) as f:
            rep = json.load(f)
        per = ", ".join(f"{k}={v['checks'] - v['failures']}/{v['checks']}"
                        for k, v in rep["passes"].items())
        print(f"  {rep['total_checks']} checks, "
              f"{rep['total_failures']} failures ({per})")
    else:
        print("  no lint report yet "
              "(run: python -m repro.analysis.lint --json)")

    print("=" * 72)
    print("obs: metrics + trajectory")
    print("=" * 72)
    kernels = OBS_LAUNCH.kernel_summary()
    metrics_path = SK.flush_metrics()
    record = {
        "schema": SK.SCHEMA_VERSION,
        "kind": "bench_trajectory",
        "created_unix": time.time(),
        "run_id": SK.run_id(),
        "mode": ("smoke" if args.smoke else "fast" if args.fast else "full"),
        "wall_s": time.time() - t0,
        "kernels": kernels,
    }
    traj_path = "BENCH_trajectory.json"
    traj = []
    if os.path.exists(traj_path):
        try:
            with open(traj_path) as f:
                traj = json.load(f)
            assert isinstance(traj, list)
        except Exception:
            traj = []
    traj.append(record)
    with open(traj_path + ".tmp", "w") as f:
        json.dump(traj, f, indent=1)
    os.replace(traj_path + ".tmp", traj_path)
    for name in sorted(kernels):
        k = kernels[name]
        print(f"  {name:28s} launched={k['tiles_launched']:>9d} "
              f"bb={k['tiles_bb']:>9d} util={k['utilization']:.3f} "
              f"I={k['improvement_vs_bb']:.3f}")
    print(f"  metrics -> {metrics_path}; trajectory -> {traj_path} "
          f"({len(traj)} records)")
    SK.disable()
    print(f"\nall benchmarks done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
