"""Paper Fig. 5 reproduction: EDM with d = 1..4 features, LTM vs BB.

Both strategies run as compiled XLA scans over their block enumeration
(LTM: T = n(n+1)/2 steps; BB: n^2 steps with the paper's block-coordinate
guard), so the CPU wall-clock ratio isolates exactly what the paper's GPU
experiment isolates — the cost of the wasted space of computation — without
GPU-specific effects. Numerics are validated against the O(N^2) oracle.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import best_of as _time
from repro.kernels.tri_edm import ops as E
from repro.kernels.tri_edm import ref as R

BLOCK = 64




def run(n_values=(1024, 2048, 4096), features=(1, 2, 3, 4),
        out_path=None) -> list:
    rows = []
    key = jax.random.key(0)
    ltm = jax.jit(lambda x: E.edm(x, BLOCK, impl="scan"))
    bb = jax.jit(lambda x: E.edm(x, BLOCK, impl="bb_scan"))
    for n_pts in n_values:
        for d in features:
            x = jax.random.normal(key, (n_pts, d), jnp.float32)
            t_ltm = _time(lambda: ltm(x))
            t_bb = _time(lambda: bb(x))
            # numerics vs oracle (small N only to bound the O(N^2) ref)
            err = None
            if n_pts <= 2048:
                packed = ltm(x)
                full = E.unpack_tri(np.asarray(packed), n_pts)
                ref = R.edm_full(x)
                err = float(jnp.max(jnp.abs(
                    jnp.tril(full) - jnp.tril(ref))))
            rows.append({
                "N": n_pts, "features": d,
                "t_ltm_ms": t_ltm * 1e3, "t_bb_ms": t_bb * 1e3,
                "I": t_bb / t_ltm, "max_err_vs_oracle": err,
            })
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def main():
    rows = run(out_path="artifacts/bench_edm.json")
    print(f"{'N':>6} {'d':>2} {'ltm ms':>9} {'bb ms':>9} {'I':>6}  err")
    for r in rows:
        print(f"{r['N']:6d} {r['features']:2d} {r['t_ltm_ms']:9.2f} "
              f"{r['t_bb_ms']:9.2f} {r['I']:6.3f}  "
              f"{r['max_err_vs_oracle']}")


if __name__ == "__main__":
    main()
