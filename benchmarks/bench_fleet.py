"""Fleet front end under skewed arrivals, with and without engine death.

Two replicas behind tri(n) tile-cost routing serve a skewed arrival mix
(a few long prompts among many short ones — the workload where naive
round-robin routing imbalances worst). Two scenarios:

  healthy — no faults. Reports per-replica routed requests/tiles and
            checks the greedy least-loaded balance bound: the replicas'
            routed-tile totals differ by at most one maximal request.
  failover — a FaultPlan kills replica 0's decode a few rounds in
            (persistent strikes exhaust its retry ladder). Reports
            failovers/migrations/restores and checks the determinism
            contract: every request — including the migrated ones —
            finishes token-identically to a fault-free SINGLE-engine run.

Structural columns (tiles, migrations) are hardware-independent; the
wall-clock column times the scan-impl engines on CPU through a
VirtualClock, so the fault schedule is bitwise-reproducible.

  PYTHONPATH=src python -m benchmarks.bench_fleet [--smoke]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _prompts(n: int, rng: np.random.Generator, long_len: int,
             short_max: int) -> list:
    """Skewed mix: every 4th request is long, the rest short-ragged."""
    out = []
    for i in range(n):
        size = long_len if i % 4 == 0 else int(rng.integers(2, short_max))
        out.append(rng.integers(1, 50, size=size).astype(np.int32))
    return out


def run(n_requests: int = 12, engines: int = 2, max_new: int = 3,
        long_len: int = 16, short_max: int = 7, seed: int = 0,
        out_path: str | None = None) -> dict:
    import jax

    from repro.configs import registry as REG
    from repro.models import model as MD
    from repro.resilience import faults as F
    from repro.serve.engine import Engine
    from repro.serve.fleet import Fleet

    cfg = REG.smoke_config("yi-9b")
    params = MD.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(seed)
    prompts = _prompts(n_requests, rng, long_len, short_max)
    engine_kw = dict(slots=2, max_len=48, temperature=0.0, prefill_block=4)

    # the determinism yardstick: one engine, no faults
    eng = Engine(params, cfg, clock=F.VirtualClock(), **engine_kw)
    for uid, p in enumerate(prompts):
        eng.submit(p, max_new=max_new, uid=uid)
    baseline = eng.run()

    kill = F.FaultPlan([F.Fault("launch_error", "decode", 2, times=99,
                                engine=0)])
    rec = {"n_requests": n_requests, "engines": engines,
           "max_new": max_new, "seed": seed, "scenarios": {}}
    for name, plan in (("healthy", None), ("failover", kill)):
        fleet = Fleet(params, cfg, engines=engines, fault_plan=plan,
                      engine_kw=engine_kw, heartbeat_timeout_s=5.0,
                      snapshot_every=2)
        for uid, p in enumerate(prompts):
            fleet.submit(p, max_new=max_new, uid=uid)
        routed = {e: int(fleet.registry.counter_value(
            "fleet_requests_routed_total", {"engine": str(e)}))
            for e in range(engines)}
        tiles = {e: int(fleet.registry.counter_value(
            "fleet_routed_tiles_total", {"engine": str(e)}))
            for e in range(engines)}
        max_item = max(fleet.engines[0]._prefill_tiles(r)
                       for f_eng in fleet.engines for r in f_eng.queue)
        t0 = time.perf_counter()
        res = fleet.run(max_steps=500)
        wall_s = time.perf_counter() - t0
        rep = fleet.report()
        identical = all(res.get(u) == baseline[u] for u in baseline)
        st = fleet.stats
        rec["scenarios"][name] = {
            "routed_requests": routed, "routed_tiles": tiles,
            "tile_spread": max(tiles.values()) - min(tiles.values()),
            "max_request_tiles": max_item,
            "statuses": sorted({r["status"] for r in rep.values()}),
            "token_identical_to_single_engine": identical,
            "failovers": st["fleet_failovers_total"],
            "migrated": st["fleet_requests_migrated_total"],
            "restores": st["fleet_engine_restores_total"],
            "fleet_rounds": st["rounds"], "wall_s": wall_s,
        }
        # hard gates: a bench that prints broken numbers is worse than one
        # that fails loudly.
        assert identical, f"{name}: migrated streams diverged"
        assert set(rep) == set(range(n_requests))
        assert rec["scenarios"][name]["tile_spread"] <= max_item, (
            "greedy least-loaded routing must keep per-replica tile "
            "totals within one maximal request")
    assert rec["scenarios"]["failover"]["failovers"] >= 1
    assert rec["scenarios"]["failover"]["migrated"] >= 1
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main(smoke: bool = False,
         out_path: str = "artifacts/bench_fleet.json"):
    rec = run(n_requests=8 if smoke else 16,
              max_new=3 if smoke else 4, out_path=out_path)
    for name, s in rec["scenarios"].items():
        print(f"  {name:8s}: routed={s['routed_requests']} "
              f"tiles={s['routed_tiles']} "
              f"(spread {s['tile_spread']} <= max request "
              f"{s['max_request_tiles']}) failovers={s['failovers']} "
              f"migrated={s['migrated']} identical="
              f"{s['token_identical_to_single_engine']} "
              f"wall={s['wall_s']:.2f}s")
    print(f"  OK: failover run token-identical to the fault-free "
          f"single engine ({rec['scenarios']['failover']['migrated']} "
          f"requests migrated)")
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes (CI tier, scripts/check.sh)")
    args = ap.parse_args()
    import os

    os.makedirs("artifacts", exist_ok=True)
    main(smoke=args.smoke)
