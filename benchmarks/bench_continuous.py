"""Continuous batching: one FUSED engine-step launch vs the split pair.

One serving round carries newly admitted prompts AND live decode slots.
The split engine pays two grids — a packed-prefill launch over the admit
members plus a packed-decode launch over the live slots' KV prefixes —
where the fused step (serve/decode.fused_step, the "mixed" schedule kind)
pays ONE grid of exactly the same tiles:

  fused  — 1 launch, psched.steps + sum_b ceil(kv_len_b / blk) steps.
  split  — 2 launches, the identical tile total split across them.
  lockstep-split — 2 launches with the decode half padded to max: the
           pre-packed baseline (psched.steps + B * max tiles).

Per position-skew ratio K in {1, 4, 16}: slot 0 decodes at KV length
``base_len``, the others at ``base_len / K``, while the round also admits
a fixed ragged prompt pair. Structural columns (launches, tiles) are
hardware-independent; wall-clock times the scan impls on CPU (the Pallas
twins run the same member tables on TPU). A correctness gate inside the
bench asserts the fused outputs equal the split halves before timing.

  PYTHONPATH=src python -m benchmarks.bench_continuous [--smoke]
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import best_of as _time
from repro.kernels.tri_attn import ops as OPS
from repro.serve import decode as D


def run(skews=(1, 4, 16), base_len: int = 256, slots: int = 4,
        admit_lens=(64, 32), block: int = 16, h: int = 2, hkv: int = 1,
        d: int = 16, out_path: str | None = None) -> list:
    rows = []
    admit_lens = tuple(int(s) for s in admit_lens)
    assert all(s % block == 0 for s in admit_lens)
    s_pack = sum(admit_lens)
    psched = OPS.make_packed_sched(list(admit_lens), block=block)
    for skew in skews:
        short = max(1, base_len // skew)
        kv_lens = [base_len] + [short] * (slots - 1)
        s_cache = -(-base_len // block) * block
        ks = jax.random.split(jax.random.PRNGKey(skew), 6)
        qp = jax.random.normal(ks[0], (1, h, s_pack, d), jnp.float32)
        kp = jax.random.normal(ks[1], (1, hkv, s_pack, d), jnp.float32)
        vp = jax.random.normal(ks[2], (1, hkv, s_pack, d), jnp.float32)
        qd = jax.random.normal(ks[3], (slots, h, d), jnp.float32)
        kc = jax.random.normal(ks[4], (slots, s_cache, hkv, d), jnp.float32)
        vc = jax.random.normal(ks[5], (slots, s_cache, hkv, d), jnp.float32)

        n_members = len(admit_lens) + slots + 1
        tbl, needed = OPS.make_fused_table(
            psched, kv_lens, list(range(slots)), blk=block,
            n_members=n_members, n_slots=slots, s_cache=s_cache)
        needed_dec = needed - psched.steps
        cap = psched.steps + D.round_capacity(needed_dec)
        fspec = OPS.FusedStepSpec(n_members=n_members, capacity=cap,
                                  blk=block, impl="scan")
        fused_fn = jax.jit(lambda a, b, c, e, f, g, t:
                           OPS.fused_step_attention(a, b, c, e, f, g, t,
                                                    psched, fspec))

        dtbl, dneeded = OPS.make_decode_table(
            kv_lens, list(range(slots)), blk=block, n_members=slots + 1,
            n_slots=slots, s_cache=s_cache)
        dspec = OPS.DecodeRoundSpec(n_members=slots + 1,
                                    capacity=D.round_capacity(dneeded),
                                    blk=block, impl="scan")
        prefill_fn = jax.jit(lambda a, b, c: OPS.packed_prefill_attention(
            a, b, c, psched, impl="scan"))
        decode_fn = jax.jit(lambda a, b, c, t: OPS.packed_decode_attention(
            a, b, c, t, dspec))

        def split_round(a, b, c, e, f, g, t):
            return prefill_fn(a, b, c), decode_fn(e, f, g, t)

        # correctness gate: the fused launch IS the split pair
        o_p, o_d = fused_fn(qp, kp, vp, qd, kc, vc, jnp.asarray(tbl))
        w_p, w_d = split_round(qp, kp, vp, qd, kc, vc, jnp.asarray(dtbl))
        np.testing.assert_allclose(np.asarray(o_p), np.asarray(w_p),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(o_d), np.asarray(w_d),
                                   rtol=2e-5, atol=2e-5)

        t_fused = _time(fused_fn, qp, kp, vp, qd, kc, vc, jnp.asarray(tbl))
        t_split = _time(split_round, qp, kp, vp, qd, kc, vc,
                        jnp.asarray(dtbl))
        tiles_lockstep = psched.steps + slots * max(
            -(-kl // block) for kl in kv_lens)
        rows.append({
            "skew": skew, "kv_lens": kv_lens, "admit_lens": list(admit_lens),
            "block": block, "slots": slots,
            "launches": {"fused": 1, "split": 2, "lockstep_split": 2},
            "tiles": {"fused": needed, "split": needed,
                      "lockstep_split": tiles_lockstep},
            "waste_vs_fused": tiles_lockstep / needed,
            "times_ms": {"fused": t_fused * 1e3, "split": t_split * 1e3},
        })
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def main(smoke: bool = False,
         out_path: str = "artifacts/bench_continuous.json"):
    rows = run(base_len=64 if smoke else 256,
               admit_lens=(16, 8) if smoke else (64, 32),
               block=8 if smoke else 16, out_path=out_path)
    for r in rows:
        t, tm = r["tiles"], r["times_ms"]
        print(f"  skew {r['skew']:3d}x kv={r['kv_lens']} "
              f"admit={r['admit_lens']}: launches fused=1 split=2; "
              f"tiles fused={t['fused']} "
              f"lockstep-split={t['lockstep_split']} "
              f"({r['waste_vs_fused']:.2f}x waste) "
              f"t_fused={tm['fused']:.2f}ms t_split={tm['split']:.2f}ms")
    hi = rows[-1]
    assert hi["launches"]["fused"] == 1 < hi["launches"]["split"], (
        "the fused step must pay ONE launch where split pays two")
    assert hi["tiles"]["fused"] == hi["tiles"]["split"] < \
        hi["tiles"]["lockstep_split"], (
        "the fused grid must carry exactly the split tiles and beat the "
        "lockstep pad-to-max decode half under position skew")
    print(f"  OK: 1 launch, {hi['tiles']['fused']} tiles < "
          f"{hi['tiles']['lockstep_split']} lockstep-split tiles at "
          f"{hi['skew']}x skew")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes (CI tier, scripts/check.sh)")
    args = ap.parse_args()
    import os

    os.makedirs("artifacts", exist_ok=True)
    main(smoke=args.smoke)
