"""LTM vs BB causal flash attention: compiled-artifact accounting + CPU
wall-clock.

This is the paper's technique applied to its dominant modern td-problem.
Three measurements per (seq, block):

  1. grid steps (launched tiles): T = n(n+1)/2 vs n^2 — the paper's O(n^2)
     -> O(n) wasted-block claim at tile granularity,
  2. trip-count-corrected HLO dot-FLOPs of the compiled programs (the
     structural analogue of the paper's dummy-kernel cost),
  3. CPU wall-clock of both compiled scans.

Extends beyond the paper with the BandSchedule (sliding-window) and
PrefixSchedule (VLM) domains.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp

from benchmarks._util import best_of as _time
from repro.core import mapping as M
from repro.kernels.tri_attn import ops as AO
from repro.roofline import hlo_parse as H




def _flops(fn, *args) -> float:
    comp = jax.jit(fn).lower(*args).compile()
    return H.analyze(comp.as_text())["flops"]


def run(seqs=(1024, 2048), block: int = 128, out_path=None):
    rows = []
    b, h, hkv, d = 2, 4, 2, 64
    key = jax.random.key(0)
    for s in seqs:
        q = jax.random.normal(key, (b, h, s, d), jnp.float32)
        k = jax.random.normal(key, (b, hkv, s, d), jnp.float32)
        v = jax.random.normal(key, (b, hkv, s, d), jnp.float32)
        n = s // block

        def ltm(q, k, v):
            return AO.triangular_attention(q, k, v, impl="scan",
                                           block_q=block, block_k=block)

        def band(q, k, v):
            return AO.triangular_attention(q, k, v, impl="scan",
                                           window=s // 4, block_q=block,
                                           block_k=block)

        # BB baseline as a scan over the full n^2 grid (guarded) — mirrors
        # kernel.py's fwd_bb structure in pure XLA for CPU timing.
        def bb(q, k, v):
            from repro.kernels.tri_attn.kernel import TriSched
            from repro.kernels.tri_attn import scan_impl as SC
            sched = AO.make_sched(s, block_q=block, block_k=block)
            return _bb_scan(q, k, v, sched)

        t_ltm = _time(jax.jit(ltm), q, k, v)
        t_bb = _time(jax.jit(bb), q, k, v)
        t_band = _time(jax.jit(band), q, k, v)
        f_ltm = _flops(ltm, q, k, v)
        f_bb = _flops(bb, q, k, v)
        f_band = _flops(band, q, k, v)
        rows.append({
            "seq": s, "block": block, "tiles_ltm": M.tri(n),
            "tiles_bb": n * n,
            "tiles_band": M.band_blocks(n, (s // 4) // block + 1),
            "t_ltm_ms": t_ltm * 1e3, "t_bb_ms": t_bb * 1e3,
            "t_band_ms": t_band * 1e3,
            "I_wallclock": t_bb / t_ltm,
            "flops_ltm": f_ltm, "flops_bb": f_bb, "flops_band": f_band,
            "I_flops": f_bb / f_ltm,
        })
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def _bb_scan(q, k, v, sched):
    """Full-grid causal attention scan (the BB space of computation)."""
    from repro.kernels.tri_attn.kernel import MASK_VALUE, _token_mask
    b, h, s_len, dd = q.shape
    hkv = k.shape[1]
    g = h // hkv
    bq, bk, n = sched.bq, sched.bk, sched.n
    scale = 1.0 / (dd ** 0.5)
    qg = q.reshape(b, hkv, g, s_len, dd)

    def cell(qc, kc, vc):  # (G, S, D), (S, D), (S, D)
        def step(carry, lam):
            m, l, acc, out = carry
            i, j = lam // n, lam % n
            reset = j == 0

            def body(m, l, acc):
                qi = jax.lax.dynamic_slice(
                    qc, (0, i * bq, 0), (g, bq, dd)).astype(jnp.float32)
                kj = jax.lax.dynamic_slice(
                    kc, (j * bk, 0), (bk, dd)).astype(jnp.float32)
                vj = jax.lax.dynamic_slice(
                    vc, (j * bk, 0), (bk, dd)).astype(jnp.float32)
                s_ = jnp.einsum("gqd,kd->gqk", qi, kj) * scale
                s_ = jnp.where(_token_mask(sched, i, j, bq, bk)[None], s_,
                               MASK_VALUE)
                m_new = jnp.maximum(m, jnp.max(s_, axis=-1))
                alpha = jnp.exp(m - m_new)
                p = jnp.exp(s_ - m_new[..., None])
                l_ = l * alpha + jnp.sum(p, axis=-1)
                acc_ = acc * alpha[..., None] + jnp.einsum("gqk,kd->gqd", p,
                                                           vj)
                return m_new, l_, acc_

            m = jnp.where(reset, MASK_VALUE, m)
            l = jnp.where(reset, 0.0, l)
            acc = jnp.where(reset, 0.0, acc)
            # paper's optimized BB: guard whole tile by block coords
            m, l, acc = jax.lax.cond(j <= i, lambda: body(m, l, acc),
                                     lambda: (m, l, acc))
            out = jax.lax.cond(
                j == n - 1,
                lambda: jax.lax.dynamic_update_slice(
                    out, (acc / l[..., None]).astype(out.dtype),
                    (0, i * bq, 0)),
                lambda: out)
            return (m, l, acc, out), None

        init = (jnp.full((g, bq), MASK_VALUE, jnp.float32),
                jnp.zeros((g, bq), jnp.float32),
                jnp.zeros((g, bq, dd), jnp.float32),
                jnp.zeros((g, s_len, dd), qc.dtype))
        (_, _, _, out), _ = jax.lax.scan(
            step, init, jnp.arange(n * n, dtype=jnp.int32))
        return out

    out = jax.vmap(jax.vmap(cell))(qg, k, v)
    return out.reshape(b, h, s_len, dd)


def main():
    rows = run(out_path="artifacts/bench_attention.json")
    print(f"{'seq':>6} {'tiles L/B':>12} {'I_wall':>7} {'I_flops':>8} "
          f"{'ltm ms':>8} {'bb ms':>8}")
    for r in rows:
        print(f"{r['seq']:6d} {r['tiles_ltm']:5d}/{r['tiles_bb']:5d} "
              f"{r['I_wallclock']:7.3f} {r['I_flops']:8.3f} "
              f"{r['t_ltm_ms']:8.2f} {r['t_bb_ms']:8.2f}")


if __name__ == "__main__":
    main()
