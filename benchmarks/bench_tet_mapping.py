"""BB-3D vs tetrahedral launch — the paper's Fig. 3 methodology in 3D.

Structural columns (hardware-independent): blocks launched by the 3D
bounding box (n^3) vs the tetrahedral map (n(n+1)(n+2)/6) and the waste
fraction, which grows to 5/6 — the reason an exact lambda -> (i,j,k) map
pays off even more in 3D than g(lambda) did in 2D (Navarro et al.,
arXiv 1606.08881).

Wall-clock columns (CPU analogue of the dummy kernel): a jitted vectorized
tet_map over every launched tet lambda vs the BB-3D div/mod + simplex
guard over every launched cube lambda, plus the 3-body triplet kernel
(scan impls) at small scale.

On an accelerator backend, --accelerator times the REAL Pallas tet kernel
(interpret=False, block=128, production scale) against the BB-3D Pallas
baseline instead of the scan-at-toy-scale stand-ins; on CPU the flag
falls back to the scan impls with a note (ROADMAP open item).

  PYTHONPATH=src python -m benchmarks.bench_tet_mapping [--accelerator]
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from benchmarks._util import best_of as _time
from repro.core import mapping as M

RHO = 8  # assumed block edge (rho^3-point tiles) for the N column


@jax.jit
def _tet_dummy(lams):
    i, j, k = M.tet_map(lams)
    return i + j + k


@jax.jit
def _bb3_dummy(lams_n):
    lams, n = lams_n
    i, j, k = M.bb3_map(lams, n)
    return jnp.where(M.bb3_active(i, j, k), i + j + k, -1)


def run(n_values=None, out_path: str | None = None) -> list:
    if n_values is None:
        n_values = [16, 32, 64, 128, 256]
    rows = []
    for n in n_values:
        t3 = M.tet(n)
        bb3 = M.bb3_blocks(n)
        lam_tet = jnp.arange(t3, dtype=jnp.int32)
        lam_bb3 = jnp.arange(bb3, dtype=jnp.int32)
        t_tet = _time(_tet_dummy, lam_tet)
        t_bb3 = _time(_bb3_dummy, (lam_bb3, jnp.int32(n)))
        rows.append({
            "N": n * RHO, "n": n,
            "launched_tet": t3,
            "launched_bb3": bb3,
            "wasted_bb3": M.wasted_blocks_bb3(n),
            "waste_fraction_bb3": M.wasted_blocks_bb3(n) / bb3,
            "launch_reduction": bb3 / t3,
            "times_ms": {"tet": t_tet * 1e3, "bb3": t_bb3 * 1e3},
            "improvement_I_vs_bb3": t_bb3 / t_tet,
        })
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def kernel_run(n_rows: int = 32, block: int = 8, d: int = 4, *,
               accelerator: bool = False) -> dict:
    """3-body triplet reduction wall-clock.

    Default: tet scan vs BB-3D scan at toy scale (CPU-friendly).
    accelerator=True on a non-CPU backend: the real Pallas kernels with
    interpret=False and block=128 at production tile counts — the numbers
    that actually validate the launch-reduction claim on hardware.
    """
    from repro.kernels.tri_3body import ops as OPS

    backend = jax.default_backend()
    on_hw = accelerator and backend != "cpu"
    if accelerator and not on_hw:
        print(f"--accelerator requested but backend is {backend!r}; "
              "falling back to scan impls at toy scale")
    if on_hw:
        block = 128
        n_rows = 16 * block  # n = 16 tiles/side: tet 816 vs bb3 4096 tiles
        d = max(d, 64)
        impls = ("pallas", "bb3")
        interpret = False
    else:
        impls = ("scan", "bb3_scan")
        interpret = True

    x = jax.random.normal(jax.random.PRNGKey(0), (n_rows, d), jnp.float32)
    tet_fn = jax.jit(lambda v: OPS.three_body(
        v, block, impl=impls[0], interpret=interpret))
    bb3_fn = jax.jit(lambda v: OPS.three_body(
        v, block, impl=impls[1], interpret=interpret))
    t_tet = _time(tet_fn, x)
    t_bb3 = _time(bb3_fn, x)
    n = n_rows // block
    return {"n_rows": n_rows, "block": block, "d": d,
            "backend": backend, "impls": impls,
            "tiles_tet": M.tet(n), "tiles_bb3": n ** 3,
            "t_tet_ms": t_tet * 1e3, "t_bb3_ms": t_bb3 * 1e3,
            "I_wallclock": t_bb3 / t_tet}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--accelerator", action="store_true",
                    help="time the Pallas tet kernel with interpret=False "
                         "and block=128 (needs a non-CPU backend)")
    args = ap.parse_args(argv)
    rows = run(out_path="artifacts/bench_tet_mapping.json")
    print(f"{'N':>6} {'tet':>10} {'bb3':>11} {'waste%':>7} {'reduce':>7} "
          f"{'I(map)':>7}")
    for r in rows:
        print(f"{r['N']:6d} {r['launched_tet']:10d} {r['launched_bb3']:11d} "
              f"{100 * r['waste_fraction_bb3']:6.1f}% "
              f"{r['launch_reduction']:6.2f}x "
              f"{r['improvement_I_vs_bb3']:7.3f}")
    k = kernel_run(accelerator=args.accelerator)
    print(f"3-body kernel (N={k['n_rows']}, b={k['block']}, "
          f"{k['impls'][0]}/{k['impls'][1]} on {k['backend']}): "
          f"tiles {k['tiles_tet']}/{k['tiles_bb3']} "
          f"tet={k['t_tet_ms']:.1f}ms bb3={k['t_bb3_ms']:.1f}ms "
          f"I={k['I_wallclock']:.3f}")


if __name__ == "__main__":
    main()
