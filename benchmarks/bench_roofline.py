"""Roofline table generator: reads artifacts/dryrun/*.json -> markdown.

One row per (arch x shape x mesh) cell with the three terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, and the per-device memory footprint.
"""

from __future__ import annotations

import glob
import json
import os


def load(dryrun_dir: str = "artifacts/dryrun"):
    recs = []
    for p in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b):
    return f"{b/2**30:.2f} GiB"


def table(recs, mesh: str = "single", tag: str = "") -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | mem(adj)_s | "
        "collective_s | dominant(adj) | useful | MFU@bound(adj) | mem/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh or r.get("tag", "") != tag:
            continue
        if not r.get("supported", True):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"skipped | — | — | — |")
            continue
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"FAILED: {r.get('error','')[:40]} | — | — | — |")
            continue
        rf = r["roofline"]
        mem = r["analysis"]["memory"]["peak_bytes_per_device"]
        madj = rf.get("memory_kernel_adj_s", rf["memory_s"])
        mfua = rf.get("mfu_at_bound_kernel_adj", rf["mfu_at_bound"])
        dom = rf.get("dominant_kernel_adj", rf["dominant"])
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4f} | "
            f"{rf['memory_s']:.4f} | {madj:.4f} | "
            f"{rf['collective_s']:.4f} | "
            f"**{dom}** | {rf['useful_flops_ratio']:.2f} | "
            f"{mfua:.4f} | {fmt_bytes(mem)} |")
    return "\n".join(lines)


def summary(recs, tag=None) -> dict:
    out = {"total": 0, "ok": 0, "skipped": 0, "failed": 0}
    for r in recs:
        if tag is not None and r.get("tag", "") != tag:
            continue
        out["total"] += 1
        if not r.get("supported", True):
            out["skipped"] += 1
        elif r.get("ok"):
            out["ok"] += 1
        else:
            out["failed"] += 1
    return out


def main():
    import sys
    tag = sys.argv[1] if len(sys.argv) > 1 else ""
    recs = load()
    print("baseline:", summary(recs, ""), " optimized:", summary(recs, "opt"))
    for mesh in ("single", "multi"):
        print(f"\n### mesh={mesh} tag={tag or 'baseline'}\n")
        print(table(recs, mesh, tag))


if __name__ == "__main__":
    main()
