"""Paper Fig. 3 reproduction (structural + CPU wall-clock).

The paper's 'dummy kernel' isolates the mapping cost: each block computes
its (i, j) and writes i+j. The CPU analogue times a jitted vectorized map
over every launched block index for each strategy; the structural columns
(launched / useful / wasted blocks, block-ratio-vs-BB) are hardware-
independent and reproduce the right panel of Fig. 3 exactly.

The paper's three sqrt variants (LTM-X sqrtf / LTM-N Newton / LTM-R rsqrt)
are reproduced as: exact integer-corrected sqrt (ours), float rsqrt + eps
(the paper's LTM-R), both compared for exactness over the paper's range.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import best_of as _time
from repro.core import analysis as A
from repro.core import mapping as M

RHO = 16  # paper blocksize 16x16




@jax.jit
def _ltm_dummy(lams):
    i, j = M.ltm_map(lams)
    return i + j


@jax.jit
def _ltm_r_dummy(lams):
    i, j = M.ltm_map_float_r(lams)
    return i + j


@jax.jit
def _bb_dummy(lams_n):
    lams, n = lams_n
    i, j = lams // n, lams % n
    return jnp.where(j <= i, i + j, -1)


@jax.jit
def _utm_dummy(lams_n):
    lams, n = lams_n
    a, b = M.utm_map(jnp.minimum(lams, M.tri(n - 1) - 1), n)
    return a + b


@jax.jit
def _rb_dummy(lams_n):
    lams, n = lams_n
    h, w = M.rb_grid_shape(n)
    y, x = lams // w, lams % w
    i, j = M.rb_map(x, y, n)
    return jnp.where(M.rb_valid(x, y, n), i + j, -1)


def run(n_values=None, out_path: str | None = None) -> list:
    if n_values is None:
        n_values = [64, 128, 256, 512, 1024, 1536, 1920]  # N = rho * n
    rows = []
    for n in n_values:
        stats = A.strategy_stats(n, band_w=max(2, n // 8), rec_m=1)
        t = M.tri(n)
        lam_t = jnp.arange(t, dtype=jnp.int32)
        lam_bb = jnp.arange(n * n, dtype=jnp.int32)
        h, w = M.rb_grid_shape(n)
        lam_rb = jnp.arange(h * w, dtype=jnp.int32)
        nj = jnp.int32(n)

        times = {
            "ltm": _time(_ltm_dummy, lam_t),
            "ltm_r": _time(_ltm_r_dummy, lam_t),
            "bb": _time(_bb_dummy, (lam_bb, nj)),
            "utm": _time(_utm_dummy, (lam_t, nj)),
            "rb": _time(_rb_dummy, (lam_rb, nj)),
        }
        row = {
            "N": n * RHO, "n": n,
            "times_ms": {k: v * 1e3 for k, v in times.items()},
            "improvement_I_vs_bb": {k: times["bb"] / v
                                    for k, v in times.items()},
            "blocks": {k: dataclass_dict(s) for k, s in stats.items()},
        }
        rows.append(row)
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def dataclass_dict(s):
    return {"launched": s.launched, "useful": s.useful, "wasted": s.wasted,
            "block_ratio_vs_bb": s.block_ratio_vs_bb}


def exactness_check(max_n: int = 4096) -> dict:
    """Paper §III: LTM-R (rsqrt + eps) exactness envelope vs exact isqrt."""
    lam = jnp.arange(M.tri(max_n), dtype=jnp.int32)
    i_exact, j_exact = M.ltm_map(lam)
    i_r, j_r = M.ltm_map_float_r(lam)
    mism = int(jnp.sum(i_exact != i_r))
    first_bad = (int(lam[jnp.argmax(i_exact != i_r)]) if mism else None)
    return {"n": max_n, "N": max_n * RHO, "lambda_range": int(lam.shape[0]),
            "ltm_r_mismatches": mism, "first_bad_lambda": first_bad}


def main():
    rows = run(out_path="artifacts/bench_mapping.json")
    print(f"{'N':>6} {'I(ltm)':>7} {'I(rb)':>7} {'I(utm)':>7} "
          f"{'bb waste':>9} {'ltm waste':>9}")
    for r in rows:
        ii = r["improvement_I_vs_bb"]
        print(f"{r['N']:6d} {ii['ltm']:7.3f} {ii['rb']:7.3f} "
              f"{ii['utm']:7.3f} {r['blocks']['bb']['wasted']:9d} "
              f"{r['blocks']['ltm']['wasted']:9d}")
    ex = exactness_check()
    print("LTM-R exactness:", ex)


if __name__ == "__main__":
    main()
