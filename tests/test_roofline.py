"""HLO-parser tests: trip-count-corrected FLOPs on known programs, the
synthetic-HLO fixture, and the roofline term arithmetic."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry as REG
from repro.roofline import hlo_parse as H
from repro.roofline import model as RF


def _flops_of(fn, *args):
    comp = jax.jit(fn).lower(*args).compile()
    return H.analyze(comp.as_text())


def test_plain_matmul_flops_exact():
    a = jnp.ones((64, 128), jnp.float32)
    b = jnp.ones((128, 32), jnp.float32)
    an = _flops_of(lambda a, b: a @ b, a, b)
    assert an["flops"] == 2 * 64 * 128 * 32


def test_scan_multiplies_by_trip_count():
    w = jnp.ones((64, 64), jnp.float32)
    x = jnp.ones((8, 64), jnp.float32)

    def f(x, w):
        def step(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(step, x, None, length=17)
        return y

    an = _flops_of(f, x, w)
    assert an["flops"] == 17 * 2 * 8 * 64 * 64
    assert an["unknown_trip_loops"] == 0
    # XLA's own cost_analysis counts the body once — this is the bug the
    # parser exists to fix; keep the regression visible:
    from repro.launch.compat import cost_analysis_dict

    comp = jax.jit(f).lower(x, w).compile()
    xla_flops = cost_analysis_dict(comp).get("flops", 0.0)
    assert xla_flops <= an["flops"] / 16


def test_nested_scan_multiplies():
    w = jnp.ones((16, 16), jnp.float32)
    x = jnp.ones((4, 16), jnp.float32)

    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    an = _flops_of(f, x, w)
    assert an["flops"] == 3 * 5 * 2 * 4 * 16 * 16


def test_synthetic_collective_fixture():
    hlo = """
HloModule test, num_partitions=4

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (p: f32[128,256]) -> f32[128,256] {
  %p = f32[128,256]{1,0} parameter(0)
  %ag = f32[128,256]{1,0} all-gather(%p), dimensions={0}
  ROOT %ar = f32[128,256]{1,0} all-reduce(%ag), to_apply=%add
}
"""
    an = H.analyze(hlo)
    assert an["collective_bytes"]["all-gather"] == 128 * 256 * 4
    assert an["collective_bytes"]["all-reduce"] == 128 * 256 * 4
    assert an["collective_bytes_total"] == 2 * 128 * 256 * 4


def test_tuple_shape_bytes():
    assert H._shape_bytes("(f32[2,3]{1,0}, bf16[4])") == 2 * 3 * 4 + 4 * 2
    assert H._shape_bytes("pred[]") == 1
    assert H._shape_bytes("s32[]") == 4


def test_roofline_terms_and_dominance():
    an = {"flops": 197e12, "hbm_bytes": 819e9 / 2,
          "collective_bytes_total": 50e9 / 4}
    t = RF.terms_from_analysis(an, n_chips=4, model_flops=4 * 197e12 / 2)
    assert t.dominant == "compute"
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 0.5) < 1e-9
    assert abs(t.collective_s - 0.25) < 1e-9
    assert abs(t.useful_flops_ratio - 0.5) < 1e-9
    assert abs(t.mfu_at_bound - 0.5) < 1e-9


def test_model_flops_train_vs_decode():
    cfg = REG.get_config("yi-9b")
    train = RF.model_flops(cfg, REG.get_shape("train_4k"))
    dec = RF.model_flops(cfg, REG.get_shape("decode_32k"))
    # train: 6*N*B*S; decode: 2*N*B
    assert train / dec == pytest.approx(
        (6 * 256 * 4096) / (2 * 128), rel=1e-6)


def test_attention_scan_flop_ratio_matches_tiles():
    """The compiled LTM attention executes T(n)/n^2 of the BB dot-FLOPs —
    the paper's improvement, visible in the compiled artifact."""
    from benchmarks.bench_attention import run
    r = run(seqs=(512,), block=64)[0]
    n = 512 // 64
    expect = (n * n) / (n * (n + 1) / 2)
    assert r["I_flops"] == pytest.approx(expect, rel=1e-6)
