"""Property + unit tests for the packed multi-domain schedule
(core/packing.py) and the packed ragged-prefill attention built on it.

The acceptance claims: PackedSchedule launches exactly
sum(member.num_blocks) blocks for a mixed batch (zero interior waste,
verified by an enumerate_host bijection), the traced map matches the host
map everywhere, and the packed attention path equals the per-request path
bit-for-bit (scan impl) / to tolerance (pallas interpret).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import oracles as O
from repro.core import schedule as S
from repro.core.packing import PackedSchedule, padded_bb_blocks
from repro.kernels.tri_attn import ops as OPS


def _mixed_members():
    return (S.TriangularSchedule(n=3), S.BandSchedule(n=5, w=2),
            S.PrefixSchedule(n=4, p=2), S.TriangularSchedule(n=1),
            S.RowSchedule(n=2), S.PrefixSchedule(n=3, p=0),
            S.BandSchedule(n=4, w=9), S.RowSchedule(n=1))


def _member_from(kind: int, n: int, param: int):
    if kind == 0:
        return S.TriangularSchedule(n=n)
    if kind == 1:
        return S.BandSchedule(n=n, w=max(1, param))
    if kind == 2:
        return S.PrefixSchedule(n=n, p=param % (n + 1))
    return S.RowSchedule(n=n)  # the decode-round member


# ---------------------------------------------------------------------------
# Structure: offsets, zero waste, bijection
# ---------------------------------------------------------------------------


def test_offsets_monotone_and_total():
    pk = PackedSchedule.from_members(_mixed_members())
    offs = pk.offsets
    assert offs[0] == 0 and offs[-1] == pk.num_blocks
    assert all(b > a for a, b in zip(offs, offs[1:]))  # every member owns >0
    assert pk.num_blocks == sum(m.num_blocks for m in pk.members)
    rows = pk.row_offsets
    assert rows[-1] == pk.n == sum(m.n for m in pk.members)


def test_zero_interior_waste_bijection():
    """The acceptance criterion: exactly sum(member.num_blocks) blocks,
    enumerating each member's domain exactly once (tagged union)."""
    pk = PackedSchedule.from_members(_mixed_members())
    seen = pk.enumerate_host()
    assert len(seen) == len(set(seen)) == pk.num_blocks
    assert pk.num_blocks == pk.domain_blocks  # zero waste
    expect = {(r, i, j) for r, m in enumerate(pk.members)
              for (i, j) in m.enumerate_host()}
    assert set(seen) == expect
    assert pk.waste_fraction == 0.0


def test_host_roundtrip_exhaustive():
    pk = PackedSchedule.from_members(_mixed_members())
    for lam in range(pk.num_blocks):
        r, i, j = pk.host_map(lam)
        assert pk.pack_lambda(r, i, j) == lam


def test_traced_matches_host_exhaustive():
    pk = PackedSchedule.from_members(_mixed_members())
    lams = jnp.arange(pk.num_blocks, dtype=jnp.int32)
    rt, it, jt = jax.jit(jax.vmap(pk.index_map))(lams)
    for lam in range(pk.num_blocks):
        assert (int(rt[lam]), int(it[lam]), int(jt[lam])) == pk.host_map(lam)


def test_packed_rows_traced_matches_host():
    pk = PackedSchedule.from_members(_mixed_members())
    lams = jnp.arange(pk.num_blocks, dtype=jnp.int32)
    qr, kr = jax.jit(jax.vmap(pk.packed_rows))(lams)
    for lam in range(pk.num_blocks):
        r, i, j = pk.host_map(lam)
        base = pk.row_offsets[r]
        assert (int(qr[lam]), int(kr[lam])) == (base + i, base + j)


@given(st.lists(st.integers(min_value=0, max_value=3), min_size=1,
                max_size=6),
       st.data())
@settings(max_examples=25)
def test_property_roundtrip_random_members(kinds, data):
    members = tuple(
        _member_from(k, data.draw(st.integers(min_value=1, max_value=9)),
                     data.draw(st.integers(min_value=0, max_value=9)))
        for k in kinds)
    pk = PackedSchedule.from_members(members)
    assert pk.num_blocks == sum(m.num_blocks for m in members)
    lam = data.draw(st.integers(min_value=0, max_value=pk.num_blocks - 1))
    r, i, j = pk.host_map(lam)
    assert 0 <= r < len(members)
    li, lj = members[r].host_map(lam - pk.offsets[r])
    assert (i, j) == (li, lj)
    assert pk.pack_lambda(r, i, j) == lam
    rt, it, jt = jax.jit(pk.index_map)(jnp.int32(lam))
    assert (int(rt), int(it), int(jt)) == (r, i, j)


# ---------------------------------------------------------------------------
# Segment bookkeeping
# ---------------------------------------------------------------------------


def test_seg_counts_equal_sum_of_member_rows():
    pk = PackedSchedule.from_members(_mixed_members())
    lams = jnp.arange(pk.num_blocks, dtype=jnp.int32)
    starts = jax.jit(jax.vmap(pk.seg_start))(lams)
    ends = jax.jit(jax.vmap(pk.seg_end))(lams)
    # one segment per distinct (request, row) — RowSchedule members are a
    # single n-tile row, so this is NOT sum(m.n)
    rows = len({(r, i) for r, i, _ in pk.enumerate_host()})
    assert int(jnp.sum(starts)) == rows
    assert int(jnp.sum(ends)) == rows


def test_seg_predicates_match_row_transitions():
    pk = PackedSchedule.from_members(_mixed_members())
    lams = jnp.arange(pk.num_blocks, dtype=jnp.int32)
    starts = jax.jit(jax.vmap(pk.seg_start))(lams)
    ends = jax.jit(jax.vmap(pk.seg_end))(lams)
    prev = None
    for lam in range(pk.num_blocks):
        outer = pk.host_map(lam)[:2]  # (request, row)
        is_start = outer != prev
        is_end = (lam == pk.num_blocks - 1
                  or pk.host_map(lam + 1)[:2] != outer)
        assert bool(starts[lam]) == is_start == pk.host_seg_start(lam), lam
        assert bool(ends[lam]) == is_end == pk.host_seg_end(lam), lam
        prev = outer


# ---------------------------------------------------------------------------
# Registration + validation
# ---------------------------------------------------------------------------


def test_make_schedule_packed_registration():
    members = _mixed_members()
    pk = S.make_schedule("packed", 0, members=members)
    assert isinstance(pk, PackedSchedule)
    assert pk.num_blocks == sum(m.num_blocks for m in members)
    with pytest.raises(ValueError, match="packed n"):
        S.make_schedule("packed", 1, members=members)


def test_unsupported_members_rejected():
    with pytest.raises(TypeError, match="unsupported member"):
        PackedSchedule.from_members((S.DenseSchedule(n=3),))
    with pytest.raises(ValueError, match="diagonal"):
        PackedSchedule.from_members(
            (S.TriangularSchedule(n=3, include_diagonal=False),))
    with pytest.raises(ValueError, match="at least one member"):
        PackedSchedule.from_members(())


def test_padded_bb_baseline_counts():
    members = _mixed_members()
    n_max = max(m.n for m in members)
    assert padded_bb_blocks(members) == len(members) * n_max * n_max
    assert padded_bb_blocks(members) > \
        PackedSchedule.from_members(members).num_blocks


# ---------------------------------------------------------------------------
# Packed ragged-prefill attention
# ---------------------------------------------------------------------------


def _qkv(lens, h=4, hkv=2, d=8, seed=0):
    return O.rand_qkv(seed, 1, h, hkv, sum(lens), d)


@pytest.mark.parametrize("window,prefix", [(None, 0), (10, 0),
                                           (None, (0, 12, 0, 8))])
def test_packed_attention_matches_per_request(window, prefix):
    """Packed-prefill output equivalence vs the per-request path: the scan
    impl is BITWISE identical per request segment (same tile enumeration,
    same online-softmax op order)."""
    blk, lens = 8, (24, 16, 40, 8)
    q, k, v = _qkv(lens)
    ps = OPS.make_packed_sched(lens, block=blk, window=window,
                               prefix=list(prefix) if isinstance(
                                   prefix, tuple) else prefix)
    out = OPS.packed_prefill_attention(q, k, v, ps, impl="scan")
    base = 0
    for r, s_r in enumerate(lens):
        seg = slice(base, base + s_r)
        p_r = prefix[r] if isinstance(prefix, tuple) else prefix
        single = OPS.triangular_attention(
            q[:, :, seg], k[:, :, seg], v[:, :, seg], window=window,
            prefix=p_r, impl="scan", block_q=blk, block_k=blk)
        np.testing.assert_array_equal(np.asarray(out[:, :, seg]),
                                      np.asarray(single))
        base += s_r


def test_packed_pallas_matches_scan_and_ref():
    blk, lens = 8, (16, 32, 8)
    q, k, v = _qkv(lens, seed=1)
    ps = OPS.make_packed_sched(lens, block=blk)
    sc = OPS.packed_prefill_attention(q, k, v, ps, impl="scan")
    pal = OPS.packed_prefill_attention(q, k, v, ps, impl="pallas")
    ref = OPS.packed_prefill_attention(q, k, v, ps, impl="ref")
    O.assert_close(pal, sc, "attn")
    O.assert_close(sc, ref, "attn")


def test_make_packed_sched_rejects_short_param_lists():
    """Regression: a window/prefix list shorter than the batch used to be
    zip-truncated, silently dropping requests (all-zero outputs)."""
    with pytest.raises(AssertionError, match="per-request"):
        OPS.make_packed_sched((16, 8, 16), block=8, window=[8, 8])
    with pytest.raises(AssertionError, match="per-request"):
        OPS.make_packed_sched((16, 8), block=8, prefix=[4])


def test_packed_attention_rejects_wrong_operand_length():
    ps = OPS.make_packed_sched((16, 8), block=8)
    q, k, v = _qkv((16, 16))  # 32 packed rows vs a 24-row schedule
    with pytest.raises(AssertionError, match="packed operand"):
        OPS.packed_prefill_attention(q, k, v, ps, impl="scan")


def test_packed_sched_launch_counts():
    """One launch covers sum_r tri(n_r) tiles — the structural claim the
    engine's stats counter asserts end-to-end."""
    from repro.core import mapping as M

    blk, lens = 8, (24, 16, 40, 8)
    ps = OPS.make_packed_sched(lens, block=blk)
    assert ps.steps == sum(M.tri(s // blk) for s in lens)
    assert ps.s_total == sum(lens)
    # no cross-request tiles: every k row's request == its q row's request
    from repro.kernels.tri_attn.kernel import _packed_decode

    tbl = jnp.asarray(ps.table())
    for lam in range(ps.steps):
        r, i, j, qrow, krow = (int(x) for x in _packed_decode(
            jnp.int32(lam), tbl, len(ps.members)))
        base, n_r = int(tbl[1, r]), int(tbl[2, r])
        assert base <= qrow < base + n_r
        assert base <= krow < base + n_r
        assert j <= i
