"""EDM kernel validation vs the shared numpy oracle (tests/oracles.py),
sweeping shapes/dtypes/features.

Mirrors the paper's experiment grid (features d in 1..4, plus larger d) at
CPU-test scale. The in-package jnp ref (ref.py) keeps its pack/unpack
round-trip coverage; distance values diff against the independent float64
oracle.
"""

import jax.numpy as jnp
import numpy as np
import pytest

import oracles as O
from repro.core import mapping as M
from repro.kernels.tri_edm import ops as OPS
from repro.kernels.tri_edm import ref as REF


@pytest.mark.parametrize("impl", ["pallas", "scan"])
@pytest.mark.parametrize("d", [1, 2, 3, 4, 16])  # paper uses 1..4 features
@pytest.mark.parametrize("n_rows,block", [(32, 8), (64, 16), (96, 32)])
def test_edm_packed_matches_oracle(impl, d, n_rows, block):
    x = O.rand_points(d, n_rows, d)
    got = OPS.edm(x, block, impl=impl)
    want = O.edm_packed_oracle(x, block)
    assert got.shape == (M.tri(n_rows // block), block, block)
    # 'edm' tolerance: sqrt amplifies f32 roundoff of d^2 ~ 0 on diagonal
    # blocks (|x_i - x_j|^2 via a+b-2ab differs from the direct reduction).
    O.assert_close(got, want, "edm")


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_edm_dtypes(dtype):
    x = O.rand_points(0, 32, 4).astype(dtype)
    got = OPS.edm(x, 8, impl="pallas")
    want = O.edm_packed_oracle(x, 8)
    O.assert_close(got, want, "edm", dtype)


def test_edm_bb_matches_full_lower():
    """BB baseline writes the lower triangle of the full matrix; §IV: every
    strategy must produce the same (correct) output."""
    x = O.rand_points(1, 64, 3)
    got = np.asarray(OPS.edm(x, 16, impl="bb"))
    want = O.edm_full_oracle(x)
    n = 64 // 16
    for i in range(n):
        for j in range(n):
            blk = got[i * 16:(i + 1) * 16, j * 16:(j + 1) * 16]
            if j <= i:
                O.assert_close(blk,
                               want[i * 16:(i + 1) * 16, j * 16:(j + 1) * 16],
                               "edm", err_msg=f"block {(i, j)}")
            else:
                np.testing.assert_array_equal(blk, 0.0)


def test_edm_squared():
    x = O.rand_points(2, 32, 4)
    got = OPS.edm(x, 8, impl="scan", squared=True)
    O.assert_close(got, O.edm_packed_oracle(x, 8, squared=True), "edm_sq")


def test_jnp_ref_matches_oracle():
    """In-package jnp ref (used by benches) vs the independent oracle."""
    x = O.rand_points(9, 48, 3)
    O.assert_close(REF.edm_packed_ref(x, 16), O.edm_packed_oracle(x, 16),
                   "edm")


def test_pack_unpack_roundtrip():
    x = O.rand_points(3, 48, 2)
    full = REF.edm_full(x)
    packed = REF.pack_tri(full, 16)
    back = REF.unpack_tri(packed, 48, symmetric=True)
    np.testing.assert_allclose(np.asarray(back), np.asarray(full), atol=1e-6)


def test_dummy_kernel_mapping():
    """Paper's dummy kernel: output block lambda holds i+j."""
    from repro.kernels.tri_edm.kernel import dummy_ltm

    n = 8
    out = np.asarray(dummy_ltm(n))
    for lam in range(M.tri(n)):
        i, j = M.ltm_map(lam)
        assert out[lam, 0] == i + j


def test_packed_memory_is_half():
    """The packed layout achieves the paper's ~half-size claim."""
    n_rows, block = 128, 16
    n = n_rows // block
    packed_elems = M.tri(n) * block * block
    full_elems = n_rows * n_rows
    ratio = packed_elems / full_elems
    assert 0.5 <= ratio <= 0.5 + 1.0 / n  # (n+1)/2n -> 1/2
