"""Model-component unit/consistency tests.

The strongest invariant here: for every family, PREFILL-then-DECODE must
equal the full-sequence FORWARD — i.e. the recurrent/KV cache semantics
match the parallel (triangular-scheduled) formulation exactly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as REG
from repro.models import model as MD
from repro.models import moe as MOE
from repro.models.mamba import init_mamba, init_mamba_state, mamba_mix
from repro.models.rwkv6 import init_rwkv, init_rwkv_state, rwkv_time_mix


# ---------------------------------------------------------------------------
# prefill+decode == forward (the KV/state-cache correctness invariant)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["yi-9b", "mixtral-8x7b", "rwkv6-1.6b",
                                  "jamba-1.5-large-398b", "granite-34b"])
def test_prefill_decode_matches_forward(arch):
    cfg = REG.smoke_config(arch)
    params = MD.init_params(jax.random.key(1), cfg)
    b, s = 2, 32
    toks = jax.random.randint(jax.random.key(2), (b, s), 0, cfg.vocab_size)

    # full forward logits at every position
    hidden, _, _ = MD.forward(params, cfg, {"tokens": toks}, remat=False)
    full_logits = MD.logits_from_hidden(params, cfg, hidden)

    # prefill on the first s-1 tokens, then decode token s-1
    _, cache = MD.prefill_cache(params, cfg, {"tokens": toks[:, :s - 1]},
                                max_len=s, cache_dtype=jnp.float32)
    dec_logits, _ = MD.decode_step(params, cfg, cache, toks[:, s - 1:s],
                                   jnp.int32(s - 1))
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["yi-9b", "rwkv6-1.6b"])
def test_stepwise_decode_matches_forward(arch):
    """Decode every position one-by-one from an empty cache; logits at the
    final position must match the full parallel forward."""
    cfg = REG.smoke_config(arch)
    params = MD.init_params(jax.random.key(1), cfg)
    b, s = 1, 16
    toks = jax.random.randint(jax.random.key(3), (b, s), 0, cfg.vocab_size)
    hidden, _, _ = MD.forward(params, cfg, {"tokens": toks}, remat=False)
    full_logits = MD.logits_from_hidden(params, cfg, hidden)

    cache = MD.init_cache(cfg, b, s, jnp.float32)
    for t in range(s):
        logits, cache = MD.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                       jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-2, atol=2e-2)


def test_sliding_window_rolling_cache():
    """SWA decode with a W-slot rolling buffer == decode with a full cache
    (the window masks out everything the rolling buffer evicts)."""
    cfg = REG.smoke_config("mixtral-8x7b")  # sliding_window=64 reduced
    w = cfg.sliding_window
    params = MD.init_params(jax.random.key(1), cfg)
    b, s = 1, w + 24  # long enough to wrap the rolling buffer
    toks = jax.random.randint(jax.random.key(4), (b, s), 0, cfg.vocab_size)
    hidden, _, _ = MD.forward(params, cfg, {"tokens": toks}, remat=False)
    full_logits = MD.logits_from_hidden(params, cfg, hidden)

    cache = MD.init_cache(cfg, b, s, jnp.float32)  # clamps slots to W
    k_leaf = jax.tree.leaves(cache)[0]
    for t in range(s):
        logits, cache = MD.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                       jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# MoE dispatch
# ---------------------------------------------------------------------------


def _moe_cfg(**kw):
    import dataclasses
    cfg = REG.smoke_config("mixtral-8x7b")
    return dataclasses.replace(cfg, **kw) if kw else cfg


def test_moe_capacity_drop_and_combine():
    cfg = _moe_cfg()
    params = MOE.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    out, aux = MOE.moe_mlp(params, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0.5  # Switch aux ~= 1 for near-uniform routing

    # generous capacity == no drops: doubling capacity shouldn't change much
    import dataclasses
    cfg2 = dataclasses.replace(cfg, capacity_factor=8.0)
    out2, _ = MOE.moe_mlp(params, x, cfg2)
    # with cf=8 nothing is dropped; cf=1.25 may drop a few tokens
    frac_same = float(jnp.mean(jnp.isclose(out, out2, atol=1e-5)))
    assert frac_same > 0.6


def test_moe_is_permutation_invariant_at_high_capacity():
    """With no drops, each token's output is independent of batch order."""
    cfg = _moe_cfg()
    import dataclasses
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = MOE.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 16, cfg.d_model))
    out, _ = MOE.moe_mlp(params, x, cfg)
    perm = jnp.arange(15, -1, -1)
    out_p, _ = MOE.moe_mlp(params, x[:, perm], cfg)
    np.testing.assert_allclose(np.asarray(out[:, perm]), np.asarray(out_p),
                               rtol=1e-4, atol=1e-5)


def test_moe_grads_flow_to_router():
    cfg = _moe_cfg()
    params = MOE.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model))

    def loss(p):
        out, aux = MOE.moe_mlp(p, x, cfg)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    assert float(jnp.linalg.norm(g["router"])) > 0


# ---------------------------------------------------------------------------
# Mamba / RWKV chunked-vs-stepwise equivalence
# ---------------------------------------------------------------------------


def test_mamba_chunked_equals_stepwise():
    cfg = REG.smoke_config("jamba-1.5-large-398b")
    params = init_mamba(jax.random.key(0), cfg, jnp.float32)
    b, s = 2, 40
    x = jax.random.normal(jax.random.key(1), (b, s, cfg.d_model)) * 0.3

    out_full, _ = mamba_mix(params, x, cfg, state=None)
    state = init_mamba_state(cfg, b)
    outs = []
    for t in range(s):
        o, state = mamba_mix(params, x[:, t:t + 1], cfg, state=state)
        outs.append(o)
    out_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_full), np.asarray(out_step),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_chunked_equals_stepwise():
    cfg = REG.smoke_config("rwkv6-1.6b")
    params = init_rwkv(jax.random.key(0), cfg, jnp.float32)
    b, s = 2, 40
    x = jax.random.normal(jax.random.key(1), (b, s, cfg.d_model)) * 0.3

    out_full, _ = rwkv_time_mix(params, x, cfg, state=None)
    st = init_rwkv_state(cfg, b)
    state = {"shift": st["shift"], "s": st["s"]}
    outs = []
    for t in range(s):
        o, state = rwkv_time_mix(params, x[:, t:t + 1], cfg, state=state)
        outs.append(o)
    out_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_full), np.asarray(out_step),
                               rtol=2e-3, atol=2e-3)
