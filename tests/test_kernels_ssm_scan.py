"""Fused selective-scan kernel vs the jnp oracle: shape/dtype sweeps in
interpret mode (per-kernel allclose contract), state chaining, and
consistency with the model's chunked associative-scan formulation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssm_scan import ops as O
from repro.kernels.ssm_scan import ref as R


def _inputs(key, b, l, d, n, dtype):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, d), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, d), dtype) - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (d, n), jnp.float32) * 0.3)
    Bt = jax.random.normal(ks[3], (b, l, n), dtype)
    Ct = jax.random.normal(ks[4], (b, l, n), dtype)
    return x, dt.astype(dtype), A, Bt, Ct


@pytest.mark.parametrize("b,l,d,n", [
    (1, 8, 16, 4),
    (2, 32, 64, 16),
    (2, 128, 256, 16),
    (1, 64, 128, 8),
    (3, 16, 32, 32),
])
def test_allclose_vs_ref_shapes(b, l, d, n):
    x, dt, A, Bt, Ct = _inputs(jax.random.key(0), b, l, d, n, jnp.float32)
    y_k, h_k = O.selective_scan(x, dt, A, Bt, Ct, impl="pallas",
                                block_d=min(64, d), block_l=min(32, l))
    y_r, h_r = R.selective_scan_ref(x, dt, A, Bt, Ct)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    x, dt, A, Bt, Ct = _inputs(jax.random.key(1), 2, 32, 64, 8, dtype)
    y_k, h_k = O.selective_scan(x, dt, A, Bt, Ct, impl="pallas",
                                block_d=32, block_l=16)
    y_r, h_r = R.selective_scan_ref(x, dt, A, Bt, Ct)
    assert y_k.dtype == dtype
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r.astype(jnp.float32)),
                               rtol=tol, atol=tol)


def test_state_chaining_across_calls():
    """scan(x1++x2) == scan(x2, h0=scan(x1).h) — the decode/streaming
    contract."""
    x, dt, A, Bt, Ct = _inputs(jax.random.key(2), 2, 64, 32, 8, jnp.float32)
    y_full, h_full = O.selective_scan(x, dt, A, Bt, Ct, impl="pallas",
                                      block_d=32, block_l=16)
    y1, h1 = O.selective_scan(x[:, :32], dt[:, :32], A, Bt[:, :32],
                              Ct[:, :32], impl="pallas", block_d=32,
                              block_l=16)
    y2, h2 = O.selective_scan(x[:, 32:], dt[:, 32:], A, Bt[:, 32:],
                              Ct[:, 32:], h0=h1, impl="pallas", block_d=32,
                              block_l=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)


def test_matches_model_chunked_formulation():
    """The kernel recurrence equals models/mamba.py's associative-scan
    chunk math (same decay/injection convention)."""
    from repro.models.mamba import _ssm_chunk
    b, l, d, n = 2, 32, 16, 8
    x, dt, A, Bt, Ct = _inputs(jax.random.key(3), b, l, d, n, jnp.float32)
    decay = jnp.exp(dt[..., None] * A)                   # (B, L, D, N)
    inject = (dt * x)[..., None] * Bt[:, :, None, :]     # (B, L, D, N)
    h0 = jnp.zeros((b, d, n), jnp.float32)
    y_chunk, h_chunk = _ssm_chunk(h0, decay, inject, Ct)
    y_k, h_k = O.selective_scan(x, dt, A, Bt, Ct, impl="pallas",
                                block_d=16, block_l=16)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_chunk),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_chunk),
                               rtol=1e-4, atol=1e-4)
