"""Shared differential-oracle module for every kernel/schedule family.

One module owns (a) the NUMPY float64 reference implementations the kernel
tests diff against — deliberately independent of the jnp refs that ship
inside each kernel package (``repro.kernels.*.ref``), so a bug in the
shared repro code cannot agree with itself — and (b) the tolerance policy,
so "how close is close enough" is decided once per (domain, dtype) pair
instead of re-invented per test file.

Imported by test_kernels_tri_attn.py, test_kernels_tri_edm.py,
test_kernels_tri_3body.py, test_packing.py, test_decode_packed.py, and
test_packed_backward.py (the f64 VJP oracles for causal + packed
attention).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mapping as M

NEG_INF = float(np.finfo(np.float32).min)

# ---------------------------------------------------------------------------
# Tolerance policy: one (domain, dtype) table. Notes:
#   attn      — flash-style online softmax vs full softmax reassociation.
#   attn_bitwise_pair — two impls sharing schedule AND op order (scan vs
#               pallas interpret): f32 roundoff only.
#   attn_grad — custom-VJP kernels vs autodiff through the oracle.
#   edm       — sqrt amplifies f32 roundoff of d^2 ~ 0 on diagonal blocks
#               (a+b-2ab vs the oracle's direct |x_i-x_j|^2 reduction).
#   3body     — triple-product reductions over Gram tiles.
# ---------------------------------------------------------------------------

_TOLS = {
    ("attn", "float32"): dict(atol=2e-5, rtol=2e-5),
    ("attn", "bfloat16"): dict(atol=2e-2, rtol=2e-2),
    ("attn_bitwise_pair", "float32"): dict(atol=1e-6, rtol=1e-6),
    ("attn_grad", "float32"): dict(atol=2e-4, rtol=2e-3),
    ("edm", "float32"): dict(atol=2e-3, rtol=1e-4),
    ("edm", "bfloat16"): dict(atol=5e-2, rtol=5e-2),
    ("edm_sq", "float32"): dict(atol=1e-5, rtol=1e-5),
    ("3body", "float32"): dict(atol=2e-4, rtol=2e-5),
    ("3body_total", "float32"): dict(atol=0.0, rtol=1e-5),
}


def _dtype_name(dtype) -> str:
    try:
        return jnp.dtype(dtype).name
    except TypeError:
        return str(dtype)


def tol(kind: str, dtype=jnp.float32) -> dict:
    """Tolerance kwargs for np.testing.assert_allclose."""
    return dict(_TOLS[(kind, _dtype_name(dtype))])


def assert_close(got, want, kind: str, dtype=jnp.float32, err_msg=""):
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        err_msg=err_msg, **tol(kind, dtype))


# ---------------------------------------------------------------------------
# Shared random inputs (jax.random so values match the kernels' precision
# expectations; generation is not the system under test)
# ---------------------------------------------------------------------------


def rand_qkv(seed: int, b: int, h: int, hkv: int, s: int, d: int,
             dtype=jnp.float32):
    """(q (B,H,S,D), k, v (B,Hkv,S,D)) from one seed."""
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (b, h, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (b, hkv, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (b, hkv, s, d), jnp.float32).astype(dtype)
    return q, k, v


def rand_points(seed: int, n_rows: int, d: int, dtype=jnp.float32):
    """(N, d) feature points for the EDM / 3-body workloads."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n_rows, d), jnp.float32)
    return x.astype(dtype)


def rand_decode_state(seed: int, b: int, h: int, hkv: int, s_cache: int,
                      d: int, dtype=jnp.float32):
    """(q (B,H,D), k_cache, v_cache (B,S,Hkv,D)) — one decode round."""
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (b, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (b, s_cache, hkv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (b, s_cache, hkv, d), jnp.float32).astype(dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# Attention oracles (numpy, float64 accumulation)
# ---------------------------------------------------------------------------


def attention_mask_np(s_q: int, s_k: int, *, window=None, prefix: int = 0,
                      q_offset: int = 0) -> np.ndarray:
    """Boolean (s_q, s_k); True = attend. causal + optional SWA + prefix."""
    qp = np.arange(s_q)[:, None] + q_offset
    kp = np.arange(s_k)[None, :]
    m = kp <= qp
    if window is not None:
        m &= (qp - kp) < window
    if prefix:
        m |= kp < prefix
    return m


def attention_oracle(q, k, v, *, sm_scale=None, window=None, prefix: int = 0,
                     q_offset: int = 0) -> np.ndarray:
    """Full-softmax MHA in numpy float64.

    q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D), H % Hkv == 0. -> (B, H, Sq, D)
    float32."""
    q = np.asarray(q, np.float64)
    k = np.asarray(k, np.float64)
    v = np.asarray(v, np.float64)
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    if g > 1:
        k = np.repeat(k, g, axis=1)
        v = np.repeat(v, g, axis=1)
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    s = np.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = attention_mask_np(sq, sk, window=window, prefix=prefix,
                             q_offset=q_offset)
    s = np.where(mask[None, None], s, -np.inf)
    m = s.max(axis=-1, keepdims=True)
    p = np.exp(s - m)
    out = np.einsum("bhqk,bhkd->bhqd", p, v) / p.sum(axis=-1, keepdims=True)
    return out.astype(np.float32)


def attention_grad_oracle(q, k, v, do, *, sm_scale=None, window=None,
                          prefix: int = 0):
    """Numpy float64 VJP of full-softmax MHA — the gradient oracle the
    custom-VJP kernels (per-domain AND packed) are diffed against.

    q, do: (B, H, S, D); k, v: (B, Hkv, S, D). Returns (dq, dk, dv)
    float32 with dk/dv group-summed back to the kv-head count, matching
    the kernels' GQA convention. Algorithm: explicit softmax Jacobian
    (ds = p * (dp - delta)) on the full S x S score matrix — deliberately
    NOT the flash-style streamed recomputation, so a reassociation bug in
    the kernels cannot agree with itself.
    """
    q = np.asarray(q, np.float64)
    k = np.asarray(k, np.float64)
    v = np.asarray(v, np.float64)
    do = np.asarray(do, np.float64)
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    kr = np.repeat(k, g, axis=1) if g > 1 else k
    vr = np.repeat(v, g, axis=1) if g > 1 else v
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    s = np.einsum("bhqd,bhkd->bhqk", q, kr) * scale
    mask = attention_mask_np(sq, sk, window=window, prefix=prefix)
    s = np.where(mask[None, None], s, -np.inf)
    m = s.max(axis=-1, keepdims=True)
    e = np.exp(s - m)
    p = e / e.sum(axis=-1, keepdims=True)
    dv_h = np.einsum("bhqk,bhqd->bhkd", p, do)
    dp = np.einsum("bhqd,bhkd->bhqk", do, vr)
    delta = np.sum(p * dp, axis=-1, keepdims=True)
    ds = p * (dp - delta) * scale
    dq = np.einsum("bhqk,bhkd->bhqd", ds, kr)
    dk_h = np.einsum("bhqk,bhqd->bhkd", ds, q)
    if g > 1:
        dk_h = dk_h.reshape(b, hkv, g, sk, d).sum(axis=2)
        dv_h = dv_h.reshape(b, hkv, g, sk, d).sum(axis=2)
    return (dq.astype(np.float32), dk_h.astype(np.float32),
            dv_h.astype(np.float32))


def packed_attention_grad_oracle(q, k, v, do, member_lens, *, windows=None,
                                 prefixes=None, sm_scale=None):
    """Gradient oracle for the PACKED ragged layout: each member's segment
    of the concatenated operands is differentiated in ISOLATION (the
    per-document sequential reference) and the pieces are concatenated
    back. member_lens are the padded per-member token counts summing to S;
    windows / prefixes are per-member (None / 0 = plain causal)."""
    r = len(member_lens)
    windows = windows or (None,) * r
    prefixes = prefixes or (0,) * r
    dqs, dks, dvs = [], [], []
    base = 0
    for s_r, w, p in zip(member_lens, windows, prefixes):
        seg = slice(base, base + s_r)
        dq, dk, dv = attention_grad_oracle(
            np.asarray(q)[:, :, seg], np.asarray(k)[:, :, seg],
            np.asarray(v)[:, :, seg], np.asarray(do)[:, :, seg],
            sm_scale=sm_scale, window=w, prefix=p)
        dqs.append(dq)
        dks.append(dk)
        dvs.append(dv)
        base += s_r
    return (np.concatenate(dqs, axis=2), np.concatenate(dks, axis=2),
            np.concatenate(dvs, axis=2))


def decode_round_oracle(q, k_cache, v_cache, kv_lens) -> np.ndarray:
    """Oracle for one packed mixed-position decode round.

    q: (B, H, D) single rotated queries; k_cache, v_cache: (B, S, Hkv, D)
    native cache layout; kv_lens: (B,) ints — slot b attends cache rows
    [0, kv_lens[b]) (its valid prefix; 0 = retired slot -> zero output).
    Each slot is reduced in ISOLATION (the sequential per-slot reference
    the packed launch must match). Returns (B, H, D) float32."""
    q = np.asarray(q, np.float64)
    b, h, d = q.shape
    out = np.zeros((b, h, d), np.float32)
    for bi in range(b):
        kl = int(kv_lens[bi])
        if kl == 0:
            continue
        kc = np.asarray(k_cache[bi, :kl], np.float64)  # (kl, Hkv, D)
        vc = np.asarray(v_cache[bi, :kl], np.float64)
        o = attention_oracle(q[bi][None, :, None, :],
                             kc.transpose(1, 0, 2)[None],
                             vc.transpose(1, 0, 2)[None],
                             q_offset=kl - 1)
        out[bi] = o[0, :, 0, :]
    return out


# ---------------------------------------------------------------------------
# EDM oracle (numpy, float64)
# ---------------------------------------------------------------------------


def edm_full_oracle(x, *, squared: bool = False) -> np.ndarray:
    """(N, d) -> (N, N) pairwise Euclidean distances, direct |x_i - x_j|
    reduction (no a+b-2ab trick — deliberately a different algorithm than
    the kernels)."""
    x = np.asarray(x, np.float64)
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    return (d2 if squared else np.sqrt(d2)).astype(np.float32)


def edm_packed_oracle(x, block: int, *, squared: bool = False) -> np.ndarray:
    """(N, d) -> (T, block, block) block-packed lower triangle, tile
    lambda = g^-1(i, j) row-major (the paper's packed layout)."""
    full = edm_full_oracle(x, squared=squared)
    n = full.shape[0] // block
    out = np.empty((M.tri(n), block, block), np.float32)
    for lam in range(M.tri(n)):
        i, j = M.ltm_map(lam)
        out[lam] = full[i * block:(i + 1) * block,
                        j * block:(j + 1) * block]
    return out


# ---------------------------------------------------------------------------
# 3-body oracle (numpy, float64)
# ---------------------------------------------------------------------------


def three_body_packed_oracle(x, block: int,
                             strict: bool = False) -> np.ndarray:
    """(N, d) -> (T3, 1) per-unique-tile-triple reductions of
    G[a,b] G[b,c] G[a,c] over the tet domain; strict keeps only a > b > c
    point triples (mirrors the kernels' diagonal-tile masking)."""
    x = np.asarray(x, np.float64)
    g = x @ x.T
    n = x.shape[0] // block
    idx = np.arange(x.shape[0])
    out = np.empty((M.tet(n), 1), np.float32)
    for lam in range(M.tet(n)):
        i, j, k = M.tet_map(lam)
        si, sj, sk = (slice(t * block, (t + 1) * block) for t in (i, j, k))
        a, b, c = g[si, sj], g[sj, sk], g[si, sk]
        if strict:
            a = np.where(idx[si][:, None] > idx[sj][None, :], a, 0.0)
            b = np.where(idx[sj][:, None] > idx[sk][None, :], b, 0.0)
        out[lam, 0] = np.sum((a @ b) * c)
    return out


def three_body_total_oracle(x, strict: bool = False) -> float:
    """Dense float64 total: all ordered triples (loose) or each distinct
    unordered triple a > b > c once (strict)."""
    x = np.asarray(x, np.float64)
    g = x @ x.T
    if not strict:
        return float(np.einsum("ab,bc,ac->", g, g, g))
    lower = np.tril(np.ones_like(g), -1)
    a = g * lower
    return float(np.sum((a @ a) * g))
