"""§Perf optimization passes must be semantics-preserving: with hints set,
outputs equal the baseline (they only pin layouts / regroup dispatch)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as REG
from repro.models import model as MD
from repro.models import moe as MOE
from repro.parallel import hints


def test_hints_scope_and_default():
    assert hints.get("nope") is None
    with hints.hints(a=1, b=None):
        assert hints.get("a") == 1
        assert hints.get("b") is None  # None values are not set
        with hints.hints(a=2):
            assert hints.get("a") == 2
        assert hints.get("a") == 1
    assert hints.get("a") is None


def test_constrain_identity_without_hint():
    x = jnp.ones((4, 4))
    assert hints.constrain(x, "attn_qkv") is x


def test_moe_grouped_equals_global_dispatch():
    cfg = dataclasses.replace(REG.smoke_config("mixtral-8x7b"),
                              capacity_factor=8.0)
    params = MOE.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model))
    out1, aux1 = MOE.moe_mlp(params, x, cfg)
    with hints.hints(moe_groups=4):
        out2, aux2 = MOE.moe_mlp(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-4, atol=1e-5)
    assert float(aux1) == float(aux2)


def test_moe_groups_fall_back_when_indivisible():
    cfg = dataclasses.replace(REG.smoke_config("mixtral-8x7b"),
                              capacity_factor=8.0)
    params = MOE.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 9, cfg.d_model))  # t=9
    out1, _ = MOE.moe_mlp(params, x, cfg)
    with hints.hints(moe_groups=4):  # 9 % 4 != 0 -> groups=1
        out2, _ = MOE.moe_mlp(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6)


def test_remat_policy_hint_preserves_loss_and_grads():
    cfg = REG.smoke_config("yi-9b")
    params = MD.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    def loss(p):
        return MD.loss_fn(p, cfg, batch)[0]

    l1, g1 = jax.value_and_grad(loss)(params)
    with hints.hints(remat_policy=("attn_out",)):
        l2, g2 = jax.value_and_grad(loss)(params)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


def test_kernel_region_detection_on_compiled_model():
    """The vmap(vmap())+while signature isolates a nonzero attention
    interior on a compiled train step (CPU, 1 device)."""
    from repro.roofline import hlo_parse as H
    from repro.train import optimizer as OPT
    from repro.train import train_step as TS
    cfg = REG.smoke_config("yi-9b")
    opt = OPT.OptConfig()
    state = TS.init_state(jax.random.key(0), cfg, opt)
    toks = jnp.zeros((2, 128), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    step = TS.make_train_step(cfg, opt, block=32)  # several tiles
    comp = jax.jit(step).lower(state, batch).compile()
    an = H.analyze(comp.as_text())
    assert an["hbm_kernel_interior"] > 0
    assert an["hbm_bytes_kernel_adj"] < an["hbm_bytes"]
