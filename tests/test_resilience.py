"""Resilience tests: deterministic fault injection, lifecycle hardening,
degradation ladder, quarantine + replay, crash-safe snapshot/restore.

The load-bearing assertion, repeated across the fault matrix: under any
seeded FaultPlan the engine TERMINATES, every submitted request reaches
exactly one explicit terminal status (no silent drops), and every request
that completes is token-identical to the fault-free run (greedy decode).
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import registry as REG
from repro.models import model as MD
from repro.obs import schema as SCH
from repro.obs import sinks as SK
from repro.resilience import faults as F
from repro.resilience import health as H
from repro.resilience import snapshot as SNAP
from repro.serve import kv_cache as KV
from repro.serve.engine import Engine

TERMINAL = {"done", "shed", "deadline_miss", "failed"}

PROMPTS = [np.array([3, 1, 4, 1], np.int32),
           np.array([2, 7, 1], np.int32),
           np.array([9, 8, 2, 6, 5], np.int32)]
MAX_NEW = 3


@pytest.fixture(scope="module")
def ctx():
    cfg = REG.smoke_config("yi-9b")
    params = MD.init_params(jax.random.key(0), cfg)

    def make(**kw):
        kw.setdefault("clock", F.VirtualClock())
        eng = Engine(params, cfg, slots=2, max_len=32, temperature=0.0,
                     prefill_block=4, **kw)
        for uid, p in enumerate(PROMPTS):
            eng.submit(p, max_new=MAX_NEW, uid=uid)
        return eng

    def run(**kw):
        eng = make(**kw)
        return eng, eng.run()

    _, baseline = run()
    return {"cfg": cfg, "params": params, "make": make, "run": run,
            "baseline": baseline}


def _check_contract(eng, res, baseline):
    """Termination + no silent drops + token identity for completions."""
    rep = eng.report()
    assert set(rep) == set(range(len(PROMPTS))), "request lost"
    assert all(r["status"] in TERMINAL for r in rep.values()), rep
    for uid, r in rep.items():
        if r["status"] == "done":
            assert res[uid] == baseline[uid], (uid, res[uid], baseline[uid])
    return rep


# ---------------------------------------------------------------------------
# fault matrix: {kind} x {phase} x {decode mode}
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,phase", [
    ("launch_error", "admit"), ("admit_oom", "admit"),
    ("poison", "admit"), ("straggler", "admit"),
    ("launch_error", "decode"), ("poison", "decode"),
    ("straggler", "decode"),
])
@pytest.mark.parametrize("decode_mode", ["auto", "lockstep"])
def test_fault_matrix_token_identity(ctx, kind, phase, decode_mode):
    """A transient fault (clears within the retry budget) must leave the
    output indistinguishable from the fault-free run — all requests done,
    none failed or dropped."""
    plan = F.FaultPlan([F.Fault(kind, phase, 0, times=1, delay_s=0.01)])
    eng, res = ctx["run"](fault_plan=plan, decode_mode=decode_mode)
    rep = _check_contract(eng, res, ctx["baseline"])
    assert all(r["status"] == "done" for r in rep.values()), rep
    assert eng.stats["requests_failed_total"] == 0


def test_retry_exhaustion_degrades_admit(ctx):
    """4 strikes outlast the default 3 retries: the admit round must walk
    the ladder (packed -> sequential), count the transition, and still
    produce identical tokens."""
    plan = F.FaultPlan([F.Fault("admit_oom", "admit", 0, times=4)])
    eng, res = ctx["run"](fault_plan=plan)
    _check_contract(eng, res, ctx["baseline"])
    assert res == ctx["baseline"]
    assert eng.stats["launches_degraded_total"] >= 1
    assert eng.stats["requests_retried_total"] >= 1


def test_retry_exhaustion_degrades_decode(ctx):
    """Decode ladder: packed -> lockstep when the packed round keeps
    failing (decode_mode="packed" so round 0 starts on the packed
    grid — "auto" would pick lockstep for an unskewed first round)."""
    plan = F.FaultPlan([F.Fault("launch_error", "decode", 0, times=4)])
    eng, res = ctx["run"](fault_plan=plan, decode_mode="packed")
    _check_contract(eng, res, ctx["baseline"])
    assert res == ctx["baseline"]
    assert eng.stats["launches_degraded_total"] >= 1
    assert eng.stats["decode_lockstep_launches"] >= 1


def test_ladder_exhaustion_attributes_failures(ctx):
    """A fault that outlasts EVERY rung fails the round's requests
    explicitly — attributed by uid in stats, engine keeps serving."""
    plan = F.FaultPlan([F.Fault("launch_error", "decode", 0, times=99)])
    eng, res = ctx["run"](fault_plan=plan)
    rep = _check_contract(eng, res, ctx["baseline"])
    failed = [u for u, r in rep.items() if r["status"] == "failed"]
    assert failed, rep
    assert eng.stats["requests_failed_total"] == len(failed)
    blamed = {f["uid"] for f in eng.stats["failures"]}
    assert set(failed) <= blamed
    # the engine stayed alive: someone still finished, identically
    done = [u for u, r in rep.items() if r["status"] == "done"]
    assert done


def test_member_scoped_fault_fails_one_request(ctx):
    """On the sequential path a member-scoped persistent fault takes down
    only ITS request; round-mates complete token-identically."""
    plan = F.FaultPlan([F.Fault("launch_error", "admit", 0, member=1,
                                times=99)])
    eng, res = ctx["run"](fault_plan=plan, prefill_mode="sequential")
    rep = _check_contract(eng, res, ctx["baseline"])
    assert sum(r["status"] == "failed" for r in rep.values()) == 1
    assert sum(r["status"] == "done" for r in rep.values()) == 2


# ---------------------------------------------------------------------------
# quarantine + replay
# ---------------------------------------------------------------------------


def test_poison_quarantines_and_replays(ctx):
    """A poisoned decode round quarantines the slot, replays the request
    from prompt + emitted tokens, and the final output is identical."""
    plan = F.FaultPlan([F.Fault("poison", "decode", 1, times=1)])
    eng, res = ctx["run"](fault_plan=plan)
    rep = _check_contract(eng, res, ctx["baseline"])
    assert res == ctx["baseline"]
    assert eng.stats["slots_quarantined_total"] == 1
    assert sum(r["replays"] for r in rep.values()) == 1


def test_quarantine_never_deadlocks(ctx):
    """Poison every early round on a 1-slot engine: with every slot
    quarantined and work queued, the engine must force-release a slot
    rather than spin forever."""
    cfg, params = ctx["cfg"], ctx["params"]
    plan = F.FaultPlan([F.Fault("poison", "decode", r, times=1)
                        for r in range(3)])
    eng = Engine(params, cfg, slots=1, max_len=32, temperature=0.0,
                 prefill_block=4, fault_plan=plan, clock=F.VirtualClock(),
                 quarantine_rounds=10_000)
    eng.submit(PROMPTS[0], max_new=MAX_NEW, uid=0)
    res = eng.run(max_steps=200)
    assert eng.report()[0]["status"] == "done"
    assert res[0] == ctx["baseline"][0]


# ---------------------------------------------------------------------------
# deadlines, shedding, stragglers
# ---------------------------------------------------------------------------


def test_deadline_miss_is_explicit(ctx):
    """A straggler delay past the TTL retires requests with an explicit
    deadline_miss status (queued AND running), counted in metrics."""
    plan = F.FaultPlan([F.Fault("straggler", "decode", 0, times=1,
                                delay_s=2.0)])
    eng, res = ctx["run"](fault_plan=plan, deadline_s=0.5)
    rep = _check_contract(eng, res, ctx["baseline"])
    missed = [u for u, r in rep.items() if r["status"] == "deadline_miss"]
    assert missed
    assert eng.stats["deadline_misses_total"] == len(missed)
    assert all(rep[u]["error"] for u in missed)


def test_overload_shedding_spares_the_head(ctx):
    """Backpressure sheds the heaviest non-head request, explicitly; the
    queue head (oldest) is never shed — the starvation-free guarantee."""
    eng = ctx["make"](max_queue_tiles=2)
    assert eng.stats["requests_shed_total"] == 1
    rep = eng.report()
    assert rep[0]["status"] != "shed"  # the head survived
    res = eng.run()
    rep = _check_contract(eng, res, ctx["baseline"])
    shed = [u for u, r in rep.items() if r["status"] == "shed"]
    assert len(shed) == 1 and shed[0] != 0
    # shed requests appear in run() results with their (empty) output
    assert res[shed[0]] == []


def test_straggler_rounds_flagged():
    w = H.RoundWatch(factor=3.0, min_samples=5)
    for _ in range(8):
        assert not w.observe(0.01)
    assert w.observe(0.1)  # 10x the median
    assert w.flagged == 1


def test_retry_policy_is_seeded():
    a = [F.RetryPolicy(seed=7).delay(i) for i in range(4)]
    b = [F.RetryPolicy(seed=7).delay(i) for i in range(4)]
    c = [F.RetryPolicy(seed=8).delay(i) for i in range(4)]
    assert a == b != c
    assert all(d <= F.RetryPolicy().cap_s for d in a)


# ---------------------------------------------------------------------------
# traced-envelope fallback
# ---------------------------------------------------------------------------


def test_envelope_fallback_to_host_map(ctx):
    """With the certified traced-isqrt envelope artificially floored, the
    admit round must degrade traced -> host (sequential prefill) and stay
    token-identical."""
    eng, res = ctx["run"](traced_max_lam=0)
    _check_contract(eng, res, ctx["baseline"])
    assert res == ctx["baseline"]
    assert eng.stats["launches_degraded_total"] >= 1
    # the packed launch counter stays 0: every admit went sequential
    assert eng.stats["prefill_launches"] > eng.stats["admit_rounds"]


# ---------------------------------------------------------------------------
# snapshot / restore
# ---------------------------------------------------------------------------


def test_snapshot_restore_token_identical(ctx):
    eng = ctx["make"]()
    eng._expire_deadlines()
    eng._admit()
    eng.step()
    eng.step()
    snap = SNAP.snapshot(eng)
    resumed = Engine.restore(snap).run()
    assert resumed == ctx["baseline"]
    # restoring twice from the same snapshot is also identical (the
    # snapshot is a value, not a handle into the live engine)
    assert Engine.restore(snap).run() == ctx["baseline"]


def test_snapshot_file_roundtrip(ctx, tmp_path):
    eng = ctx["make"]()
    eng._expire_deadlines()
    eng._admit()
    eng.step()
    snap = SNAP.snapshot(eng)
    path = SNAP.to_dir(snap, str(tmp_path / "snap"))
    loaded = SNAP.from_dir(path)
    assert Engine.restore(loaded).run() == ctx["baseline"]
    # crash-safety: a half-written .tmp is never visible as a snapshot
    assert not (tmp_path / "snap.tmp").exists()


@settings(max_examples=4)
@given(cut=st.integers(min_value=0, max_value=5))
def test_snapshot_any_cut_point(ctx, cut):
    """Property: snapshotting after ANY number of decode rounds resumes
    token-identically (the fault_tolerance.py replay discipline, ported
    to serving)."""
    eng = ctx["make"]()
    eng._expire_deadlines()
    eng._admit()
    for _ in range(cut):
        eng.step()
    resumed = Engine.restore(SNAP.snapshot(eng)).run()
    assert resumed == ctx["baseline"]


@settings(max_examples=4)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_random_plans_uphold_contract(ctx, seed):
    """Property: any seeded random FaultPlan leaves the engine terminated
    with every request in a terminal status and completions identical."""
    plan = F.FaultPlan.random(seed, n_rounds=6, rate=0.4, delay_s=0.01)
    eng, res = ctx["run"](fault_plan=plan)
    _check_contract(eng, res, ctx["baseline"])


# ---------------------------------------------------------------------------
# KV splice hardening
# ---------------------------------------------------------------------------


def _states_like(cache, s_total):
    return jax.tree.map(
        lambda x: jnp.zeros((x.shape[0], 1, s_total) + x.shape[3:],
                            x.dtype) if x.ndim == 5 else x, cache)


def test_kv_splice_overlength_raises(ctx):
    cfg = ctx["cfg"]
    cache = MD.init_cache(cfg, 2, 8, jnp.float32)
    states = _states_like(cache, 32)
    with pytest.raises(ValueError, match="longer than max_len"):
        KV.splice_slot(cache, 0, states, 0, 32)


def test_kv_splice_bad_slot_raises(ctx):
    cfg = ctx["cfg"]
    cache = MD.init_cache(cfg, 2, 8, jnp.float32)
    states = _states_like(cache, 8)
    with pytest.raises(ValueError, match="neighboring|NEIGHBORING"):
        KV.splice_slot(cache, 5, states, 0, 4)


def test_kv_splice_reads_past_packed_raises(ctx):
    cfg = ctx["cfg"]
    cache = MD.init_cache(cfg, 2, 8, jnp.float32)
    states = _states_like(cache, 4)
    with pytest.raises(ValueError, match="NEXT packed"):
        KV.splice_slot(cache, 0, states, 2, 4)


def test_submit_rejects_overlong_prompt(ctx):
    eng = ctx["make"]()
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.submit(np.arange(100, dtype=np.int32), max_new=1, uid=99)
    with pytest.raises(ValueError, match="empty"):
        eng.submit(np.array([], np.int32), max_new=1, uid=98)


# ---------------------------------------------------------------------------
# trace events + schema
# ---------------------------------------------------------------------------


def test_degrade_quarantine_events_schema_valid(ctx, tmp_path):
    plan = F.FaultPlan([F.Fault("admit_oom", "admit", 0, times=4),
                        F.Fault("poison", "decode", 1, times=1)])
    trace_path = SK.enable(trace_dir=str(tmp_path), metrics_path=None,
                           run_id="test-resilience")
    try:
        eng, res = ctx["run"](fault_plan=plan)
    finally:
        SK.disable()
    assert res == ctx["baseline"]
    kinds = {"degrade": 0, "quarantine": 0}
    with open(trace_path, encoding="utf-8") as fh:
        for line in fh:
            ev = json.loads(line)
            if ev.get("type") not in kinds:
                continue
            kinds[ev["type"]] += 1
            assert SCH.validate_event(ev) == [], ev
            if ev["type"] == "degrade":
                assert F.is_registered_transition(
                    ev["phase"], ev["from"], ev["to"]), ev
    assert kinds["degrade"] >= 1 and kinds["quarantine"] >= 1


def test_unregistered_transition_rejected_by_schema():
    ev = {"type": "degrade", "phase": "decode", "from": "lockstep",
          "to": "packed", "round": 0, "reason": "x"}
    # schema accepts stage names but the registry rejects UP-ladder moves
    assert SCH.validate_event(ev, envelope=False) == []
    assert not F.is_registered_transition("decode", "lockstep", "packed")
    bad = dict(ev, to="warp_drive")
    assert SCH.validate_event(bad, envelope=False) != []


def test_launch_hook_injects_at_launch_site():
    """install_launch_hook wraps EVERY instrumented launch: a
    phase="launch" fault raises at the matching sequential launch index
    and clears after its strikes are spent."""
    from repro.kernels.tri_edm import ops as OE

    x = np.zeros((16, 4), np.float32)
    plan = F.FaultPlan([F.Fault("launch_error", "launch", 1, times=1)])
    with F.install_launch_hook(plan):
        OE.edm(x, block=8, impl="scan")  # launch #0: clean
        with pytest.raises(F.InjectedLaunchError):
            OE.edm(x, block=8, impl="scan")  # launch #1: injected
        OE.edm(x, block=8, impl="scan")  # strikes spent: clean again
    # hook uninstalled on exit
    plan.reset()
    n = plan._launch_calls
    OE.edm(x, block=8, impl="scan")
    assert plan._launch_calls == n


def test_resilience_counters_integral_in_metrics():
    doc = {"schema": SK.SCHEMA_VERSION, "kind": "metrics",
           "created_unix": 0.0,
           "counters": {"requests_shed_total": 2.5},
           "gauges": {}, "histograms": {}}
    assert any("integral" in e for e in SCH.validate_metrics(doc))
    doc["counters"]["requests_shed_total"] = 2
    assert SCH.validate_metrics(doc) == []


# ---------------------------------------------------------------------------
# fused-mode snapshots (the PR 9 seam: step_mode + packing templates)
# ---------------------------------------------------------------------------


def test_snapshot_captures_fused_packing_state(ctx, tmp_path):
    """A fused engine's snapshot carries step_mode and the length-bucketed
    packing templates it has compiled, through the file format too, so a
    restored replica re-serves without re-paying those compiles."""
    eng = ctx["make"](step_mode="fused")
    eng.round()
    eng.round()
    assert eng.fused_templates, "two fused rounds must record a template"
    snap = SNAP.snapshot(eng)
    assert snap.step_mode == "fused"
    assert {(tuple(t), c) for t, c in snap.fused_templates} == \
        eng.fused_templates
    loaded = SNAP.from_dir(SNAP.to_dir(snap, str(tmp_path / "snap")))
    assert loaded.step_mode == "fused"
    assert loaded.fused_templates == snap.fused_templates
    assert loaded.mode_cost == snap.mode_cost
    restored = Engine.restore(loaded)
    assert restored.step_mode == "fused"
    assert restored.fused_templates == eng.fused_templates
    assert restored.run() == ctx["baseline"]


@settings(max_examples=4, deadline=None)
@given(cut=st.integers(min_value=0, max_value=5))
def test_snapshot_fused_any_cut_point(ctx, cut):
    """Property: a fused engine snapshotted after ANY number of mixed
    packed rounds restores token-identically (greedy fused == split)."""
    eng = ctx["make"](step_mode="fused")
    for _ in range(cut + 1):
        eng.round()
    resumed = Engine.restore(SNAP.snapshot(eng)).run()
    assert resumed == ctx["baseline"]


def test_snapshot_step_mode_drift_rejected(ctx):
    """Restoring a snapshot into an engine whose recorded kwargs resolve
    to a DIFFERENT step mode is config drift, not resumption — refuse."""
    eng = ctx["make"](step_mode="fused")
    eng.round()
    bad = dataclasses.replace(SNAP.snapshot(eng), step_mode="split")
    with pytest.raises(ValueError, match="step_mode"):
        Engine.restore(bad)


def test_snapshot_mode_cost_roundtrips(ctx, tmp_path):
    """The decode auto-mode cost table survives snapshot -> file ->
    restore, so a restored engine keeps its measured crossover."""
    eng = ctx["make"](decode_mode="auto")
    eng._expire_deadlines()
    eng._admit()
    eng.step()
    eng.step()
    snap = SNAP.snapshot(eng)
    loaded = SNAP.from_dir(SNAP.to_dir(snap, str(tmp_path / "snap")))
    assert loaded.mode_cost == snap.mode_cost
    restored = Engine.restore(loaded)
    assert dict(restored._mode_cost) == dict(eng._mode_cost)
    assert restored.run() == ctx["baseline"]


# ---------------------------------------------------------------------------
# health edges
# ---------------------------------------------------------------------------


def test_roundwatch_median_partial_window():
    """median() on a partially filled window: None when empty, upper
    median of what has actually been observed otherwise."""
    w = H.RoundWatch(factor=3.0, window=64, min_samples=5)
    assert w.median() is None
    w.observe(3.0)
    assert w.median() == 3.0
    w.observe(1.0)
    assert w.median() == 3.0  # sorted([1,3])[1] — upper median
    w.observe(2.0)
    assert w.median() == 2.0


def test_roundwatch_needs_min_samples_before_flagging():
    """The min_samples gate counts PRIOR history: the flag decision for a
    round never includes that round's own duration in the median."""
    w = H.RoundWatch(factor=3.0, window=64, min_samples=2)
    assert not w.observe(0.01)  # no history at all
    assert not w.observe(1.0)   # 1 sample < min_samples: cold start
    assert w.observe(10.0)      # 2 samples, median 1.0, 10 > 3*1.0
    assert w.flagged == 1


def test_heartbeat_exactly_at_timeout_not_failed():
    """failed() is strict: a beat aged EXACTLY timeout_s is still alive;
    one instant past it is not."""
    mon = H.HeartbeatMonitor([0], timeout_s=5.0)
    assert mon.failed(now=100.0) == set()  # never beat: not failed
    mon.beat(0, step=0, now=0.0)
    assert mon.failed(now=5.0) == set()
    assert mon.failed(now=5.0 + 1e-9) == {0}


def test_heartbeat_recovers_after_failure():
    mon = H.HeartbeatMonitor([0, 1], timeout_s=5.0)
    mon.beat(0, step=0, now=0.0)
    mon.beat(1, step=0, now=0.0)
    assert mon.failed(now=10.0) == {0, 1}
    mon.beat(0, step=1, now=10.0)
    assert mon.failed(now=10.0) == {1}  # 0 recovered, 1 still dead
    assert mon.failed(now=30.0) == {0, 1}
