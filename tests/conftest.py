"""Shared pytest config. IMPORTANT: do NOT set XLA_FLAGS here — smoke tests
and benches must see the single real CPU device; only launch/dryrun.py forces
512 placeholder devices (in its own process).

Offline-test compat policy: the suite must collect and pass with no network
and no optional deps. `_hypo_compat.install()` registers a fixed-seed
stand-in for `hypothesis` when the real package is absent (real hypothesis
is used untouched when available)."""

import gc

import pytest

import _hypo_compat

_HAVE_REAL_HYPOTHESIS = _hypo_compat.install()

from hypothesis import HealthCheck, settings  # noqa: E402 (after install)

settings.register_profile(
    "repro",
    deadline=None,  # first example pays JIT compile; timings are not the SUT
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("repro")


@pytest.fixture(autouse=True, scope="module")
def _drop_compiled_executables_between_modules():
    """Free XLA executables after each test module.

    Every distinct jitted program mmaps its compiled code and stays alive
    for the life of the process; a full-suite run accumulates enough of
    them to exhaust the kernel's vm.max_map_count (65530 by default), at
    which point the NEXT compilation segfaults inside XLA's code
    allocator. Modules rarely share compiled shapes, so clearing between
    modules bounds the map count at roughly one module's worth while
    keeping the (hot) intra-module jit caches intact.
    """
    yield
    import jax

    jax.clear_caches()
    gc.collect()
