"""Shared pytest config. IMPORTANT: do NOT set XLA_FLAGS here — smoke tests
and benches must see the single real CPU device; only launch/dryrun.py forces
512 placeholder devices (in its own process)."""

from hypothesis import HealthCheck, settings

settings.register_profile(
    "repro",
    deadline=None,  # first example pays JIT compile; timings are not the SUT
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("repro")
