"""Fleet tests: tile-cost routing, deterministic failover, circuit
breaker, fleet-wide backpressure.

The load-bearing property, repeated across the fault matrix in BOTH step
modes: a fleet where a seeded FaultPlan kills one replica mid-round
produces final per-request token streams IDENTICAL to a fault-free
single-engine run, and every request ends in exactly one terminal status
in Fleet.report().
"""

import json

import jax
import numpy as np
import pytest

from repro.configs import registry as REG
from repro.models import model as MD
from repro.obs import schema as SCH
from repro.obs import sinks as SK
from repro.resilience import faults as F
from repro.serve.engine import Engine
from repro.serve.fleet import Fleet

TERMINAL = {"done", "shed", "deadline_miss", "failed"}

PROMPTS = [np.array([3, 1, 4, 1], np.int32),
           np.array([2, 7, 1], np.int32),
           np.array([9, 8, 2, 6, 5], np.int32),
           np.array([5, 5, 2], np.int32)]
MAX_NEW = 3

# Each fault kind as an ENGINE KILLER, scoped to replica 0: persistent
# strikes exhaust the ladder (launch_error / admit_oom), the poison
# guard escalates, and the straggler outlasts the heartbeat budget.
KILLS = {
    "launch_error": F.Fault("launch_error", "decode", 1, times=99,
                            engine=0),
    "admit_oom": F.Fault("admit_oom", "admit", 0, times=99, engine=0),
    "poison": F.Fault("poison", "decode", 1, times=1, engine=0),
    "straggler": F.Fault("straggler", "decode", 1, times=1, delay_s=10.0,
                         engine=0),
}


@pytest.fixture(scope="module")
def ctx():
    cfg = REG.smoke_config("yi-9b")
    params = MD.init_params(jax.random.key(0), cfg)

    eng = Engine(params, cfg, slots=2, max_len=32, temperature=0.0,
                 prefill_block=4, clock=F.VirtualClock())
    for uid, p in enumerate(PROMPTS):
        eng.submit(p, max_new=MAX_NEW, uid=uid)
    baseline = eng.run()

    def make(plan=None, submit=True, **kw):
        engine_kw = dict(slots=2, max_len=32, temperature=0.0,
                         prefill_block=4)
        engine_kw.update(kw.pop("engine_kw", {}))
        kw.setdefault("heartbeat_timeout_s", 5.0)
        kw.setdefault("snapshot_every", 2)
        fleet = Fleet(params, cfg, engines=2, fault_plan=plan,
                      engine_kw=engine_kw, **kw)
        if submit:
            for uid, p in enumerate(PROMPTS):
                fleet.submit(p, max_new=MAX_NEW, uid=uid)
        return fleet

    return {"cfg": cfg, "params": params, "make": make,
            "baseline": baseline}


def _check_fleet_contract(fleet, res, baseline, uids):
    """Termination + exactly-one-terminal-status + token identity."""
    rep = fleet.report()
    assert set(rep) == set(uids), "request lost or double-reported"
    assert all(r["status"] in TERMINAL for r in rep.values()), rep
    for uid in uids:
        if rep[uid]["status"] == "done":
            assert res[uid] == baseline[uid % len(PROMPTS)], (
                uid, res[uid], baseline[uid % len(PROMPTS)])
    return rep


# ---------------------------------------------------------------------------
# the failover property: each kill kind x step mode -> token identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", sorted(KILLS))
@pytest.mark.parametrize("step_mode", ["split", "fused"])
def test_failover_token_identity(ctx, kind, step_mode):
    fleet = ctx["make"](plan=F.FaultPlan([KILLS[kind]]),
                        engine_kw=dict(step_mode=step_mode))
    res = fleet.run(max_steps=200)
    rep = _check_fleet_contract(fleet, res, ctx["baseline"],
                                range(len(PROMPTS)))
    # the kill really happened, everyone still finished identically
    assert all(r["status"] == "done" for r in rep.values()), rep
    st = fleet.stats
    assert st["fleet_failovers_total"] >= 1, st
    assert st["fleet_requests_migrated_total"] >= 1, st
    assert st["fleet_engine_restores_total"] >= 1, st
    assert st["engines_quarantined"] == 0  # probation fully drained


def test_failover_report_marks_migration(ctx):
    """Migrated in-flight requests carry a replay count and land on the
    surviving engine in the report."""
    fleet = ctx["make"](plan=F.FaultPlan([KILLS["launch_error"]]))
    res = fleet.run(max_steps=200)
    rep = _check_fleet_contract(fleet, res, ctx["baseline"],
                                range(len(PROMPTS)))
    assert sum(r["replays"] for r in rep.values()) >= 1, rep
    engines = {r["engine"] for r in rep.values()}
    assert engines <= {0, 1, None}


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------


def test_routing_balances_by_tiles(ctx):
    """Greedy least-loaded routing on the tri(n) cost model: with every
    request submitted up front, per-replica routed-tile totals stay
    within one maximal request of each other, and both replicas work."""
    fleet = ctx["make"](submit=False)
    long = np.arange(1, 17, dtype=np.int32)  # tri(4) = 10 tiles
    prompts = [long if i % 4 == 0 else PROMPTS[i % len(PROMPTS)]
               for i in range(8)]
    for uid, p in enumerate(prompts):
        fleet.submit(p, max_new=MAX_NEW, uid=uid)
    tiles = {e: fleet.registry.counter_value(
        "fleet_routed_tiles_total", {"engine": str(e)})
        for e in range(2)}
    routed = {e: fleet.registry.counter_value(
        "fleet_requests_routed_total", {"engine": str(e)})
        for e in range(2)}
    assert all(v >= 1 for v in routed.values()), routed
    max_item = max(
        fleet.engines[0]._prefill_tiles(r)
        for eng in fleet.engines for r in eng.queue)
    assert abs(tiles[0] - tiles[1]) <= max_item, (tiles, max_item)
    res = fleet.run()
    rep = fleet.report()
    assert set(rep) == set(range(8))
    assert all(r["status"] == "done" for r in rep.values()), rep
    for uid in range(8):
        if uid % 4 == 0:  # the long prompt has no PROMPTS baseline
            assert len(res[uid]) == MAX_NEW
        else:
            assert res[uid] == ctx["baseline"][uid % len(PROMPTS)]


def test_fleet_backpressure_never_sheds_heads(ctx):
    """Global tile budget: overload sheds the heaviest non-head request
    across the fleet; every replica's queue head survives."""
    fleet = ctx["make"](submit=False, max_fleet_tiles=4)
    for uid, p in enumerate(PROMPTS * 2):
        fleet.submit(p, max_new=MAX_NEW, uid=uid)
    shed_now = [r.uid for r in fleet._terminal if r.status == "shed"]
    assert shed_now, "budget of 4 tiles must shed something"
    heads = {eng.queue[0].uid for eng in fleet.engines if eng.queue}
    assert not (set(shed_now) & heads)
    res = fleet.run()
    rep = _check_fleet_contract(fleet, res, ctx["baseline"], range(8))
    shed = [u for u, r in rep.items() if r["status"] == "shed"]
    assert shed and fleet.stats["fleet_requests_shed_total"] == len(shed)
    assert all(res[u] == [] for u in shed)


# ---------------------------------------------------------------------------
# circuit breaker + probation
# ---------------------------------------------------------------------------


def test_circuit_breaker_stretches_probation(ctx):
    """First fault: a 1-round probation. A second CONSECUTIVE fault (no
    successful working round between) trips the breaker: the replica is
    parked for the full probation window, then drained back in."""
    plan = F.FaultPlan([
        F.Fault("launch_error", "decode", 1, times=99, engine=0),
        F.Fault("launch_error", "decode", 2, times=99, engine=0)])
    fleet = ctx["make"](plan=plan, breaker_k=2, probation_rounds=6)
    for _ in range(50):  # drive until the first restoration
        fleet.tick()
        if fleet.stats["fleet_engine_restores_total"] >= 1:
            break
    assert fleet.stats["fleet_engine_restores_total"] >= 1
    # a second wave routes to the (idle, restored) replica 0, whose next
    # decode round index is 2 — straight into the second kill
    for uid, p in enumerate(PROMPTS, start=len(PROMPTS)):
        fleet.submit(p, max_new=MAX_NEW, uid=uid)
    res = fleet.run(max_steps=300)
    rep = _check_fleet_contract(fleet, res, ctx["baseline"], range(8))
    assert all(r["status"] == "done" for r in rep.values()), rep
    st = fleet.stats
    assert st["fleet_failovers_total"] == 2, st
    windows = [q["probation_rounds"] for q in fleet.quarantine_log]
    assert windows == [1, 6], fleet.quarantine_log
    assert [q["consecutive"] for q in fleet.quarantine_log] == [1, 2]
    assert st["fleet_engine_restores_total"] == 2
    assert st["engines_quarantined"] == 0  # drained back in


def test_every_replica_dead_self_restores(ctx):
    """An engine-agnostic kill (engine=-1) takes down EVERY replica; the
    fleet must immediately restore one (liveness beats probation) and
    still finish token-identically."""
    plan = F.FaultPlan(
        [F.Fault("launch_error", "decode", 1, times=99, engine=-1)])
    fleet = ctx["make"](plan=plan)
    res = fleet.run(max_steps=300)
    rep = _check_fleet_contract(fleet, res, ctx["baseline"],
                                range(len(PROMPTS)))
    assert all(r["status"] == "done" for r in rep.values()), rep
    assert fleet.stats["fleet_failovers_total"] >= 2


# ---------------------------------------------------------------------------
# trace events
# ---------------------------------------------------------------------------


def test_fleet_events_schema_valid(ctx, tmp_path):
    trace_path = SK.enable(trace_dir=str(tmp_path), metrics_path=None,
                           run_id="test-fleet")
    try:
        fleet = ctx["make"](plan=F.FaultPlan([KILLS["launch_error"]]))
        res = fleet.run(max_steps=200)
    finally:
        SK.disable()
    assert all(res[u] == ctx["baseline"][u] for u in ctx["baseline"])
    kinds = {"failover": 0, "engine_quarantine": 0, "rebalance": 0}
    with open(trace_path, encoding="utf-8") as fh:
        for line in fh:
            ev = json.loads(line)
            if ev.get("type") not in kinds:
                continue
            kinds[ev["type"]] += 1
            assert SCH.validate_event(ev) == [], ev
    assert all(v >= 1 for v in kinds.values()), kinds


def test_fleet_counters_integral_in_metrics():
    doc = {"schema": SK.SCHEMA_VERSION, "kind": "metrics",
           "created_unix": 0.0,
           "counters": {"fleet_failovers_total": 1.5},
           "gauges": {"engines_quarantined": 0.5}, "histograms": {}}
    errs = SCH.validate_metrics(doc)
    assert any("fleet counter" in e for e in errs)
    assert any("fleet gauge" in e for e in errs)
    doc["counters"]["fleet_failovers_total"] = 1
    doc["gauges"]["engines_quarantined"] = 1
    assert SCH.validate_metrics(doc) == []


def test_fault_plan_engine_scoping():
    """for_engine keeps engine-scoped faults apart and gives each
    sub-plan independent strike bookkeeping."""
    plan = F.FaultPlan([
        F.Fault("launch_error", "decode", 0, times=1, engine=0),
        F.Fault("launch_error", "decode", 1, times=1, engine=-1)])
    p0, p1 = plan.for_engine(0), plan.for_engine(1)
    assert len(p0.faults) == 2 and len(p1.faults) == 1
    with pytest.raises(F.InjectedLaunchError):
        p0.maybe_fail("decode", 0)
    p0.maybe_fail("decode", 0)  # strike spent on THIS sub-plan
    with pytest.raises(F.InjectedLaunchError):
        p1.maybe_fail("decode", 1)  # p1's own bookkeeping untouched
