"""Telemetry-subsystem tests: registry semantics, span nesting/exception
safety, launch counters vs schedule contracts, trace-JSONL schema
round-trip, engine decode-tile accounting, and the RingLog cap."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analysis as A
from repro.core import mapping as M
from repro.obs import launch as L
from repro.obs import metrics as MET
from repro.obs import schema as SCH
from repro.obs import sinks as SK
from repro.obs import timing as TM
from repro.obs import trace as TR


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_counter_gauge_histogram():
    reg = MET.Registry("t")
    reg.counter_inc("c", 2, {"k": "a"})
    reg.counter_inc("c", 3, {"k": "a"})
    reg.counter_inc("c", 7, {"k": "b"})
    assert reg.counter_value("c", {"k": "a"}) == 5
    assert reg.counter_total("c") == 12
    reg.gauge_set("g", 4.5)
    assert reg.gauge_value("g") == 4.5
    reg.histogram_observe("h", 3.0)
    h = reg.histogram_value("h")
    assert h["count"] == 1 and h["sum"] == 3.0
    snap = reg.snapshot()
    assert snap["counters"]["c{k=a}"] == 5
    hs = snap["histograms"]["h"]
    assert len(hs["bucket_counts"]) == len(hs["buckets"]) + 1
    assert sum(hs["bucket_counts"]) == hs["count"]
    with pytest.raises(AssertionError):
        reg.counter_inc("c", -1)


def test_scope_fans_out_to_global_and_scoped():
    reg = MET.Registry("scoped")
    g0 = MET.global_registry().counter_value("scope_test_total")
    with MET.scope(reg):
        MET.counter_inc("scope_test_total", 2)
    MET.counter_inc("scope_test_total", 1)  # outside: global only
    assert reg.counter_value("scope_test_total") == 2
    assert MET.global_registry().counter_value("scope_test_total") == g0 + 3


def test_ringlog_caps_but_counts_everything():
    log = MET.RingLog(maxlen=3)
    for i in range(10):
        log.append(i)
    assert log.items() == [7, 8, 9]
    assert len(log) == 3
    assert log.total_appended == 10
    assert log.dropped == 7


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


def test_span_nesting_paths_and_depth():
    with TR.span("outer") as so:
        assert TR.current_span() is so
        with TR.span("inner", detail=1) as si:
            assert si.depth == 1
            assert si.path == "outer/inner"
            assert si.parent == "outer"
        assert TR.current_span() is so
    assert TR.current_span() is None
    assert so.duration_ms >= 0.0


def test_span_exception_safety():
    with pytest.raises(ValueError, match="boom"):
        with TR.span("exploder") as sp:
            raise ValueError("boom")
    # the stack unwound and the error was recorded on the span
    assert TR.current_span() is None
    assert "ValueError" in sp.error
    ev = sp.as_event()
    assert SCH.validate_event(ev, envelope=False) == []


def test_span_attach_blocks_device_work():
    with TR.span("attached") as sp:
        out = sp.attach(jnp.ones((8, 8)) * 2.0)
    assert float(out[0, 0]) == 2.0
    assert sp.t1 is not None


# ---------------------------------------------------------------------------
# launch counters vs schedule contracts
# ---------------------------------------------------------------------------


def test_launch_counters_match_edm_schedule_contract():
    from repro.kernels.tri_edm import ops as OE

    n_rows, block = 64, 8
    n = n_rows // block
    x = np.random.default_rng(0).normal(size=(n_rows, 3)).astype(np.float32)
    reg = MET.Registry("edm")
    with MET.scope(reg):
        OE.edm(x, block=block, impl="scan")
    labels = {"name": "tri_edm.ltm", "impl": "scan"}
    st = A.strategy_stats(n)["ltm"]
    assert reg.counter_value("launches_total", labels) == 1
    assert reg.counter_value("tiles_launched_total", labels) \
        == st.launched == M.tri(n)
    assert reg.counter_value("tiles_bb_total", labels) == n * n
    assert reg.counter_value("tiles_wasted_total", labels) == st.wasted == 0


def test_launch_counters_match_attention_schedule_contract():
    from repro.kernels.tri_attn import ops as OPS

    b, h, s, d, blk = 2, 3, 64, 8, 16
    n = s // blk
    q = np.zeros((b, h, s, d), np.float32)
    reg = MET.Registry("attn")
    with MET.scope(reg):
        OPS.triangular_attention(q, q, q, impl="scan",
                                 block_q=blk, block_k=blk)
    labels = {"name": "tri_attn.fwd", "impl": "scan"}
    # tiles multiply by cells = b*h (prefix grid dims)
    assert reg.counter_value("tiles_launched_total", labels) \
        == M.tri(n) * b * h
    assert reg.counter_value("tiles_bb_total", labels) == n * n * b * h


def test_kernel_summary_utilization_consistent_with_closed_forms():
    from repro.kernels.tri_edm import ops as OE

    n_rows, block = 48, 8
    n = n_rows // block
    x = np.zeros((n_rows, 2), np.float32)
    reg = MET.Registry("summary")
    with MET.scope(reg):
        OE.edm(x, block=block, impl="scan")
        OE.edm(x, block=block, impl="bb_scan")
    summ = L.kernel_summary(reg)
    ltm, bb = summ["tri_edm.ltm"], summ["tri_edm.bb"]
    st = A.strategy_stats(n)
    assert ltm["tiles_launched"] == st["ltm"].launched
    assert ltm["utilization"] == 1.0
    assert abs(ltm["improvement_vs_bb"]
               - st["ltm"].block_ratio_vs_bb) < 1e-12
    assert bb["tiles_launched"] == st["bb"].launched == n * n
    assert abs(bb["utilization"] - (1.0 - st["bb"].waste_fraction)) < 1e-12
    # the summary is trajectory-schema shaped
    rec = [{"schema": SK.SCHEMA_VERSION, "created_unix": 0.0,
            "kernels": summ}]
    assert SCH.validate_trajectory(rec) == []


def test_set_enabled_false_silences_launch_telemetry():
    from repro.kernels.tri_edm import ops as OE

    x = np.zeros((16, 2), np.float32)
    reg = MET.Registry("off")
    L.set_enabled(False)
    try:
        with MET.scope(reg):
            OE.edm(x, block=8, impl="scan")
    finally:
        L.set_enabled(True)
    assert reg.counter_total("launches_total") == 0


# ---------------------------------------------------------------------------
# sinks: trace JSONL + metrics.json schema round-trip
# ---------------------------------------------------------------------------


def test_trace_jsonl_schema_roundtrip(tmp_path):
    from repro.kernels.tri_edm import ops as OE

    x = np.zeros((32, 2), np.float32)
    trace_dir = tmp_path / "trace"
    metrics_path = tmp_path / "metrics.json"
    path = SK.enable(trace_dir=str(trace_dir),
                     metrics_path=str(metrics_path), run_id="testrun")
    try:
        with TR.span("roundtrip") as sp:
            sp.attach(OE.edm(x, block=8, impl="scan"))
        written = SK.flush_metrics()
    finally:
        SK.disable()
    assert path.endswith("trace-testrun.jsonl")
    lines = [json.loads(ln) for ln in
             open(path, encoding="utf-8").read().splitlines()]
    assert len(lines) >= 2  # one launch + one span
    types = {ev["type"] for ev in lines}
    assert types == {"launch", "span"}
    for ev in lines:
        assert SCH.validate_event(ev) == [], ev
    # seq is monotone from 1
    assert [ev["seq"] for ev in lines] == list(range(1, len(lines) + 1))
    # launch events are phase-tagged eager here (no jit in this test)
    launch = next(ev for ev in lines if ev["type"] == "launch")
    assert launch["phase"] == "eager"
    assert launch["tiles_launched"] == M.tri(4)
    doc = json.load(open(written, encoding="utf-8"))
    assert SCH.validate_metrics(doc) == []
    assert doc["run_id"] == "testrun"


def test_emit_event_noop_when_disabled():
    SK.disable()
    before = MET.global_registry().counter_value("obs_events_written")
    SK.emit_event({"type": "span", "name": "ghost", "path": "ghost",
                   "depth": 0, "duration_ms": 0.0})
    assert MET.global_registry().counter_value("obs_events_written") \
        == before


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------


def test_median_of_k_and_best_of():
    calls = []

    def fn(a):
        calls.append(1)
        return a + 1

    t_med = TM.median_of_k(fn, jnp.zeros(()), reps=3, warmup=1)
    assert t_med >= 0.0
    assert len(calls) == 4  # 1 warmup + 3 timed
    reg = MET.Registry("bench")
    with MET.scope(reg):
        TM.best_of(fn, jnp.zeros(()), reps=2, warmup=0, name="unit")
    h = reg.histogram_value("bench_seconds", {"name": "unit"})
    assert h["count"] == 2


def test_benchmarks_util_is_a_shim():
    import sys
    sys.path.insert(0, ".")
    try:
        from benchmarks import _util
    except ImportError:
        pytest.skip("benchmarks package not importable from test cwd")
    finally:
        sys.path.pop(0)
    assert _util.best_of is TM.best_of
    assert _util.median_of_k is TM.median_of_k


# ---------------------------------------------------------------------------
# engine accounting: packed decode never launches more tiles than padded
# ---------------------------------------------------------------------------


def _engine_fixture(**kw):
    from repro.configs import registry as REG
    from repro.models import model as MD
    from repro.serve.engine import Engine

    cfg = REG.smoke_config("yi-9b")
    params = MD.init_params(jax.random.key(0), cfg)
    eng = Engine(params, cfg, slots=2, max_len=48, temperature=0.0, **kw)
    return eng


def test_engine_decode_tiles_packed_le_padded():
    eng = _engine_fixture()
    rng = np.random.default_rng(3)
    for uid, s in enumerate((11, 3, 7)):
        eng.submit(rng.integers(1, 50, size=s).astype(np.int32),
                   max_new=4, uid=uid)
    eng.run()
    st = eng.stats
    assert st["decode_rounds"] > 0
    assert 0 < st["decode_tiles_packed"] <= st["decode_tiles_padded"]
    # the same counters are mirrored into the process-global registry
    g = MET.global_registry()
    assert g.counter_value("engine_decode_tiles_packed") > 0


def test_engine_stats_ringlog_caps_admit_logs():
    eng = _engine_fixture(stats_log_rounds=2)
    rng = np.random.default_rng(5)
    for uid in range(6):
        eng.submit(rng.integers(1, 50, size=4).astype(np.int32),
                   max_new=2, uid=uid)
    eng.run()
    st = eng.stats
    assert len(st["admit_round_tiles"]) <= 2
    assert len(st["admit_order_log"]) <= 2
    assert st["admit_rounds_total"] == st["admit_rounds"]
    assert st["admit_log_dropped"] == \
        st["admit_rounds_total"] - len(st["admit_round_tiles"])
    assert st["admit_rounds"] >= 3  # 6 requests, 2 slots: >= 3 admit rounds
