"""Tests for the static block-space contract checker (repro.analysis).

Pins the satellite invariants of the checker PR:
  * the rb closed form in core/analysis.py vs the O(n^2) host_active loop,
  * traced-vs-host boundary behaviour at the certified envelope edges
    (tet planes 1622/1623/1624, the 2D row LTM_TRACED_MAX_I), including
    the tightness witness just PAST each envelope,
  * the trace-time guards that read the certified constants,
  * the lint CLI failing when a declared contract is deliberately broken
    (mutated probe count), and the --json report surface.
"""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analysis as A
from repro.core import mapping as M
from repro.core import schedule as S


# ---------------------------------------------------------------------------
# satellite (b): rb closed form == O(n^2) loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", list(range(1, 33)) + [63, 64, 128, 255, 256])
def test_rb_closed_form_matches_host_active_loop(n):
    """strategy_stats' rb useful count is closed-form tri(n); pin it to
    the O(n^2) per-cell host_active loop it replaced."""
    sched = S.RBSchedule(n=n)
    h, w = M.rb_grid_shape(n)
    loop = sum(1 for lam in range(h * w) if sched.host_active(lam))
    st = A.strategy_stats(n)["rb"]
    assert st.useful == loop == M.tri(n)
    assert st.launched == h * w
    assert st.wasted == h * w - M.tri(n)


# ---------------------------------------------------------------------------
# satellite (c): boundary behaviour at the certified envelopes
# ---------------------------------------------------------------------------


def _traced_tet(lam):
    i, j, k = M.tet_map(jnp.asarray(lam, jnp.int32))
    return (int(i), int(j), int(k))


@pytest.mark.parametrize("i", [M.TET_TRACED_MAX_I - 2,
                               M.TET_TRACED_MAX_I - 1,
                               M.TET_TRACED_MAX_I])
def test_tet_traced_vs_host_at_envelope_planes(i):
    """tet_map traced == host at every lambda around planes 1622..1624
    that is still inside the certified envelope."""
    for lam in [M.tet(i) - 2, M.tet(i) - 1, M.tet(i), M.tet(i) + 1]:
        if 0 <= lam <= M.TET_TRACED_MAX_LAM:
            assert _traced_tet(lam) == M.tet_map(lam), lam


def test_tet_envelope_is_tight():
    """One past TET_TRACED_MAX_LAM the clamped probes can no longer reach
    the true plane: the certified envelope is exact, not conservative."""
    lam = M.TET_TRACED_MAX_LAM + 1  # == tet(TET_TRACED_MAX_I)
    assert M.tet_map(lam) == (M.TET_TRACED_MAX_I, 0, 0)
    assert _traced_tet(lam) != M.tet_map(lam)
    assert _traced_tet(lam)[0] == M.TET_TRACED_MAX_I - 1  # clamp artifact


def _traced_ltm(lam):
    i, j = M.ltm_map(jnp.asarray(lam, jnp.int32))
    return (int(i), int(j))


def test_ltm_traced_vs_host_at_envelope_boundary():
    """2D boundary: traced == host right up to LTM_TRACED_MAX_LAM
    (the top of the certified int32 envelope, row LTM_TRACED_MAX_I),
    including the last row's seams."""
    top = M.LTM_TRACED_MAX_LAM
    row0 = M.tri(M.LTM_TRACED_MAX_I)  # first lam of the last full row
    for lam in [top, top - 1, row0, row0 - 1, row0 + 1]:
        assert _traced_ltm(lam) == M.ltm_map(lam), lam
    assert M.ltm_map(top)[0] == M.LTM_TRACED_MAX_I
    # 8*lam + 1 is the binding int32 constraint: one past the envelope
    # the traced discriminant overflows (envelope tight by construction)
    assert 8 * (top + 1) + 1 > M.INT32_MAX


def test_isqrt_traced_exact_across_int32_including_clamp_region():
    """Regression for the probe-overflow bug: x near INT32_MAX used to
    return 46341 because the up-probe (r+1)^2 wrapped negative."""
    xs = []
    for r in [1, 2, 46339, M.ISQRT_MAX_R]:
        xs += [r * r - 1, r * r, r * r + 1]
    xs += [M.INT32_MAX - 1, M.INT32_MAX]
    xs = sorted({x for x in xs if 0 <= x <= M.INT32_MAX})
    got = np.asarray(M._isqrt_traced(jnp.asarray(xs, jnp.int32)))
    want = np.asarray([int(np.floor(np.sqrt(np.float64(x)))) for x in xs])
    np.testing.assert_array_equal(got, want)


def test_trace_time_guards_read_certified_constants():
    """Schedules refuse to trace past the certified envelopes."""
    # largest legal row count, then one row too many
    S.TriangularSchedule(n=M.LTM_TRACED_MAX_I).index_map(0)
    with pytest.raises(AssertionError, match="envelope"):
        S.TriangularSchedule(n=M.LTM_TRACED_MAX_I + 2).index_map(0)
    S.TetrahedralSchedule(n=M.TET_TRACED_MAX_I).index_map(0)
    with pytest.raises(AssertionError, match="envelope"):
        S.TetrahedralSchedule(n=M.TET_TRACED_MAX_I + 1).index_map(0)


# ---------------------------------------------------------------------------
# the checker itself: green on the real repo, red on a broken contract
# ---------------------------------------------------------------------------


def test_envelope_pass_is_green():
    from repro.analysis import envelope

    results = envelope.run()
    assert results and all(r.ok for r in results), \
        [r.as_dict() for r in results if not r.ok]


def test_lint_cli_fails_on_mutated_probe_count(monkeypatch, tmp_path,
                                               capsys):
    """Deliberately break a declared contract: drop the tet down-probe
    count below the derived requirement (2). The envelope pass must
    report the violation and the CLI must exit nonzero."""
    from repro.analysis import lint

    monkeypatch.setattr(M, "TET_PROBES_DOWN", 1)
    report = tmp_path / "lint_report.json"
    rc = lint.main(["--pass", "envelope", "-q", "--json", str(report)])
    assert rc != 0
    rep = json.loads(report.read_text())
    assert rep["total_failures"] >= 1
    bad_rules = {r["rule"] for r in rep["results"] if not r["ok"]}
    assert any("tet" in r for r in bad_rules), bad_rules


def test_lint_cli_json_report_green(tmp_path):
    """Unmutated envelope pass: exit 0 and a well-formed JSON report."""
    from repro.analysis import lint

    report = tmp_path / "lint_report.json"
    rc = lint.main(["--pass", "envelope", "-q", "--json", str(report)])
    assert rc == 0
    rep = json.loads(report.read_text())
    assert rep["total_failures"] == 0
    assert rep["passes"]["envelope"]["checks"] == rep["total_checks"] > 0
    assert {"pass_name", "rule", "ok", "detail"} <= set(rep["results"][0])


def test_contract_verifier_catches_wrong_closed_form():
    """The verifier engine itself must notice a contract whose counting
    closed form is off by one (meta-test: the proof is not vacuous)."""
    from repro.analysis import contracts as C
    from repro.analysis import verifier as V

    con = C.schedule_contracts()["ltm"]
    broken = C.ScheduleContract(
        kind=con.kind, bijectivity=con.bijectivity, rank=con.rank,
        make=con.make, launched=lambda case: con.launched(case) + 1,
        domain=con.domain, segments=con.segments, in_domain=con.in_domain,
        inverse=con.inverse, cases=con.cases[:1],
        seg_active_count=con.seg_active_count, active_at=con.active_at)
    results = V.verify_contract(broken)
    assert any(not r.ok and "counting" in r.rule for r in results)
