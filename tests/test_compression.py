"""int8 gradient compression: codec bounds, error-feedback telescoping,
and convergence of EF-compressed SGD (hypothesis + numeric)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.launch.compat import make_mesh
from repro.parallel import compression as C


@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False, width=32),
                min_size=1, max_size=64))
def test_quantize_bounds(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, scale = C.quantize_int8(x)
    err = jnp.abs(C.dequantize_int8(q, scale) - x)
    # symmetric per-tensor int8: |err| <= scale/2 = max|x|/254
    assert float(jnp.max(err)) <= float(scale) / 2 + 1e-6


def test_error_feedback_telescopes():
    """Over T steps, sum(dequantized) + final_err == sum(grads) exactly
    (the EF invariant that makes the scheme unbiased over time)."""
    key = jax.random.key(0)
    g_sum = jnp.zeros((32,))
    q_sum = jnp.zeros((32,))
    err = jnp.zeros((32,))
    for t in range(20):
        key, k = jax.random.split(key)
        g = jax.random.normal(k, (32,)) * (10.0 ** (t % 3))
        q, scale, err = C.ef_compress(g, err)
        g_sum = g_sum + g
        q_sum = q_sum + C.dequantize_int8(q, scale)
    np.testing.assert_allclose(np.asarray(q_sum + err), np.asarray(g_sum),
                               rtol=1e-4, atol=1e-3)


def test_ef_sgd_converges_like_fp32():
    """EF-int8 SGD on a quadratic tracks full-precision SGD."""
    w_fp = jnp.array([5.0, -3.0, 2.0, -7.0])
    w_q = w_fp
    err = jnp.zeros_like(w_fp)
    lr = 0.05
    for _ in range(300):
        g_fp = 2 * w_fp
        w_fp = w_fp - lr * g_fp
        g_q = 2 * w_q
        q, scale, err = C.ef_compress(g_q, err)
        w_q = w_q - lr * C.dequantize_int8(q, scale)
    assert float(jnp.abs(w_q).max()) < 0.05
    assert float(jnp.abs(w_fp).max()) < 1e-3


def test_compressed_psum_single_device_mesh():
    """On a 1-way mesh the compressed all-reduce must be the identity
    (up to quantization handled by EF)."""
    mesh = make_mesh((1,), ("data",))
    ar = C.make_compressed_allreduce(mesh, axis="data")
    grads = {"w": jnp.array([1.0, -2.0, 3.0]), "b": jnp.array([0.5])}
    err = C.init_error_state(grads)
    out, err2 = ar(grads, err)
    # mean over 1 shard of dequant(quant(g)) == g - err2
    for k in grads:
        np.testing.assert_allclose(np.asarray(out[k] + err2[k]),
                                   np.asarray(grads[k]), rtol=1e-5,
                                   atol=1e-6)
