"""Fused continuous-batching step == split admit + decode rounds.

The tentpole claim, verified at every layer:

  * ops level — fused_step_attention (scan and pallas, one mixed member
    table) equals the split halves: packed_prefill_attention over the
    pack AND packed_decode_attention over the live slots' cache prefixes;
  * driver level — serve.decode.fused_step emits the same admit logits,
    decode logits and cache as packed_prefill + decode_step_packed;
  * engine level — step_mode="fused" is TOKEN-IDENTICAL to the split
    engine and to the isolated greedy reference, including under a fault
    matrix (launch errors, poison, OOM): the fused -> split ladder rung
    absorbs every fused-attempt failure without changing the streams;
  * capacity — a pinned grid the round outgrew rebuckets (schema-valid
    degrade, satellite of PR 8's bare-assert bugfix) instead of crashing;
  * compat — the HLO kernel-region op_name spellings live in ONE tested
    table (launch/compat) shared with roofline/hlo_parse.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import oracles as O
from repro.configs import registry as REG
from repro.kernels.tri_attn import ops as OPS
from repro.launch import compat as C
from repro.models import model as MD
from repro.resilience import faults as F
from repro.serve import decode as D
from repro.serve import engine as E
from repro.serve.engine import Engine

# ---------------------------------------------------------------------------
# ops level: one mixed launch == the two split launches
# ---------------------------------------------------------------------------


def _fused_round(seed=0, h=4, hkv=2, d=8, blk=4, s_cache=32, b=3,
                 pads=(8, 4), kv_lens=(7, 18), slots=(0, 2)):
    qp, kp, vp = O.rand_qkv(seed, 1, h, hkv, sum(pads), d)
    qd, kc, vc = O.rand_decode_state(seed + 1, b, h, hkv, s_cache, d)
    psched = OPS.make_packed_sched(list(pads), block=blk)
    n_members = len(pads) + b + 1
    tbl, needed = OPS.make_fused_table(psched, list(kv_lens), list(slots),
                                       blk=blk, n_members=n_members,
                                       n_slots=b, s_cache=s_cache)
    return (qp, kp, vp, qd, kc, vc, psched, tbl, needed, n_members)


@pytest.mark.parametrize("impl", ["scan", "pallas"])
def test_fused_round_matches_split_halves(impl):
    blk, s_cache, b = 4, 32, 3
    kv_lens, slots = [7, 18], [0, 2]  # slot 1 has no live decode member
    (qp, kp, vp, qd, kc, vc, psched, tbl, needed,
     n_members) = _fused_round(blk=blk, s_cache=s_cache, b=b,
                               kv_lens=kv_lens, slots=slots)
    spec = OPS.FusedStepSpec(n_members=n_members,
                             capacity=psched.steps + D.round_capacity(
                                 needed - psched.steps),
                             blk=blk, impl=impl)
    out_p, out_d = OPS.fused_step_attention(qp, kp, vp, qd, kc, vc,
                                            jnp.asarray(tbl), psched, spec)
    want_p = OPS.packed_prefill_attention(qp, kp, vp, psched, impl="ref")
    dtbl, dneeded = OPS.make_decode_table(kv_lens, slots, blk=blk,
                                          n_members=b + 1, n_slots=b,
                                          s_cache=s_cache)
    dspec = OPS.DecodeRoundSpec(n_members=b + 1,
                                capacity=D.round_capacity(dneeded),
                                blk=blk, impl="ref")
    want_d = OPS.packed_decode_attention(qd, kc, vc, jnp.asarray(dtbl),
                                         dspec)
    O.assert_close(out_p, want_p, "attn", err_msg=f"pack half {impl}")
    O.assert_close(out_d, want_d, "attn", err_msg=f"decode half {impl}")
    # uncovered slot: no live member -> exact zeros, not garbage
    np.testing.assert_array_equal(np.asarray(out_d[1]), 0.0)


def test_fused_capacity_padding_is_inert():
    """Bigger fused capacity buckets only add masked pad steps — the
    recompile-avoidance contract the length-bucketed templates rely on."""
    (qp, kp, vp, qd, kc, vc, psched, tbl, needed,
     n_members) = _fused_round()
    outs = []
    for extra in (0, 5, 3 * needed):
        for impl in ("scan", "pallas"):
            spec = OPS.FusedStepSpec(n_members=n_members,
                                     capacity=needed + extra, blk=4,
                                     impl=impl)
            o_p, o_d = OPS.fused_step_attention(
                qp, kp, vp, qd, kc, vc, jnp.asarray(tbl), psched, spec)
            outs.append((np.asarray(o_p), np.asarray(o_d)))
    for o_p, o_d in outs[1:]:
        np.testing.assert_array_equal(outs[0][0], o_p)
        np.testing.assert_array_equal(outs[0][1], o_d)


def test_fused_table_layout_and_pad_member():
    """The (8, R) fused-table ABI is a declared contract (also pinned by
    analysis/jaxpr_lint + analysis/contracts "mixed"): prefill columns
    first (kind 0), decode columns rebased by psched.steps (kind 1), then
    the shared pad member owning the garbage outputs."""
    psched = OPS.make_packed_sched([8, 4], block=4)
    tbl, needed = OPS.make_fused_table(psched, [7, 18], [0, 2], blk=4,
                                       n_members=6, n_slots=3, s_cache=32)
    assert tbl.shape == (8, 6) and tbl.dtype == np.int32
    np.testing.assert_array_equal(tbl[0], [0, 3, 4, 6, 11, 11])  # starts
    np.testing.assert_array_equal(tbl[1], [0, 0, 1, 1, 1, 1])    # kinds
    assert int(tbl[0, 2]) == psched.steps  # decode half starts after pack
    np.testing.assert_array_equal(tbl[2, 2:4], [2, 5])   # kv tiles
    np.testing.assert_array_equal(tbl[3, 2:4], [7, 18])  # kv_len
    np.testing.assert_array_equal(tbl[5, 2:4], [0, 2])   # slots
    pad = tuple(int(v) for v in tbl[:, -1])
    assert pad == (needed, 1, OPS.DECODE_NO_EMIT, 0, 0, 3, 0, 0)
    assert needed == 11 == psched.steps + 7


# ---------------------------------------------------------------------------
# driver level: decode.fused_step == packed_prefill + decode_step_packed
# ---------------------------------------------------------------------------


def _setup(arch="yi-9b", seed=0):
    cfg = REG.smoke_config(arch)
    params = MD.init_params(jax.random.key(seed), cfg)
    return cfg, params


def _filled_cache(params, cfg, b, max_len, depth, seed=1):
    """A decode cache with ``depth`` tokens of shared history per slot."""
    rng = np.random.default_rng(seed)
    hist = rng.integers(1, cfg.vocab_size, size=(b, depth)).astype(np.int32)
    cache = MD.init_cache(cfg, b, max_len, jnp.float32)
    for t in range(depth):
        _, cache = MD.decode_step(params, cfg, cache,
                                  jnp.asarray(hist[:, t:t + 1]),
                                  jnp.int32(t))
    return cache


@pytest.mark.parametrize("impl", ["scan", "pallas"])
def test_driver_fused_step_equals_split_round(impl):
    cfg, params = _setup()
    b, max_len, depth = 3, 32, 9
    cache = _filled_cache(params, cfg, b, max_len, depth)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (6, 3)]
    live, pos_np = [0, 2], np.array([4, 0, 8], np.int32)
    kv_lens = [int(pos_np[s]) + 1 for s in live]
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size,
                                      size=(b, 1)).astype(np.int32))
    pos = jnp.asarray(pos_np)
    # split: one decode launch + one admit launch
    lg_dec, cache_dec, _ = D.decode_step_packed(
        params, cfg, cache, tokens, pos, kv_lens, live, block=8, impl=impl)
    psched, starts, lens, hidden, _ = D.packed_prefill(
        params, cfg, prompts, block=8, attn_impl=impl)
    rows = [st + ln - 1 for st, ln in zip(starts, lens)]
    lg_adm = MD.logits_from_hidden(params, cfg, hidden)[0, rows]
    # fused: ONE launch
    la, ld, cache_f, states, psched_f, starts_f, lens_f, info = D.fused_step(
        params, cfg, cache, prompts, tokens, pos, kv_lens, live,
        block=8, impl=impl)
    assert (starts_f, lens_f) == (starts, lens)
    assert psched_f.steps == psched.steps
    assert info["tiles"] == psched.steps + sum(-(-kl // info["blk"])
                                               for kl in kv_lens)
    rows_live = np.asarray(live)
    O.assert_close(la, lg_adm, "attn", err_msg=f"admit logits {impl}")
    O.assert_close(np.asarray(ld)[rows_live],
                   np.asarray(lg_dec)[rows_live, 0], "attn",
                   err_msg=f"decode logits {impl}")
    for got, want in zip(jax.tree.leaves(cache_f),
                         jax.tree.leaves(cache_dec)):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=1e-5, atol=1e-5)
    # greedy decisions identical, not just close
    assert (np.argmax(np.asarray(la)[:, :cfg.vocab_size], -1).tolist()
            == np.argmax(np.asarray(lg_adm)[:, :cfg.vocab_size],
                         -1).tolist())


def test_driver_fused_capacity_pin_rebuckets():
    """Satellite: a pinned capacity the round outgrew is a RECOVERABLE
    sizing miss — both decode_step_packed and fused_step rebucket to the
    canonical grid (reported via info) instead of tripping an assert."""
    cfg, params = _setup()
    b, max_len = 2, 32
    cache = _filled_cache(params, cfg, b, max_len, 9)
    rng = np.random.default_rng(4)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size,
                                      size=(b, 1)).astype(np.int32))
    pos = jnp.asarray(np.array([8, 8], np.int32))
    kv_lens, live = [9, 9], [0, 1]
    base, _, info0 = D.decode_step_packed(params, cfg, cache, tokens, pos,
                                          kv_lens, live, block=4)
    assert not info0["rebucketed"]
    pinned, _, info1 = D.decode_step_packed(params, cfg, cache, tokens,
                                            pos, kv_lens, live, block=4,
                                            capacity=1)
    assert info1["rebucketed"] and info1["capacity"] >= info1["tiles"]
    np.testing.assert_array_equal(np.asarray(base), np.asarray(pinned))
    # same audit on the fused-step capacity path
    prompts = [rng.integers(1, cfg.vocab_size, size=5).astype(np.int32)]
    out0 = D.fused_step(params, cfg, cache, prompts, tokens, pos, kv_lens,
                        live, block=4)
    out1 = D.fused_step(params, cfg, cache, prompts, tokens, pos, kv_lens,
                        live, block=4, capacity=1)
    assert not out0[-1]["rebucketed"] and out1[-1]["rebucketed"]
    np.testing.assert_array_equal(np.asarray(out0[0]), np.asarray(out1[0]))
    np.testing.assert_array_equal(np.asarray(out0[1]), np.asarray(out1[1]))


# ---------------------------------------------------------------------------
# engine level: fused == split token streams (incl. the fault matrix)
# ---------------------------------------------------------------------------


def _run(cfg, params, prompts, max_news, *, step_mode, fault_plan=None,
         slots=2, **kw):
    eng = Engine(params, cfg, slots=slots, max_len=48, temperature=0.0,
                 prefill_block=4, decode_mode="packed", decode_block=8,
                 step_mode=step_mode, fault_plan=fault_plan, **kw)
    for uid, (p, mn) in enumerate(zip(prompts, max_news)):
        eng.submit(p, max_new=mn, uid=uid)
    return eng.run(), eng.stats


def _queue(cfg, seed=3, n=5):
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (11, 2, 19, 5, 8)[:n]]
    return prompts, [3, 7, 2, 5, 4][:n]


def test_engine_fused_token_identical_to_split():
    """step_mode='fused' emits byte-identical streams to the split engine
    while paying ONE launch per admit-carrying round (fused_launches),
    with the round's tile accounting recorded."""
    cfg, params = _setup()
    prompts, max_news = _queue(cfg)
    res_f, st_f = _run(cfg, params, prompts, max_news, step_mode="fused")
    res_s, st_s = _run(cfg, params, prompts, max_news, step_mode="split")
    assert res_f == res_s
    assert st_f["fused_rounds"] == st_f["fused_launches"] > 0
    assert st_f["fused_fallbacks"] == 0
    assert st_f["fused_tiles"] > 0
    assert st_f["prefill_requests"] == st_s["prefill_requests"] == 5
    # the fused engine never pays a separate packed-prefill launch for
    # rounds it fused (split pays one per admit round)
    assert st_f["prefill_launches"] < st_s["prefill_launches"] + 1


def test_engine_fused_matches_isolated_greedy_reference():
    cfg, params = _setup()
    prompts, max_news = _queue(cfg, n=3)
    res, _ = _run(cfg, params, prompts, max_news, step_mode="fused")
    from test_decode_packed import _greedy_reference
    for uid, (p, mn) in enumerate(zip(prompts, max_news)):
        assert res[uid] == _greedy_reference(params, cfg, list(p), mn)


@pytest.mark.parametrize("kind,phase,rnd,times", [
    ("launch_error", "admit", 0, 1),
    ("launch_error", "decode", 1, 1),
    ("poison", "admit", 0, 1),
    ("poison", "decode", 1, 1),
    ("admit_oom", "admit", 0, 5),
])
def test_engine_fused_fault_matrix_token_identical(kind, phase, rnd, times):
    """The fused attempt is NOT retried: any strike inside it takes the
    registered step: fused -> split rung (requeue admits, re-run through
    the split ladders) — or, for decode poison, the shared quarantine
    machinery. Either way the streams equal the fault-free baseline."""
    cfg, params = _setup()
    prompts, max_news = _queue(cfg, n=4)
    base, _ = _run(cfg, params, prompts, max_news, step_mode="fused")
    plan = F.FaultPlan([F.Fault(kind=kind, phase=phase, round=rnd,
                                times=times)])
    res_f, st_f = _run(cfg, params, prompts, max_news, step_mode="fused",
                       fault_plan=plan)
    assert res_f == base, (kind, phase)
    plan.reset()
    res_s, _ = _run(cfg, params, prompts, max_news, step_mode="split",
                    fault_plan=plan)
    assert res_s == base, (kind, phase)
    if phase == "admit":  # strikes the fused attempt -> ladder rung taken
        assert st_f["fused_fallbacks"] >= 1
        assert st_f["launches_degraded_total"] >= 1


def test_engine_fused_requires_attention_only():
    """Recurrent mixers have no packed-member notion: the ctor falls back
    to split mode rather than letting fused_step leak state."""
    cfg, params = _setup("rwkv6-1.6b")
    eng = Engine(params, cfg, slots=2, max_len=32, step_mode="fused")
    assert eng.step_mode == "split"


# ---------------------------------------------------------------------------
# compat: kernel-region op_name spellings live in ONE tested table
# ---------------------------------------------------------------------------


def test_kernel_region_spellings_pinned():
    """Satellite: both per-JAX-version spellings of the scan-attention
    cell — "vmap(vmap())/.../while" (new) and "vmap(vmap(while))"
    (0.4.x) — are in launch/compat's table, and roofline/hlo_parse builds
    its regex from that table (no ad-hoc copy to drift)."""
    from repro.roofline import hlo_parse as H

    r = C.kernel_region_regex()
    assert r.search('op_name="jit(f)/vmap(vmap())/while/body/add"')
    assert r.search('op_name="vmap(vmap(while))"')
    for marker in ("ssm_scan_kernel", "wkv_scan_kernel",
                   "tri_attn_kernel"):
        assert any(marker in s for s in
                   C.KERNEL_REGION_OP_NAME_SPELLINGS)
        assert r.search(marker)
    # near-misses must NOT match (a plain while loop is not a kernel cell)
    assert not r.search('op_name="jit(f)/while/body/add"')
    assert not r.search('op_name="vmap(while)"')
    assert H._KERNEL_REGION_RE.pattern == r.pattern
