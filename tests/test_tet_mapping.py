"""Property + unit tests for the tetrahedral (3D simplex) mapping.

The 3D analogue of the paper's central claim: tet_map is a bijection from
[0, T3(n)) onto {(i,j,k): 0 <= k <= j <= i < n}, exact on host and traced,
with plane-contiguous enumeration (the property per-plane accumulation
kernels rely on).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import mapping as M
from repro.core import schedule as S


# ---------------------------------------------------------------------------
# tet_map bijection / round-trip
# ---------------------------------------------------------------------------


def test_tet_numbers():
    assert [M.tet(i) for i in range(6)] == [0, 1, 4, 10, 20, 35]
    for n in range(200):
        assert M.tet(n) == n * (n + 1) * (n + 2) // 6
        assert M.bb3_blocks(n) - M.wasted_blocks_bb3(n) == M.tet_blocks(n)


@pytest.mark.parametrize("n", [1, 2, 3, 8, 17, 64])
def test_tet_enumerates_tetrahedron_exactly(n):
    """Every lambda < T3(n) hits a unique in-domain (i, j, k)."""
    seen = {M.tet_map(l) for l in range(M.tet(n))}
    expect = {(i, j, k) for i in range(n) for j in range(i + 1)
              for k in range(j + 1)}
    assert seen == expect


@pytest.mark.parametrize("n", [1, 2, 3, 8, 17, 64])
def test_tet_roundtrip_exhaustive(n):
    for lam in range(M.tet(n)):
        i, j, k = M.tet_map(lam)
        assert 0 <= k <= j <= i < n
        assert M.tet_inverse(i, j, k) == lam


@given(st.integers(min_value=0, max_value=2**52))
def test_tet_host_roundtrip_large(lam):
    i, j, k = M.tet_map(lam)
    assert 0 <= k <= j <= i
    assert M.tet_inverse(i, j, k) == lam


@given(st.integers(min_value=1, max_value=50))
def test_given_coexists_with_fixtures(tmp_path, n):
    """Regression for the offline hypothesis shim: strategy values must
    bind to the RIGHTMOST parameters by name, leaving pytest fixtures
    (passed as kwargs) intact. Also passes under real hypothesis."""
    assert tmp_path.exists()
    assert 1 <= n <= 50


def test_tet_plane_major_contiguity():
    # Plane i occupies lambdas [tet(i), tet(i+1)), enumerated by g(mu):
    # the 3D analogue of LTM's row-major contiguity.
    for i in range(30):
        lams = [M.tet_inverse(i, j, k) for j in range(i + 1)
                for k in range(j + 1)]
        assert lams == list(range(M.tet(i), M.tet(i + 1)))


# ---------------------------------------------------------------------------
# Traced == host
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 2, 5, 16, 64])
def test_tet_traced_matches_host_exhaustive(n):
    lams = jnp.arange(M.tet(n), dtype=jnp.int32)
    it, jt, kt = jax.jit(jax.vmap(M.tet_map))(lams)
    for l in range(M.tet(n)):
        assert (int(it[l]), int(jt[l]), int(kt[l])) == M.tet_map(l), l


# Traced exactness envelope: tet() int32 intermediates fit for arguments
# up to TET_TRACED_MAX_I, so planes i <= TET_TRACED_EXACT_PLANES
# (lam <= TET_TRACED_MAX_LAM ~ 7.15e8) are exact. The constants live in
# core/mapping.py and are certified from derived float error bounds by
# repro.analysis.envelope.
@given(st.integers(min_value=0, max_value=M.TET_TRACED_MAX_LAM))
@settings(max_examples=200)
def test_tet_traced_matches_host_envelope(lam):
    i_h, j_h, k_h = M.tet_map(lam)
    i_t, j_t, k_t = M.tet_map(jnp.asarray(lam, jnp.int32))
    assert (int(i_t), int(j_t), int(k_t)) == (i_h, j_h, k_h)


def test_tet_traced_exact_at_plane_boundaries():
    """Plane boundaries are where the cbrt repair earns its keep."""
    edges = []
    for i in [1, 2, 3, 100, 500, 1000, M.TET_TRACED_EXACT_PLANES]:
        t = M.tet(i)
        edges += [t - 1, t, t + 1]
    edges = [e for e in set(edges) if 0 <= e <= M.TET_TRACED_MAX_LAM]
    lams = jnp.asarray(sorted(edges), jnp.int32)
    it, jt, kt = jax.jit(jax.vmap(M.tet_map))(lams)
    for idx, l in enumerate(sorted(edges)):
        assert (int(it[idx]), int(jt[idx]), int(kt[idx])) == M.tet_map(l), l


# ---------------------------------------------------------------------------
# Schedules: TetrahedralSchedule vs Dense3DSchedule (BB-3D)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 2, 5, 12])
def test_tet_schedule_covers_domain(n):
    sched = S.TetrahedralSchedule(n=n)
    seen = sched.enumerate_host()
    assert len(seen) == len(set(seen)) == M.tet(n) == sched.num_blocks
    assert sched.domain_blocks == sched.num_blocks
    assert sched.waste_fraction == 0.0


@pytest.mark.parametrize("n", [1, 2, 5, 8])
def test_bb3_schedule_guard_matches_domain(n):
    sched = S.Dense3DSchedule(n=n)
    assert sched.num_blocks == n ** 3
    active = [sched.host_map(l) for l in range(sched.num_blocks)
              if bool(sched.active(l))]
    assert len(active) == M.tet(n) == sched.domain_blocks
    assert set(active) == set(S.TetrahedralSchedule(n=n).enumerate_host())


def test_launch_reduction_vs_bb3():
    """The acceptance claim: tet launches n(n+1)(n+2)/6 of BB-3D's n^3,
    an asymptotic 6x reduction (5/6 of the cube is waste)."""
    for n in (8, 64, 512):
        frac = S.Dense3DSchedule(n=n).waste_fraction
        assert frac > 5 / 6 - 3 / n
        assert M.tet_blocks(n) * 6 >= M.bb3_blocks(n)
        assert M.tet_blocks(n) <= M.bb3_blocks(n) // 6 + n * n


@pytest.mark.parametrize("kind", ["tet", "bb3"])
def test_tet_traced_index_map_matches_host(kind):
    n = 9
    sched = S.make_schedule(kind, n)
    lams = jnp.arange(sched.num_blocks)
    it, jt, kt = jax.jit(jax.vmap(sched.index_map))(lams)
    for l in range(sched.num_blocks):
        got = (int(it[l]), int(jt[l]), int(kt[l]))
        assert got == tuple(sched.host_map(l)), (kind, l)


@pytest.mark.parametrize("n", [1, 3, 7])
def test_tet_segment_bookkeeping(n):
    """seg_start/seg_end fire exactly at plane boundaries (shared 2D/3D
    segment machinery)."""
    sched = S.TetrahedralSchedule(n=n)
    for lam in range(sched.num_blocks):
        i = sched.host_map(lam)[0]
        assert bool(sched.seg_start(lam)) == (lam == M.tet(i))
        assert bool(sched.seg_end(lam)) == (lam == M.tet(i + 1) - 1)


def test_2d_segment_origin_consistent_with_rows():
    """The shared segment bookkeeping agrees with the 2D row structure for
    every segment-contiguous schedule kind."""
    for sched in [S.TriangularSchedule(n=9),
                  S.TriangularSchedule(n=9, include_diagonal=False),
                  S.DenseSchedule(n=7),
                  S.BandSchedule(n=11, w=4),
                  S.PrefixSchedule(n=9, p=3),
                  S.TetrahedralSchedule(n=6),
                  S.Dense3DSchedule(n=4)]:
        prev_outer = None
        for lam in range(sched.num_blocks):
            outer = sched.host_map(lam)[0]
            assert bool(sched.seg_start(lam)) == (outer != prev_outer)
            last = (lam == sched.num_blocks - 1
                    or sched.host_map(lam + 1)[0] != outer)
            assert bool(sched.seg_end(lam)) == last
            prev_outer = outer


# ---------------------------------------------------------------------------
# rec_levels regression (malformed-assert bugfix)
# ---------------------------------------------------------------------------


def test_rec_levels_accepts_power_of_two_ratios():
    assert M.rec_levels(8, 1) == 3
    assert M.rec_levels(16, 4) == 2
    assert M.rec_levels(3, 3) == 0
    assert M.rec_levels(24, 3) == 3


@pytest.mark.parametrize("n,m", [(12, 5), (12, 8), (24, 9), (0, 1), (6, 4),
                                 (10, 2), (12, 4)])
def test_rec_levels_rejects_non_power_of_two(n, m):
    """Regression: the old first assert was vacuous whenever m divided n,
    silently relying on a later check; non-pow2 ratios and indivisible m
    must raise with a clear message."""
    with pytest.raises(AssertionError, match="REC needs n = m\\*2\\^k"):
        M.rec_levels(n, m)
