"""Packed mixed-position decode == sequential per-slot decode.

Property-tests (hypothesis, shimmed offline by tests/_hypo_compat.py) the
tentpole claim end to end:

  * ops level — packed_decode_attention (scan / pallas / ref impls) equals
    the isolated per-slot oracle for arbitrary skewed KV lengths, retired
    slots, and rolling sliding-window prefixes;
  * engine level — an Engine decoding with the packed path emits
    TOKEN-IDENTICAL streams to the lockstep engine and to an isolated
    per-request greedy reference, across position skew, SWA configs, and
    mid-round slot retirement (mixed max_new);
  * stats — packed-prefill launches and packed-decode launches are counted
    apart (a single shared counter would conflate the two claims).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import oracles as O
from repro.configs import registry as REG
from repro.core.packing import PackedSchedule
from repro.kernels.tri_attn import ops as OPS
from repro.models import model as MD
from repro.serve import decode as D
from repro.serve.engine import Engine

# ---------------------------------------------------------------------------
# ops level
# ---------------------------------------------------------------------------


def _round(kv_lens, slots, b, blk, s_cache, seed=0, h=4, hkv=2, d=8):
    q, kc, vc = O.rand_decode_state(seed, b, h, hkv, s_cache, d)
    tbl, needed = OPS.make_decode_table(kv_lens, slots, blk=blk,
                                       n_members=b + 1, n_slots=b)
    cap = D.round_capacity(needed)
    per_slot = np.zeros((b,), np.int64)
    for kl, sl in zip(kv_lens, slots):
        per_slot[sl] = kl
    want = O.decode_round_oracle(q, kc, vc, per_slot)
    return q, kc, vc, tbl, cap, want


@pytest.mark.parametrize("impl", ["scan", "pallas", "ref"])
def test_skewed_round_matches_per_slot_oracle(impl):
    b, blk, s_cache = 5, 8, 64
    kv_lens, slots = [64, 3, 17], [0, 2, 4]  # slots 1 and 3 retired
    q, kc, vc, tbl, cap, want = _round(kv_lens, slots, b, blk, s_cache)
    spec = OPS.DecodeRoundSpec(n_members=b + 1, capacity=cap, blk=blk,
                               impl=impl)
    got = OPS.packed_decode_attention(q, kc, vc, jnp.asarray(tbl), spec)
    O.assert_close(got, want, "attn", err_msg=impl)
    np.testing.assert_array_equal(np.asarray(got[1]), 0.0)
    np.testing.assert_array_equal(np.asarray(got[3]), 0.0)


@given(st.data())
@settings(max_examples=12)
def test_property_random_rounds_match_oracle(data):
    """Random live subsets x skewed lengths x tile edges: scan and pallas
    both equal the isolated per-slot oracle (mid-round retirement is the
    'absent from the table' case)."""
    b = data.draw(st.integers(min_value=1, max_value=5))
    blk = data.draw(st.integers(min_value=1, max_value=3)) * 4
    s_cache = blk * data.draw(st.integers(min_value=1, max_value=4))
    n_live = data.draw(st.integers(min_value=1, max_value=b))
    slots = sorted(np.random.RandomState(
        data.draw(st.integers(min_value=0, max_value=999))).permutation(
        b)[:n_live].tolist())
    kv_lens = [data.draw(st.integers(min_value=1, max_value=s_cache))
               for _ in slots]
    seed = data.draw(st.integers(min_value=0, max_value=99))
    q, kc, vc, tbl, cap, want = _round(kv_lens, slots, b, blk, s_cache,
                                       seed=seed)
    for impl in ("scan", "pallas"):
        spec = OPS.DecodeRoundSpec(n_members=b + 1, capacity=cap, blk=blk,
                                   impl=impl)
        got = OPS.packed_decode_attention(q, kc, vc, jnp.asarray(tbl), spec)
        O.assert_close(got, want, "attn",
                       err_msg=f"{impl} {kv_lens} {slots} blk={blk}")


def test_capacity_padding_is_inert():
    """Bigger static capacity buckets only add masked pad steps: output
    identical across capacities (the recompile-avoidance contract)."""
    b, blk, s_cache = 3, 4, 32
    kv_lens, slots = [9, 30], [0, 2]
    q, kc, vc, tbl, cap, want = _round(kv_lens, slots, b, blk, s_cache)
    outs = []
    for capacity in (cap, cap + 5, 4 * cap):
        for impl in ("scan", "pallas"):
            spec = OPS.DecodeRoundSpec(n_members=b + 1, capacity=capacity,
                                       blk=blk, impl=impl)
            outs.append(np.asarray(OPS.packed_decode_attention(
                q, kc, vc, jnp.asarray(tbl), spec)))
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)
    O.assert_close(outs[0], want, "attn")


def test_decode_table_layout():
    tbl, needed = OPS.make_decode_table([9, 1, 16], [0, 1, 3], blk=4,
                                        n_members=6, n_slots=5)
    assert tbl.shape == (5, 6)
    np.testing.assert_array_equal(tbl[0], [0, 3, 4, 8, 8, 8])  # starts
    np.testing.assert_array_equal(tbl[1, :4], [0, 1, 3, 0])    # slots
    np.testing.assert_array_equal(tbl[2, :4], [3, 1, 4, 0])    # kv_tiles
    np.testing.assert_array_equal(tbl[3], [9, 1, 16, 0, 0, 0])  # kv_len
    np.testing.assert_array_equal(tbl[4], 0)  # unbanded: whole prefix
    assert tbl[1, 5] == 5 and tbl[2, 5] == OPS.DECODE_NO_EMIT
    assert needed == 8
    # the table IS core/packing's decode_round: same offsets
    pk = PackedSchedule.decode_round([3, 1, 4])
    assert tuple(tbl[0, :3]) == pk.offsets[:-1]
    assert pk.num_blocks == needed


def test_decode_pad_member_declared_contract():
    """The pad-member ABI is a declared contract (also enforced by
    ``repro.analysis.jaxpr_lint``): the final column is exactly
    (cur, n_slots, DECODE_NO_EMIT, 0, 0) — it owns the garbage output
    row b and never emits — and DECODE_NO_EMIT is a fixed sentinel that
    dominates any representable tile count so the lambda search can
    never land past it."""
    assert OPS.DECODE_NO_EMIT == 2 ** 30
    for kv_lens, slots, n_members, n_slots in [
            ([9, 1, 16], [0, 1, 3], 6, 5),
            ([5], [2], 2, 4),
            ([7, 7, 7], [0, 1, 2], 4, 3)]:
        tbl, needed = OPS.make_decode_table(kv_lens, slots, blk=4,
                                            n_members=n_members,
                                            n_slots=n_slots)
        assert tbl.shape == (5, n_members) and tbl.dtype == np.int32
        pad = tuple(int(v) for v in tbl[:, -1])
        assert pad == (needed, n_slots, OPS.DECODE_NO_EMIT, 0, 0)
        # unused interior columns are zero-tile, never the pad sentinel
        for j in range(len(kv_lens), n_members - 1):
            assert tuple(int(v) for v in tbl[:, j]) == (needed, 0, 0, 0, 0)
        # the sentinel dominates any real cumulative tile count by far
        assert needed < OPS.DECODE_NO_EMIT // 2


def test_banded_decode_table_layout_and_tile_cap():
    """window=w trims each member to its LAST w tokens: kv_first row set,
    per-slot kv_tiles capped at ceil(w / blk) (+1 when kv_len is not
    tile-aligned), however deep the position."""
    from repro.serve import decode as D

    w, blk = 8, 4
    tbl, needed = D.make_decode_table([64, 9, 3], [0, 1, 2], blk=blk,
                                      n_members=5, n_slots=4, s_cache=64,
                                      window=w)
    assert tbl.shape == (5, 5)
    np.testing.assert_array_equal(tbl[3, :3], [64, 9, 3])      # kv_len
    np.testing.assert_array_equal(tbl[4, :3], [56, 1, 0])      # kv_first
    np.testing.assert_array_equal(tbl[2, :3], [2, 3, 1])       # kv_tiles
    assert needed == 6  # vs 16 + 3 + 1 unbanded
    cap = -(-w // blk) + 1
    assert max(tbl[2, :3]) <= cap
    # per-slot windows
    tbl2, _ = D.make_decode_table([64, 64], [0, 1], blk=blk, n_members=3,
                                  n_slots=2, window=[4, None])
    np.testing.assert_array_equal(tbl2[2, :2], [1, 16])
    with pytest.raises(AssertionError, match="window list"):
        D.make_decode_table([8, 8], [0, 1], blk=blk, n_members=3,
                            n_slots=2, window=[4])


@pytest.mark.parametrize("impl", ["scan", "pallas", "ref"])
def test_banded_decode_round_token_identical(impl):
    """Band-limited members equal the full-prefix WINDOWED oracle: the
    trimmed head tiles were entirely outside the window, so the packed
    banded round loses no information (token identity of the satellite)."""
    from repro.serve import decode as D

    b, blk, s_cache, w = 4, 8, 64, 16
    kv_lens, slots = [61, 17, 9], [0, 1, 3]
    q, kc, vc = O.rand_decode_state(7, b, 4, 2, s_cache, 8)
    tbl, needed = D.make_decode_table(kv_lens, slots, blk=blk,
                                      n_members=b + 1, n_slots=b,
                                      s_cache=s_cache, window=w)
    cap = D.round_capacity(needed)
    want = np.zeros((b, 4, 8), np.float32)
    for kl, sl in zip(kv_lens, slots):
        o = O.attention_oracle(
            np.asarray(q[sl])[None, :, None, :],
            np.asarray(kc[sl, :kl]).transpose(1, 0, 2)[None],
            np.asarray(vc[sl, :kl]).transpose(1, 0, 2)[None],
            window=w, q_offset=kl - 1)
        want[sl] = o[0, :, 0, :]
    spec = OPS.DecodeRoundSpec(n_members=b + 1, capacity=cap, blk=blk,
                               impl=impl)
    got = OPS.packed_decode_attention(q, kc, vc, jnp.asarray(tbl), spec)
    O.assert_close(got, want, "attn", err_msg=f"banded {impl}")
    # and the band actually trimmed tiles vs the unbanded round
    _, full = OPS.make_decode_table(kv_lens, slots, blk=blk,
                                    n_members=b + 1, n_slots=b)
    assert needed < full


def test_decode_table_rejects_overfull_and_empty():
    with pytest.raises(AssertionError, match="live members"):
        OPS.make_decode_table([1, 1, 1], [0, 1, 2], blk=4, n_members=3,
                              n_slots=4)
    with pytest.raises(AssertionError, match="attend"):
        OPS.make_decode_table([0], [0], blk=4, n_members=3, n_slots=4)
    # kv_len beyond the cache would silently re-attend the clamped last
    # tile downstream; the builder rejects it while lengths are host ints
    with pytest.raises(AssertionError, match="exceed the KV cache"):
        OPS.make_decode_table([33], [0], blk=4, n_members=3, n_slots=4,
                              s_cache=32)


# ---------------------------------------------------------------------------
# engine level (token-identical, incl. SWA + mid-round retirement)
# ---------------------------------------------------------------------------


def _setup(arch="yi-9b", seed=0):
    cfg = REG.smoke_config(arch)
    params = MD.init_params(jax.random.key(seed), cfg)
    return cfg, params


def _run_engine(cfg, params, prompts, max_news, decode_mode, **kw):
    eng = Engine(params, cfg, slots=2, max_len=48, temperature=0.0,
                 prefill_block=4, decode_mode=decode_mode, decode_block=8,
                 **kw)
    for uid, (p, mn) in enumerate(zip(prompts, max_news)):
        eng.submit(p, max_new=mn, uid=uid)
    return eng.run(), eng.stats


def _greedy_reference(params, cfg, prompt, max_new, max_len=48):
    cache = MD.init_cache(cfg, 1, max_len, jnp.float32)
    for t, p in enumerate(prompt):
        logits, cache = MD.decode_step(
            params, cfg, cache, jnp.array([[p]], jnp.int32), jnp.int32(t))
    out, pos = [], len(prompt) - 1
    nxt = int(jnp.argmax(logits[0, 0, :cfg.vocab_size]))
    for _ in range(max_new):
        out.append(nxt)
        pos += 1
        logits, cache = MD.decode_step(
            params, cfg, cache, jnp.array([[nxt]], jnp.int32),
            jnp.int32(pos))
        nxt = int(jnp.argmax(logits[0, 0, :cfg.vocab_size]))
    return out


@pytest.mark.parametrize("arch", ["yi-9b", "mixtral-8x7b"])
def test_engine_packed_decode_token_identical(arch):
    """Skewed prompts + mixed max_new (mid-round retirement): the packed
    decode engine, the lockstep engine, and the isolated per-request
    reference all emit the same tokens. mixtral exercises the rolling
    sliding-window cache."""
    cfg, params = _setup(arch)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (11, 2, 19, 5)]
    max_news = [3, 7, 2, 5]  # slots retire mid-round at different times
    res_packed, st_packed = _run_engine(cfg, params, prompts, max_news,
                                        "packed")
    res_lock, st_lock = _run_engine(cfg, params, prompts, max_news,
                                    "lockstep")
    assert res_packed == res_lock
    for uid, (p, mn) in enumerate(zip(prompts, max_news)):
        assert res_packed[uid] == _greedy_reference(params, cfg, list(p), mn)
    assert st_packed["decode_packed_launches"] == st_packed["decode_rounds"]
    assert st_packed["decode_lockstep_launches"] == 0
    assert st_lock["decode_packed_launches"] == 0
    # position skew means the packed grid beats pad-to-max
    assert st_packed["decode_tiles_packed"] < st_packed["decode_tiles_padded"]


def test_engine_auto_mode_prefers_lockstep_when_uniform():
    """decode_mode='auto' is a COST crossover, not a skew test: packed wins
    only when PACKED_TILE_COST_RATIO * sum(tiles) < B * max(tiles). Uniform
    all-live rounds stay lockstep (the regression: skew=1 must be
    lockstep); one deep straggler among short slots flips to packed."""
    from repro.serve import engine as E

    cfg, params = _setup()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, cfg.vocab_size, size=7).astype(np.int32)
               for _ in range(2)]
    res, st = _run_engine(cfg, params, prompts, [4, 4], "auto")
    # equal-length prompts, equal max_new, slots == requests: sum(tiles)
    # == B * max(tiles), so the ratio-discounted packed cost never wins
    assert st["decode_packed_launches"] == 0
    assert st["decode_lockstep_launches"] == st["decode_rounds"] > 0
    # mild skew is NOT enough any more: at B=2 even tiles [1, 2] give
    # ratio*sum = 2.3*3 > 2*2 = B*max — lockstep is genuinely cheaper
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (3, 13)]
    res, st = _run_engine(cfg, params, prompts, [4, 4], "auto")
    assert st["decode_packed_launches"] == 0
    # one deep straggler among short slots: tiles [1, 1, 1, 5] ->
    # 2.3 * 8 = 18.4 < 4 * 5 = 20 -> packed rounds appear
    assert E.PACKED_TILE_COST_RATIO * 8 < 4 * 5
    eng = Engine(params, cfg, slots=4, max_len=48, temperature=0.0,
                 prefill_block=4, decode_mode="auto", decode_block=8)
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (3, 3, 3, 37)]
    for uid, p in enumerate(prompts):
        eng.submit(p, max_new=4, uid=uid)
    eng.run()
    assert eng.stats["decode_packed_launches"] > 0


def test_engine_recurrent_arch_falls_back_to_lockstep_decode():
    cfg, params = _setup("rwkv6-1.6b")
    eng = Engine(params, cfg, slots=2, max_len=32, decode_mode="packed")
    assert eng.decode_mode == "lockstep"


def test_engine_counts_prefill_and_decode_launches_apart():
    """The satellite claim: packed-prefill launches and packed-decode
    launches are separate counters (one shared counter would conflate
    'one launch per admit round' with 'one launch per decode round')."""
    cfg, params = _setup()
    rng = np.random.default_rng(9)
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (9, 3, 12)]
    res, st = _run_engine(cfg, params, prompts, [4, 4, 4], "packed")
    assert st["prefill_launches"] == st["admit_rounds"] == 2  # 2+1 over 2 slots
    assert st["decode_packed_launches"] == st["decode_rounds"]
    assert st["decode_packed_launches"] > 0
    assert (st["decode_packed_launches"] + st["decode_lockstep_launches"]
            == st["decode_rounds"])
    # tile accounting exists per round and is packed <= padded
    assert 0 < st["decode_tiles_packed"] <= st["decode_tiles_padded"]


@given(st.data())
@settings(max_examples=3)
def test_property_engine_token_identical_random_queues(data):
    """Random skewed queues (hypothesis-driven): packed == lockstep token
    streams, decode counters consistent."""
    cfg, params = _setup()
    rng = np.random.default_rng(data.draw(st.integers(0, 99)))
    n_req = data.draw(st.integers(min_value=1, max_value=4))
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=data.draw(st.integers(1, 20))).astype(
        np.int32) for _ in range(n_req)]
    max_news = [data.draw(st.integers(1, 6)) for _ in range(n_req)]
    res_p, st_p = _run_engine(cfg, params, prompts, max_news, "packed")
    res_l, _ = _run_engine(cfg, params, prompts, max_news, "lockstep")
    assert res_p == res_l
    assert st_p["decode_packed_launches"] == st_p["decode_rounds"]
