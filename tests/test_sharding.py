"""Sharding-rule tests. Rule logic is pure (PartitionSpec construction +
divisibility fallback) and testable on a real multi-device mesh built in a
SUBPROCESS with --xla_force_host_platform_device_count=8 (the main test
process keeps the single real CPU device, per the dry-run contract)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import registry as REG
from repro.launch.compat import make_mesh
from repro.parallel import sharding as SH


def _mesh1():
    return make_mesh((1, 1), ("data", "model"))


def test_fallback_drops_indivisible_axes():
    mesh = _mesh1()
    # axis size 1 divides everything -> spec preserved
    assert SH.fallback(P("data", "model"), (7, 13), mesh) == \
        P("data", "model")


def test_param_rules_cover_every_leaf():
    """Every param leaf of every arch gets a VALID spec (divisible dims)."""
    mesh = _mesh1()
    for arch in REG.ARCH_IDS:
        cfg = REG.get_config(arch)
        params = REG.params_specs(cfg)
        shardings = SH.param_shardings(mesh, params)
        assert len(jax.tree.leaves(shardings)) == \
            len(jax.tree.leaves(params))


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import registry as REG
    from repro.launch.compat import make_mesh
    from repro.parallel import sharding as SH

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))

    # 1. every full-scale arch: all specs valid on the mesh
    for arch in REG.ARCH_IDS:
        cfg = REG.get_config(arch)
        params = REG.params_specs(cfg)
        sh = SH.param_shardings(mesh, params)
        flat = jax.tree_util.tree_flatten_with_path(sh)[0]
        pflat = jax.tree_util.tree_flatten_with_path(params)[0]
        for (path, s), (_, spec) in zip(flat, pflat):
            for dim, axes in zip(spec.shape, s.spec):
                if axes is None:
                    continue
                size = SH._axis_size(mesh, axes)
                assert dim % size == 0, (arch, path, spec.shape, s.spec)

    # 2. rules: wq is (FSDP, TP); wo transposed; norms replicated
    cfg = REG.get_config("yi-9b")
    params = REG.params_specs(cfg)
    sh = SH.param_shardings(mesh, params)
    l0 = sh["layers"]["l0"]
    def norm(spec):  # PartitionSpec modulo trailing Nones
        t = tuple(spec)
        while t and t[-1] is None:
            t = t[:-1]
        return t

    assert norm(l0["mixer"]["wq"].spec) == (None, "data", "model")
    assert norm(l0["mixer"]["wo"].spec) == (None, "model", "data")
    assert norm(l0["norm1"].spec) == ()
    assert norm(sh["embed"].spec) == ("model", "data")

    # 3. batch sharding composes pod+data on the batch dim
    batch = REG.batch_specs(cfg, REG.get_shape("train_4k"))
    bs = SH.batch_shardings(mesh, batch)
    assert bs["tokens"].spec == P(("pod", "data"), None)

    # 4. cache: B=1 long-context falls back to sharding the KV sequence
    cache = REG.cache_specs(REG.get_config("jamba-1.5-large-398b"),
                            REG.get_shape("long_500k"))
    cs = SH.cache_shardings(mesh, cache)
    kv = cs["l3"]["k"].spec
    assert kv[1] is None and kv[2] == ("pod", "data", "model"), kv

    # 5. a sharded matmul with these rules runs and matches unsharded
    w = jnp.arange(16 * 8, dtype=jnp.float32).reshape(16, 8)
    x = jnp.arange(4 * 16, dtype=jnp.float32).reshape(4, 16)
    wsh = jax.device_put(w, NamedSharding(mesh, P("data", "model")))
    xsh = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"), None)))
    y = jax.jit(lambda x, w: x @ w)(xsh, wsh)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w))
    print("SUBPROC_OK")
""")


def test_rules_on_8_device_mesh():
    r = subprocess.run([sys.executable, "-c", _SUBPROC],
                       capture_output=True, text=True, timeout=420,
                       cwd="/root/repo")
    assert "SUBPROC_OK" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]
