"""Training substrate tests: optimizers, microbatch-accumulation
equivalence, data determinism, loss decrease."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.configs import registry as REG
from repro.configs.base import ShapeConfig
from repro.train import data as DATA
from repro.train import optimizer as OPT
from repro.train import train_step as TS


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizer_minimizes_quadratic(kind):
    opt = OPT.OptConfig(kind=kind, lr=0.1, weight_decay=0.0,
                        warmup_steps=0, total_steps=200, min_lr_frac=1.0)
    params = {"w": jnp.array([[3.0, -2.0], [1.5, 4.0]])}
    state = OPT.init_opt_state(opt, params)
    step = jnp.zeros((), jnp.int32)
    for i in range(150):
        grads = jax.tree.map(lambda p: 2 * p, params)  # d/dp |p|^2
        params, state, _ = OPT.apply_updates(opt, params, grads, state, step)
        step = step + 1
    assert float(jnp.abs(params["w"]).max()) < 0.1, kind


def test_adafactor_state_is_factored():
    opt = OPT.OptConfig(kind="adafactor")
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
    s = OPT.init_opt_state(opt, params)
    assert s["vr"]["w"].shape == (64,)
    assert s["vc"]["w"].shape == (32,)
    assert s["vr"]["b"].shape == (64,)  # unfactored 1-D


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = OPT.clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    assert abs(float(OPT.global_norm(clipped)) - 1.0) < 1e-5


@given(st.integers(0, 10_000))
def test_schedule_bounds(step):
    opt = OPT.OptConfig(lr=1e-3, warmup_steps=100, total_steps=10_000,
                        min_lr_frac=0.1)
    lr = float(OPT.schedule(opt, jnp.int32(step)))
    assert 0.0 <= lr <= opt.lr + 1e-9
    if step >= 100:
        assert lr >= opt.lr * opt.min_lr_frac - 1e-9


# ---------------------------------------------------------------------------
# Microbatch accumulation == single batch
# ---------------------------------------------------------------------------


def test_microbatch_equivalence():
    cfg = REG.smoke_config("yi-9b")
    opt = OPT.OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    state1 = TS.init_state(jax.random.key(0), cfg, opt)
    state4 = jax.tree.map(lambda x: x, state1)  # copy

    shape = ShapeConfig("t", 32, 8, "train")
    batch = DATA.SyntheticLM(cfg, shape, act_dtype=jnp.float32).batch(0)

    s1, m1 = TS.make_train_step(cfg, opt, microbatches=1)(state1, batch)
    s4, m4 = TS.make_train_step(cfg, opt, microbatches=4)(state4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_per_step():
    cfg = REG.smoke_config("yi-9b")
    shape = ShapeConfig("t", 64, 4, "train")
    ds1 = DATA.SyntheticLM(cfg, shape, seed=3)
    ds2 = DATA.SyntheticLM(cfg, shape, seed=3)
    b1, b2 = ds1.batch(17), ds2.batch(17)
    assert jnp.array_equal(b1["tokens"], b2["tokens"])
    assert not jnp.array_equal(ds1.batch(18)["tokens"], b1["tokens"])


def test_data_labels_are_shifted_tokens():
    cfg = REG.smoke_config("yi-9b")
    shape = ShapeConfig("t", 64, 2, "train")
    b = DATA.SyntheticLM(cfg, shape).batch(0)
    assert jnp.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_vlm_mask_zeroes_prefix():
    cfg = REG.smoke_config("internvl2-1b")
    shape = ShapeConfig("t", 64, 2, "train")
    b = DATA.SyntheticLM(cfg, shape).batch(0)
    p = cfg.n_patches
    assert b["embeds"].shape == (2, p, cfg.d_model)
    assert b["tokens"].shape == (2, 64 - p)
    assert float(b["mask"][:, :p].sum()) == 0.0
    assert float(b["mask"][:, p:].min()) == 1.0


# ---------------------------------------------------------------------------
# Short training run drops the loss (system-level sanity)
# ---------------------------------------------------------------------------


def test_loss_decreases_20_steps():
    cfg = REG.smoke_config("granite-34b")
    opt = OPT.OptConfig(lr=3e-3, warmup_steps=2, total_steps=20)
    state = TS.init_state(jax.random.key(0), cfg, opt)
    shape = ShapeConfig("t", 64, 4, "train")
    ds = DATA.SyntheticLM(cfg, shape, act_dtype=jnp.float32)
    step = jax.jit(TS.make_train_step(cfg, opt), donate_argnums=(0,))
    losses = []
    for i in range(20):
        state, metrics = step(state, ds.batch(i))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses
