"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
config of the same family and runs one forward + one train step + one decode
step on CPU, asserting output shapes and finiteness.

Full-scale configs are exercised only via launch/dryrun.py (lower+compile,
no allocation).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry as REG
from repro.configs.base import SHAPES, ShapeConfig
from repro.models import model as MD
from repro.train import data as DATA
from repro.train import optimizer as OPT
from repro.train import train_step as TS


def _smoke_batch(cfg, b=2, s=64, seed=0):
    shape = ShapeConfig("smoke", s, b, "train")
    return DATA.SyntheticLM(cfg, shape, seed=seed,
                            act_dtype=jnp.float32).batch(0)


@pytest.mark.parametrize("arch", REG.ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = REG.smoke_config(arch)
    params = MD.init_params(jax.random.key(0), cfg)
    batch = _smoke_batch(cfg)
    b, s = batch["labels"].shape
    hidden, aux, _ = MD.forward(params, cfg, batch)
    assert hidden.shape == (b, s, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden.astype(jnp.float32))))
    loss, metrics = MD.loss_fn(params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    # random-init CE should be near ln(V)
    import math
    assert abs(float(metrics["ce"]) - math.log(cfg.vocab_size)) < 1.5


@pytest.mark.parametrize("arch", REG.ARCH_IDS)
def test_one_train_step(arch):
    cfg = REG.smoke_config(arch)
    opt = OPT.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = TS.init_state(jax.random.key(0), cfg, opt)
    step = TS.make_train_step(cfg, opt)
    batch = _smoke_batch(cfg)
    new_state, metrics = step(state, batch)
    assert int(new_state.step) == 1
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree.map(
        lambda a, b_: bool(jnp.any(a != b_)), state.params, new_state.params)
    assert any(jax.tree.leaves(moved))
    for p in jax.tree.leaves(new_state.params):
        assert bool(jnp.all(jnp.isfinite(p.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", REG.ARCH_IDS)
def test_decode_step(arch):
    cfg = REG.smoke_config(arch)
    params = MD.init_params(jax.random.key(0), cfg)
    b = 2
    cache = MD.init_cache(cfg, b, 32, jnp.float32)
    toks = jnp.ones((b, 1), jnp.int32)
    logits, cache2 = MD.decode_step(params, cfg, cache, toks, jnp.int32(0))
    assert logits.shape == (b, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache advanced for at least one leaf
    diff = jax.tree.map(lambda a, b_: bool(jnp.any(a != b_)), cache, cache2)
    assert any(jax.tree.leaves(diff))


@pytest.mark.parametrize("arch", REG.ARCH_IDS)
def test_input_specs_cover_all_shapes(arch):
    cfg = REG.get_config(arch)
    for sname, shape in SHAPES.items():
        specs = REG.input_specs(arch, sname)
        assert "params" in specs
        if shape.is_decode:
            assert specs["tokens"].shape == (shape.global_batch, 1)
            assert "cache" in specs
            n_leaves = len(jax.tree.leaves(specs["cache"]))
            assert n_leaves > 0
        else:
            lbl = specs["batch"]["labels"]
            assert lbl.shape == (shape.global_batch, shape.seq_len)


def test_supported_matrix():
    """long_500k runs only for sub-quadratic archs; 40 cells total."""
    cells = REG.runnable_cells()
    assert len(cells) == 40
    skipped = {(a, s) for a, s, ok, _ in cells if not ok}
    assert all(s == "long_500k" for _, s in skipped)
    runnable_long = {a for a, s, ok, _ in cells if s == "long_500k" and ok}
    assert runnable_long == {"rwkv6-1.6b", "jamba-1.5-large-398b",
                             "mixtral-8x7b"}


def test_param_counts_plausible():
    """Config param counts should be within ~20% of the nameplate sizes."""
    expect = {
        "llama3-405b": 405e9,
        "yi-9b": 8.8e9,
        "granite-34b": 34e9,
        "mixtral-8x7b": 46.7e9,
        "rwkv6-1.6b": 1.6e9,
    }
    for arch, n in expect.items():
        got = REG.get_config(arch).param_counts()["total"]
        assert abs(got - n) / n < 0.25, (arch, got, n)
    # MoE active << total
    mix = REG.get_config("mixtral-8x7b").param_counts()
    assert mix["active"] < 0.35 * mix["total"]
