"""Kernel validation: Pallas (interpret) + scan impl vs the jnp oracle.

Sweeps shapes, dtypes, GQA group sizes, and schedule kinds; checks both
forward values and gradients.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.tri_attn import ops as OPS
from repro.kernels.tri_attn import ref as REF


def _rand_qkv(key, b, h, hkv, s, d, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (b, hkv, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (b, hkv, s, d), jnp.float32).astype(dtype)
    return q, k, v


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=2e-5, rtol=2e-5)


CASES = [
    # (b, h, hkv, s, d, block, window, prefix)
    (1, 1, 1, 32, 8, 8, None, 0),
    (2, 4, 2, 64, 16, 16, None, 0),   # GQA group 2
    (1, 4, 1, 64, 16, 16, None, 0),   # MQA
    (1, 2, 2, 64, 16, 16, 24, 0),     # sliding window
    (1, 2, 2, 64, 16, 16, 16, 0),     # window == block
    (1, 2, 1, 64, 16, 16, None, 24),  # prefix-causal (VLM)
    (1, 2, 2, 96, 16, 16, 40, 0),     # non-pow2 #blocks
]


@pytest.mark.parametrize("impl", ["scan", "pallas"])
@pytest.mark.parametrize("case", CASES, ids=[str(c) for c in CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwd_matches_ref(impl, case, dtype):
    b, h, hkv, s, d, blk, window, prefix = case
    q, k, v = _rand_qkv(jax.random.PRNGKey(0), b, h, hkv, s, d, dtype)
    got = OPS.triangular_attention(q, k, v, window=window, prefix=prefix,
                                   impl=impl, block_q=blk, block_k=blk)
    want = REF.mha_reference(q, k, v, window=window, prefix=prefix)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("impl", ["scan", "pallas"])
@pytest.mark.parametrize("case", CASES[:5], ids=[str(c) for c in CASES[:5]])
def test_grads_match_ref(impl, case):
    b, h, hkv, s, d, blk, window, prefix = case
    q, k, v = _rand_qkv(jax.random.PRNGKey(1), b, h, hkv, s, d, jnp.float32)

    def loss(fn):
        def inner(q, k, v):
            o = fn(q, k, v)
            return jnp.sum(o * jnp.cos(jnp.arange(o.size, dtype=jnp.float32)
                                       .reshape(o.shape)))
        return inner

    attn = functools.partial(OPS.triangular_attention, window=window,
                             prefix=prefix, impl=impl, block_q=blk,
                             block_k=blk)
    ref = functools.partial(REF.mha_reference, window=window, prefix=prefix)
    g_got = jax.grad(loss(attn), argnums=(0, 1, 2))(q, k, v)
    g_want = jax.grad(loss(ref), argnums=(0, 1, 2))(q, k, v)
    for got, want, name in zip(g_got, g_want, "qkv"):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4, rtol=2e-3, err_msg=f"d{name}")


def test_bb_baseline_matches_ref():
    """The paper's BB strategy must produce identical output (it only wastes
    blocks; § IV 'We checked the output for each strategy to be always
    correct and the same')."""
    q, k, v = _rand_qkv(jax.random.PRNGKey(2), 1, 2, 2, 64, 16, jnp.float32)
    got = OPS.triangular_attention(q, k, v, impl="bb", block_q=16, block_k=16)
    want = REF.mha_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_scan_equals_pallas_bitwise_family():
    """scan and pallas share schedules + math; outputs should agree to f32
    roundoff on identical inputs."""
    q, k, v = _rand_qkv(jax.random.PRNGKey(3), 2, 4, 2, 64, 16, jnp.float32)
    a = OPS.triangular_attention(q, k, v, impl="scan", block_q=16, block_k=16)
    b = OPS.triangular_attention(q, k, v, impl="pallas", block_q=16,
                                 block_k=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                               rtol=1e-6)


def test_single_block_degenerate():
    q, k, v = _rand_qkv(jax.random.PRNGKey(4), 1, 1, 1, 16, 8, jnp.float32)
    got = OPS.triangular_attention(q, k, v, impl="scan", block_q=16,
                                   block_k=16)
    want = REF.mha_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)
