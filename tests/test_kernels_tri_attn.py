"""Kernel validation: Pallas (interpret) + scan impl vs the shared oracle.

Sweeps shapes, dtypes, GQA group sizes, and schedule kinds; checks both
forward values and gradients. Reference values and tolerances come from
tests/oracles.py (the shared differential-oracle module); the in-package
jnp ref (ref.py) is only used where a DIFFERENTIABLE reference is needed
(gradient checks).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import oracles as O
from repro.kernels.tri_attn import ops as OPS
from repro.kernels.tri_attn import ref as REF

CASES = [
    # (b, h, hkv, s, d, block, window, prefix)
    (1, 1, 1, 32, 8, 8, None, 0),
    (2, 4, 2, 64, 16, 16, None, 0),   # GQA group 2
    (1, 4, 1, 64, 16, 16, None, 0),   # MQA
    (1, 2, 2, 64, 16, 16, 24, 0),     # sliding window
    (1, 2, 2, 64, 16, 16, 16, 0),     # window == block
    (1, 2, 1, 64, 16, 16, None, 24),  # prefix-causal (VLM)
    (1, 2, 2, 96, 16, 16, 40, 0),     # non-pow2 #blocks
]


@pytest.mark.parametrize("impl", ["scan", "pallas"])
@pytest.mark.parametrize("case", CASES, ids=[str(c) for c in CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwd_matches_oracle(impl, case, dtype):
    b, h, hkv, s, d, blk, window, prefix = case
    q, k, v = O.rand_qkv(0, b, h, hkv, s, d, dtype)
    got = OPS.triangular_attention(q, k, v, window=window, prefix=prefix,
                                   impl=impl, block_q=blk, block_k=blk)
    want = O.attention_oracle(q, k, v, window=window, prefix=prefix)
    O.assert_close(got, want, "attn", dtype)


def test_jnp_ref_matches_oracle():
    """The in-package jnp ref (used by the grad checks and model layers)
    must itself agree with the independent numpy oracle."""
    for case in CASES:
        b, h, hkv, s, d, _, window, prefix = case
        q, k, v = O.rand_qkv(5, b, h, hkv, s, d, jnp.float32)
        got = REF.mha_reference(q, k, v, window=window, prefix=prefix)
        want = O.attention_oracle(q, k, v, window=window, prefix=prefix)
        O.assert_close(got, want, "attn", err_msg=str(case))


@pytest.mark.parametrize("impl", ["scan", "pallas"])
@pytest.mark.parametrize("case", CASES[:5], ids=[str(c) for c in CASES[:5]])
def test_grads_match_ref(impl, case):
    b, h, hkv, s, d, blk, window, prefix = case
    q, k, v = O.rand_qkv(1, b, h, hkv, s, d, jnp.float32)

    def loss(fn):
        def inner(q, k, v):
            o = fn(q, k, v)
            return jnp.sum(o * jnp.cos(jnp.arange(o.size, dtype=jnp.float32)
                                       .reshape(o.shape)))
        return inner

    attn = functools.partial(OPS.triangular_attention, window=window,
                             prefix=prefix, impl=impl, block_q=blk,
                             block_k=blk)
    ref = functools.partial(REF.mha_reference, window=window, prefix=prefix)
    g_got = jax.grad(loss(attn), argnums=(0, 1, 2))(q, k, v)
    g_want = jax.grad(loss(ref), argnums=(0, 1, 2))(q, k, v)
    for got, want, name in zip(g_got, g_want, "qkv"):
        O.assert_close(got, want, "attn_grad", err_msg=f"d{name}")


def test_bb_baseline_matches_oracle():
    """The paper's BB strategy must produce identical output (it only wastes
    blocks; § IV 'We checked the output for each strategy to be always
    correct and the same')."""
    q, k, v = O.rand_qkv(2, 1, 2, 2, 64, 16, jnp.float32)
    got = OPS.triangular_attention(q, k, v, impl="bb", block_q=16, block_k=16)
    O.assert_close(got, O.attention_oracle(q, k, v), "attn")


def test_scan_equals_pallas_bitwise_family():
    """scan and pallas share schedules + math; outputs should agree to f32
    roundoff on identical inputs."""
    q, k, v = O.rand_qkv(3, 2, 4, 2, 64, 16, jnp.float32)
    a = OPS.triangular_attention(q, k, v, impl="scan", block_q=16, block_k=16)
    b = OPS.triangular_attention(q, k, v, impl="pallas", block_q=16,
                                 block_k=16)
    O.assert_close(a, b, "attn_bitwise_pair")


def test_single_block_degenerate():
    q, k, v = O.rand_qkv(4, 1, 1, 1, 16, 8, jnp.float32)
    got = OPS.triangular_attention(q, k, v, impl="scan", block_q=16,
                                   block_k=16)
    O.assert_close(got, O.attention_oracle(q, k, v), "attn")


def test_oracle_mask_matches_ref_mask():
    """The shared numpy mask and the in-package jnp mask are the same
    function (differential check of the oracles themselves)."""
    for window, prefix, q_off in ((None, 0, 0), (7, 0, 0), (None, 5, 0),
                                  (None, 0, 12), (9, 3, 4)):
        got = O.attention_mask_np(8, 20, window=window, prefix=prefix,
                                  q_offset=q_off)
        want = np.asarray(REF.attention_mask(8, 20, window=window,
                                             prefix=prefix, q_offset=q_off))
        np.testing.assert_array_equal(got, want, err_msg=str((window, prefix,
                                                              q_off)))
