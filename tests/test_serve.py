"""Serving-layer tests: generation loop, engine continuous batching ==
isolated sequential decode, cache accounting."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as REG
from repro.models import model as MD
from repro.serve import decode as D
from repro.serve import kv_cache as KV
from repro.serve.engine import Engine


def _setup(arch="yi-9b", seed=0):
    cfg = REG.smoke_config(arch)
    params = MD.init_params(jax.random.key(seed), cfg)
    return cfg, params


def _greedy_reference(params, cfg, prompt, max_new, max_len):
    """Single-sequence greedy decode, token by token."""
    cache = MD.init_cache(cfg, 1, max_len, jnp.float32)
    tok = None
    for t, p in enumerate(prompt):
        logits, cache = MD.decode_step(
            params, cfg, cache, jnp.array([[p]], jnp.int32), jnp.int32(t))
    out = []
    pos = len(prompt) - 1
    nxt = int(jnp.argmax(logits[0, 0, :cfg.vocab_size]))
    for _ in range(max_new):
        out.append(nxt)
        pos += 1
        logits, cache = MD.decode_step(
            params, cfg, cache, jnp.array([[nxt]], jnp.int32),
            jnp.int32(pos))
        nxt = int(jnp.argmax(logits[0, 0, :cfg.vocab_size]))
    return out


def test_engine_matches_sequential_decode():
    cfg, params = _setup()
    prompts = [np.array([3, 1, 4, 1, 5], np.int32),
               np.array([2, 7, 1], np.int32),
               np.array([9, 9, 8, 2, 6, 5], np.int32)]
    eng = Engine(params, cfg, slots=2, max_len=48, temperature=0.0)
    for uid, p in enumerate(prompts):
        eng.submit(p, max_new=6, uid=uid)
    results = eng.run()
    assert set(results) == {0, 1, 2}
    for uid, p in enumerate(prompts):
        ref = _greedy_reference(params, cfg, list(p), 6, 48)
        assert results[uid] == ref, (uid, results[uid], ref)


def test_engine_packed_prefill_matches_sequential():
    """The batched ragged prefill must be token-for-token identical to the
    sequential per-request prefill on a mixed-length queue, while issuing
    exactly ONE packed launch per admit round."""
    cfg, params = _setup()
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (9, 3, 17, 5, 12)]

    def run(mode, bucket=0):
        eng = Engine(params, cfg, slots=2, max_len=48, temperature=0.0,
                     prefill_mode=mode, prefill_block=4,
                     prefill_bucket=bucket)
        for uid, p in enumerate(prompts):
            eng.submit(p, max_new=4, uid=uid)
        return eng.run(), eng.stats

    res_packed, st_packed = run("packed")
    res_seq, st_seq = run("sequential")
    assert res_packed == res_seq
    # length bucketing only adds inert tail padding: same tokens out
    res_bucket, _ = run("packed", bucket=16)
    assert res_bucket == res_seq
    # one packed launch per admit round vs one decode step per prompt token
    assert st_packed["prefill_launches"] == st_packed["admit_rounds"]
    assert st_seq["prefill_launches"] == sum(len(p) for p in prompts)
    assert st_packed["prefill_requests"] == len(prompts)
    # prefill launches are counted APART from decode launches: decode
    # rounds ran (tokens were generated) without touching the prefill
    # counter, and every decode round landed in exactly one decode bucket.
    assert st_packed["decode_rounds"] > 0
    assert (st_packed["decode_packed_launches"]
            + st_packed["decode_lockstep_launches"]
            == st_packed["decode_rounds"])


def test_engine_cost_ordered_admission_equalizes_rounds():
    """admit_order="cost" (default) admits the oldest request each round
    (aging), then alternates light/heavy so successive packed admit
    rounds get near-equal tile totals; "fifo" keeps arrival order. Token
    streams stay identical per uid either way, and the chosen order is
    exposed in stats."""
    cfg, params = _setup()
    rng = np.random.default_rng(11)
    # arrival order deliberately lumpy: two long then two short prompts
    lens = (17, 16, 2, 3)
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in lens]

    def run(order):
        eng = Engine(params, cfg, slots=2, max_len=48, temperature=0.0,
                     prefill_block=4, admit_order=order)
        for uid, p in enumerate(prompts):
            eng.submit(p, max_new=4, uid=uid)
        return eng.run(), eng.stats

    res_cost, st_cost = run("cost")
    res_fifo, st_fifo = run("fifo")
    assert res_cost == res_fifo  # ordering never changes any token stream
    assert st_fifo["admit_round_tiles"] == [15 + 10, 1 + 1]  # lumpy
    assert st_cost["admit_round_tiles"] == [15 + 1, 10 + 1]  # equalized
    spread = lambda ts: max(ts) - min(ts)
    assert spread(st_cost["admit_round_tiles"]) < \
        spread(st_fifo["admit_round_tiles"])
    # the per-round order log names (uid, tiles) in launch order
    assert st_cost["admit_order_log"][0] == [(0, 15), (2, 1)]
    assert st_fifo["admit_order_log"][0] == [(0, 15), (1, 10)]


def test_engine_recurrent_arch_falls_back_to_sequential():
    """Recurrent token mixers cannot splice packed state across request
    boundaries; the engine must silently keep the sequential path."""
    cfg, params = _setup("rwkv6-1.6b")
    eng = Engine(params, cfg, slots=2, max_len=32, prefill_mode="packed")
    assert eng.prefill_mode == "sequential"


def test_engine_more_requests_than_slots_refills():
    cfg, params = _setup("rwkv6-1.6b")  # recurrent-state engine path
    eng = Engine(params, cfg, slots=2, max_len=32, temperature=0.0)
    for uid in range(5):
        eng.submit(np.array([uid + 1, 2, 3], np.int32), max_new=4, uid=uid)
    results = eng.run()
    assert len(results) == 5
    assert all(len(v) == 4 for v in results.values())


def test_generate_masks_inactive_slots():
    cfg, params = _setup()
    cache = MD.init_cache(cfg, 2, 16, jnp.float32)
    active = jnp.array([True, False])
    toks, cache2, pos = D.generate(
        params, cfg, cache, jnp.array([[1], [1]], jnp.int32),
        jnp.zeros((2,), jnp.int32), 5, active=active)
    assert toks.shape == (2, 5)
    assert jnp.all(toks[1] == 0)          # inactive slot emits pad
    assert int(pos[0]) == 5 and int(pos[1]) == 0  # pos frozen when inactive
    # inactive slot's cache untouched
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)):
        np.testing.assert_array_equal(np.asarray(a[:, 1]),
                                      np.asarray(b[:, 1]))


def test_sampling_temperature_and_topk():
    key = jax.random.key(0)
    logits = jnp.array([[0.0, 10.0, 0.0, 5.0]])
    assert int(D.sample_logits(key, logits, temperature=0.0)[0]) == 1
    t = D.sample_logits(key, logits, temperature=1.0, top_k=1)
    assert int(t[0]) == 1
    # padded-vocab positions never sampled
    s = D.sample_logits(key, jnp.array([[0.0, 0.0, 100.0]]),
                        temperature=0.0, vocab_size=2)
    assert int(s[0]) < 2


def test_cache_accounting():
    cfg = REG.get_config("yi-9b")
    per_tok = KV.cache_bytes_per_token(cfg)
    # 48 layers * 2 (k+v) * 4 kv heads * 128 hd * 2 bytes
    assert per_tok == 48 * 2 * 4 * 128 * 2
    swa = REG.get_config("mixtral-8x7b")
    assert KV.cache_bytes_per_token(swa) == 0  # rolling buffer

    cfg_r = REG.smoke_config("yi-9b")
    cache = KV.init_cache(cfg_r, 2, 16, jnp.bfloat16)
    got = KV.cache_bytes(cache)
    want = (cfg_r.n_layers * 2 * 2 * 16 * cfg_r.n_kv_heads
            * cfg_r.head_dim * 2)
    assert got == want
