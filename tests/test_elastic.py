"""Elastic-scaling integration: a checkpoint saved on ONE device restores
onto an 8-device (2x2x2 pod/data/model) mesh with the production sharding
rules and trains a further step — the restart-after-topology-change path
(node failure -> replan_mesh -> restore -> continue)."""

import os
import subprocess
import sys
import textwrap

import jax

from repro.configs import registry as REG
from repro.train import checkpoint as CKPT
from repro.train import optimizer as OPT
from repro.train import train_step as TS


def test_elastic_restore_onto_8_device_mesh(tmp_path):
    # save on the single real device
    import jax.numpy as jnp
    cfg = REG.smoke_config("yi-9b")
    opt = OPT.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = TS.init_state(jax.random.key(0), cfg, opt)
    state.step = jnp.full((), 4, jnp.int32)
    CKPT.save(str(tmp_path), state, 4)

    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from repro.configs import registry as REG
        from repro.configs.base import ShapeConfig
        from repro.launch.compat import make_mesh
        from repro.parallel import sharding as SH
        from repro.train import checkpoint as CKPT
        from repro.train import data as DATA
        from repro.train import optimizer as OPT
        from repro.train import train_step as TS

        # the elastic replan for 8 surviving chips, TP axis preserved at 2
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = REG.smoke_config("yi-9b")
        opt = OPT.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        ref = TS.init_state(jax.random.key(0), cfg, opt)
        target = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), ref)
        sh = TS.TrainState(
            params=SH.param_shardings(mesh, ref.params),
            opt_state=SH.param_shardings(mesh, ref.opt_state),
            step=SH.scalar_sharding(mesh), err_state=None)
        state, manifest = CKPT.restore(r"{tmp_path}", target, shardings=sh)
        assert manifest["step"] == 4
        assert int(state.step) == 4
        # every leaf landed with its production sharding
        flat = jax.tree.leaves(state.params)
        assert all(len(x.sharding.device_set) >= 1 for x in flat)

        # one more step on the new mesh
        shape = ShapeConfig("t", 32, 8, "train")
        batch = DATA.SyntheticLM(cfg, shape,
                                 act_dtype=jnp.float32).batch(4)
        bs = SH.batch_shardings(mesh, batch)
        batch = jax.tree.map(jax.device_put, batch, bs)
        with mesh:
            step = jax.jit(TS.make_train_step(cfg, opt))
            state, metrics = step(state, batch)
        assert int(state.step) == 5
        assert bool(jnp.isfinite(metrics["loss"]))
        print("ELASTIC_OK", float(metrics["loss"]))
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=480, cwd="/root/repo", env=env)
    assert "ELASTIC_OK" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]
