"""3-body kernel validation vs the numpy oracle.

The 3D analogue of the tri_edm tests: every impl (tet-grid Pallas, scan,
BB-3D baseline) must produce the same per-tile-triple reductions, and the
multiplicity-weighted total over unique tiles must equal the dense einsum
over ALL ordered point triples — the proof that launching tet(n) tiles
instead of n^3 loses nothing.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mapping as M
from repro.kernels.tri_3body import ops as OPS
from repro.kernels.tri_3body import ref as REF


@pytest.mark.parametrize("impl", ["pallas", "scan"])
@pytest.mark.parametrize("d", [1, 3, 8])
@pytest.mark.parametrize("n_rows,block", [(16, 8), (32, 8), (48, 16)])
def test_three_body_packed_matches_ref(impl, d, n_rows, block):
    x = jax.random.normal(jax.random.PRNGKey(d), (n_rows, d), jnp.float32)
    got = OPS.three_body(x, block, impl=impl)
    want = REF.three_body_packed_ref(x, block)
    assert got.shape == (M.tet(n_rows // block), 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)


def test_three_body_bb3_matches_packed():
    """BB-3D baseline writes the simplex entries of the full cube and
    zeros elsewhere; same values as the packed launch."""
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 4), jnp.float32)
    block = 8
    n = 32 // block
    cube = np.asarray(OPS.three_body(x, block, impl="bb3"))
    want = np.asarray(REF.three_body_packed_ref(x, block))
    assert cube.shape == (n, n, n)
    for lam in range(M.tet(n)):
        i, j, k = M.tet_map(lam)
        np.testing.assert_allclose(cube[i, j, k], want[lam, 0],
                                   rtol=2e-5, atol=2e-4)
    dead = [(i, j, k) for i in range(n) for j in range(n) for k in range(n)
            if not (k <= j <= i)]
    for i, j, k in dead:
        assert cube[i, j, k] == 0.0


def test_bb3_scan_matches_packed():
    x = jax.random.normal(jax.random.PRNGKey(2), (24, 2), jnp.float32)
    block = 8
    n = 24 // block
    flat = np.asarray(OPS.three_body(x, block, impl="bb3_scan"))
    want = np.asarray(REF.three_body_packed_ref(x, block))
    assert flat.shape == (n ** 3, 1)
    for lam in range(M.tet(n)):
        i, j, k = M.tet_map(lam)
        np.testing.assert_allclose(flat[(i * n + j) * n + k, 0],
                                   want[lam, 0], rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("impl", ["pallas", "scan", "ref", "bb3",
                                  "bb3_scan"])
def test_three_body_total_matches_dense_einsum(impl):
    """tet(n) unique tiles + multiset weights == all n_rows^3 ordered
    triples: the 3D unique-pair exactness claim."""
    x = jax.random.normal(jax.random.PRNGKey(3), (24, 3), jnp.float32)
    tot = float(OPS.three_body_total(x, 8, impl=impl))
    want = float(REF.three_body_total_ref(x))
    np.testing.assert_allclose(tot, want, rtol=1e-5)


def test_tile_mult_partitions_cube():
    """Multiplicities over unique tiles partition the full cube of tile
    triples: sum(mult) == n^3."""
    for n in (1, 2, 3, 7, 12):
        tot = sum(REF.tile_mult(*M.tet_map(l)) for l in range(M.tet(n)))
        assert tot == n ** 3


def test_dummy_tet_kernel_mapping():
    """3D dummy kernel: output block lambda holds i+j+k (mapping cost
    isolation, the paper's methodology one dimension up)."""
    from repro.kernels.tri_3body.kernel import dummy_tet

    n = 6
    out = np.asarray(dummy_tet(n))
    for lam in range(M.tet(n)):
        i, j, k = M.tet_map(lam)
        assert out[lam, 0] == i + j + k


def test_packed_memory_vs_cube():
    """Packed tet storage is ~1/6 of the full tile cube."""
    n = 16
    ratio = M.tet(n) / n ** 3
    assert 1 / 6 <= ratio <= 1 / 6 + 1.0 / n


# ---------------------------------------------------------------------------
# strict a > b > c masking (in-kernel, ROADMAP open item)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["pallas", "scan", "ref"])
def test_strict_packed_matches_strict_ref(impl):
    x = jax.random.normal(jax.random.PRNGKey(11), (24, 3), jnp.float32)
    got = OPS.three_body(x, 8, impl=impl, strict=True)
    want = REF.three_body_packed_ref(x, 8, strict=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)


def test_strict_changes_only_diagonal_tiles():
    """Strictness is an IN-KERNEL diagonal-tile mask: off-diagonal tile
    triples (i > j > k) are bitwise untouched."""
    x = jax.random.normal(jax.random.PRNGKey(12), (32, 4), jnp.float32)
    loose = np.asarray(OPS.three_body(x, 8, impl="scan"))
    strict = np.asarray(OPS.three_body(x, 8, impl="scan", strict=True))
    n = 4
    for lam in range(M.tet(n)):
        i, j, k = M.tet_map(lam)
        if i > j > k:
            assert loose[lam, 0] == strict[lam, 0], (lam, i, j, k)
        else:
            # diagonal tiles lose their degenerate triples (generic x)
            assert loose[lam, 0] != strict[lam, 0], (lam, i, j, k)


@pytest.mark.parametrize("impl", ["pallas", "scan", "ref", "bb3",
                                  "bb3_scan"])
def test_strict_total_matches_distinct_triple_oracle(impl):
    """strict total == sum over a > b > c of the dense oracle — each
    unordered triple of distinct points exactly once, with NO post-hoc
    multiplicity correction."""
    x = jax.random.normal(jax.random.PRNGKey(13), (24, 3), jnp.float32)
    tot = float(OPS.three_body_total(x, 8, impl=impl, strict=True))
    want = float(REF.three_body_total_strict_ref(x))
    np.testing.assert_allclose(tot, want, rtol=1e-5)


def test_strict_singleton_tile_is_zero():
    """One tile (i == j == k == 0) with block == n_rows: the only
    surviving triples are a > b > c inside the tile."""
    x = jax.random.normal(jax.random.PRNGKey(14), (8, 2), jnp.float32)
    got = float(OPS.three_body(x, 8, impl="scan", strict=True)[0, 0])
    g = np.asarray(REF.gram(x))
    want = sum(g[a, b] * g[b, c] * g[a, c]
               for a in range(8) for b in range(a) for c in range(b))
    np.testing.assert_allclose(got, want, rtol=1e-5)
