"""3-body kernel validation vs the shared numpy oracle (tests/oracles.py).

The 3D analogue of the tri_edm tests: every impl (tet-grid Pallas, scan,
BB-3D baseline) must produce the same per-tile-triple reductions, and the
multiplicity-weighted total over unique tiles must equal the dense einsum
over ALL ordered point triples — the proof that launching tet(n) tiles
instead of n^3 loses nothing.
"""

import numpy as np
import pytest

import oracles as O
from repro.core import mapping as M
from repro.kernels.tri_3body import ops as OPS
from repro.kernels.tri_3body import ref as REF


@pytest.mark.parametrize("impl", ["pallas", "scan"])
@pytest.mark.parametrize("d", [1, 3, 8])
@pytest.mark.parametrize("n_rows,block", [(16, 8), (32, 8), (48, 16)])
def test_three_body_packed_matches_oracle(impl, d, n_rows, block):
    x = O.rand_points(d, n_rows, d)
    got = OPS.three_body(x, block, impl=impl)
    want = O.three_body_packed_oracle(x, block)
    assert got.shape == (M.tet(n_rows // block), 1)
    O.assert_close(got, want, "3body")


def test_three_body_bb3_matches_packed():
    """BB-3D baseline writes the simplex entries of the full cube and
    zeros elsewhere; same values as the packed launch."""
    x = O.rand_points(1, 32, 4)
    block = 8
    n = 32 // block
    cube = np.asarray(OPS.three_body(x, block, impl="bb3"))
    want = O.three_body_packed_oracle(x, block)
    assert cube.shape == (n, n, n)
    for lam in range(M.tet(n)):
        i, j, k = M.tet_map(lam)
        O.assert_close(cube[i, j, k], want[lam, 0], "3body",
                       err_msg=str((i, j, k)))
    dead = [(i, j, k) for i in range(n) for j in range(n) for k in range(n)
            if not (k <= j <= i)]
    for i, j, k in dead:
        assert cube[i, j, k] == 0.0


def test_bb3_scan_matches_packed():
    x = O.rand_points(2, 24, 2)
    block = 8
    n = 24 // block
    flat = np.asarray(OPS.three_body(x, block, impl="bb3_scan"))
    want = O.three_body_packed_oracle(x, block)
    assert flat.shape == (n ** 3, 1)
    for lam in range(M.tet(n)):
        i, j, k = M.tet_map(lam)
        O.assert_close(flat[(i * n + j) * n + k, 0], want[lam, 0], "3body",
                       err_msg=str((i, j, k)))


@pytest.mark.parametrize("impl", ["pallas", "scan", "ref", "bb3",
                                  "bb3_scan"])
def test_three_body_total_matches_dense_einsum(impl):
    """tet(n) unique tiles + multiset weights == all n_rows^3 ordered
    triples: the 3D unique-pair exactness claim."""
    x = O.rand_points(3, 24, 3)
    tot = float(OPS.three_body_total(x, 8, impl=impl))
    O.assert_close(tot, O.three_body_total_oracle(x), "3body_total")


def test_jnp_ref_matches_oracle():
    """In-package jnp ref vs the independent float64 oracle, loose and
    strict."""
    x = O.rand_points(21, 24, 3)
    O.assert_close(REF.three_body_packed_ref(x, 8),
                   O.three_body_packed_oracle(x, 8), "3body")
    O.assert_close(REF.three_body_packed_ref(x, 8, strict=True),
                   O.three_body_packed_oracle(x, 8, strict=True), "3body")
    O.assert_close(float(REF.three_body_total_strict_ref(x)),
                   O.three_body_total_oracle(x, strict=True), "3body_total")


def test_tile_mult_partitions_cube():
    """Multiplicities over unique tiles partition the full cube of tile
    triples: sum(mult) == n^3."""
    for n in (1, 2, 3, 7, 12):
        tot = sum(REF.tile_mult(*M.tet_map(l)) for l in range(M.tet(n)))
        assert tot == n ** 3


def test_dummy_tet_kernel_mapping():
    """3D dummy kernel: output block lambda holds i+j+k (mapping cost
    isolation, the paper's methodology one dimension up)."""
    from repro.kernels.tri_3body.kernel import dummy_tet

    n = 6
    out = np.asarray(dummy_tet(n))
    for lam in range(M.tet(n)):
        i, j, k = M.tet_map(lam)
        assert out[lam, 0] == i + j + k


def test_packed_memory_vs_cube():
    """Packed tet storage is ~1/6 of the full tile cube."""
    n = 16
    ratio = M.tet(n) / n ** 3
    assert 1 / 6 <= ratio <= 1 / 6 + 1.0 / n


# ---------------------------------------------------------------------------
# strict a > b > c masking (in-kernel, ROADMAP open item)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["pallas", "scan", "ref"])
def test_strict_packed_matches_strict_oracle(impl):
    x = O.rand_points(11, 24, 3)
    got = OPS.three_body(x, 8, impl=impl, strict=True)
    O.assert_close(got, O.three_body_packed_oracle(x, 8, strict=True),
                   "3body")


def test_strict_changes_only_diagonal_tiles():
    """Strictness is an IN-KERNEL diagonal-tile mask: off-diagonal tile
    triples (i > j > k) are bitwise untouched."""
    x = O.rand_points(12, 32, 4)
    loose = np.asarray(OPS.three_body(x, 8, impl="scan"))
    strict = np.asarray(OPS.three_body(x, 8, impl="scan", strict=True))
    n = 4
    for lam in range(M.tet(n)):
        i, j, k = M.tet_map(lam)
        if i > j > k:
            assert loose[lam, 0] == strict[lam, 0], (lam, i, j, k)
        else:
            # diagonal tiles lose their degenerate triples (generic x)
            assert loose[lam, 0] != strict[lam, 0], (lam, i, j, k)


@pytest.mark.parametrize("impl", ["pallas", "scan", "ref", "bb3",
                                  "bb3_scan"])
def test_strict_total_matches_distinct_triple_oracle(impl):
    """strict total == sum over a > b > c of the dense oracle — each
    unordered triple of distinct points exactly once, with NO post-hoc
    multiplicity correction."""
    x = O.rand_points(13, 24, 3)
    tot = float(OPS.three_body_total(x, 8, impl=impl, strict=True))
    O.assert_close(tot, O.three_body_total_oracle(x, strict=True),
                   "3body_total")


def test_strict_singleton_tile_is_zero():
    """One tile (i == j == k == 0) with block == n_rows: the only
    surviving triples are a > b > c inside the tile."""
    x = O.rand_points(14, 8, 2)
    got = float(OPS.three_body(x, 8, impl="scan", strict=True)[0, 0])
    g = np.asarray(REF.gram(x), np.float64)
    want = sum(g[a, b] * g[b, c] * g[a, c]
               for a in range(8) for b in range(a) for c in range(b))
    np.testing.assert_allclose(got, want, rtol=1e-5)
